"""End-to-end serving driver (the paper's deployment scenario).

    PYTHONPATH=src python examples/serve_quiver.py [--requests 200]

Serves a GraphSAGE model over a skewed synthetic graph with batched requests
through the full Quiver pipeline on the executor-graph stack — per-executor
PSGS calibration, the four operating points as cost-model routing policies,
dynamic PSGS-budget batching, per-batch futures with admission control — and
prints a per-policy latency/throughput report. With ``--multi-model`` a
second, wider GraphSAGE joins the engine through a ``ModelRegistry`` sharing
the same feature store: the report then shows both models' PSGS cut-points
and per-model routing splits on one interleaved stream.
"""
import argparse
import json

import numpy as np

from repro.core import DynamicBatcher
from repro.launch.serve import build_stack, make_infer_fn
from repro.serving import (AdaptiveConfig, AdaptiveController,
                           CalibrationResult, CostModelRouter,
                           DeviceExecutor, HostExecutor, ModelRegistry,
                           ServingEngine, build_model_entry,
                           calibrate_executors)


def run_multi_model(args) -> None:
    """Two GraphSAGE variants (base + wide) co-served by one engine over
    ONE shared store; requests interleave round-robin and each model routes
    by its own calibrated curves."""
    graph, feats, psgs, fap, store, gen, infer_fn = build_stack(
        nodes=args.nodes, avg_degree=10.0, d_feat=64, fanouts=(6, 4),
        hot_frac=0.3)
    registry = ModelRegistry()
    widths = {"base": (64, 64), "wide": (256, 256)}
    for i, (name, hidden) in enumerate(widths.items()):
        entry = build_model_entry(
            name, graph=graph, store=store, fanouts=(6, 4),
            infer_fn=make_infer_fn(64, hidden, (6, 4), seed=i),
            psgs_table=psgs, capacity=2, max_batch=32, rng_seed=i)
        registry.add(entry)
    engine = ServingEngine(registry, max_inflight=64)
    gen.rng = np.random.default_rng(5)
    reqs = list(gen.stream(args.requests, seeds_per_request=args.batch_seeds,
                           models=list(widths)))
    engine.warmup([reqs[0]])
    batcher = DynamicBatcher(deadline_s=0.02, psgs_table=psgs, max_batch=16)
    m = engine.serve_stream(reqs, batcher, gap_s=0.002)
    # crossover() returns inf when one executor dominates everywhere;
    # json.dumps would emit the non-standard `Infinity` token, so map it
    cuts = {name: registry.get(name).router.crossover("host", "device")
            for name in registry}
    report = {"cutpoints": {n: c if np.isfinite(c) else None
                            for n, c in cuts.items()},
              **m.summary()}
    print(json.dumps(report, indent=2))
    engine.close()


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--requests", type=int, default=150)
    p.add_argument("--nodes", type=int, default=8000)
    p.add_argument("--batch-seeds", type=int, default=8)
    p.add_argument("--adaptive", action="store_true",
                   help="hook the online workload-adaptation loop into the "
                        "engine (live FAP re-placement + drift refit)")
    p.add_argument("--multi-model", action="store_true",
                   help="co-serve a second (wider) GraphSAGE through a "
                        "ModelRegistry over the same shared feature store")
    args = p.parse_args()

    if args.multi_model:
        run_multi_model(args)
        return

    def fresh_stack():
        graph, feats, psgs, fap, store, gen, infer_fn = build_stack(
            nodes=args.nodes, avg_degree=10.0, d_feat=64, fanouts=(6, 4),
            hot_frac=0.3)
        executors = {
            "host": HostExecutor(graph, store, (6, 4), infer_fn, capacity=2,
                                 psgs_table=psgs),
            "device": DeviceExecutor(graph.device_arrays(), store, (6, 4),
                                     infer_fn, max_batch=32, capacity=2,
                                     psgs_table=psgs),
        }
        # calibrate every executor (paper Fig. 6)
        order = np.argsort(psgs)
        batches = [order[int(q * len(order)):][:args.batch_seeds]
                   .astype(np.int64) for q in np.linspace(0.05, 0.95, 6)]
        curves = calibrate_executors(executors, batches, psgs, repeats=2)
        return graph, psgs, store, gen, executors, curves

    graph, psgs, store, gen, executors, curves = fresh_stack()
    print(f"[stack] {graph.num_nodes} nodes, tiers "
          f"{store.plan.tier_counts()}")

    report = {}
    for policy in ("latency_preferred", "throughput_preferred"):
        if args.adaptive and report:
            # live migration mutates the store: rebuild per policy so one
            # policy's adaptation cannot contaminate the next one's run
            graph, psgs, store, gen, executors, curves = fresh_stack()
        calib = CalibrationResult(host=curves["host"],
                                  device=curves["device"])
        thr = calib.threshold(policy)  # PSGS budget for the batcher
        router = CostModelRouter.from_curves(psgs, curves, policy,
                                             executors=executors)
        controller = None
        if args.adaptive:
            controller = AdaptiveController(
                graph, (6, 4), store, router, psgs_table=psgs,
                config=AdaptiveConfig(interval_batches=16))
        engine = ServingEngine(executors, router, max_inflight=64,
                               hooks=[controller] if controller else [])
        gen.rng = np.random.default_rng(5)
        reqs = list(gen.stream(args.requests,
                               seeds_per_request=args.batch_seeds))
        engine.warmup([reqs[0]])
        batcher = DynamicBatcher(deadline_s=0.02,
                                 psgs_budget=thr if np.isfinite(thr)
                                 else None,
                                 psgs_table=psgs, max_batch=16)
        m = engine.serve_stream(reqs, batcher, gap_s=0.002)
        report[policy] = {"threshold": thr, **m.summary()}
        if controller is not None:
            report[policy]["adaptation"] = controller.report()
    print(json.dumps(report, indent=2))


if __name__ == "__main__":
    main()

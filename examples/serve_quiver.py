"""End-to-end serving driver (the paper's deployment scenario).

    PYTHONPATH=src python examples/serve_quiver.py [--requests 200]

Serves a GraphSAGE model over a skewed synthetic graph with batched requests
through the full Quiver pipeline — PSGS calibration, all four operating
points, dynamic PSGS-budget batching, multiplexed workers — and prints a
per-policy latency/throughput report.
"""
import argparse
import json

import jax
import numpy as np

from repro.core import (DynamicBatcher, HybridScheduler, StaticScheduler,
                        calibrate)
from repro.launch.serve import build_stack
from repro.core.pipeline import ServingEngine


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--requests", type=int, default=150)
    p.add_argument("--nodes", type=int, default=8000)
    p.add_argument("--batch-seeds", type=int, default=8)
    args = p.parse_args()

    graph, feats, psgs, fap, store, gen, infer_fn = build_stack(
        nodes=args.nodes, avg_degree=10.0, d_feat=64, fanouts=(6, 4),
        hot_frac=0.3)
    print(f"[stack] {graph.num_nodes} nodes, tiers "
          f"{store.plan.tier_counts()}")

    # calibrate once (paper Fig. 6)
    probe = ServingEngine(graph, store, (6, 4), infer_fn,
                          StaticScheduler("host"), num_workers=1,
                          max_batch=32)
    order = np.argsort(psgs)
    batches = [order[int(q * len(order)):][:args.batch_seeds]
               .astype(np.int64) for q in np.linspace(0.05, 0.95, 6)]
    calib = calibrate(
        lambda b: jax.block_until_ready(probe._host_path(b)),
        lambda b: jax.block_until_ready(probe._device_path(b)),
        batches, psgs, repeats=2)
    report = {}
    for policy in ("latency_preferred", "throughput_preferred"):
        thr = calib.threshold(policy)
        engine = ServingEngine(graph, store, (6, 4), infer_fn,
                               HybridScheduler(psgs, thr, policy),
                               num_workers=2, max_batch=32)
        gen.rng = np.random.default_rng(5)
        reqs = list(gen.stream(args.requests,
                               seeds_per_request=args.batch_seeds))
        engine.warmup([reqs[0]])
        batcher = DynamicBatcher(deadline_s=0.02,
                                 psgs_budget=thr if np.isfinite(thr)
                                 else None,
                                 psgs_table=psgs, max_batch=16)
        m = engine.serve_stream(reqs, batcher, gap_s=0.002)
        report[policy] = {"threshold": thr, **m.summary()}
    print(json.dumps(report, indent=2))


if __name__ == "__main__":
    main()

"""DIN recsys serving with FAP-style embedding placement (DESIGN.md §4):
item popularity drives hot-row replication of the embedding table through
the same tiered store used for GNN features.

    PYTHONPATH=src python examples/recsys_din.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TieredFeatureStore, TopologySpec, quiver_placement
from repro.models.din import DINConfig, din_forward, din_init


def main() -> None:
    cfg = DINConfig(n_items=50_000, n_cates=500, embed_dim=18, hist_len=50,
                    n_dense_feat=8)
    params = din_init(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)

    # item popularity (the recsys FAP): zipf over items
    pop = 1.0 / np.power(np.arange(1, cfg.n_items + 1), 1.2)
    pop = pop[rng.permutation(cfg.n_items)].astype(np.float32)

    topo = TopologySpec(num_pods=1, devices_per_pod=4,
                        rows_per_device=4000, rows_host=20000,
                        hot_replicate_fraction=0.4)
    plan = quiver_placement(pop, topo)
    store = TieredFeatureStore.build(np.asarray(params["item_embed"]), plan)
    print("item-table placement:", plan.tier_counts())

    def item_lookup(ids):
        flat = ids.reshape(-1)
        rows = store.lookup(jnp.asarray(flat, jnp.int32))
        return rows.reshape(ids.shape + (cfg.embed_dim,))

    b = 256
    items = rng.choice(cfg.n_items, size=b, p=pop / pop.sum())
    batch = dict(
        target_item=jnp.asarray(items, jnp.int32),
        target_cate=jnp.asarray(rng.integers(0, 500, b), jnp.int32),
        hist_items=jnp.asarray(
            rng.choice(cfg.n_items, size=(b, 50), p=pop / pop.sum()),
            jnp.int32),
        hist_cates=jnp.asarray(rng.integers(0, 500, (b, 50)), jnp.int32),
        dense_feat=jnp.asarray(rng.normal(size=(b, 8)), jnp.float32))
    scores = din_forward(params, cfg, batch["target_item"],
                         batch["target_cate"], batch["hist_items"],
                         batch["hist_cates"], batch["dense_feat"],
                         item_lookup=item_lookup)
    hist = store.tier_histogram(np.asarray(batch["hist_items"]).ravel())
    tot = sum(hist.values())
    print(f"scored {b} requests; embedding fetch tier mix:",
          {k: round(v / tot, 3) for k, v in hist.items()})
    print("score stats:", float(scores.mean()), float(scores.std()))


if __name__ == "__main__":
    main()

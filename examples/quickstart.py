"""Quickstart: the full Quiver stack on a small synthetic graph in ~a minute.

    PYTHONPATH=src python examples/quickstart.py

Builds a skewed graph, computes the workload metrics (PSGS + FAP), places
features across the tiered store, calibrates the hybrid scheduler, and serves
a batch of GNN requests end to end.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (HybridScheduler, ServingEngine, TieredFeatureStore,
                        TopologySpec, WorkloadGenerator, compute_fap,
                        compute_psgs, quiver_placement)
from repro.graph import power_law_graph
from repro.models.gnn_basic import sage_init, sage_layered


def main() -> None:
    # 1. graph + features (stand-in for ogbn-products/Reddit)
    graph = power_law_graph(3000, 8.0, seed=0)
    feats = np.random.default_rng(1).normal(
        size=(graph.num_nodes, 64)).astype(np.float32)
    fanouts = (6, 4)
    print(f"graph: {graph.num_nodes} nodes / {graph.num_edges} edges, "
          f"max out-degree {graph.out_degree.max()}")

    # 2. workload metrics (paper §4.1 / §5.1)
    psgs = compute_psgs(graph, fanouts)
    gen = WorkloadGenerator(graph.num_nodes, graph.out_degree, seed=2)
    fap = compute_fap(graph, fanouts, seed_prob=gen.p)
    print(f"PSGS: min={psgs.min():.1f} median={np.median(psgs):.1f} "
          f"max={psgs.max():.1f}")

    # 3. workload-aware placement + tiered feature store (§5.2/§5.3)
    topo = TopologySpec(num_pods=1, devices_per_pod=1,
                        rows_per_device=800, rows_host=1400,
                        hot_replicate_fraction=0.3)
    plan = quiver_placement(fap, topo)
    store = TieredFeatureStore.build(feats, plan)
    print("placement tiers:", plan.tier_counts())

    # 4. model + serving engine with the PSGS hybrid scheduler (§4.2)
    params = sage_init(jax.random.key(0), [64, 64, 64])

    @jax.jit
    def infer_fn(hop_feats, hop_ids):
        masks = [(h >= 0).astype(jnp.float32)[:, None] for h in hop_ids]
        return sage_layered(params, hop_feats, fanouts, hop_masks=masks)

    sched = HybridScheduler(psgs, threshold=float(np.median(psgs)) * 64)
    engine = ServingEngine(graph, store, fanouts, infer_fn, sched,
                           num_workers=2, max_batch=32)

    # 5. serve!
    batches = [[r] for r in gen.stream(30, seeds_per_request=8)]
    engine.warmup(batches[0])
    metrics = engine.run(batches)
    for k, v in metrics.summary().items():
        print(f"  {k}: {v}")


if __name__ == "__main__":
    main()

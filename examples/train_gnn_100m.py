"""End-to-end training driver: a ~100M-parameter MeshGraphNet-style GNN
trained for a few hundred steps with the full substrate (sampler, AdamW,
async fault-tolerant checkpointing).

    PYTHONPATH=src python examples/train_gnn_100m.py --steps 300 \
        [--params-scale full]

``--params-scale small`` (default) runs a 4M-param proxy in a couple of
minutes on CPU; ``full`` instantiates the actual ~100M configuration
(d_hidden=512, 20 blocks) — same code, more patience.
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph import power_law_graph
from repro.models.common import count_params
from repro.models.meshgraphnet import mgn_forward, mgn_init
from repro.training import AdamW, CheckpointManager, run_training


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--params-scale", choices=("small", "full"),
                   default="small")
    p.add_argument("--nodes", type=int, default=2048)
    p.add_argument("--ckpt-dir", default=None)
    args = p.parse_args()

    if args.params_scale == "full":
        d_hidden, n_layers = 512, 20      # ≈ 100M params
    else:
        d_hidden, n_layers = 128, 8       # ≈ 4M params proxy

    d_feat = 64
    params = mgn_init(jax.random.key(0), d_node_in=d_feat, d_edge_in=4,
                      d_hidden=d_hidden, n_layers=n_layers, d_out=3)
    print(f"[train] MeshGraphNet {count_params(params):,} params "
          f"({d_hidden}h x {n_layers}L)")

    graph = power_law_graph(args.nodes, 8.0, seed=0)
    src, dst = map(jnp.asarray, graph.to_coo())
    n, e = graph.num_nodes, graph.num_edges

    def batch_fn(step: int) -> dict:
        rng = np.random.default_rng(step)   # deterministic → restart-safe
        pos = rng.normal(size=(n, 3)).astype(np.float32)
        x = rng.normal(size=(n, d_feat)).astype(np.float32)
        target = np.tanh(x[:, :3] * 0.5) + 0.1 * pos
        return {"x": jnp.asarray(x), "pos": jnp.asarray(pos),
                "y": jnp.asarray(target.astype(np.float32))}

    def loss_fn(p, batch):
        s, d = jnp.maximum(src, 0), jnp.maximum(dst, 0)
        rel = batch["pos"][d] - batch["pos"][s]
        dist = jnp.sqrt((rel ** 2).sum(-1, keepdims=True) + 1e-12)
        ef = jnp.concatenate([rel, dist], axis=-1)
        out = mgn_forward(p, batch["x"], ef, src, dst, num_nodes=n)
        return jnp.mean((out - batch["y"]) ** 2)

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="mgn_ckpt_")
    state = run_training(
        loss_fn=loss_fn, params=params,
        opt=AdamW(lr=1e-3, weight_decay=0.0, warmup_steps=20),
        batch_fn=batch_fn, steps=args.steps,
        ckpt=CheckpointManager(ckpt_dir, keep=2, async_write=True),
        ckpt_every=100, log_every=20)
    print(f"[train] finished step {state.step}; checkpoints in {ckpt_dir}")


if __name__ == "__main__":
    main()

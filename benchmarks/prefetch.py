"""Cold-tier prefetch benchmark: critical-path host callbacks with and
without the device-side staging buffer.

Quiver's latency case rests on keeping CPU–GPU data movement off the
request critical path. HOST/DISK-tier rows used to cost one synchronous
``io_callback`` per sample; the prefetcher
(:class:`repro.core.prefetch.Prefetcher`) stages the predicted cold rows
into device memory off the critical path, so lookups resolve them with a
plain device gather and only fall back to the callback on a prefetch miss.
This benchmark reports, on a zipf-skewed workload over a store whose DISK
tier is a real ``np.memmap`` spill file:

  1. DISK-tier exactness: lookups against the spill-backed store are
     bit-identical to an all-HOT reference store (the old zeros-stub is
     gone) — with and without a published stage,
  2. critical-path host callbacks per request and DISK misses per request,
     prefetch off vs on (the structural win; strictly reduced),
  3. end-to-end serving throughput and p99 for both modes, plus the
     staged-hit/fallback-miss split.

    PYTHONPATH=src python benchmarks/prefetch.py [--dry-run]

``--dry-run`` shrinks every dimension so CI can smoke the full path.
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time

if __package__ in (None, ""):  # direct `python benchmarks/prefetch.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (build_serving_stack, emit,
                               latency_percentiles, make_engine,
                               write_bench_json)
from repro.core import (Prefetcher, TieredFeatureStore, TopologySpec,
                        quiver_placement)
from repro.core.placement import TIER_HOST
from repro.serving import HybridScheduler


def _all_hot_reference(stack) -> TieredFeatureStore:
    """Reference store with every row replicated in HBM (no cold tiers)."""
    nodes = stack["graph"].num_nodes
    topo = TopologySpec(num_pods=1, devices_per_pod=1, rows_per_device=nodes,
                        rows_host=64, hot_replicate_fraction=1.0)
    return TieredFeatureStore.build(stack["feats"],
                                    quiver_placement(stack["fap"], topo))


def _disk_bit_identity(stack, store) -> None:
    """Spill-backed lookups must match the all-HOT reference bit for bit,
    staged or not (DISK rows are real feature rows, not zeros)."""
    ref = _all_hot_reference(stack)
    rng = np.random.default_rng(11)
    ids = rng.integers(-1, stack["graph"].num_nodes, 512).astype(np.int32)
    want = np.asarray(ref.lookup(jnp.asarray(ids)))
    got = np.asarray(store.lookup(jnp.asarray(ids)))
    assert np.array_equal(want, got), "spill-backed lookup diverged"
    pf = Prefetcher(store, budget=stack["graph"].num_nodes)
    pf.refresh(scores=stack["fap"])
    got_staged = np.asarray(store.lookup(jnp.asarray(ids)))
    [got_fused] = store.lookup_hops([ids])
    store.publish_stage(None, None)
    assert np.array_equal(want, got_staged), "staged lookup diverged"
    assert np.array_equal(want, np.asarray(got_fused)), "fused diverged"
    emit("prefetch/disk_bit_identical", 1.0,
         "spill-backed == all-HOT reference, staged and unstaged")


def run(dry_run: bool = False) -> dict:
    nodes = 900 if dry_run else 6000
    n_req, per = (12, 8) if dry_run else (60, 8)
    spill = tempfile.NamedTemporaryFile(suffix=".spill", delete=False)
    spill.close()
    try:
        # small HBM tiers (rows_frac) so the skewed stream actually exercises
        # the cold path: the off-mode baseline pays real host callbacks
        stack = build_serving_stack(nodes=nodes, distribution="zipf",
                                    rows_frac=0.1, spill_path=spill.name)
        store, psgs, gen, fap = (stack["store"], stack["psgs"], stack["gen"],
                                 stack["fap"])
        results: dict = {}

        # -- 1) DISK tier is real: bit-identity vs all-HOT reference ---------
        _disk_bit_identity(stack, store)

        # -- 2/3) serve the same skewed stream, prefetch off vs on -----------
        n_cold = int((np.asarray(store.tier_t) >= TIER_HOST).sum())
        thr = float(np.median(psgs)) * per * 2
        for mode in ("off", "on"):
            engine = make_engine(stack, HybridScheduler(psgs, thr),
                                 num_workers=2, max_batch=32)
            if mode == "on":
                # stage the offline-FAP prediction (covers multi-hop
                # frontiers); budget sized to the cold working set
                pf = Prefetcher(store, budget=n_cold)
                staged = pf.refresh(scores=fap)
                emit("prefetch/staged_rows", float(staged),
                     f"cold_rows={n_cold}")
            gen.rng = np.random.default_rng(7)  # same workload both modes
            reqs = list(gen.stream(n_req, seeds_per_request=per))
            engine.warmup([reqs[0]])
            store.reset_stats()
            m = engine.run([[r] for r in reqs])
            stats = store.reset_stats()
            s = m.summary()
            results[mode] = {
                "rps": s["throughput_rps"], "p99_ms": s["p99_ms"],
                "host_cb_per_req": stats["host_fetches"] / n_req,
                "disk_miss_per_req": stats["disk_misses"] / n_req,
                "prefetch_hits": stats["prefetch_hits"],
                "prefetch_misses": stats["prefetch_misses"],
                **latency_percentiles(m),
            }
            emit(f"prefetch/{mode}_host_cb_per_req",
                 results[mode]["host_cb_per_req"],
                 f"p99={s['p99_ms']:.1f}ms;rps={s['throughput_rps']:.1f};"
                 f"disk_miss_per_req={results[mode]['disk_miss_per_req']:.2f}")
            engine.close()
            store.publish_stage(None, None)

        off, on = results["off"], results["on"]
        emit("prefetch/host_cb_reduction_x",
             off["host_cb_per_req"] / max(on["host_cb_per_req"], 1e-9),
             f"hits={on['prefetch_hits']};misses={on['prefetch_misses']}")
        # the acceptance signal: staging strictly removes critical-path
        # host callbacks on the skewed workload
        assert on["host_cb_per_req"] < off["host_cb_per_req"], results
        write_bench_json("prefetch", {"dry_run": dry_run, "modes": results})
        return results
    finally:
        os.unlink(spill.name)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--dry-run", action="store_true",
                   help="tiny sizes; CI smoke for the full prefetch path")
    args = p.parse_args()
    t0 = time.time()
    results = run(dry_run=args.dry_run)
    off, on = results["off"], results["on"]
    print(f"# prefetch: host callbacks/request {off['host_cb_per_req']:.2f} "
          f"-> {on['host_cb_per_req']:.2f}, "
          f"p99 {off['p99_ms']:.1f} -> {on['p99_ms']:.1f} ms "
          f"({time.time() - t0:.1f}s)")


if __name__ == "__main__":
    main()

"""Gateway soak benchmark: SLO-aware admission vs FIFO under overload.

A flash-crowd of heavy batch requests (seeds concentrated on the coldest
DISK-tier rows, reused from ``flash_crowd.flash_hotspot``) lands ahead of a
burst of light interactive requests carrying deadlines, plus a few "doomed"
requests whose deadline already passed at arrival. Both modes serve the
identical seeded stream over identical fresh stacks:

  fifo      requests hit ``ServingEngine.submit_batch`` in arrival order
            (admission="wait"): interactive traffic queues behind every
            heavy batch request, and doomed requests occupy executors.
  gateway   the :class:`repro.serving.ServingGateway` orders admission by
            deadline slack (estimated from the calibrated router curves)
            with anti-starvation aging, sheds hopeless requests at
            admission and re-checks staleness at dequeue.

Asserted in-benchmark (gateway mode): zero dispatches of expired requests,
queue depth bounded by the configured admission window, telemetry
timestamps monotone, every request ends in exactly one terminal outcome,
and interactive p99 strictly below the FIFO baseline's.

    PYTHONPATH=src python benchmarks/gateway_soak.py [--dry-run] \\
        [--json-out PATH]

``--dry-run`` shrinks every dimension so CI can smoke the full path;
``--json-out`` additionally writes the two result rows as JSON.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

if __package__ in (None, ""):  # direct `python benchmarks/gateway_soak.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

import numpy as np

from benchmarks.common import (build_serving_stack, emit, make_executors,
                               write_bench_json)
from benchmarks.flash_crowd import flash_hotspot
from repro.serving import (CostModelRouter, GatewayConfig, ServingEngine,
                           ServingGateway, calibrate_executors)

#: Pinned result-row schema — one row per mode in ``BENCH_gateway_soak.json``
#: (``tests/test_gateway.py`` regresses against this tuple).
ROW_SCHEMA = ("mode", "requests", "completed", "shed_window",
              "shed_deadline", "expired_dispatches", "max_queue_depth",
              "interactive_p50_ms", "interactive_p99_ms",
              "batch_p50_ms", "batch_p99_ms", "wall_s")


def build_row(**fields) -> dict:
    """One mode's result row in ``ROW_SCHEMA`` order.

    Raises:
        ValueError: on any drift (missing or extra field) from the pinned
            schema, so a silent BENCH-format change cannot ship.
    """
    missing = set(ROW_SCHEMA) - set(fields)
    extra = set(fields) - set(ROW_SCHEMA)
    if missing or extra:
        raise ValueError(f"row drifted from ROW_SCHEMA: "
                         f"missing={sorted(missing)} extra={sorted(extra)}")
    return {k: fields[k] for k in ROW_SCHEMA}


def class_percentiles(reqs) -> dict:
    """Per-class completed-request latency percentiles (ms), computed from
    the request objects themselves — mode-agnostic (FIFO mode has no
    gateway telemetry to read them from)."""
    out = {}
    for cls in ("interactive", "batch"):
        lat = [r.latency for r in reqs
               if r.priority == cls and r.outcome == "completed"]
        arr = np.asarray(lat if lat else [0.0], dtype=np.float64)
        out[cls] = {"p50_ms": float(np.quantile(arr, 0.5) * 1e3),
                    "p99_ms": float(np.quantile(arr, 0.99) * 1e3)}
    return out


def expired_dispatches(reqs) -> int:
    """Requests handed to an executor after their deadline had already
    passed (the gateway's dequeue-time staleness re-check exists to force
    this to zero; FIFO happily burns executor slots on them)."""
    n = 0
    for r in reqs:
        t = getattr(r, "dispatched", None)
        if (r.deadline_s is not None and t is not None
                and t > r.arrival + r.deadline_s):
            n += 1
    return n


def build_stream(stack, *, n_heavy: int, n_light: int, n_doomed: int,
                 heavy_per: int, light_per: int, deadline_s: float) -> list:
    """The mixed overload stream: heavy deadline-free batch requests on the
    coldest DISK rows first, then light interactive requests with a
    deadline, then doomed interactive requests already expired at arrival
    (``deadline_s=-1``) — deterministic per stack seed."""
    gen, nodes = stack["gen"], stack["graph"].num_nodes
    hotspot = flash_hotspot(stack["store"], stack["fap"],
                            size=max(4, n_heavy // 2))
    p = np.zeros(nodes)
    p[hotspot] = 1.0 / hotspot.size
    gen.set_seed_prob(p)
    heavy = [gen.make_request(heavy_per, priority="batch")
             for _ in range(n_heavy)]
    gen.set_seed_prob(None)
    light = [gen.make_request(light_per, priority="interactive",
                              deadline_s=deadline_s)
             for _ in range(n_light)]
    doomed = [gen.make_request(light_per, priority="interactive",
                               deadline_s=-1.0) for _ in range(n_doomed)]
    return heavy + light + doomed


def _make_engine(stack, *, max_inflight: int) -> ServingEngine:
    """Calibrated host+device engine over the stack — a real
    ``CostModelRouter`` so the gateway's slack estimation exercises the
    per-executor latency curves (not the 0-estimate fallback)."""
    executors = make_executors(stack, num_workers=2, max_batch=64)
    psgs = stack["psgs"]
    order = np.argsort(psgs)
    batches = [order[int(q * order.size):][:16].astype(np.int64)
               for q in np.linspace(0.1, 0.9, 4)]
    curves = calibrate_executors(executors, batches, psgs, repeats=1)
    router = CostModelRouter.from_curves(psgs, curves, "latency_preferred",
                                         executors=executors)
    return ServingEngine(executors, router, max_inflight=max_inflight,
                         admission="wait")


def _run_fifo(engine, reqs) -> None:
    """FIFO baseline: arrival-order ``submit_batch`` under wait-admission.
    The dispatch stamp lands when admission unblocks — the moment the
    request takes an executor-window slot."""
    t0 = engine.clock()
    for r in reqs:
        r.arrival = t0                      # burst: all arrived at once
    m = engine.begin_run()
    for r in reqs:
        engine.submit_batch([r])
        r.dispatched = engine.clock()
    engine.drain()
    engine.end_run(m)


def run(dry_run: bool = False, json_out: str | None = None) -> dict:
    n_heavy, n_light, n_doomed = (8, 8, 2) if dry_run else (32, 32, 4)
    heavy_per, light_per = (16, 4) if dry_run else (32, 4)
    nodes = 600 if dry_run else 4000
    fanouts = (4, 3) if dry_run else (6, 4)
    deadline_s, queue_limit, max_inflight = 30.0, 256, 2
    spill = tempfile.NamedTemporaryFile(suffix=".spill", delete=False)
    spill.close()
    rows: dict = {}
    try:
        for mode in ("fifo", "gateway"):
            # fresh stack per mode (same seed -> identical plan + stream);
            # tiny HBM tiers so heavy requests really pay the DISK price
            stack = build_serving_stack(nodes=nodes, fanouts=fanouts, seed=0,
                                        distribution="zipf", rows_frac=0.1,
                                        spill_path=spill.name)
            engine = _make_engine(stack, max_inflight=max_inflight)
            engine.warmup(np.arange(light_per))
            reqs = build_stream(stack, n_heavy=n_heavy, n_light=n_light,
                                n_doomed=n_doomed, heavy_per=heavy_per,
                                light_per=light_per, deadline_s=deadline_s)
            t0 = time.perf_counter()
            if mode == "fifo":
                _run_fifo(engine, reqs)
                shed_window = shed_deadline = 0
                max_depth = 0
            else:
                gw = ServingGateway(engine, config=GatewayConfig(
                    queue_limit=queue_limit))
                gw.serve(reqs)
                rep = gw.report()
                shed_window = rep["shed_window"]
                shed_deadline = rep["shed_deadline"]
                max_depth = rep["max_queue_depth"]
                # the tentpole invariants, asserted on the live run:
                assert max_depth <= queue_limit, rep
                assert expired_dispatches(reqs) == 0, \
                    "gateway dispatched an expired request"
                for r in reqs[-n_doomed:]:
                    assert r.outcome == "shed_deadline", r
                    assert getattr(r, "dispatched", None) is None, r
                ts = [s["t"] for s in gw.telemetry_samples()]
                assert ts == sorted(ts), "telemetry timestamps not monotone"
                assert all(r.outcome in ("completed", "shed_window",
                                         "shed_deadline") for r in reqs)
            wall = time.perf_counter() - t0
            cp = class_percentiles(reqs)
            rows[mode] = build_row(
                mode=mode, requests=len(reqs),
                completed=sum(r.outcome == "completed" for r in reqs),
                shed_window=shed_window, shed_deadline=shed_deadline,
                expired_dispatches=expired_dispatches(reqs),
                max_queue_depth=max_depth,
                interactive_p50_ms=cp["interactive"]["p50_ms"],
                interactive_p99_ms=cp["interactive"]["p99_ms"],
                batch_p50_ms=cp["batch"]["p50_ms"],
                batch_p99_ms=cp["batch"]["p99_ms"], wall_s=wall)
            emit(f"gateway_soak/{mode}_interactive_p99_ms",
                 rows[mode]["interactive_p99_ms"],
                 f"batch_p99={rows[mode]['batch_p99_ms']:.1f}ms;"
                 f"expired_dispatches={rows[mode]['expired_dispatches']};"
                 f"shed_deadline={shed_deadline}")
            engine.close()

        fifo, gw_row = rows["fifo"], rows["gateway"]
        emit("gateway_soak/interactive_p99_speedup_x",
             fifo["interactive_p99_ms"] / max(gw_row["interactive_p99_ms"],
                                              1e-9),
             f"fifo={fifo['interactive_p99_ms']:.1f}ms "
             f"gateway={gw_row['interactive_p99_ms']:.1f}ms")
        # the acceptance signal: on the identical mixed stream the gateway
        # strictly improves interactive tail latency over FIFO
        assert gw_row["interactive_p99_ms"] < fifo["interactive_p99_ms"], \
            rows
        payload = {"dry_run": dry_run, "modes": rows}
        write_bench_json("gateway_soak", payload)
        if json_out:
            with open(json_out, "w") as f:
                json.dump(payload, f, indent=2, sort_keys=True)
                f.write("\n")
        return rows
    finally:
        os.unlink(spill.name)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--dry-run", action="store_true",
                   help="tiny sizes; CI smoke for the full soak path")
    p.add_argument("--json-out", default=None, metavar="PATH",
                   help="also write the result rows as JSON to PATH")
    args = p.parse_args()
    t0 = time.time()
    rows = run(dry_run=args.dry_run, json_out=args.json_out)
    fifo, gw = rows["fifo"], rows["gateway"]
    print(f"# gateway_soak: interactive p99 {fifo['interactive_p99_ms']:.1f}"
          f" -> {gw['interactive_p99_ms']:.1f} ms, expired dispatches "
          f"{fifo['expired_dispatches']} -> {gw['expired_dispatches']} "
          f"({time.time() - t0:.1f}s)")


if __name__ == "__main__":
    main()

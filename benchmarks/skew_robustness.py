"""Fig. 13 analogue: robustness to data skew — PSGS-hybrid vs static
host/device across small/medium/large workloads and batch sizes."""
from __future__ import annotations

import numpy as np

from benchmarks.common import build_serving_stack, emit, make_executors, timeit
from repro.serving import HybridScheduler


def run() -> None:
    stack = build_serving_stack(nodes=5000, fanouts=(10, 5))
    psgs = stack["psgs"]
    order = np.argsort(psgs)
    workloads = {
        "small": order[:512],            # low-degree seeds
        "medium": order[len(order) // 2: len(order) // 2 + 512],
        "large": order[-512:],           # hub seeds
    }
    for batch in (4, 96):
        for wname, pool in workloads.items():
            seeds = pool[:batch].astype(np.int64)
            executors = make_executors(stack, max_batch=batch)
            t_host = timeit(lambda: executors["host"].process(seeds),
                            repeats=3)
            t_dev = timeit(lambda: executors["device"].process(seeds),
                           repeats=3)
            # PSGS picks per-batch using the throughput threshold
            thr = float(np.median(psgs)) * batch * 2
            hybrid = HybridScheduler(psgs, thr)
            t_psgs = t_host if hybrid.route(seeds) == "host" else t_dev
            emit(f"skew/{wname}_b{batch}_host_us", t_host * 1e6, "")
            emit(f"skew/{wname}_b{batch}_device_us", t_dev * 1e6, "")
            emit(f"skew/{wname}_b{batch}_psgs_us", t_psgs * 1e6,
                 f"routed={hybrid.routed}")
            # the PSGS strategy must match the best static choice
            best = min(t_host, t_dev)
            assert t_psgs <= best * 1.5 + 1e-3


if __name__ == "__main__":
    run()

"""Workload-drift benchmark: online adaptation on vs off.

Serves two phases over identical stacks: phase 1 draws seeds from the
distribution the placement was computed for; phase 2 shifts 90% of the seed
mass onto a hot subgraph that the initial FAP ranked cold (placed on the
HOST/DISK tiers). With adaptation off the stale placement keeps paying the
slow-tier price forever; with the :class:`AdaptiveController` hooked into the
engine, the frequency sketch picks up the drift, FAP is recomputed with the
empirical seed distribution and the hot rows migrate into HBM tiers while
serving continues — reported as the host/disk-tier access rate of the
post-drift workload, plus p99 latency and migration counters.

    PYTHONPATH=src python benchmarks/workload_drift.py [--dry-run]

``--dry-run`` shrinks every dimension so CI can smoke the full path.
"""
from __future__ import annotations

import argparse
import os
import sys

if __package__ in (None, ""):  # direct `python benchmarks/workload_drift.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

import numpy as np

from benchmarks.common import build_serving_stack, emit, make_executors
from repro.core import Request, migration_pairs  # noqa: F401 (re-export check)
from repro.graph.sampler import host_sample_dense
from repro.serving import (AdaptiveConfig, AdaptiveController,
                           CostModelRouter, ServingEngine,
                           calibrate_executors, pad_to_bucket)


def _requests(seed_arrays, start_id: int = 0):
    import time
    return [[Request(start_id + i, s, time.perf_counter())]
            for i, s in enumerate(seed_arrays)]


def host_access_rate(graph, store, seed_batches, fanouts, *,
                     seed: int = 0) -> float:
    """Fraction of sampled feature accesses (seeds + all hop neighbors)
    that land on the slow HOST/DISK tiers under the store's current plan."""
    rng = np.random.default_rng(seed)
    hbm = slow = 0
    for seeds in seed_batches:
        hops = host_sample_dense(rng, graph,
                                 pad_to_bucket(seeds.astype(np.int32)),
                                 fanouts)
        ids = np.concatenate([np.asarray(h).ravel() for h in hops])
        h = store.tier_histogram(ids)
        hbm += h["hot"] + h["warm"]
        slow += h["host"] + h["disk"]
    return slow / max(hbm + slow, 1)


def run(dry_run: bool = False) -> dict:
    nodes = 600 if dry_run else 4000
    per = 8
    n1, n2 = (10, 20) if dry_run else (40, 120)
    fanouts = (4, 3) if dry_run else (6, 4)

    results = {}
    seed_rng = np.random.default_rng(11)
    # one stack build defines the workload + hotspot; each system then gets
    # its own fresh store/plan so migration in one cannot leak into the other
    base = build_serving_stack(nodes=nodes, fanouts=fanouts, seed=0,
                               distribution="degree")
    graph = base["graph"]

    # phase-1 seeds follow the calibrated-for distribution; phase-2 seeds
    # concentrate on nodes the initial plan put on the slow tiers
    cold = np.flatnonzero(base["store"].plan.tier >= 2)  # HOST + DISK
    if cold.size == 0:
        raise RuntimeError("placement has no cold tier; enlarge the graph")
    hotspot = cold[seed_rng.permutation(cold.size)[:max(cold.size // 4, 8)]]
    p2 = np.full(nodes, 0.1 / nodes)
    p2[hotspot] += 0.9 / hotspot.size
    p2 /= p2.sum()

    phase1 = [seed_rng.choice(nodes, size=per, p=base["gen"].p)
              for _ in range(n1)]
    phase2 = [seed_rng.choice(nodes, size=per, p=p2) for _ in range(n2)]
    probe = [seed_rng.choice(nodes, size=per, p=p2) for _ in range(16)]

    for mode in ("static", "adaptive"):
        stack = build_serving_stack(nodes=nodes, fanouts=fanouts, seed=0,
                                    distribution="degree")
        executors = make_executors(stack, num_workers=2, max_batch=32)
        order = np.argsort(stack["psgs"])
        cal_batches = [order[int(q * nodes):][:per].astype(np.int64)
                       for q in np.linspace(0.05, 0.95, 4 if dry_run else 8)]
        curves = calibrate_executors(executors, cal_batches, stack["psgs"],
                                     repeats=1 if dry_run else 2)
        router = CostModelRouter.from_curves(stack["psgs"], curves,
                                             "latency_preferred",
                                             executors=executors)
        hooks = []
        controller = None
        if mode == "adaptive":
            controller = AdaptiveController(
                graph, fanouts, stack["store"], router,
                psgs_table=stack["psgs"],
                config=AdaptiveConfig(interval_batches=4 if dry_run else 8,
                                      rows_per_step=64 if dry_run else 256,
                                      decay=0.8))
            hooks.append(controller)
        engine = ServingEngine(executors, router, max_inflight=16,
                               hooks=hooks)
        engine.warmup(np.arange(per))

        engine.run(_requests(phase1))
        m2 = engine.run(_requests(phase2, start_id=n1))
        rate = host_access_rate(graph, stack["store"], probe, fanouts)
        results[mode] = {
            "p99_ms": m2.percentile(0.99) * 1e3,
            "host_access_rate": rate,
            "migrated_rows": stack["store"].migrated_rows,
            "refits": controller.report()["refits"] if controller else 0,
        }
        emit(f"workload_drift/{mode}_host_rate", rate * 100,
             f"p99={results[mode]['p99_ms']:.1f}ms;"
             f"migrated={results[mode]['migrated_rows']}")
        engine.close()

    win = (results["static"]["host_access_rate"]
           - results["adaptive"]["host_access_rate"])
    emit("workload_drift/adaptation_win_pp", win * 100,
         "host-tier access-rate reduction (percentage points)")
    return results


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--dry-run", action="store_true",
                   help="tiny sizes; CI smoke for the full adaptation path")
    args = p.parse_args()
    results = run(dry_run=args.dry_run)
    better = (results["adaptive"]["host_access_rate"]
              < results["static"]["host_access_rate"])
    print(f"# adaptation {'BEATS' if better else 'did NOT beat'} static "
          f"placement on host-tier access rate: "
          f"{results['adaptive']['host_access_rate']:.3f} vs "
          f"{results['static']['host_access_rate']:.3f}")


if __name__ == "__main__":
    main()

"""Fused gather→aggregate benchmark: layer-1 aggregation straight from the
tier buffers vs gather-then-aggregate.

The unfused serve path pays for the innermost hop twice: ``lookup_hops``
writes the dense (n_sampled, d) neighbor tensor, then the model's first
layer re-reads all of it just to reduce each fan-sized segment into its
parent. ``TieredFeatureStore.lookup_aggregate`` (the ``gather_aggregate``
Pallas kernel) folds that reduction into the gather — the dense tensor is
never materialized — so per request it saves one full kernel pass and two
trips of the largest tensor through memory.

Because feature dimension is the axis that flips gather kernels between
latency- and bandwidth-bound (arxiv 2212.00827), every claim is swept over
embedding dims {16, 64, 256}; per dim this benchmark asserts

  1. bit-identity: fused outer-hop rows, the fused aggregate and the final
     model output all ``np.array_equal`` the unfused path,
  2. strictly fewer kernel dispatches per request (gather + model-side
     reduction pass vs one fused dispatch),
  3. strictly lower modeled bytes moved for the innermost hop (the dense
     tensor's write+read disappears),

and measures store-level collection latency, end-to-end serving rps/p99
with executors flipped between the two paths, plus the block_rows/block_dim
autotune pick. Results land in ``BENCH_gather_aggregate.json``.

    PYTHONPATH=src python benchmarks/gather_aggregate.py [--dry-run]

``--dry-run`` shrinks node counts and repeat counts so CI can smoke the
full code path (the sweep keeps all three dims and every assertion).
"""
from __future__ import annotations

import argparse
import os
import sys
import time

if __package__ in (None, ""):  # direct `python benchmarks/gather_aggregate.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (build_serving_stack, emit, make_engine,
                               make_executors, timeit, write_bench_json)
from repro.graph.sampler import host_sample_dense
from repro.kernels.gather_aggregate import autotune_gather_aggregate
from repro.serving import HybridScheduler, pad_to_bucket

DIMS = (16, 64, 256)


def _sample_hops(stack, seeds: np.ndarray, seed: int = 0):
    rng = np.random.default_rng(seed)
    hops = host_sample_dense(rng, stack["graph"],
                             pad_to_bucket(seeds.astype(np.int32)),
                             stack["fanouts"])
    return [jnp.asarray(h) for h in hops]


def _deep_bytes(hops, p: int, d: int, *, fused: bool) -> int:
    """Modeled innermost-hop traffic per request (fp32): both paths read
    each valid child row from its tier buffer once; the unfused path also
    writes the dense (n_inner, d) tensor and reads it back for the model's
    segment reduction, the fused path writes only the (P, d) aggregate."""
    n_inner = int(hops[-1].shape[0])
    n_valid = int((np.asarray(hops[-1]) >= 0).sum())
    reads_src = n_valid * d * 4
    agg_write = p * d * 4
    if fused:
        return reads_src + agg_write
    return reads_src + 2 * n_inner * d * 4 + agg_write


def run(dry_run: bool = False) -> dict:
    nodes = 700 if dry_run else 4000
    n_req, per = (10, 8) if dry_run else (50, 8)
    repeats = 3 if dry_run else 5
    fanouts = (4, 3)
    results: dict = {"sweep": []}

    # -- 1) embedding-dim sweep: identity + dispatches + bytes + latency -----
    for d_feat in DIMS:
        stack = build_serving_stack(nodes=nodes, d_feat=d_feat,
                                    fanouts=fanouts, seed=0)
        store, gen = stack["store"], stack["gen"]
        gen.rng = np.random.default_rng(7)
        hops = _sample_hops(stack, gen.make_request(per).seeds)
        p = int(hops[-2].shape[0])
        fan = fanouts[-1]

        store.reset_stats()
        feats_u = store.lookup_hops(hops)
        jax.block_until_ready(feats_u)
        s_u = store.reset_stats()
        feats_f, agg = store.lookup_aggregate(hops)
        jax.block_until_ready((feats_f, agg))
        s_f = store.reset_stats()

        # bit-identity: outer rows, the aggregate, and the model output
        child = feats_u[-1].reshape(p, fan, -1)
        m = (hops[-1] >= 0).astype(jnp.float32).reshape(p, fan, 1)
        expected = (child * m).sum(1)
        ident = (all(bool(jnp.array_equal(a, b))
                     for a, b in zip(feats_u[:-1], feats_f))
                 and bool(jnp.array_equal(agg, expected)))
        infer = stack["infer_fn"]
        out_ident = bool(jnp.array_equal(
            infer(feats_u, hops), infer(feats_f, hops, deep_agg=agg)))
        assert ident and out_ident, (
            f"fused/unfused layer-1 paths diverged at d={d_feat}: "
            f"collect={ident} model={out_ident}")

        # kernel dispatches per request: the unfused path runs the tier
        # gather AND a model-side reduction pass over the dense deepest-hop
        # tensor; the fused path folds the reduction into its one dispatch
        disp_u = s_u["device_gathers"] + 1
        disp_f = s_f["device_gathers"]
        assert disp_f < disp_u, (disp_f, disp_u)

        bytes_u = _deep_bytes(hops, p, d_feat, fused=False)
        bytes_f = _deep_bytes(hops, p, d_feat, fused=True)
        assert bytes_f < bytes_u, (bytes_f, bytes_u)

        t_u = timeit(lambda: infer(store.lookup_hops(hops), hops),
                     repeats=repeats)
        t_f = timeit(lambda: (lambda ff, ag: infer(ff, hops, deep_agg=ag))(
            *store.lookup_aggregate(hops)), repeats=repeats)
        store.reset_stats()

        row = {"d_feat": d_feat, "bit_identical": ident and out_ident,
               "dispatches": {"unfused": disp_u, "fused": disp_f},
               "deep_hop_bytes": {"unfused": bytes_u, "fused": bytes_f},
               "collect_infer_us": {"unfused": t_u * 1e6,
                                    "fused": t_f * 1e6}}
        results["sweep"].append(row)
        emit(f"gather_aggregate/d{d_feat}_dispatches", float(disp_f),
             f"unfused={disp_u};bit_identical={int(ident and out_ident)}")
        emit(f"gather_aggregate/d{d_feat}_deep_bytes", float(bytes_f),
             f"unfused={bytes_u};"
             f"saved={1 - bytes_f / max(bytes_u, 1):.0%}")
        emit(f"gather_aggregate/d{d_feat}_collect_infer_us", t_f * 1e6,
             f"unfused={t_u * 1e6:.0f}us")

    # -- 2) executor-level equivalence + end-to-end serving ------------------
    stack = build_serving_stack(nodes=nodes, fanouts=fanouts, seed=0)
    store, psgs, gen = stack["store"], stack["psgs"], stack["gen"]
    gen.rng = np.random.default_rng(7)
    seeds = gen.make_request(per).seeds

    ex_u = make_executors(stack, num_workers=1, rng_seed=11)
    ex_f = make_executors(stack, num_workers=1, fuse_aggregate=True,
                          rng_seed=11)
    # identical rng seeds → identical sampled hops → outputs must match
    exec_ident = bool(jnp.array_equal(ex_u["host"].process(seeds),
                                      ex_f["host"].process(seeds)))
    assert exec_ident, "executor outputs diverged under fuse_aggregate"
    results["executor_bit_identical"] = exec_ident
    emit("gather_aggregate/executor_bit_identical", float(exec_ident))
    for e in (*ex_u.values(), *ex_f.values()):
        e.close()

    thr = float(np.median(psgs)) * per * 2
    for mode in ("fused", "fuse_aggregate"):
        engine = make_engine(stack, HybridScheduler(psgs, thr),
                             num_workers=2, max_batch=32,
                             fuse_aggregate=mode == "fuse_aggregate")
        gen.rng = np.random.default_rng(7)  # same workload for both modes
        reqs = list(gen.stream(n_req, seeds_per_request=per))
        engine.warmup([reqs[0]])
        store.reset_stats()
        metrics = engine.run([[r] for r in reqs])
        stats = store.reset_stats()
        s = metrics.summary()
        results[mode] = {"rps": s["throughput_rps"], "p99_ms": s["p99_ms"],
                         "fused_aggregates": stats["fused_aggregates"]}
        emit(f"gather_aggregate/{mode}_rps", s["throughput_rps"],
             f"p99={s['p99_ms']:.1f}ms;"
             f"fused_aggregates={stats['fused_aggregates']}")
        engine.close()
    results["serve_speedup_x"] = (results["fuse_aggregate"]["rps"]
                                  / max(results["fused"]["rps"], 1e-9))
    emit("gather_aggregate/serve_speedup_x", results["serve_speedup_x"],
         "fuse_aggregate vs fused end-to-end throughput")

    # -- 3) block_rows/block_dim autotune (interpret-mode timing) ------------
    hops = _sample_hops(stack, gen.make_request(per).seeds)
    rng = np.random.default_rng(3)
    s_seg = 64 if dry_run else 256
    tier = jnp.asarray(rng.choice([0, 1, 99], size=(s_seg, fanouts[-1]),
                                  p=[.5, .4, .1]).astype(np.int32))
    slot = jnp.asarray(rng.integers(0, max(int(store.hot.shape[0]), 1),
                                    (s_seg, fanouts[-1])).astype(np.int32))
    tune = autotune_gather_aggregate(
        tier, slot, store.hot, store.warm,
        jnp.zeros((1, store.feat_dim), store.hot.dtype),
        block_rows_candidates=(8, 16) if dry_run else (4, 8, 16, 32),
        repeats=2 if dry_run else 3)
    results["autotune"] = tune
    emit("gather_aggregate/autotune_block_rows",
         float(tune["best"]["block_rows"]),
         f"block_dim={tune['best']['block_dim']};"
         f"interpret={int(tune['interpret'])}")

    write_bench_json("gather_aggregate", results)
    return results


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--dry-run", action="store_true",
                   help="tiny sizes; CI smoke for the full fused path")
    args = p.parse_args()
    t0 = time.time()
    results = run(dry_run=args.dry_run)
    d0 = results["sweep"][0]["dispatches"]
    print(f"# gather_aggregate: {d0['unfused']} -> {d0['fused']} "
          f"dispatches/request, serve speedup "
          f"{results['serve_speedup_x']:.2f}x over "
          f"{len(results['sweep'])} dims ({time.time() - t0:.1f}s)")


if __name__ == "__main__":
    main()

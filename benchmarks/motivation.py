"""Fig. 2/3 analogue: skew in sampled-neighbor counts and aggregated feature
sizes on a power-law graph — the irregularity that motivates Quiver."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.graph import host_sample, power_law_graph, realized_size


def run() -> None:
    g = power_law_graph(20000, 12.0, seed=0)
    rng = np.random.default_rng(0)
    d_feat = 128
    for fanouts, tag in (((25, 10), "25-10"), ((50, 35), "50-35")):
        sizes = []
        for _ in range(200):
            seeds = rng.integers(0, g.num_nodes, size=8)
            sizes.append(realized_size(host_sample(rng, g, seeds, fanouts)))
        sizes = np.asarray(sizes)
        feat_mb = sizes * d_feat * 4 / 2**20
        emit(f"motivation/sampled_nodes_{tag}_p05", float(np.quantile(sizes, .05)),
             f"p95={np.quantile(sizes, .95):.0f};max={sizes.max()}")
        emit(f"motivation/feat_mb_{tag}_p50", float(np.quantile(feat_mb, .5)),
             f"p95={np.quantile(feat_mb, .95):.2f}MB")
        emit(f"motivation/size_skew_{tag}", float(sizes.max() / sizes.min()),
             "max/min sampled-size ratio")


if __name__ == "__main__":
    run()

"""Fig. 15 analogue: feature-aggregation cost under placement policies —
Quiver FAP vs hash (DGL), degree (AliGraph), training-frequency (GNNLab)
and P3 feature-dim partitioning.

Two views per policy:
  * modeled aggregation cost on the TPU topology (per-row fetch cost by tier:
    HBM=1, ICI=16, host=160, disk=1600 — inverse-bandwidth ratios), both the
    mean per-batch total and the p95 of the slowest-tier ("tail gates DNN
    start", paper §5.2);
  * measured wall-time of the real tiered-store lookup on this host
    (validates the code path; on CPU all tiers are local RAM, so only the
    modeled numbers reflect the TPU topology).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import build_serving_stack, emit, timeit
from repro.core import (TieredFeatureStore, TopologySpec, degree_placement,
                        freq_placement, hash_placement, monte_carlo_fap,
                        p3_placement, quiver_placement)
from repro.core.placement import TIER_DISK, TIER_HOST, TIER_HOT, TIER_WARM
from repro.serving import pad_to_bucket

TIER_COST = {TIER_HOT: 1.0, TIER_WARM: 16.0, TIER_HOST: 160.0,
             TIER_DISK: 1600.0}


def run() -> None:
    stack = build_serving_stack(nodes=6000, fanouts=(6, 4))
    g, feats, fap = stack["graph"], stack["feats"], stack["fap"]
    topo = TopologySpec(num_pods=2, devices_per_pod=4,
                        rows_per_device=g.num_nodes // 16,
                        rows_host=g.num_nodes // 3,
                        hot_replicate_fraction=0.3)

    # training-frequency baseline: counts from a *uniform* seed workload
    # (the train/serve distribution shift of paper §2.3)
    train_freq = monte_carlo_fap(g, stack["fanouts"], requests=1500, seed=9)

    plans = {
        "quiver": quiver_placement(fap, topo),
        "hash": hash_placement(g.num_nodes, topo),
        "degree": degree_placement(g.out_degree, topo),
        "freq": freq_placement(train_freq, topo),
        "p3": p3_placement(g.num_nodes, topo),
    }

    # serving workload: ids actually touched by sampled requests
    stack["gen"].rng = np.random.default_rng(3)
    from repro.graph import host_sample
    rng = np.random.default_rng(4)
    touched = []
    for r in stack["gen"].stream(120, seeds_per_request=8):
        hops = host_sample(rng, g, r.seeds, stack["fanouts"])
        t = np.concatenate(hops)
        touched.append(t[t >= 0])

    for name, plan in plans.items():
        if plan.dim_sharded:
            # P3: every row is split across all G devices → (G-1)/G of each
            # row's bytes cross ICI on every fetch, no cold tier
            g_dev = topo.devices_per_pod
            per_row = (TIER_COST[TIER_WARM] * (g_dev - 1) / g_dev
                       + TIER_COST[TIER_HOT] / g_dev)
            costs = [len(t) * per_row for t in touched]
            emit(f"placement/{name}_mean_cost", float(np.mean(costs)),
                 "modeled;dim-sharded")
            emit(f"placement/{name}_p95_tail_tier", TIER_COST[TIER_WARM],
                 "every fetch crosses ICI")
            continue
        costs = [float(TIER_COST[TIER_HOT] * 0 + sum(
            TIER_COST[x] for x in plan.tier[t])) for t in touched]
        tails = [float(max(TIER_COST[x] for x in np.unique(plan.tier[t])))
                 for t in touched]
        store = TieredFeatureStore.build(feats, plan)
        # bucket-pad the measured id vector the same way the serving-layer
        # executors do, so every policy is timed at an identical jit shape
        ids = jnp.asarray(pad_to_bucket(touched[0][:512].astype(np.int32)))
        t_lookup = timeit(lambda: store.lookup(ids, include_host=False),
                          repeats=3)
        hist = store.tier_histogram(np.concatenate(touched))
        tot = sum(hist.values())
        emit(f"placement/{name}_mean_cost", float(np.mean(costs)),
             f"hot%={hist['hot']/tot:.2f};warm%={hist['warm']/tot:.2f};"
             f"disk%={hist['disk']/tot:.3f}")
        emit(f"placement/{name}_p95_tail_tier",
             float(np.quantile(tails, 0.95)), "slowest tier gating batch")
        emit(f"placement/{name}_lookup_us", t_lookup * 1e6,
             "measured device-tier path")


if __name__ == "__main__":
    run()

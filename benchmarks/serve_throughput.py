"""Fig. 9 analogue: throughput vs p99 latency — Quiver's PSGS-hybrid
scheduler vs static CPU-only / device-only execution, through the
executor-graph serving engine."""
from __future__ import annotations

import numpy as np

from benchmarks.common import build_serving_stack, emit, make_engine
from repro.serving import HybridScheduler, StaticScheduler


def run() -> None:
    stack = build_serving_stack(nodes=5000)
    psgs = stack["psgs"]
    gen = stack["gen"]
    n_req, per = 60, 8

    for name, router_fn in (
            ("quiver", lambda: HybridScheduler(psgs, float(np.median(psgs))
                                               * per * 2)),
            ("host_only", lambda: StaticScheduler("host")),
            ("device_only", lambda: StaticScheduler("device"))):
        engine = make_engine(stack, router_fn(), num_workers=2, max_batch=32)
        gen.rng = np.random.default_rng(7)  # same workload for all systems
        batches = [[r] for r in gen.stream(n_req, seeds_per_request=per)]
        engine.warmup(batches[0])  # compile every executor outside measurement
        m = engine.run(batches)
        s = m.summary()
        emit(f"serve_throughput/{name}_rps", s["throughput_rps"],
             f"p99={s['p99_ms']:.1f}ms;host={s['routed_host']};"
             f"dev={s['routed_device']}")


if __name__ == "__main__":
    run()

"""Fused feature-collection benchmark: per-hop lookups vs lookup_hops.

Quiver's throughput case rests on cheap feature aggregation: the serving
executors used to collect features with one ``store.lookup(h)`` per hop —
2·(L+1) tier gathers plus (L+1) host round-trips per sample. The fused path
(``TieredFeatureStore.lookup_hops``) deduplicates ids once across hops and
issues ONE address-sorted ``tiered_gather`` dispatch for the device tiers
plus ONE host callback. This benchmark reports, on the serve_throughput
workload:

  1. dispatch counts per sample, per-hop vs fused (the structural win),
  2. store-level feature-collection latency for both paths,
  3. end-to-end serving throughput/p99 with executors flipped between the
     legacy and the fused path, plus a fused + micro-batched stream run
     (the PSGS-aware coalescing stage that feeds the gather big batches).

    PYTHONPATH=src python benchmarks/fused_gather.py [--dry-run]

``--dry-run`` shrinks every dimension so CI can smoke the full path.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

if __package__ in (None, ""):  # direct `python benchmarks/fused_gather.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

import jax
import numpy as np

from benchmarks.common import (build_serving_stack, emit, make_engine,
                               timeit, write_bench_json)
from repro.core import DynamicBatcher, MicroBatcher
from repro.graph.sampler import host_sample_dense
from repro.serving import HybridScheduler, pad_to_bucket


def _sample_hops(stack, seeds: np.ndarray, seed: int = 0):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    hops = host_sample_dense(rng, stack["graph"],
                             pad_to_bucket(seeds.astype(np.int32)),
                             stack["fanouts"])
    return [jnp.asarray(h) for h in hops]


def run(dry_run: bool = False) -> dict:
    nodes = 800 if dry_run else 5000
    n_req, per = (10, 8) if dry_run else (60, 8)
    stack = build_serving_stack(nodes=nodes)
    store, psgs, gen = stack["store"], stack["psgs"], stack["gen"]
    results: dict = {}

    # -- 1) dispatch counts per sample ---------------------------------------
    hops = _sample_hops(stack, gen.make_request(per).seeds)
    store.reset_stats()
    per_hop_feats = [store.lookup(h) for h in hops]
    jax.block_until_ready(per_hop_feats)
    d_old = store.reset_stats()
    fused_feats = store.lookup_hops(hops)
    jax.block_until_ready(fused_feats)
    d_new = store.reset_stats()
    old_n = d_old["device_gathers"] + d_old["host_fetches"]
    new_n = d_new["device_gathers"] + d_new["host_fetches"]
    results["dispatches"] = {"per_hop": old_n, "fused": new_n}
    emit("fused_gather/dispatches_per_sample", float(new_n),
         f"per_hop={old_n};reduction={old_n / max(new_n, 1):.1f}x")

    # -- 2) store-level feature-collection latency ---------------------------
    store.reset_stats()  # phase boundary: phase 1's probes must not bleed in
    t_old = timeit(lambda: [store.lookup(h) for h in hops])
    t_new = timeit(lambda: store.lookup_hops(hops))
    results["collect_us"] = {"per_hop": t_old * 1e6, "fused": t_new * 1e6}
    emit("fused_gather/collect_per_hop_us", t_old * 1e6)
    emit("fused_gather/collect_fused_us", t_new * 1e6,
         f"speedup={t_old / max(t_new, 1e-12):.2f}x")
    store.reset_stats()  # phase boundary: drop the timing loops' dispatches

    # -- 3) end-to-end serving: legacy vs fused vs fused+micro ---------------
    thr = float(np.median(psgs)) * per * 2
    for mode in ("per_hop", "fused", "fused_micro"):
        engine = make_engine(stack, HybridScheduler(psgs, thr),
                             num_workers=2, max_batch=32,
                             fused=mode != "per_hop")
        gen.rng = np.random.default_rng(7)  # same workload for all modes
        reqs = list(gen.stream(n_req, seeds_per_request=per))
        engine.warmup([reqs[0]])
        store.reset_stats()
        if mode == "fused_micro":
            micro = MicroBatcher(deadline_s=0.004, max_seeds=4 * per,
                                 psgs_table=psgs)
            m = engine.serve_stream(reqs, DynamicBatcher(deadline_s=0.0,
                                                         max_batch=1),
                                    micro=micro)
            extra = f";super_batches={micro.emitted}"
        else:
            m = engine.run([[r] for r in reqs])
            extra = ""
        stats = store.reset_stats()
        s = m.summary()
        results[mode] = {"rps": s["throughput_rps"], "p99_ms": s["p99_ms"],
                         "dispatches": stats["device_gathers"]
                         + stats["host_fetches"]}
        emit(f"fused_gather/{mode}_rps", s["throughput_rps"],
             f"p99={s['p99_ms']:.1f}ms;"
             f"dispatches={results[mode]['dispatches']}" + extra)
        engine.close()

    win = results["fused"]["rps"] / max(results["per_hop"]["rps"], 1e-9)
    emit("fused_gather/serve_speedup_x", win,
         "fused vs per-hop end-to-end throughput")
    results["serve_speedup_x"] = win
    write_bench_json("fused_gather", results)
    return results


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--dry-run", action="store_true",
                   help="tiny sizes; CI smoke for the full fused path")
    args = p.parse_args()
    t0 = time.time()
    results = run(dry_run=args.dry_run)
    d = results["dispatches"]
    print(f"# fused path: {d['per_hop']} -> {d['fused']} dispatches/sample, "
          f"serve speedup {results['fused']['rps'] / max(results['per_hop']['rps'], 1e-9):.2f}x "
          f"({time.time() - t0:.1f}s)")


if __name__ == "__main__":
    main()

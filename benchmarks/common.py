"""Shared benchmark harness utilities."""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (TieredFeatureStore, TopologySpec, WorkloadGenerator,
                        compute_fap, compute_psgs, quiver_placement)
from repro.graph import power_law_graph
from repro.models.gnn_basic import sage_init, sage_layered
from repro.serving import DeviceExecutor, HostExecutor, ServingEngine

ROWS: list[tuple] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.2f},{derived}")


def timeit(fn: Callable, *args, repeats: int = 5, warmup: int = 2) -> float:
    """Median wall-time in seconds."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def build_serving_stack(*, nodes: int = 6000, avg_degree: float = 10.0,
                        d_feat: int = 64, fanouts=(6, 4), seed: int = 0,
                        hot_frac: float = 0.25, rows_frac: float = 0.25,
                        distribution: str = "degree"):
    """Small but skewed end-to-end stack shared by the serving benchmarks."""
    graph = power_law_graph(nodes, avg_degree, seed=seed)
    rng = np.random.default_rng(seed + 1)
    feats = rng.normal(size=(nodes, d_feat)).astype(np.float32)
    psgs = compute_psgs(graph, fanouts)
    gen = WorkloadGenerator(nodes, graph.out_degree,
                            distribution=distribution, seed=seed + 2)
    fap = compute_fap(graph, fanouts, seed_prob=gen.p)
    topo = TopologySpec(num_pods=1, devices_per_pod=1,
                        rows_per_device=max(int(nodes * rows_frac), 64),
                        rows_host=max(int(nodes * 0.4), 64),
                        hot_replicate_fraction=hot_frac)
    store = TieredFeatureStore.build(feats, quiver_placement(fap, topo))
    params = sage_init(jax.random.key(seed), [d_feat, 64, 64])

    @jax.jit
    def infer_fn(hop_feats, hop_ids):
        masks = [(h >= 0).astype(jnp.float32)[:, None] for h in hop_ids]
        return sage_layered(params, hop_feats, fanouts, hop_masks=masks)

    return dict(graph=graph, feats=feats, psgs=psgs, fap=fap, gen=gen,
                store=store, infer_fn=infer_fn, fanouts=fanouts, topo=topo)


def make_executors(stack, *, num_workers: int = 2, max_batch: int = 128,
                   fused: bool = True):
    """Host + device executor pair over a built stack (executor-graph API).
    ``fused=False`` selects the legacy per-hop feature-collection path."""
    g = stack["graph"]
    host = HostExecutor(g, stack["store"], stack["fanouts"],
                        stack["infer_fn"], capacity=num_workers,
                        psgs_table=stack["psgs"], fused=fused)
    device = DeviceExecutor(g.device_arrays(), stack["store"],
                            stack["fanouts"], stack["infer_fn"],
                            max_batch=max_batch, capacity=num_workers,
                            psgs_table=stack["psgs"], fused=fused)
    return {"host": host, "device": device}


def make_engine(stack, router, *, num_workers: int = 2, max_batch: int = 128,
                max_inflight: int = 64, admission: str = "wait",
                fused: bool = True) -> ServingEngine:
    return ServingEngine(
        make_executors(stack, num_workers=num_workers, max_batch=max_batch,
                       fused=fused),
        router, max_inflight=max_inflight, admission=admission)

"""Shared benchmark harness utilities."""
from __future__ import annotations

import json
import os
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (TieredFeatureStore, TopologySpec, WorkloadGenerator,
                        compute_fap, compute_psgs, quiver_placement)
from repro.graph import power_law_graph
from repro.models.gnn_basic import sage_init, sage_layered
from repro.serving import DeviceExecutor, HostExecutor, ServingEngine

ROWS: list[tuple] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.2f},{derived}")


def write_bench_json(name: str, payload: dict, out_dir: str | None = None
                     ) -> str:
    """Write a machine-readable benchmark result to ``BENCH_<name>.json``
    (throughput, latency percentiles, host callbacks per request, ...) so
    the perf trajectory is trackable across PRs. ``out_dir`` defaults to
    ``$BENCH_JSON_DIR`` or the current directory; returns the path."""
    out_dir = out_dir or os.environ.get("BENCH_JSON_DIR", ".")
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def latency_percentiles(metrics) -> dict:
    """p50/p95/p99 (ms) from a ServeMetrics' raw latency samples — the
    summary() block reports p50/p99 only, benchmarks also track p95."""
    lat = np.asarray(metrics.latencies if metrics.latencies else [0.0])
    return {f"p{int(q * 100)}_ms": float(np.quantile(lat, q) * 1e3)
            for q in (0.5, 0.95, 0.99)}


def timeit(fn: Callable, *args, repeats: int = 5, warmup: int = 2) -> float:
    """Median wall-time in seconds."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def build_serving_stack(*, nodes: int = 6000, avg_degree: float = 10.0,
                        d_feat: int = 64, fanouts=(6, 4), seed: int = 0,
                        hot_frac: float = 0.25, rows_frac: float = 0.25,
                        distribution: str = "degree",
                        spill_path: str | None = None):
    """Small but skewed end-to-end stack shared by the serving benchmarks.
    ``spill_path`` backs the DISK tier with a real mmap spill file."""
    graph = power_law_graph(nodes, avg_degree, seed=seed)
    rng = np.random.default_rng(seed + 1)
    feats = rng.normal(size=(nodes, d_feat)).astype(np.float32)
    psgs = compute_psgs(graph, fanouts)
    gen = WorkloadGenerator(nodes, graph.out_degree,
                            distribution=distribution, seed=seed + 2)
    fap = compute_fap(graph, fanouts, seed_prob=gen.p)
    topo = TopologySpec(num_pods=1, devices_per_pod=1,
                        rows_per_device=max(int(nodes * rows_frac), 64),
                        rows_host=max(int(nodes * 0.4), 64),
                        hot_replicate_fraction=hot_frac)
    store = TieredFeatureStore.build(feats, quiver_placement(fap, topo),
                                     spill_path=spill_path)
    params = sage_init(jax.random.key(seed), [d_feat, 64, 64])

    @jax.jit
    def infer_fn(hop_feats, hop_ids, deep_agg=None):
        masks = [(h >= 0).astype(jnp.float32)[:, None] for h in hop_ids]
        return sage_layered(params, hop_feats, fanouts, hop_masks=masks,
                            deep_agg=deep_agg)

    return dict(graph=graph, feats=feats, psgs=psgs, fap=fap, gen=gen,
                store=store, infer_fn=infer_fn, fanouts=fanouts, topo=topo)


def make_model_infer_fn(stack, hidden: tuple[int, ...] = (64, 64), *,
                        seed: int = 0):
    """Another jitted GraphSAGE ``infer_fn`` over the stack's fanouts —
    multi-model benchmarks give each co-served model its own widths.
    Delegates to the launcher's builder so the two stay one definition."""
    from repro.launch.serve import make_infer_fn
    return make_infer_fn(stack["feats"].shape[1], tuple(hidden),
                         stack["fanouts"], seed)


def store_bytes(store) -> int:
    """Resident bytes of a tiered store's feature arrays (all tiers) —
    the shared-store-vs-isolated-engines memory comparison signal. A
    spill-backed DISK tier reports only its RAM overlay
    (``resident_nbytes``): the memmap pages live on disk and materializing
    them here would both misreport and read the whole file."""
    total = 0
    for a in (store.hot, store.warm, store.host, store.disk):
        resident = getattr(a, "resident_nbytes", None)
        total += int(resident if resident is not None
                     else np.asarray(a).nbytes)
    return total


def make_executors(stack, *, num_workers: int = 2, max_batch: int = 128,
                   fused: bool = True, fuse_aggregate: bool = False,
                   infer_fn=None, store=None, rng_seed: int = 0):
    """Host + device executor pair over a built stack (executor-graph API).
    ``fused=False`` selects the legacy per-hop feature-collection path;
    ``fuse_aggregate=True`` the gather→aggregate fast path
    (``store.lookup_aggregate``); ``infer_fn``/``store`` override the
    stack's (multi-model benchmarks build one executor pair per model over
    the shared store)."""
    g = stack["graph"]
    infer_fn = infer_fn if infer_fn is not None else stack["infer_fn"]
    store = store if store is not None else stack["store"]
    host = HostExecutor(g, store, stack["fanouts"], infer_fn,
                        capacity=num_workers, psgs_table=stack["psgs"],
                        fused=fused, fuse_aggregate=fuse_aggregate,
                        rng_seed=rng_seed)
    device = DeviceExecutor(g.device_arrays(), store, stack["fanouts"],
                            infer_fn, max_batch=max_batch,
                            capacity=num_workers, psgs_table=stack["psgs"],
                            fused=fused, fuse_aggregate=fuse_aggregate,
                            rng_seed=rng_seed)
    return {"host": host, "device": device}


def make_engine(stack, router, *, num_workers: int = 2, max_batch: int = 128,
                max_inflight: int = 64, admission: str = "wait",
                fused: bool = True,
                fuse_aggregate: bool = False) -> ServingEngine:
    return ServingEngine(
        make_executors(stack, num_workers=num_workers, max_batch=max_batch,
                       fused=fused, fuse_aggregate=fuse_aggregate),
        router, max_inflight=max_inflight, admission=admission)

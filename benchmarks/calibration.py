"""Fig. 6 analogue: measured per-executor latency vs accumulated PSGS and the
four crossover operating points, via the N-way executor calibration."""
from __future__ import annotations

import numpy as np

from benchmarks.common import build_serving_stack, emit, make_executors
from repro.serving import CalibrationResult, calibrate_executors


def run() -> None:
    stack = build_serving_stack(nodes=5000)
    executors = make_executors(stack, num_workers=1, max_batch=64)
    psgs = stack["psgs"]
    order = np.argsort(psgs)
    batches = [order[int(q * len(order)):][:32].astype(np.int64)
               for q in np.linspace(0.05, 0.95, 8)]
    curves = calibrate_executors(executors, batches, psgs, repeats=3)
    calib = CalibrationResult(host=curves["host"], device=curves["device"])
    for q in (0.2, 0.5, 0.9):
        x = float(np.quantile(psgs, q) * 32)
        emit(f"calibration/host_avg_ms_q{int(q*100)}",
             calib.host.eval_avg(x) * 1e6, f"psgs={x:.0f}")
        emit(f"calibration/device_avg_ms_q{int(q*100)}",
             calib.device.eval_avg(x) * 1e6, f"psgs={x:.0f}")
    for policy in ("cpu_preferred", "gpu_preferred", "latency_preferred",
                   "throughput_preferred"):
        emit(f"calibration/threshold_{policy}", calib.threshold(policy),
             "accumulated-PSGS crossover")


if __name__ == "__main__":
    run()

"""Multi-model serving benchmark: one shared-store registry vs N isolated
engines.

Real deployments co-serve several GNNs over one graph and one feature
store. The registry path (`ModelRegistry` + one `ServingEngine`) shares the
store, the samplers and the admission window across models while keeping
calibration and routing per model; the naive alternative runs one engine
per model, each with its *own copy* of the feature store. This benchmark
reports, on a 2-model mix (a small and a wide GraphSAGE):

  1. per-model PSGS cut-points (`CostModelRouter.crossover`) — the routing
     divergence that makes per-model calibration matter,
  2. feature-store memory: one shared store vs per-engine copies,
  3. throughput of the interleaved 2-model stream through the shared
     engine vs the same requests through two isolated engines.

    PYTHONPATH=src python benchmarks/multi_model.py [--dry-run]

``--dry-run`` shrinks every dimension so CI can smoke the full path.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

if __package__ in (None, ""):  # direct `python benchmarks/multi_model.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

import numpy as np

from benchmarks.common import (build_serving_stack, emit, make_executors,
                               make_model_infer_fn, store_bytes)
from repro.core import TieredFeatureStore
from repro.serving import (CostModelRouter, ModelRegistry, ServingEngine,
                           calibrate_executors)

MODELS = {"small": (32, 32), "wide": (128, 128)}


def _probe_batches(psgs: np.ndarray, per: int) -> list[np.ndarray]:
    order = np.argsort(psgs)
    return [order[int(q * order.size):][:per].astype(np.int64)
            for q in np.linspace(0.05, 0.95, 6)]


def run(dry_run: bool = False) -> dict:
    nodes = 800 if dry_run else 4000
    n_req, per = (12, 6) if dry_run else (80, 8)
    stack = build_serving_stack(nodes=nodes)
    psgs, gen, store = stack["psgs"], stack["gen"], stack["store"]
    batches = _probe_batches(psgs, per)
    results: dict = {}

    # -- shared-store registry: one engine, two models -----------------------
    infer_fns = {m: make_model_infer_fn(stack, hidden, seed=i)
                 for i, (m, hidden) in enumerate(MODELS.items())}
    registry = ModelRegistry()
    curves_by_model = {}
    for i, m in enumerate(MODELS):
        ex = make_executors(stack, num_workers=2, max_batch=32,
                            infer_fn=infer_fns[m], rng_seed=i)
        curves = calibrate_executors(ex, batches, psgs, repeats=2)
        curves_by_model[m] = curves
        router = CostModelRouter.from_curves(psgs, curves,
                                             "latency_preferred",
                                             executors=ex)
        registry.register(m, ex, router, infer_fn=infer_fns[m])
        cut = router.crossover("host", "device")
        results.setdefault("cutpoints", {})[m] = cut
        emit(f"multi_model/cutpoint_{m}", cut,
             "host/device PSGS crossover (per-model calibration)")

    shared = ServingEngine(registry, max_inflight=32)
    gen.rng = np.random.default_rng(11)
    reqs = list(gen.stream(n_req, seeds_per_request=per,
                           models=list(MODELS)))
    shared.warmup([reqs[0]])
    m_shared = shared.run([[r] for r in reqs])
    s = m_shared.summary()
    results["shared"] = {"rps": s["throughput_rps"], "p99_ms": s["p99_ms"],
                         "models": s["models"]}
    emit("multi_model/shared_rps", s["throughput_rps"],
         f"p99={s['p99_ms']:.1f}ms;interleaved {len(MODELS)}-model stream")
    shared.close()

    # -- isolated engines: one store COPY + one engine per model -------------
    iso_stores = {m: TieredFeatureStore.build(stack["feats"], store.plan)
                  for m in MODELS}
    t_iso = 0.0
    iso_requests = 0
    for i, m in enumerate(MODELS):
        ex = make_executors(stack, num_workers=2, max_batch=32,
                            infer_fn=infer_fns[m], store=iso_stores[m],
                            rng_seed=i)
        router = CostModelRouter.from_curves(psgs, curves_by_model[m],
                                             "latency_preferred",
                                             executors=ex)
        engine = ServingEngine(ex, router, max_inflight=32)
        gen.rng = np.random.default_rng(11)  # same workload as shared mode
        mine = [r for r in gen.stream(n_req, seeds_per_request=per,
                                      models=list(MODELS)) if r.model == m]
        for r in mine:
            r.model = "default"  # isolated engines are single-model
        engine.warmup([mine[0]])
        mm = engine.run([[r] for r in mine])
        t_iso += mm.finished - mm.started
        iso_requests += mm.requests
        engine.close()
    iso_rps = iso_requests / max(t_iso, 1e-9)
    results["isolated"] = {"rps": iso_rps}
    emit("multi_model/isolated_rps", iso_rps,
         f"{len(MODELS)} single-model engines, per-engine store copies")

    # -- memory: shared store vs per-engine copies ---------------------------
    mem_shared = store_bytes(store)
    mem_iso = sum(store_bytes(st) for st in iso_stores.values())
    results["store_mb"] = {"shared": mem_shared / 2**20,
                           "isolated": mem_iso / 2**20}
    emit("multi_model/store_shared_mb", mem_shared / 2**20,
         f"isolated={mem_iso / 2**20:.1f}MB;"
         f"saving={(1 - mem_shared / max(mem_iso, 1)) * 100:.0f}%")
    emit("multi_model/throughput_ratio_x",
         s["throughput_rps"] / max(iso_rps, 1e-9),
         "shared registry vs isolated engines on the same request mix")
    return results


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--dry-run", action="store_true",
                   help="tiny sizes; CI smoke for the full multi-model path")
    args = p.parse_args()
    t0 = time.time()
    r = run(dry_run=args.dry_run)
    cuts = ", ".join(f"{m}={c:.1f}" for m, c in r["cutpoints"].items())
    print(f"# multi-model: cutpoints [{cuts}], shared "
          f"{r['shared']['rps']:.1f} rps vs isolated "
          f"{r['isolated']['rps']:.1f} rps, store "
          f"{r['store_mb']['shared']:.1f}MB vs "
          f"{r['store_mb']['isolated']:.1f}MB ({time.time() - t0:.1f}s)")


if __name__ == "__main__":
    main()

"""Generate the EXPERIMENTS.md §Dry-run/§Roofline tables from artifacts."""
from __future__ import annotations

import json
import sys


def load(path):
    return {(r["arch"], r["shape"], r["mesh"]): r
            for r in json.load(open(path)) if r.get("ok")}


def dryrun_table(path="artifacts/dryrun.json") -> str:
    recs = load(path)
    lines = ["| arch | shape | mesh | compile s | peak HBM GiB/dev | "
             "HLO GFLOP/dev† | HLO GB/dev† | collective GB/dev† | "
             "loop× | collectives (ag/ar/rs/a2a/cp) |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for (a, s, m), r in sorted(recs.items()):
        c = r["collectives"]["counts"]
        lines.append(
            f"| {a} | {s} | {m} | {r['compile_s']:.1f} "
            f"| {r['memory']['peak_hbm_bytes']/2**30:.2f} "
            f"| {r['cost']['flops']/1e9:.1f} "
            f"| {r['cost']['bytes_accessed']/1e9:.1f} "
            f"| {r['collectives']['total_bytes']/1e9:.2f} "
            f"| {r.get('loop_factor', 1)} "
            f"| {c['all-gather']}/{c['all-reduce']}/{c['reduce-scatter']}"
            f"/{c['all-to-all']}/{c['collective-permute']} |")
    return "\n".join(lines)


def roofline_table(path="artifacts/dryrun.json") -> str:
    recs = load(path)
    from benchmarks.roofline import model_flops
    lines = ["| arch | shape | mesh | compute ms* | memory ms* | "
             "collective ms* | dominant | step LB ms* | model/HLO FLOPs* |",
             "|---|---|---|---|---|---|---|---|---|"]
    for (a, s, m), r in sorted(recs.items()):
        ro = r.get("roofline_corrected", r["roofline"])
        try:
            mf = model_flops(a, s) / r["world"]
            ratio = mf / max(r["cost"]["flops"]
                             * r.get("loop_factor", 1), 1.0)
            ratio = f"{ratio:.2f}"
        except Exception:
            ratio = "–"
        lines.append(
            f"| {a} | {s} | {m} | {ro['compute_s']*1e3:.2f} "
            f"| {ro['memory_s']*1e3:.2f} | {ro['collective_s']*1e3:.2f} "
            f"| {ro['dominant'].replace('_s','')} "
            f"| {ro['step_lower_bound_s']*1e3:.2f} | {ratio} |")
    return "\n".join(lines)


def before_after(baseline="artifacts/dryrun_baseline.json",
                 current="artifacts/dryrun.json") -> str:
    b = load(baseline)
    c = load(current)
    lines = ["| cell | metric | baseline | optimized | Δ |",
             "|---|---|---|---|---|"]
    cells = [("equiformer-v2", "ogb_products", "16x16"),
             ("qwen1.5-4b", "decode_32k", "16x16"),
             ("qwen1.5-4b", "long_500k", "16x16"),
             ("gin-tu", "ogb_products", "16x16"),
             ("qwen3-4b", "decode_32k", "16x16"),
             ("phi3.5-moe-42b", "decode_32k", "16x16")]
    for cell in cells:
        if cell not in b or cell not in c:
            continue
        rb, rc = b[cell], c[cell]
        rows = [
            ("peak HBM GiB/dev", rb["memory"]["peak_hbm_bytes"] / 2**30,
             rc["memory"]["peak_hbm_bytes"] / 2**30),
            ("collective GB/dev", rb["collectives"]["total_bytes"] / 1e9,
             rc["collectives"]["total_bytes"] / 1e9),
            ("memory-term ms", rb["roofline"]["memory_s"] * 1e3,
             rc["roofline"]["memory_s"] * 1e3),
        ]
        for name, vb, vc in rows:
            d = vb / vc if vc > 0 else float("inf")
            lines.append(f"| {cell[0]}×{cell[1]} | {name} | {vb:.2f} "
                         f"| {vc:.2f} | {d:.1f}× |")
    return "\n".join(lines)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("dryrun", "all"):
        print("## Dry-run\n")
        print(dryrun_table())
    if which in ("roofline", "all"):
        print("\n## Roofline\n")
        print(roofline_table())
    if which in ("delta", "all"):
        print("\n## Before/after\n")
        print(before_after())

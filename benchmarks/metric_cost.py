"""Tab. 1-adjacent: PSGS/FAP precompute cost and lookup-table memory vs
graph size (paper claims minutes for 100M+ nodes on GPU; we verify the
O(K·|E|) scaling on CPU)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import compute_fap, compute_psgs
from repro.graph import power_law_graph


def run() -> None:
    for n in (2000, 20000, 100000):
        g = power_law_graph(n, 12.0, seed=0)
        t_psgs = timeit(lambda: compute_psgs(g, (25, 10)), repeats=3,
                        warmup=1)
        t_fap = timeit(lambda: compute_fap(g, (25, 10)), repeats=3, warmup=1)
        emit(f"metric_cost/psgs_us_n{n}", t_psgs * 1e6,
             f"edges={g.num_edges};table_MB={n*4/2**20:.2f}")
        emit(f"metric_cost/fap_us_n{n}", t_fap * 1e6, "")
        emit(f"metric_cost/psgs_us_per_edge_n{n}",
             t_psgs * 1e6 / g.num_edges, "O(K|E|) check")


if __name__ == "__main__":
    run()

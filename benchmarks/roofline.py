"""§Roofline report generator: per (arch × shape × mesh) the three terms,
dominant bottleneck, MODEL_FLOPS vs HLO_FLOPs ratio, and a markdown table
for EXPERIMENTS.md."""
from __future__ import annotations

import json
import os

from benchmarks.common import emit
from repro.configs import get_arch
from repro.launch.hlo_analysis import PEAK_FLOPS

# analytic MODEL_FLOPS per cell: 6·N·D for LM train, 2·N_active·tokens for
# serve; GNN/recsys use 2·(edge_params·E + node_params·N)·(3 if train)
def model_flops(arch: str, shape: str) -> float:
    from repro.configs import (codeqwen15_7b, deepseek_moe_16b, din,
                               phi35_moe_42b, qwen15_4b, qwen3_4b)
    from repro.configs.gnn_common import SHAPES as GNN_SHAPES
    from repro.configs.lm_common import SHAPES as LM_SHAPES
    from repro.models.common import count_params
    from repro.models.transformer import lm_active_param_count
    import jax

    lm = {"qwen1.5-4b": qwen15_4b.CONFIG, "qwen3-4b": qwen3_4b.CONFIG,
          "codeqwen1.5-7b": codeqwen15_7b.CONFIG,
          "deepseek-moe-16b": deepseek_moe_16b.CONFIG,
          "phi3.5-moe-42b": phi35_moe_42b.CONFIG}
    if arch in lm:
        cfg = lm[arch]
        n_active = lm_active_param_count(cfg)
        info = LM_SHAPES[shape]
        if info["kind"] == "train":
            return 6.0 * n_active * info["batch"] * info["seq"]
        if info["kind"] == "prefill":
            return 2.0 * n_active * info["batch"] * info["seq"]
        return 2.0 * n_active * info["batch"]  # decode: one token per seq
    if arch == "din":
        from repro.configs.din import CONFIG, SHAPES
        import jax
        dense_params = 3.3e5  # attention+main MLP params (embed excluded)
        info = SHAPES[shape]
        n = info.get("candidates", info["batch"]) * CONFIG.hist_len
        mult = 3.0 if info["kind"] == "train" else 1.0
        return 2.0 * dense_params * n * mult
    # GNN: parameters touched per edge and node
    a = get_arch(arch)
    info = GNN_SHAPES[shape]
    import jax
    params_a = jax.eval_shape(
        lambda: __import__("repro.configs." + arch.replace("-", "_").replace(".", "_"),
                           fromlist=["_init"])._init(
            jax.random.key(0), info["d_feat"],
            info["classes"] or 1, shape))
    import numpy as np
    p = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params_a))
    # message passing touches edge-side weights E times, node-side N times;
    # crude but consistent across iterations: 2·P·(N+E)/L_scale ·3 (train)
    return 2.0 * p * (info["nodes"] + info["edges"]) / 10.0 * 3.0


def run(path: str = "artifacts/dryrun.json") -> None:
    if not os.path.exists(path):
        print(f"roofline/skipped,0,{path} missing")
        return
    recs = [r for r in json.load(open(path)) if r["ok"]]
    for r in recs:
        ro = r["roofline"]
        try:
            mf = model_flops(r["arch"], r["shape"]) / r["world"]
            ratio = mf / max(r["cost"]["flops"], 1.0)
        except Exception:
            ratio = float("nan")
        emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
             ro["step_lower_bound_s"] * 1e6,
             f"dom={ro['dominant']};frac={ro['roofline_fraction']:.2f};"
             f"model/hlo_flops={ratio:.2f}")


def markdown_table(path: str = "artifacts/dryrun.json") -> str:
    recs = [r for r in json.load(open(path)) if r["ok"]]
    lines = ["| arch | shape | mesh | compute (ms) | memory (ms) | "
             "collective (ms) | dominant | HBM GiB/dev | model/HLO FLOPs |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        ro = r["roofline"]
        try:
            mf = model_flops(r["arch"], r["shape"]) / r["world"]
            ratio = f"{mf / max(r['cost']['flops'], 1.0):.2f}"
        except Exception:
            ratio = "–"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {ro['compute_s']*1e3:.2f} | {ro['memory_s']*1e3:.2f} "
            f"| {ro['collective_s']*1e3:.2f} | {ro['dominant'].replace('_s','')} "
            f"| {r['memory']['peak_hbm_bytes']/2**30:.2f} | {ratio} |")
    return "\n".join(lines)


if __name__ == "__main__":
    run()

"""Fig. 10 analogue: latency predictability under PSGS-Strict / PSGS-Loose /
Batchsize-Bound batching.

The paper's claim is that cost-aware (PSGS-budget) batches have *predictable*
processing latency while fixed-size batches inherit the per-request cost
variance. On this CPU container the per-batch fixed overhead (~50 ms of
Python/jit dispatch) would drown queueing comparisons, so we measure the
claim directly: the distribution of realized per-batch processing time for
batch compositions produced by each policy (same request stream, same
executor). PSGS budgeting should compress p99/p50 and the coefficient of
variation; Batchsize-Bound should not. End-to-end stream numbers are
reported as a secondary view.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import build_serving_stack, emit, make_executors, timeit
from repro.core import DynamicBatcher


def _compose(batcher, requests):
    batches = []
    for r in requests:
        out = batcher.add(r)
        if out:
            batches.append(out)
    tail = batcher.flush()
    if tail:
        batches.append(tail)
    return batches


def run() -> None:
    stack = build_serving_stack(nodes=5000, fanouts=(25, 10),
                                distribution="uniform")
    psgs = stack["psgs"]
    med = float(np.median(psgs))
    stack["gen"].rng = np.random.default_rng(11)
    requests = list(stack["gen"].stream(256, seeds_per_request=1))

    host = make_executors(stack, num_workers=1, max_batch=64)["host"]
    host.warmup(requests[0].seeds)

    policies = {
        "psgs_strict": DynamicBatcher(deadline_s=1e9, psgs_budget=med * 16,
                                      psgs_table=psgs, max_batch=64),
        "psgs_loose": DynamicBatcher(deadline_s=1e9, psgs_budget=med * 48,
                                     psgs_table=psgs, max_batch=64),
        "batchsize_bound": DynamicBatcher(deadline_s=1e9, max_batch=16),
    }
    for name, batcher in policies.items():
        batches = _compose(batcher, list(requests))
        times, works = [], []
        for b in batches:
            seeds = np.concatenate([r.seeds for r in b])
            t = timeit(lambda: host.process(seeds), repeats=2,
                       warmup=1)
            times.append(t)
            works.append(float(psgs[seeds].sum()))
        times = np.asarray(times)
        works = np.asarray(works)
        emit(f"policy_cdf/{name}_batch_p50_ms",
             float(np.quantile(times, 0.5) * 1e3),
             f"p99/p50={np.quantile(times,0.99)/np.quantile(times,0.5):.2f};"
             f"cv={times.std()/times.mean():.2f};batches={len(batches)}")
        emit(f"policy_cdf/{name}_work_cv",
             float(works.std() / max(works.mean(), 1e-9)),
             "per-batch accumulated-PSGS spread")


if __name__ == "__main__":
    run()

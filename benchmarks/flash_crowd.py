"""Flash-crowd benchmark: device cache vs adaptive-only between control
steps.

The adaptive controller only reacts at control-step boundaries (every
``interval_batches`` batches): a flash crowd that lands mid-interval pays
the slow-tier price on every request until the next step migrates the rows.
The request-granularity :class:`repro.core.gpu_cache.GPUFeatureCache`
closes that gap — the first miss admits a row into device memory and every
subsequent access is a device-side hit, so critical-path host callbacks
fall within the same interval instead of waiting for migration.

Both modes serve identical seeded streams over identical fresh stacks with
the :class:`AdaptiveController` hooked into the engine; the "cache" mode
additionally attaches a device cache sharing the controller's frequency
sketch. Phase 1 warms the system on the calibrated-for distribution
(crossing one control step); the flash phase then concentrates all seed
mass on cold-tier nodes the sketch has never seen, sized to land entirely
*between* control steps (asserted: the controller's step counter does not
move during it). Host callbacks per request are measured over the second
half of the flash window — the steady state the crowd settles into while
the controller still cannot react — plus latency percentiles and the cache
hit/miss/evict counters. Asserted in-benchmark: the cache strictly reduces
host callbacks in that window, and cached lookups are bit-identical to
uncached (and to an all-HOT reference store).

    PYTHONPATH=src python benchmarks/flash_crowd.py [--dry-run]

``--dry-run`` shrinks every dimension so CI can smoke the full path.
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time

if __package__ in (None, ""):  # direct `python benchmarks/flash_crowd.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (build_serving_stack, emit,
                               latency_percentiles, make_executors,
                               write_bench_json)
from repro.core import GPUFeatureCache, TieredFeatureStore, TopologySpec
from repro.core.placement import TIER_HOST, quiver_placement
from repro.serving import (AdaptiveConfig, AdaptiveController,
                           HybridScheduler, ServingEngine)


def _all_hot_reference(stack) -> TieredFeatureStore:
    """Reference store with every row replicated in HBM (no cold tiers)."""
    nodes = stack["graph"].num_nodes
    topo = TopologySpec(num_pods=1, devices_per_pod=1, rows_per_device=nodes,
                        rows_host=64, hot_replicate_fraction=1.0)
    return TieredFeatureStore.build(stack["feats"],
                                    quiver_placement(stack["fap"], topo))


def _assert_bit_identical(stack, store) -> None:
    """Cached lookups must equal uncached lookups and the all-HOT
    reference bit for bit — after migrations ran and the cache filled."""
    ref = _all_hot_reference(stack)
    rng = np.random.default_rng(13)
    hops = [rng.integers(-1, stack["graph"].num_nodes, n).astype(np.int32)
            for n in (64, 256)]
    cached = [np.asarray(h) for h in store.lookup_hops(hops)]
    cached_flat = np.asarray(store.lookup(jnp.asarray(hops[1])))
    cache, store.cache = store.cache, None  # detach: uncached tier path
    try:
        plain = [np.asarray(h) for h in store.lookup_hops(hops)]
        plain_flat = np.asarray(store.lookup(jnp.asarray(hops[1])))
    finally:
        store.attach_cache(cache)
    want = [np.asarray(h) for h in ref.lookup_hops(hops)]
    for c, p, w in zip(cached, plain, want):
        assert np.array_equal(c, p), "cached lookup_hops != uncached"
        assert np.array_equal(c, w), "cached lookup_hops != all-HOT ref"
    assert np.array_equal(cached_flat, plain_flat), "cached lookup diverged"
    emit("flash_crowd/bit_identical", 1.0,
         "cached == uncached == all-HOT reference")


def flash_hotspot(store, fap, *, size: int) -> np.ndarray:
    """Cold-tier nodes the offline FAP ranked lowest: phase-1 traffic never
    touches them, so migration leaves them cold for the flash phase (also
    reused by ``gateway_soak`` to build its slow-tier overload stream)."""
    tier = np.asarray(store.tier_t)
    cold = np.flatnonzero(tier >= TIER_HOST)
    if cold.size == 0:
        raise RuntimeError("placement has no cold tier; enlarge the graph")
    return cold[np.argsort(np.asarray(fap)[cold])][:size]


def run(dry_run: bool = False) -> dict:
    nodes = 600 if dry_run else 4000
    per = 8
    fanouts = (4, 3) if dry_run else (6, 4)
    interval = 10 if dry_run else 24
    n_warm, n_flash = (interval, (interval - 2) // 2)
    hotspot_size = 4 if dry_run else 8
    spill = tempfile.NamedTemporaryFile(suffix=".spill", delete=False)
    spill.close()
    results: dict = {}
    try:
        for mode in ("adaptive", "cache"):
            # fresh stack per mode (same seed -> identical plan/workload);
            # small HBM tiers so the flash crowd really lands on cold tiers
            stack = build_serving_stack(nodes=nodes, fanouts=fanouts, seed=0,
                                        distribution="zipf", rows_frac=0.1,
                                        spill_path=spill.name)
            store, psgs, gen = stack["store"], stack["psgs"], stack["gen"]
            executors = make_executors(stack, num_workers=2, max_batch=32)
            router = HybridScheduler(psgs, float(np.median(psgs)) * per * 2)
            # router=None: the HybridScheduler has no cost curves to refit;
            # the controller still does sketch/migration/cold-path tuning
            controller = AdaptiveController(
                stack["graph"], fanouts, store, None, psgs_table=psgs,
                config=AdaptiveConfig(interval_batches=interval,
                                      rows_per_step=64, decay=0.8))
            cache = None
            if mode == "cache":
                cache = GPUFeatureCache.for_store(store, nodes // 4,
                                                  sketch=controller.sketch)
                store.attach_cache(cache)
            engine = ServingEngine(executors, router, max_inflight=16,
                                   hooks=[controller])
            engine.warmup(np.arange(per))

            # phase 1: calibrated-for stream, exactly one control step
            gen.rng = np.random.default_rng(7)
            warm = list(gen.stream(n_warm, seeds_per_request=per))
            engine.run([[r] for r in warm])

            # flash phase: all seed mass jumps onto never-seen cold nodes;
            # two half-windows of n_flash requests each, 2*n_flash <
            # interval, so no control step can react anywhere inside it —
            # the second (steady-state) half is the measured window
            hotspot = flash_hotspot(store, stack["fap"], size=hotspot_size)
            p2 = np.zeros(nodes)
            p2[hotspot] = 1.0 / hotspot.size
            gen.set_seed_prob(p2)
            gen.rng = np.random.default_rng(9)
            steps_before = controller.report()["steps"]
            onset = list(gen.stream(n_flash, seeds_per_request=per))
            engine.run([[r] for r in onset])
            flash = list(gen.stream(n_flash, seeds_per_request=per))
            store.reset_stats()
            m = engine.run([[r] for r in flash])
            stats = store.snapshot_stats()
            steps = controller.report()["steps"]
            assert steps == steps_before, \
                "control step fired inside the flash window"

            results[mode] = {
                "host_cb_per_req": stats["host_fetches"] / n_flash,
                "cache_hits": stats["cache_hits"],
                "cache_misses": stats["cache_misses"],
                "cache_evictions": stats["cache_evictions"],
                "control_steps": steps,
                **latency_percentiles(m),
            }
            emit(f"flash_crowd/{mode}_host_cb_per_req",
                 results[mode]["host_cb_per_req"],
                 f"p99={results[mode]['p99_ms']:.1f}ms;"
                 f"cache_hits={stats['cache_hits']};steps={steps}")
            if mode == "cache":
                _assert_bit_identical(stack, store)
            engine.close()

        off, on = results["adaptive"], results["cache"]
        emit("flash_crowd/host_cb_reduction_x",
             off["host_cb_per_req"] / max(on["host_cb_per_req"], 1e-9),
             f"window={n_flash}req steady-state between control steps")
        # the acceptance signal: within one control interval the cache
        # strictly reduces critical-path host callbacks vs adaptive-only
        assert on["host_cb_per_req"] < off["host_cb_per_req"], results
        write_bench_json("flash_crowd", {"dry_run": dry_run,
                                         "modes": results})
        return results
    finally:
        os.unlink(spill.name)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--dry-run", action="store_true",
                   help="tiny sizes; CI smoke for the full flash-crowd path")
    args = p.parse_args()
    t0 = time.time()
    results = run(dry_run=args.dry_run)
    off, on = results["adaptive"], results["cache"]
    print(f"# flash_crowd: host callbacks/request {off['host_cb_per_req']:.2f}"
          f" -> {on['host_cb_per_req']:.2f} within one control interval, "
          f"p99 {off['p99_ms']:.1f} -> {on['p99_ms']:.1f} ms "
          f"({time.time() - t0:.1f}s)")


if __name__ == "__main__":
    main()

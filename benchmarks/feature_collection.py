"""Fig. 16 analogue: feature-collection throughput of the one-sided read
engine vs RPC-style collection.

Two views (this container is CPU-only, so host RAM *is* local here):
  * modeled GB/s on the TPU topology: each policy's bytes are split across
    tiers and divided by tier bandwidth (HBM 819 GB/s, ICI 50 GB/s,
    host-PCIe 16 GB/s; RPC = all bytes CPU-mediated at PCIe with one extra
    copy) — this is the paper's Fig. 16 story on v5e constants;
  * measured wall-time of the actual code paths (validates correctness and
    relative host-python overhead honestly).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import build_serving_stack, emit, timeit
from repro.core.placement import TIER_HOST, TIER_HOT, TIER_WARM

BW = {TIER_HOT: 819e9, TIER_WARM: 50e9, TIER_HOST: 16e9}
RPC_BW = 16e9 / 2  # CPU-mediated: PCIe + extra staging copy


def run() -> None:
    stack = build_serving_stack(nodes=20000, d_feat=256, hot_frac=0.5,
                                rows_frac=0.5)
    store, feats, plan = stack["store"], stack["feats"], stack["store"].plan
    rng = np.random.default_rng(0)
    m = 8192
    fap_order = np.argsort(-stack["fap"])
    ids = fap_order[rng.zipf(1.3, size=m) % stack["graph"].num_nodes]
    ids = ids.astype(np.int32)
    row_bytes = feats.shape[1] * 4
    total_bytes = m * row_bytes

    # ---- modeled on TPU topology ---------------------------------------
    tiers = plan.tier[ids]
    t_model = sum((tiers == t).sum() * row_bytes / BW[t]
                  for t in (TIER_HOT, TIER_WARM, TIER_HOST)
                  ) + (tiers > TIER_HOST).sum() * row_bytes / 1e9
    emit("collection/tiered_modeled_GBps", total_bytes / t_model / 1e9,
         f"hot={np.mean(tiers==TIER_HOT):.2f};"
         f"warm={np.mean(tiers==TIER_WARM):.2f}")
    emit("collection/rpc_modeled_GBps", RPC_BW / 1e9,
         "all bytes CPU-mediated")
    # dedup (TLB-analogue): fraction of gather bytes saved by id-sort+unique
    uniq = np.unique(ids)
    emit("collection/dedup_bytes_saved_pct",
         100.0 * (1 - uniq.size / ids.size), "sorted-unique before fetch")

    # ---- measured on this host ------------------------------------------
    t = timeit(lambda: store.lookup(jnp.asarray(ids), include_host=False),
               repeats=5)
    emit("collection/tiered_device_measured_GBps", total_bytes / t / 1e9,
         f"{m} rows x {feats.shape[1]}f32")
    t_host = timeit(lambda: store.lookup(jnp.asarray(ids)), repeats=3)
    emit("collection/tiered_with_host_measured_GBps",
         total_bytes / t_host / 1e9, "io_callback slow path included")

    def rpc_collect(idx):
        idx = np.asarray(idx)
        return jnp.asarray(feats[np.maximum(idx, 0)])

    t_rpc = timeit(lambda: rpc_collect(ids), repeats=3)
    emit("collection/rpc_style_measured_GBps", total_bytes / t_rpc / 1e9,
         "host gather + device copy (host RAM is local on CPU)")


if __name__ == "__main__":
    run()

"""Fig. 11/12 analogue: scalability 1→512 chips, derived from the compiled
dry-run roofline terms (this container cannot time real pods; the model is
step_time ≥ max(compute, memory, collective) with compute/memory scaling
1/chips and collective scaling with the ring factor)."""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import emit
from repro.launch.hlo_analysis import HBM_BW, ICI_BW, PEAK_FLOPS

CELLS = [("gin-tu", "ogb_products", "graph-serving GNN"),
         ("qwen1.5-4b", "train_4k", "dense LM train"),
         ("deepseek-moe-16b", "train_4k", "MoE LM train")]


def run(path: str = "artifacts/dryrun.json") -> None:
    if not os.path.exists(path):
        print(f"scalability/skipped,0,{path} missing - run dryrun first")
        return
    recs = {(r["arch"], r["shape"], r["world"]): r
            for r in json.load(open(path)) if r["ok"]}
    for arch, shape, tag in CELLS:
        base = recs.get((arch, shape, 256))
        if base is None:
            continue
        # per-device quantities at 256 chips → global totals (loop-factor
        # corrected: scan bodies are counted once by cost_analysis)
        lf = base.get("loop_factor", 1)
        g_flops = base["cost"]["flops"] * lf * 256
        g_bytes = base["cost"]["bytes_accessed"] * lf * 256
        coll_per_dev = base["collectives"]["total_bytes"] * lf
        for chips in (1, 8, 64, 256, 512):
            compute = g_flops / chips / PEAK_FLOPS
            memory = g_bytes / chips / HBM_BW
            ring = (chips - 1) / chips if chips > 1 else 0.0
            base_ring = 255 / 256
            coll = coll_per_dev * (256 / chips) * (ring / base_ring) / ICI_BW
            step = max(compute, memory, coll)
            emit(f"scalability/{arch}_{shape}_c{chips}_steps_per_s",
                 1.0 / step, f"{tag};bound="
                 f"{'coll' if coll == step else ('mem' if memory == step else 'comp')}")


if __name__ == "__main__":
    run()

"""Sharded-store hierarchy benchmark: the dedup exchange, per-shard
staging and spill files vs the allgather/post-pass baseline.

The paper's distributed design partitions features over the GPU NUMA
topology by access probability; our ``ShardedFeatureStore`` now serves
the *whole* hierarchy through the mesh exchange — cold (HOST/DISK) ids
resolve from per-shard device staging inside the ``all_to_all``, cross-
hop duplicates ride the interconnect once, and the host is the miss
path, not the path. On a zipf-skewed multi-hop workload this reports:

  1. bit-identity: the owner-sorted dedup exchange (``alltoall``)
     returns exactly the rows of per-hop ``lookup`` calls, of the legacy
     ``allgather`` strategy AND of the single-host ``TieredFeatureStore``
     — HOST/DISK ids included, staged and unstaged (asserted),
  2. host callbacks per request with per-shard staging + spill files
     strictly below the allgather/post-pass baseline; stage hits and
     per-shard spill reads both exercised (asserted),
  3. cross-hop dedup: the ``exchanged_ids`` dispatch stat equals the
     distinct exchange-id count and sits strictly below the raw
     occurrence count (asserted).

    PYTHONPATH=src python benchmarks/sharded_hierarchy.py [--dry-run]

Runs on however many devices the runtime has (CI: one CPU device — a
world-1 mesh still exercises every exchange/staging/spill code path);
``--dry-run`` shrinks every dimension so CI can smoke the full path.
"""
from __future__ import annotations

import argparse
import os
import shutil
import sys
import tempfile
import time

if __package__ in (None, ""):  # direct `python benchmarks/sharded_hierarchy.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, write_bench_json
from repro.compat import make_mesh
from repro.core import (Prefetcher, ShardedFeatureStore, TieredFeatureStore,
                        TopologySpec, WorkloadGenerator, compute_fap,
                        quiver_placement)
from repro.core.placement import TIER_DISK, TIER_WARM

FANOUTS = (6, 4)


def _build(nodes: int, world: int, spill_path: str):
    """Source tiered store with real HOST and DISK (mmap spill) tiers,
    warm sized per mesh device — plus the workload's FAP/zipf pieces."""
    from repro.graph import power_law_graph
    graph = power_law_graph(nodes, 10.0, seed=0)
    rng = np.random.default_rng(1)
    feats = rng.normal(size=(nodes, 48)).astype(np.float32)
    gen = WorkloadGenerator(nodes, graph.out_degree, distribution="zipf",
                            seed=2)
    fap = compute_fap(graph, FANOUTS, seed_prob=gen.p)
    # small HBM tiers so the skewed stream actually exercises HOST + DISK
    topo = TopologySpec(num_pods=1, devices_per_pod=world,
                        rows_per_device=max(int(nodes * 0.08) // world, 16),
                        rows_host=max(int(nodes * 0.25), 32),
                        hot_replicate_fraction=0.3)
    src = TieredFeatureStore.build(feats, quiver_placement(fap, topo),
                                   spill_path=spill_path)
    return graph, feats, gen, fap, src


def _hops(rng, gen, world: int, sizes) -> list[np.ndarray]:
    """One request's hop id vectors: zipf-distributed draws with forced
    cross-hop duplication (the frontier overlap the dedup exchange
    collapses) and ``-1`` padding, each length a multiple of world."""
    hops = []
    for k, s in enumerate(sizes):
        s = -(-s // world) * world
        ids = rng.choice(gen.num_nodes, size=s, p=gen.p).astype(np.int32)
        if hops:  # duplicate a slice of the previous hop into this one
            take = min(len(hops[-1]), s // 2)
            ids[:take] = hops[-1][:take]
        ids[rng.random(s) < 0.05] = -1  # padding flows through
        hops.append(ids)
    return hops


def _check_identity(src, base, dedup, fap, gen, rng) -> None:
    """Every path returns the same bits for the same ids — per-hop vs
    fused, allgather vs alltoall, sharded vs single-host, staged or not."""
    hops = _hops(rng, gen, base.world, (16, 64, 192))
    want = [np.asarray(src.lookup(jnp.asarray(h))) for h in hops]

    def check(store, label):
        fused = store.lookup_hops([jnp.asarray(h) for h in hops])
        per_hop = [store.lookup(jnp.asarray(h)) for h in hops]
        for k, w in enumerate(want):
            assert np.array_equal(w, np.asarray(fused[k])), \
                f"{label}: fused hop {k} diverged from single-host store"
            assert np.array_equal(w, np.asarray(per_hop[k])), \
                f"{label}: per-hop lookup hop {k} diverged"

    check(base, "allgather")
    check(dedup, "alltoall")
    pf = Prefetcher(dedup, budget=gen.num_nodes)
    pf.refresh(scores=np.maximum(fap, 1e-12))  # stage the full cold set
    check(dedup, "alltoall+staged")
    dedup.publish_stage(None, None)
    emit("sharded_hierarchy/bit_identical", 1.0,
         "alltoall==allgather==per-hop==single-host, HOST/DISK included, "
         "staged and unstaged")


def run(dry_run: bool = False) -> dict:
    nodes = 800 if dry_run else 4000
    n_req = 8 if dry_run else 48
    sizes = (4, 16, 48) if dry_run else (8, 32, 128)
    world = len(jax.devices())
    mesh = make_mesh((world,), ("x",))
    spill = tempfile.NamedTemporaryFile(suffix=".spill", delete=False)
    spill.close()
    spill_dir = tempfile.mkdtemp(prefix="shard_spill_")
    try:
        graph, feats, gen, fap, src = _build(nodes, world, spill.name)
        base = ShardedFeatureStore.from_tiered(src, mesh, "x",
                                               strategy="allgather")
        dedup = ShardedFeatureStore.from_tiered(src, mesh, "x",
                                                strategy="alltoall",
                                                spill_dir=spill_dir)
        results: dict = {"world": world, "dry_run": dry_run}

        # -- 1) bit-identity across every path -------------------------------
        _check_identity(src, base, dedup, fap, gen, np.random.default_rng(11))

        # -- 2) host callbacks/request: post-pass baseline vs staged ---------
        n_cold = int((dedup.tier_table_host >= 2).sum())
        for mode, store in (("baseline", base), ("staged", dedup)):
            if mode == "staged":
                pf = Prefetcher(store, budget=n_cold)
                staged = pf.refresh(scores=np.maximum(fap, 1e-12))
                prep = store.reset_stats()
                # staging reads the DISK shard files through read_cold_rows
                assert prep["spill_reads"] > 0, prep
                emit("sharded_hierarchy/staged_rows", float(staged),
                     f"cold_rows={n_cold};spill_reads={prep['spill_reads']}")
            rng = np.random.default_rng(7)  # same workload both modes
            store.reset_stats()
            for _ in range(n_req):
                store.lookup_hops([jnp.asarray(h)
                                   for h in _hops(rng, gen, world, sizes)])
            stats = store.reset_stats()
            results[mode] = {"host_cb_per_req": stats["host_fetches"] / n_req,
                             "cold_rows": stats["cold_rows"],
                             "stage_hits": stats["stage_hits"],
                             "stage_misses": stats["stage_misses"]}
            emit(f"sharded_hierarchy/{mode}_host_cb_per_req",
                 results[mode]["host_cb_per_req"],
                 f"cold_rows={stats['cold_rows']};"
                 f"stage_hits={stats['stage_hits']}")
        off, on = results["baseline"], results["staged"]
        assert off["host_cb_per_req"] > 0, off  # baseline pays the post-pass
        assert on["host_cb_per_req"] < off["host_cb_per_req"], results
        assert on["stage_hits"] > 0, on
        emit("sharded_hierarchy/host_cb_reduction_x",
             off["host_cb_per_req"] / max(on["host_cb_per_req"], 1e-9),
             f"hits={on['stage_hits']};misses={on['stage_misses']}")
        dedup.publish_stage(None, None)

        # -- 3) cross-hop duplicates are exchanged exactly once ---------------
        rng = np.random.default_rng(13)
        hops = _hops(rng, gen, world, sizes)
        cat = np.concatenate(hops).astype(np.int64)
        m_dev = cat.size // world
        dev = np.repeat(np.arange(world), m_dev)
        warm = (cat >= 0) & (dedup.tier_table_host[np.maximum(cat, 0)]
                             == TIER_WARM)
        occurrences = int(warm.sum())
        distinct = len({(d, i) for d, i in zip(dev[warm], cat[warm])})
        dedup.reset_stats()
        dedup.lookup_hops([jnp.asarray(h) for h in hops])
        st = dedup.reset_stats()
        assert st["exchanges"] == 1, st
        assert st["exchanged_ids"] == distinct, (st, distinct)
        assert distinct < occurrences, (distinct, occurrences)
        results["dedup"] = {"exchanged_ids": distinct,
                            "occurrences": occurrences}
        emit("sharded_hierarchy/exchanged_ids_per_req", float(distinct),
             f"occurrences={occurrences}")
        emit("sharded_hierarchy/dedup_savings_x",
             occurrences / max(distinct, 1),
             "warm occurrences ÷ ids actually exchanged")
        write_bench_json("sharded_hierarchy", results)
        return results
    finally:
        os.unlink(spill.name)
        shutil.rmtree(spill_dir, ignore_errors=True)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--dry-run", action="store_true",
                   help="tiny sizes; CI smoke for the full sharded path")
    args = p.parse_args()
    t0 = time.time()
    results = run(dry_run=args.dry_run)
    off, on = results["baseline"], results["staged"]
    print(f"# sharded_hierarchy: host callbacks/request "
          f"{off['host_cb_per_req']:.2f} -> {on['host_cb_per_req']:.2f}, "
          f"dedup {results['dedup']['occurrences']} -> "
          f"{results['dedup']['exchanged_ids']} ids/exchange "
          f"({time.time() - t0:.1f}s)")


if __name__ == "__main__":
    main()

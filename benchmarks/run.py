"""Benchmark runner — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--json-out PATH]

Prints ``name,us_per_call,derived`` CSV rows; ``--json-out`` additionally
writes every emitted row (plus pass/fail per module) as JSON so the perf
trajectory is machine-trackable across PRs.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

MODULES = [
    "motivation",          # Fig. 2/3 skew
    "metric_cost",         # Tab. 1-adjacent metric precompute
    "calibration",         # Fig. 6 PSGS<->latency + crossovers
    "skew_robustness",     # Fig. 13
    "placement_compare",   # Fig. 15
    "feature_collection",  # Fig. 16
    "serve_throughput",    # Fig. 9
    "fused_gather",        # fused feature-collection hot path
    "gather_aggregate",    # fused gather→aggregate layer-1 path
    "prefetch",            # cold-tier staging vs critical-path callbacks
    "sharded_hierarchy",   # dedup exchange + per-shard staging/spill
    "flash_crowd",         # device cache vs adaptive-only under drift
    "gateway_soak",        # SLO-aware admission vs FIFO under overload
    "multi_model",         # shared-store registry vs isolated engines
    "policy_cdf",          # Fig. 10
    "workload_drift",      # online adaptation vs frozen placement
    "scalability",         # Fig. 11/12 (from dry-run artifacts)
    "roofline",            # roofline report (from dry-run artifacts)
]


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default=None)
    p.add_argument("--json-out", default=None, metavar="PATH",
                   help="also write every emitted row + per-module status "
                        "as JSON to PATH")
    args = p.parse_args()
    mods = [args.only] if args.only else MODULES
    print("name,us_per_call,derived")
    failures = 0
    status: dict[str, str] = {}
    for name in mods:
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            mod.run()
            status[name] = "ok"
            print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:
            failures += 1
            status[name] = "failed"
            print(f"# {name} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr)
    if args.json_out:
        from benchmarks.common import ROWS
        with open(args.json_out, "w") as f:
            json.dump({"modules": status,
                       "rows": [{"name": n, "value": v, "derived": d}
                                for n, v, d in ROWS]}, f, indent=2)
            f.write("\n")
        print(f"# wrote {args.json_out} ({len(ROWS)} rows)", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

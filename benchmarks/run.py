"""Benchmark runner — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME]

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    "motivation",          # Fig. 2/3 skew
    "metric_cost",         # Tab. 1-adjacent metric precompute
    "calibration",         # Fig. 6 PSGS<->latency + crossovers
    "skew_robustness",     # Fig. 13
    "placement_compare",   # Fig. 15
    "feature_collection",  # Fig. 16
    "serve_throughput",    # Fig. 9
    "fused_gather",        # fused feature-collection hot path
    "prefetch",            # cold-tier staging vs critical-path callbacks
    "multi_model",         # shared-store registry vs isolated engines
    "policy_cdf",          # Fig. 10
    "workload_drift",      # online adaptation vs frozen placement
    "scalability",         # Fig. 11/12 (from dry-run artifacts)
    "roofline",            # roofline report (from dry-run artifacts)
]


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default=None)
    args = p.parse_args()
    mods = [args.only] if args.only else MODULES
    print("name,us_per_call,derived")
    failures = 0
    for name in mods:
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            mod.run()
            print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:
            failures += 1
            print(f"# {name} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

"""Intra-repo function index and call graph.

Two resolution modes serve two different passes:

* **broad** (callback-budget): any ``Name`` load or ``Attribute`` access
  whose simple name matches a known def counts as a potential call — an
  over-approximation, so a hot path cannot *hide* an ``io_callback``
  behind ``functools.partial`` or a method reference.
* **narrow** (trace-safety): only calls that resolve unambiguously —
  bare names to same-module defs or from-imports of repo modules, and
  ``self.method()`` within the same class — so the taint checks never
  chase a duck-typed ``.update()`` into unrelated code.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Iterable

from quiverlint.driver import SourceFile


def dotted(node: ast.AST) -> str | None:
    """Render a Name/Attribute chain as ``a.b.c`` (None if not a chain)."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


@dataclasses.dataclass
class FuncInfo:
    """One function/method definition somewhere in the analyzed files."""

    qualname: str  # "Class.method" or "func" (nesting flattened with ".")
    name: str
    node: ast.FunctionDef | ast.AsyncFunctionDef
    file: SourceFile
    class_name: str | None

    @property
    def ref(self) -> str:
        return f"{self.file.rel}::{self.qualname}"


class Index:
    """All defs across the file set, plus per-module import maps."""

    def __init__(self, files: list[SourceFile]):
        self.files = files
        self.funcs: list[FuncInfo] = []
        self.by_name: dict[str, list[FuncInfo]] = {}
        self.by_qualname: dict[str, list[FuncInfo]] = {}
        # per file: local name -> "module.path:defname" for from-imports
        self.imports: dict[str, dict[str, str]] = {}
        for sf in files:
            self.imports[sf.rel] = self._imports(sf)
            self._collect(sf, sf.tree, prefix="", class_name=None)

    def _imports(self, sf: SourceFile) -> dict[str, str]:
        out: dict[str, str] = {}
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.ImportFrom) and node.module:
                for alias in node.names:
                    out[alias.asname or alias.name] = \
                        f"{node.module}:{alias.name}"
        return out

    def _collect(self, sf: SourceFile, node: ast.AST, prefix: str,
                 class_name: str | None) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}{child.name}"
                info = FuncInfo(qualname=qual, name=child.name, node=child,
                                file=sf, class_name=class_name)
                self.funcs.append(info)
                self.by_name.setdefault(child.name, []).append(info)
                self.by_qualname.setdefault(qual, []).append(info)
                self._collect(sf, child, prefix=f"{qual}.",
                              class_name=class_name)
            elif isinstance(child, ast.ClassDef):
                self._collect(sf, child, prefix=f"{child.name}.",
                              class_name=child.name)
            else:
                self._collect(sf, child, prefix=prefix,
                              class_name=class_name)

    # -- broad resolution -------------------------------------------------

    def broad_edges(self, fn: FuncInfo) -> list[FuncInfo]:
        """Every def whose simple name is referenced anywhere in ``fn``."""
        names: set[str] = set()
        for node in ast.walk(fn.node):
            if node is fn.node:
                continue
            if isinstance(node, ast.Name):
                names.add(node.id)
            elif isinstance(node, ast.Attribute):
                names.add(node.attr)
        out: list[FuncInfo] = []
        for name in names:
            for target in self.by_name.get(name, ()):
                if target is not fn:
                    out.append(target)
        return out

    # -- narrow resolution ------------------------------------------------

    def narrow_callees(self, fn: FuncInfo) -> list[FuncInfo]:
        out: list[FuncInfo] = []
        for node in ast.walk(fn.node):
            if not isinstance(node, ast.Call):
                continue
            out.extend(self.resolve_callable(node.func, fn))
        return out

    def resolve_callable(self, expr: ast.AST,
                         scope: FuncInfo | SourceFile) -> list[FuncInfo]:
        """Unambiguously resolve a callable expression to defs."""
        sf = scope.file if isinstance(scope, FuncInfo) else scope
        if isinstance(expr, ast.Name):
            return self._resolve_name(expr.id, sf, scope)
        if isinstance(expr, ast.Attribute):
            # self.method() within the same class
            if (isinstance(expr.value, ast.Name) and expr.value.id == "self"
                    and isinstance(scope, FuncInfo) and scope.class_name):
                qual = f"{scope.class_name}.{expr.attr}"
                return [f for f in self.by_qualname.get(qual, ())
                        if f.file is sf]
        return []

    def _resolve_name(self, name: str, sf: SourceFile,
                      scope: FuncInfo | SourceFile) -> list[FuncInfo]:
        # nested def in the same enclosing function
        if isinstance(scope, FuncInfo):
            qual = f"{scope.qualname}.{name}"
            hits = [f for f in self.by_qualname.get(qual, ()) if f.file is sf]
            if hits:
                return hits
        # module-level def in the same file
        hits = [f for f in self.by_qualname.get(name, ()) if f.file is sf]
        if hits:
            return hits
        # from-import of another analyzed module
        imp = self.imports.get(sf.rel, {}).get(name)
        if imp:
            mod, _, defname = imp.partition(":")
            mod_rel = mod.replace(".", "/")
            for f in self.by_qualname.get(defname, ()):
                if f.file.rel.endswith(f"{mod_rel}.py"):
                    return [f]
        return []


def reachable_broad(index: Index, roots: Iterable[FuncInfo],
                    stop: set[str] = frozenset()) -> dict[str, list[str]]:
    """BFS over broad edges; returns {func ref: path of refs from a root}.

    Functions whose qualname is in ``stop`` are recorded but never
    traversed *into* (gateway semantics).
    """
    paths: dict[str, list[str]] = {}
    queue: list[FuncInfo] = []
    for r in roots:
        if r.ref not in paths:
            paths[r.ref] = [r.ref]
            queue.append(r)
    while queue:
        fn = queue.pop(0)
        if fn.qualname in stop:
            continue
        for nxt in index.broad_edges(fn):
            if nxt.ref not in paths:
                paths[nxt.ref] = paths[fn.ref] + [nxt.ref]
                queue.append(nxt)
    return paths

"""Pass ``lock`` — guarded-by registry for the copy-on-write protocol.

The serving stack publishes state with a strict discipline: arrays are
replaced (never mutated) under ``_mig_lock``, counters mutate under
``_stats_lock``, and readers take a coherent snapshot under the same
lock. This pass encodes that discipline as a registry mapping
``(class, field) -> lock attribute`` and flags any ``self.<field>``
read or write outside a lexical ``with self.<lock>:`` block.

``__init__`` is always exempt (no concurrent access before the object
is published); additional per-class methods can be whitelisted for
designated publish helpers that hold the lock by construction or are
documented lock-held-only.
"""
from __future__ import annotations

import ast

from quiverlint.driver import Finding, SourceFile

RULE = "lock-discipline"


def run(config, files: list[SourceFile]) -> list[Finding]:
    registry: dict[str, dict[str, str]] = config.guarded_fields
    exempt: dict[str, set[str]] = config.lock_exempt_methods
    findings: list[Finding] = []
    for sf in files:
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            guarded = registry.get(node.name)
            if not guarded:
                continue
            skip = {"__init__"} | exempt.get(node.name, set())
            for item in node.body:
                if not isinstance(item, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                    continue
                if item.name in skip:
                    continue
                _check_method(sf, node.name, item, guarded, findings)
    return findings


def _lock_attrs(with_node: ast.With | ast.AsyncWith) -> set[str]:
    out: set[str] = set()
    for item in with_node.items:
        expr = item.context_expr
        if (isinstance(expr, ast.Attribute)
                and isinstance(expr.value, ast.Name)
                and expr.value.id == "self"):
            out.add(expr.attr)
    return out


def _check_method(sf: SourceFile, cls: str,
                  method: ast.FunctionDef | ast.AsyncFunctionDef,
                  guarded: dict[str, str],
                  findings: list[Finding]) -> None:
    symbol = f"{cls}.{method.name}"

    def visit(node: ast.AST, held: frozenset[str]) -> None:
        if isinstance(node, (ast.With, ast.AsyncWith)):
            inner = held | _lock_attrs(node)
            for item in node.items:
                visit(item.context_expr, held)
                if item.optional_vars is not None:
                    visit(item.optional_vars, held)
            for child in node.body:
                visit(child, inner)
            return
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in ("wait_for", "wait")
                and isinstance(node.func.value, ast.Attribute)
                and isinstance(node.func.value.value, ast.Name)
                and node.func.value.value.id == "self"
                and node.func.value.attr in held):
            # Condition.wait_for evaluates its predicate with the
            # condition lock re-acquired — the lambda runs under the lock
            for child in ast.iter_child_nodes(node):
                if isinstance(child, ast.Lambda):
                    visit(child.body, held)
                else:
                    visit(child, held)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            # a nested function may run after the lock is released
            # (callbacks, executors) — analyze it as holding nothing
            body = node.body if isinstance(node.body, list) else [node.body]
            for child in body:
                visit(child, frozenset())
            return
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"
                and node.attr in guarded):
            lock = guarded[node.attr]
            if lock not in held:
                findings.append(Finding(
                    rule=RULE, path=sf.rel, line=node.lineno, symbol=symbol,
                    message=f"access to `self.{node.attr}` (guarded by "
                            f"`self.{lock}`) outside `with self.{lock}:`"))
            return
        for child in ast.iter_child_nodes(node):
            visit(child, held)

    for stmt in method.body:
        visit(stmt, frozenset())

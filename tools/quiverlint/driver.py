"""Shared quiverlint driver: file loading, findings, suppressions, baseline.

Passes are plain functions ``(config, files) -> list[Finding]`` registered
in ``PASSES``. The driver parses every source file exactly once, runs the
requested passes, applies inline suppressions and the committed baseline,
and renders human or ``--json`` output.

Exit status is non-zero when there is any active (non-baselined,
non-suppressed) finding, any *stale* baseline entry (a grandfathered
finding that no longer fires — the baseline may only shrink), or any
suppression comment without a justification.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import json
import re
import sys
from pathlib import Path
from typing import Callable, Iterable

SUPPRESS_RE = re.compile(
    r"#\s*quiverlint:\s*disable=([A-Za-z0-9_,-]+)\s*(.*)$")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a location.

    ``key`` deliberately excludes the line number so baseline entries
    survive unrelated edits that shift code up or down a file.
    """

    rule: str
    path: str  # repo-relative posix path
    line: int
    symbol: str  # qualified name of the enclosing function/class, or ""
    message: str

    @property
    def key(self) -> str:
        return f"{self.rule}|{self.path}|{self.symbol}|{self.message}"

    def render(self) -> str:
        sym = f" ({self.symbol})" if self.symbol else ""
        return f"{self.path}:{self.line}: [{self.rule}]{sym} {self.message}"


@dataclasses.dataclass
class SourceFile:
    """A parsed python file shared by all passes (parsed exactly once)."""

    path: Path
    rel: str
    text: str
    lines: list[str]
    tree: ast.Module

    @classmethod
    def load(cls, path: Path, root: Path) -> "SourceFile":
        text = path.read_text()
        return cls(path=path, rel=path.relative_to(root).as_posix(),
                   text=text, lines=text.splitlines(),
                   tree=ast.parse(text, filename=str(path)))


@dataclasses.dataclass
class LintResult:
    findings: list[Finding]          # active: not suppressed, not baselined
    baselined: list[Finding]         # fired but grandfathered
    suppressed: list[Finding]        # fired but inline-disabled with reason
    stale_baseline: list[str]        # baseline keys that no longer fire
    files_checked: int

    @property
    def ok(self) -> bool:
        return not self.findings and not self.stale_baseline


PassFn = Callable[["object", list[SourceFile]], list[Finding]]


def _dedupe(findings: Iterable[Finding]) -> list[Finding]:
    seen: set[tuple[str, int]] = set()
    out = []
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule)):
        if (f.key, f.line) not in seen:
            seen.add((f.key, f.line))
            out.append(f)
    return out


def apply_suppressions(
    findings: list[Finding], files: dict[str, SourceFile]
) -> tuple[list[Finding], list[Finding]]:
    """Split findings into (kept, suppressed) using inline comments.

    A suppression comment applies to its own line, or — when it is the
    only thing on the line — to the next line. A comment with no reason
    text is itself reported as a ``bad-suppression`` finding.
    """
    kept: list[Finding] = []
    suppressed: list[Finding] = []
    bad_lines: set[tuple[str, int]] = set()
    for f in findings:
        sf = files.get(f.path)
        match = None
        if sf is not None:
            for lineno in (f.line, f.line - 1):
                if not 1 <= lineno <= len(sf.lines):
                    continue
                line = sf.lines[lineno - 1]
                m = SUPPRESS_RE.search(line)
                if m is None:
                    continue
                # an own-line comment covers the next line; a trailing
                # comment covers only its own line
                if lineno == f.line or line.lstrip().startswith("#"):
                    match = (lineno, m)
                    break
        if match is None:
            kept.append(f)
            continue
        lineno, m = match
        rules = {r.strip() for r in m.group(1).split(",")}
        reason = m.group(2).strip()
        if f.rule not in rules and "all" not in rules:
            kept.append(f)
            continue
        if not reason:
            if (f.path, lineno) not in bad_lines:
                bad_lines.add((f.path, lineno))
                kept.append(Finding(
                    rule="bad-suppression", path=f.path, line=lineno,
                    symbol=f.symbol,
                    message="suppression comment has no justification "
                            "(write `# quiverlint: disable=RULE reason`)"))
            kept.append(f)
            continue
        suppressed.append(f)
    return kept, suppressed


def load_baseline(path: Path) -> dict[str, str]:
    """Return {finding key: reason} from a baseline file (empty if absent)."""
    if not path.exists():
        return {}
    data = json.loads(path.read_text())
    return {e["key"]: e.get("reason", "") for e in data.get("findings", [])}


def write_baseline(path: Path, findings: list[Finding]) -> None:
    data = {
        "version": 1,
        "findings": [
            {"key": f.key, "reason": "grandfathered",
             "location": f"{f.path}:{f.line}"}
            for f in findings
        ],
    }
    path.write_text(json.dumps(data, indent=2) + "\n")


def run(config, files: list[SourceFile],
        passes: dict[str, PassFn],
        baseline_path: Path | None = None) -> LintResult:
    """Run ``passes`` over ``files`` and post-process the findings."""
    raw: list[Finding] = []
    for fn in passes.values():
        raw.extend(fn(config, files))
    raw = _dedupe(raw)
    by_rel = {sf.rel: sf for sf in files}
    kept, suppressed = apply_suppressions(raw, by_rel)

    baseline = load_baseline(baseline_path) if baseline_path else {}
    active, baselined = [], []
    fired_keys = set()
    for f in kept:
        fired_keys.add(f.key)
        (baselined if f.key in baseline else active).append(f)
    stale = sorted(k for k in baseline if k not in fired_keys)
    return LintResult(findings=active, baselined=baselined,
                      suppressed=suppressed, stale_baseline=stale,
                      files_checked=len(files))


def render_human(result: LintResult, pass_names: list[str]) -> str:
    out = []
    for f in result.findings:
        out.append(f"ERROR: {f.render()}")
    for key in result.stale_baseline:
        out.append(f"ERROR: stale baseline entry (no longer fires, "
                   f"remove it): {key}")
    out.append(
        f"quiverlint: {len(result.findings)} finding(s), "
        f"{len(result.baselined)} baselined, "
        f"{len(result.suppressed)} suppressed, "
        f"{len(result.stale_baseline)} stale baseline entr(ies) "
        f"across {result.files_checked} files "
        f"[passes: {', '.join(pass_names)}]")
    return "\n".join(out)


def render_json(result: LintResult, pass_names: list[str]) -> str:
    def enc(f: Finding) -> dict:
        return {"rule": f.rule, "path": f.path, "line": f.line,
                "symbol": f.symbol, "message": f.message, "key": f.key}

    return json.dumps({
        "ok": result.ok,
        "passes": pass_names,
        "files_checked": result.files_checked,
        "findings": [enc(f) for f in result.findings],
        "baselined": [enc(f) for f in result.baselined],
        "suppressed": [enc(f) for f in result.suppressed],
        "stale_baseline": result.stale_baseline,
    }, indent=2)


def collect_files(root: Path, globs: list[str]) -> list[SourceFile]:
    paths: set[Path] = set()
    for pattern in globs:
        for p in root.glob(pattern):
            if p.suffix == ".py" and "__pycache__" not in p.parts:
                paths.add(p)
    return [SourceFile.load(p, root) for p in sorted(paths)]


def main(argv: list[str] | None = None) -> int:
    # imported lazily so driver.py stays importable from fixture tests
    # without pulling in every pass module
    from quiverlint import repo_config

    parser = argparse.ArgumentParser(
        prog="quiverlint",
        description="repo-specific static analysis for the serving stack")
    parser.add_argument("--json", action="store_true", dest="as_json",
                        help="machine-readable output")
    parser.add_argument("--root", type=Path,
                        default=Path(__file__).resolve().parent.parent.parent,
                        help="repository root (default: auto-detected)")
    parser.add_argument("--baseline", type=Path, default=None,
                        help="baseline file (default: "
                             "tools/quiverlint/baseline.json)")
    parser.add_argument("--write-baseline", action="store_true",
                        help="write all current findings to the baseline "
                             "and exit 0")
    parser.add_argument("--pass", dest="passes", action="append",
                        choices=sorted(repo_config.PASSES),
                        help="run only the named pass (repeatable; "
                             "default: all)")
    args = parser.parse_args(argv)

    root = args.root.resolve()
    config = repo_config.build(root)
    baseline_path = (args.baseline if args.baseline is not None
                     else root / "tools" / "quiverlint" / "baseline.json")
    pass_names = args.passes or sorted(repo_config.PASSES)
    passes = {name: repo_config.PASSES[name] for name in pass_names}

    files = collect_files(root, config.code_globs)
    result = run(config, files, passes, baseline_path=baseline_path)

    if args.write_baseline:
        write_baseline(baseline_path, result.findings)
        print(f"wrote {len(result.findings)} finding(s) to {baseline_path}")
        return 0

    print(render_json(result, pass_names) if args.as_json
          else render_human(result, pass_names))
    return 0 if result.ok else 1

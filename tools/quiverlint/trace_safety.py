"""Pass ``trace`` — tracing discipline inside jit/shard_map/Pallas bodies.

Finds every function that JAX traces — decorated with ``@jax.jit`` (also
via ``functools.partial``), or passed to ``jax.jit`` / ``shard_map`` /
``pl.pallas_call`` / ``pmap`` — plus everything those bodies reach
through unambiguous intra-repo calls, and flags host-side operations on
traced values:

* ``np.*`` calls on a traced array (silently falls back to host),
* ``.item()`` / ``int()`` / ``float()`` / ``bool()`` coercions,
* Python ``if`` / ``while`` on a traced value (``TracerBoolConversionError``
  at runtime; use ``jnp.where`` / ``lax.cond``),
* boolean-mask indexing (data-dependent shapes break static-shape
  guarantees the shard_map exchange and Pallas grids rely on).

Parameters named in ``static_argnames`` / ``static_argnums`` are *not*
traced, and neither are parameters of reached helpers annotated with a
scalar Python type (``int``/``bool``/``float``/``str``) — branching on
those is legal and common (``if use_pallas:``). ``.shape`` / ``.ndim``
/ ``.dtype`` / ``len()`` of a traced array are static and un-taint.
Functions passed to ``io_callback`` / ``pure_callback`` run on the
host and are excluded.
"""
from __future__ import annotations

import ast

from quiverlint import callgraph
from quiverlint.driver import Finding, SourceFile

RULE = "trace-safety"

UNTAINT_ATTRS = {"shape", "ndim", "dtype", "size", "itemsize", "nbytes"}
SCALAR_ANNOTATIONS = {"int", "bool", "float", "str"}
COERCIONS = {"int", "float", "bool"}


def _static_from_keywords(call: ast.Call, params: list[str]) -> set[str]:
    static: set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for node in ast.walk(kw.value):
                if isinstance(node, ast.Constant) and isinstance(node.value,
                                                                 str):
                    static.add(node.value)
        elif kw.arg == "static_argnums":
            for node in ast.walk(kw.value):
                if isinstance(node, ast.Constant) and isinstance(node.value,
                                                                 int):
                    if 0 <= node.value < len(params):
                        static.add(params[node.value])
    return static


def _param_names(fn: ast.FunctionDef | ast.AsyncFunctionDef | ast.Lambda
                 ) -> list[str]:
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


def _annotation_static(fn, name: str) -> bool:
    a = fn.args if not isinstance(fn, ast.Lambda) else None
    if a is None:
        return False
    for p in a.posonlyargs + a.args + a.kwonlyargs:
        if p.arg == name and p.annotation is not None:
            try:
                return ast.unparse(p.annotation) in SCALAR_ANNOTATIONS
            except Exception:
                return False
    return False


def _identity_test(test: ast.AST) -> bool:
    """True for tests that never concretize a tracer: ``x is (not) None``
    and static container membership (``"b" in params``)."""
    if isinstance(test, ast.BoolOp):
        return all(_identity_test(v) for v in test.values)
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        return _identity_test(test.operand)
    return (isinstance(test, ast.Compare)
            and all(isinstance(op, (ast.Is, ast.IsNot, ast.In, ast.NotIn))
                    for op in test.ops))


class _Roots:
    """Traced entry points: (FuncInfo, static param names) pairs."""

    def __init__(self, config, index: callgraph.Index):
        self.config = config
        self.index = index
        self.roots: dict[str, tuple[callgraph.FuncInfo, set[str]]] = {}
        self.lambdas: list[tuple[SourceFile, ast.Lambda]] = []
        self.host_bodies: set[int] = set()  # id() of io_callback host fns
        for fn in index.funcs:
            self._from_decorators(fn)
        for sf in index.files:
            self._from_calls(sf)

    def _add(self, fn: callgraph.FuncInfo, static: set[str]) -> None:
        if fn.ref in self.roots:
            self.roots[fn.ref][1].update(static)
        else:
            self.roots[fn.ref] = (fn, set(static))

    def _is_wrapper(self, expr: ast.AST) -> bool:
        name = callgraph.dotted(expr)
        return name in self.config.trace_wrappers if name else False

    def _from_decorators(self, fn: callgraph.FuncInfo) -> None:
        params = _param_names(fn.node)
        for dec in fn.node.decorator_list:
            if self._is_wrapper(dec):
                self._add(fn, set())
            elif isinstance(dec, ast.Call):
                if self._is_wrapper(dec.func):
                    self._add(fn, _static_from_keywords(dec, params))
                else:
                    name = callgraph.dotted(dec.func)
                    if (name in ("partial", "functools.partial")
                            and dec.args and self._is_wrapper(dec.args[0])):
                        self._add(fn, _static_from_keywords(dec, params))

    def _from_calls(self, sf: SourceFile) -> None:
        # map call sites to their innermost enclosing function for
        # scope-aware resolution of the traced-callable argument
        scopes: dict[int, callgraph.FuncInfo] = {}
        for info in self.index.funcs:
            if info.file is sf:
                for node in ast.walk(info.node):
                    scopes[id(node)] = info

        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call) or not node.args:
                continue
            simple = callgraph.dotted(node.func)
            simple_last = simple.rsplit(".", 1)[-1] if simple else None
            scope = scopes.get(id(node), sf)
            targets = self._arg_targets(node.args[0], scope, sf)
            if simple_last in self.config.callback_names:
                for t in targets:
                    self.host_bodies.add(id(t.node))
                if isinstance(node.args[0], ast.Lambda):
                    self.host_bodies.add(id(node.args[0]))
                continue
            if not self._is_wrapper(node.func):
                continue
            if isinstance(node.args[0], ast.Lambda):
                self.lambdas.append((sf, node.args[0]))
            for t in targets:
                self._add(t, _static_from_keywords(node,
                                                   _param_names(t.node)))

    def _arg_targets(self, arg: ast.AST, scope, sf: SourceFile
                     ) -> list[callgraph.FuncInfo]:
        """Resolve the traced-callable argument to repo defs.

        Handles a direct name, ``functools.partial(f, ...)``, and a local
        ``kernel = partial(f, ...)`` binding one level deep.
        """
        hits = self.index.resolve_callable(arg, scope)
        if hits:
            return hits
        exprs = [arg]
        if (isinstance(arg, ast.Name)
                and isinstance(scope, callgraph.FuncInfo)):
            for node in ast.walk(scope.node):
                if (isinstance(node, ast.Assign)
                        and any(isinstance(t, ast.Name) and t.id == arg.id
                                for t in node.targets)):
                    exprs.append(node.value)
        out: list[callgraph.FuncInfo] = []
        for expr in exprs:
            for node in ast.walk(expr):
                if isinstance(node, ast.Name):
                    out.extend(self.index.resolve_callable(node, scope))
        return out


def run(config, files: list[SourceFile]) -> list[Finding]:
    index = callgraph.Index(files)
    roots = _Roots(config, index)
    findings: list[Finding] = []
    done: set[int] = set()

    # BFS over unambiguous calls so helpers called from traced bodies
    # (e.g. _sample_one_hop) are held to the same discipline
    queue: list[tuple[callgraph.FuncInfo, set[str]]] = [
        (fn, static) for fn, static in roots.roots.values()]
    while queue:
        fn, static = queue.pop(0)
        if id(fn.node) in done or id(fn.node) in roots.host_bodies:
            continue
        done.add(id(fn.node))
        static = static | {p for p in _param_names(fn.node)
                           if _annotation_static(fn.node, p)}
        _check_function(config, fn.file, fn.node, fn.qualname, static,
                        findings, roots.host_bodies, done)
        for callee in index.narrow_callees(fn):
            if id(callee.node) not in done:
                queue.append((callee, set()))

    for sf, lam in roots.lambdas:
        if id(lam) not in done and id(lam) not in roots.host_bodies:
            done.add(id(lam))
            _check_function(config, sf, lam, "<lambda>", set(), findings,
                            roots.host_bodies, done)
    return findings


def _check_function(config, sf: SourceFile, fn, symbol: str,
                    static: set[str], findings: list[Finding],
                    host_bodies: set[int], done: set[int]) -> None:
    tainted: set[str] = {p for p in _param_names(fn)
                         if p not in static
                         and not _annotation_static(fn, p)}
    masks: set[str] = set()  # names bound to boolean comparisons
    np_aliases = config.np_aliases

    def is_tainted(expr: ast.AST) -> bool:
        if isinstance(expr, ast.Name):
            return expr.id in tainted
        if isinstance(expr, ast.Constant):
            return False
        if isinstance(expr, ast.Attribute):
            if expr.attr in UNTAINT_ATTRS:
                return False
            return is_tainted(expr.value)
        if isinstance(expr, ast.Call):
            name = callgraph.dotted(expr.func)
            if name == "len" or name in COERCIONS:
                return False
            return any(is_tainted(c) for c in ast.iter_child_nodes(expr))
        return any(is_tainted(c) for c in ast.iter_child_nodes(expr))

    def bind(target: ast.AST, value_tainted: bool, is_mask: bool) -> None:
        for node in ast.walk(target):
            if isinstance(node, ast.Name):
                if value_tainted:
                    tainted.add(node.id)
                else:
                    tainted.discard(node.id)
                if is_mask:
                    masks.add(node.id)
                else:
                    masks.discard(node.id)

    def emit(node: ast.AST, message: str) -> None:
        findings.append(Finding(rule=RULE, path=sf.rel, line=node.lineno,
                                symbol=symbol, message=message))

    def walk(node: ast.AST, collect: bool) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)) and node is not fn:
            # nested body (fori_loop/scan/Pallas kernel) is traced too,
            # with its parameters (loop carries, refs) traced — unless
            # it is an io_callback host body
            if collect and id(node) not in host_bodies \
                    and id(node) not in done:
                done.add(id(node))
                inner_sym = f"{symbol}.<locals>.{getattr(node, 'name', 'λ')}"
                _check_function(config, sf, node, inner_sym, set(),
                                findings, host_bodies, done)
            return
        if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            value = node.value
            if value is not None:
                walk(value, collect)
                vt = is_tainted(value)
                mask = isinstance(value, ast.Compare) and vt
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                if isinstance(node, ast.AugAssign):
                    vt = vt or is_tainted(node.target)
                for t in targets:
                    if isinstance(t, (ast.Subscript, ast.Attribute)):
                        walk(t, collect)
                    else:
                        bind(t, vt, mask)
            return
        if isinstance(node, (ast.For, ast.comprehension)):
            it = node.iter
            walk(it, collect)
            bind(node.target, is_tainted(it), False)
            rest = ([*node.body, *node.orelse] if isinstance(node, ast.For)
                    else list(node.ifs))
            for child in rest:
                walk(child, collect)
            return
        if collect:
            if isinstance(node, (ast.If, ast.While)) \
                    and not _identity_test(node.test) \
                    and is_tainted(node.test):
                emit(node.test, "Python control flow on a traced value "
                                "(use jnp.where / lax.cond / lax.while_loop)")
            if isinstance(node, ast.Call):
                name = callgraph.dotted(node.func)
                if name:
                    head, last = name.split(".", 1)[0], name.rsplit(".", 1)[-1]
                    if head in np_aliases and \
                            any(is_tainted(a) for a in node.args):
                        emit(node, f"host numpy call `{name}(...)` on a "
                                   f"traced value (use jnp)")
                    if name in COERCIONS and node.args \
                            and is_tainted(node.args[0]):
                        emit(node, f"`{name}()` coercion of a traced value "
                                   f"(concretization error under jit)")
                    if last == "item" and not node.args \
                            and isinstance(node.func, ast.Attribute) \
                            and is_tainted(node.func.value):
                        emit(node, "`.item()` on a traced value "
                                   "(host sync, fails under jit)")
            if isinstance(node, ast.Subscript):
                idx = node.slice
                if (isinstance(idx, ast.Compare) and is_tainted(idx)) or \
                        (isinstance(idx, ast.Name) and idx.id in masks):
                    emit(node, "boolean-mask indexing on a traced value "
                               "(data-dependent shape; use jnp.where or a "
                               "fixed-size gather)")
        for child in ast.iter_child_nodes(node):
            walk(child, collect)

    body = fn.body if isinstance(fn.body, list) else [fn.body]
    for collect in (False, True):  # pass 1 seeds taint, pass 2 reports
        masks_snapshot = set(masks)
        taint_snapshot = set(tainted)
        if collect:
            tainted |= taint_snapshot
            masks |= masks_snapshot
        for stmt in body:
            walk(stmt, collect)

"""Pass ``callback`` — the zero-host-callback hot-path budget.

The paper's headline serving claim is that the steady-state hot path
performs **zero** host callbacks per request: every cold row is resolved
through the single designated ``TieredFeatureStore._host_fetch``
gateway, which the prefetcher and device cache keep off the critical
path. This pass turns that from a benchmark outcome (``flash_crowd``
asserting 0.00 callbacks/request) into a statically-checked property:

1. build an intra-repo call graph with *broad* (reference-based,
   over-approximate) resolution, so a callback cannot hide behind
   ``functools.partial`` or a stored method reference;
2. BFS from the registered hot-path roots (``lookup`` / ``lookup_hops``
   / ``GPUFeatureCache.query`` / executor ``submit``→``_collect``
   paths), never descending *into* a gateway;
3. flag any reached function that calls ``io_callback`` /
   ``pure_callback`` directly and is not a gateway, with the root→…→
   offender chain in the message.

Config drift is also an error: a registered root or gateway that no
longer exists would silently vacuate the proof, so both are verified to
resolve, and each gateway must actually contain a direct callback call.

Two further registries refine the proof for the distributed store:

* ``fetch_gateways`` — the designated host-data routes
  (``read_cold_rows``): each must resolve, must contain **no** direct
  callback (they are plain-numpy host code, reached only outside traced
  regions), and the BFS stops at them like at a callback gateway.
* ``restricted_roots`` — root → forbidden qualnames: e.g. the sharded
  hot path must never reach ``TieredFeatureStore._host_fetch`` even
  transitively (its cold misses merge host-side after the ``shard_map``,
  through ``read_cold_rows`` only — a zero-io_callback budget by
  construction, not by luck).
"""
from __future__ import annotations

import ast

from quiverlint import callgraph
from quiverlint.driver import Finding, SourceFile

RULE = "callback-budget"


def _direct_callers(config, index: callgraph.Index
                    ) -> dict[str, int]:
    """{func ref: line of first direct io_callback/pure_callback call}."""
    out: dict[str, int] = {}
    for fn in index.funcs:
        for node in ast.walk(fn.node):
            if isinstance(node, ast.Call):
                name = callgraph.dotted(node.func)
                last = name.rsplit(".", 1)[-1] if name else None
                if last in config.callback_names and fn.ref not in out:
                    out[fn.ref] = node.lineno
    return out


def run(config, files: list[SourceFile]) -> list[Finding]:
    index = callgraph.Index(files)
    findings: list[Finding] = []

    roots: list[callgraph.FuncInfo] = []
    for qual in sorted(config.hot_path_roots):
        hits = index.by_qualname.get(qual, [])
        if not hits:
            findings.append(Finding(
                rule=RULE, path="tools/quiverlint/repo_config.py", line=1,
                symbol=qual,
                message=f"registered hot-path root `{qual}` not found — "
                        f"update the registry so the callback proof stays "
                        f"meaningful"))
        roots.extend(hits)

    direct = _direct_callers(config, index)
    gateways = set(config.callback_gateways)
    for qual in sorted(gateways):
        hits = index.by_qualname.get(qual, [])
        if not hits:
            findings.append(Finding(
                rule=RULE, path="tools/quiverlint/repo_config.py", line=1,
                symbol=qual,
                message=f"registered callback gateway `{qual}` not found"))
        elif not any(h.ref in direct for h in hits):
            findings.append(Finding(
                rule=RULE, path=hits[0].file.rel, line=hits[0].node.lineno,
                symbol=qual,
                message=f"gateway `{qual}` contains no direct "
                        f"io_callback/pure_callback call — the budget "
                        f"proof is vacuous; update the registry"))

    fetch_gateways = set(getattr(config, "fetch_gateways", ()))
    for qual in sorted(fetch_gateways):
        hits = index.by_qualname.get(qual, [])
        if not hits:
            findings.append(Finding(
                rule=RULE, path="tools/quiverlint/repo_config.py", line=1,
                symbol=qual,
                message=f"registered fetch gateway `{qual}` not found"))
            continue
        for h in hits:
            if h.ref in direct:
                findings.append(Finding(
                    rule=RULE, path=h.file.rel, line=direct[h.ref],
                    symbol=qual,
                    message=f"fetch gateway `{qual}` performs a direct "
                            f"io_callback/pure_callback — it must stay "
                            f"plain host numpy (route device-side fetches "
                            f"through a callback gateway instead)"))

    stop = gateways | fetch_gateways
    paths = callgraph.reachable_broad(index, roots, stop=stop)
    by_ref = {fn.ref: fn for fn in index.funcs}
    for ref, chain in sorted(paths.items()):
        if ref not in direct:
            continue
        fn = by_ref[ref]
        if fn.qualname in gateways:
            continue
        pretty = " -> ".join(r.split("::", 1)[1] for r in chain)
        findings.append(Finding(
            rule=RULE, path=fn.file.rel, line=direct[ref],
            symbol=fn.qualname,
            message=f"hot path reaches a host callback outside the "
                    f"designated gateway(s) "
                    f"{sorted(gateways)}: {pretty}"))

    for root_qual, forbidden in sorted(
            getattr(config, "restricted_roots", {}).items()):
        hits = index.by_qualname.get(root_qual, [])
        if not hits:
            findings.append(Finding(
                rule=RULE, path="tools/quiverlint/repo_config.py", line=1,
                symbol=root_qual,
                message=f"registered restricted root `{root_qual}` not "
                        f"found — update the registry"))
            continue
        sub = callgraph.reachable_broad(index, hits, stop=stop)
        bad = set(forbidden)
        for ref, chain in sorted(sub.items()):
            fn = by_ref[ref]
            if fn.qualname not in bad:
                continue
            pretty = " -> ".join(r.split("::", 1)[1] for r in chain)
            findings.append(Finding(
                rule=RULE, path=hits[0].file.rel,
                line=hits[0].node.lineno, symbol=root_qual,
                message=f"restricted root `{root_qual}` reaches forbidden "
                        f"`{fn.qualname}`: {pretty}"))
    return findings

"""Pass ``docs`` — the former ``tools/check_docs.py``, now a lint pass.

Behaviorally identical checks: intra-repo markdown links in README.md /
docs/*.md must resolve, and the public serving API surface registered in
``repo_config`` must carry docstrings (a bare class name means class
docstring + every public method; ``Class.method`` pins one method).
"""
from __future__ import annotations

import ast
import re
from pathlib import Path

from quiverlint.driver import Finding, SourceFile

LINK_RULE = "docs-link"
DOC_RULE = "docs-docstring"

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def _check_links(config) -> list[Finding]:
    findings: list[Finding] = []
    root: Path = config.root
    for md in config.docs.md_files(root):
        rel = md.relative_to(root).as_posix()
        if not md.exists():
            findings.append(Finding(rule=LINK_RULE, path=rel, line=1,
                                    symbol="", message="file missing"))
            continue
        # scan the whole text, not line-by-line: [text](target) may wrap
        # across a line break inside the bracketed text
        text = md.read_text()
        for m in LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "#", "mailto:")):
                continue
            lineno = text.count("\n", 0, m.start()) + 1
            path = (md.parent / target.split("#", 1)[0]).resolve()
            if not path.exists():
                findings.append(Finding(
                    rule=LINK_RULE, path=rel, line=lineno, symbol="",
                    message=f"broken link -> {target}"))
    return findings


def _methods(cls: ast.ClassDef):
    for node in cls.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def _check_docstrings(config, files: list[SourceFile]) -> list[Finding]:
    findings: list[Finding] = []
    by_rel = {sf.rel: sf for sf in files}
    for rel, names in config.docs.api.items():
        sf = by_rel.get(rel)
        if sf is None:
            path = config.root / rel
            if not path.exists():
                findings.append(Finding(
                    rule=DOC_RULE, path=rel, line=1, symbol="",
                    message="API file missing"))
                continue
            sf = SourceFile.load(path, config.root)
        classes = {n.name: n for n in ast.walk(sf.tree)
                   if isinstance(n, ast.ClassDef)}
        for name in names:
            cls_name, _, meth_name = name.partition(".")
            cls = classes.get(cls_name)
            if cls is None:
                findings.append(Finding(
                    rule=DOC_RULE, path=rel, line=1, symbol=cls_name,
                    message=f"class {cls_name} not found"))
                continue
            if not ast.get_docstring(cls):
                findings.append(Finding(
                    rule=DOC_RULE, path=rel, line=cls.lineno,
                    symbol=cls_name,
                    message=f"{cls_name} has no class docstring"))
            wanted = ([m for m in _methods(cls) if m.name == meth_name]
                      if meth_name else
                      [m for m in _methods(cls)
                       if not m.name.startswith("_")])
            if meth_name and not wanted:
                findings.append(Finding(
                    rule=DOC_RULE, path=rel, line=cls.lineno,
                    symbol=name,
                    message=f"{cls_name}.{meth_name} not found"))
            for m in wanted:
                if not ast.get_docstring(m):
                    findings.append(Finding(
                        rule=DOC_RULE, path=rel, line=m.lineno,
                        symbol=f"{cls_name}.{m.name}",
                        message=f"{cls_name}.{m.name} has no docstring"))
    return findings


def run(config, files: list[SourceFile]) -> list[Finding]:
    return _check_links(config) + _check_docstrings(config, files)

"""quiverlint — repo-specific static analysis for the Quiver serving stack.

Enforces the invariants the serving stack's guarantees rest on (see
docs/invariants.md): lock discipline over the copy-on-write publication
protocol, trace safety inside jit/shard_map/Pallas bodies, the zero-
host-callback hot-path budget, stats-schema consistency, and docs
freshness. Pure stdlib (``ast``): files are parsed, never imported — the
same philosophy as the old ``tools/check_docs.py``, which now lives here
as the ``docs`` pass.

Run from the repo root::

    python tools/quiverlint [--json] [--pass NAME ...]

Suppress a single finding inline with a justification::

    something_flagged()  # quiverlint: disable=rule-id why this is safe

or grandfather deliberate exceptions in ``tools/quiverlint/baseline.json``
(a baselined finding that stops firing fails the run as *stale* so the
baseline can only shrink).
"""

__version__ = "1.0"

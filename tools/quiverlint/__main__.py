"""Entry point so the suite runs as ``python tools/quiverlint``."""
import sys
from pathlib import Path

# make `import quiverlint` work when invoked by directory path
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from quiverlint.driver import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())

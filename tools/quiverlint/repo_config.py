"""Repo-specific quiverlint configuration: the invariant registries.

This file is the single place where the serving stack's concurrency and
tracing contracts are written down as data (docs/invariants.md is the
prose version). Adding a guarded field, a hot-path root, or a stats
class here immediately puts it under enforcement.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path

from quiverlint import (callback_budget, docs_pass, lock_discipline,
                        schema_sync, trace_safety)

PASSES = {
    "lock": lock_discipline.run,
    "trace": trace_safety.run,
    "callback": callback_budget.run,
    "schema": schema_sync.run,
    "docs": docs_pass.run,
}


@dataclasses.dataclass
class SchemaSpec:
    schema_file: str = "src/repro/core/feature_store.py"
    schema_const: str = "STATS_SCHEMA"
    store_class: str = "TieredFeatureStore"
    cache_class: str = "GPUFeatureCache"
    # classes whose `self.stats = {...}` declaration must match their
    # `self.stats["key"]` uses exactly
    stats_classes: tuple = (
        ("core/gpu_cache.py", "GPUFeatureCache"),
        ("core/prefetch.py", "Prefetcher"),
        ("serving/adaptive.py", "AdaptiveController"),
        ("core/feature_store.py", "ShardedFeatureStore"),
        ("serving/gateway.py", "ServingGateway"),
    )
    # auxiliary schema constants: (file suffix, constant, stats class or
    # None, doc marker). Each constant's keys must match the table between
    # `<!-- quiverlint:<marker> -->` markers in marker_doc; with a stats
    # class named, that class's `self.stats` declaration must equal the
    # constant exactly (the constant is the class's published schema).
    aux_schemas: tuple = (
        ("serving/gateway.py", "GATEWAY_SCHEMA", "ServingGateway",
         "gateway-schema"),
        ("serving/gateway.py", "TELEMETRY_SAMPLE_SCHEMA", None,
         "telemetry-schema"),
        ("serving/engine.py", "CLASS_SAMPLE_SCHEMA", None, "class-schema"),
        ("core/feature_store.py", "SHARDED_STATS_SCHEMA",
         "ShardedFeatureStore", "sharded-schema"),
    )
    marker_doc: str = "docs/invariants.md"

    def doc_files(self, root: Path) -> list[Path]:
        return [root / "README.md", *sorted((root / "docs").glob("*.md"))]


@dataclasses.dataclass
class DocsSpec:
    # Public serving API surface whose docstrings are load-bearing
    # (referenced from docs/architecture.md). A bare class name means
    # "class docstring + every public method"; "Class.method" pins
    # specific methods only.
    api: dict = dataclasses.field(default_factory=lambda: {
        "src/repro/serving/engine.py": ["ServingEngine", "MicroBatcher"],
        "src/repro/serving/executors.py": ["Executor", "BaseExecutor",
                                           "HostExecutor", "DeviceExecutor",
                                           "ShardedExecutor"],
        "src/repro/serving/router.py": ["CostModelRouter"],
        "src/repro/serving/registry.py": ["ModelRegistry", "ModelEntry"],
        "src/repro/serving/adaptive.py": ["AdaptiveController",
                                          "FrequencySketch"],
        "src/repro/serving/gateway.py": ["ServingGateway", "GatewayConfig"],
        "src/repro/testing/clock.py": ["FakeClock"],
        "src/repro/core/feature_store.py": [
            "TieredFeatureStore.lookup", "TieredFeatureStore.lookup_hops",
            "TieredFeatureStore.lookup_aggregate",
            "TieredFeatureStore.swap_assignments",
            "TieredFeatureStore.publish_stage",
            "TieredFeatureStore.promote_misses", "DiskSpillTier",
            "ShardedFeatureStore.lookup", "ShardedFeatureStore.lookup_hops",
            "ShardedFeatureStore.publish_stage",
            "ShardedFeatureStore.read_cold_rows"],
        "src/repro/core/prefetch.py": ["Prefetcher"],
        "src/repro/core/gpu_cache.py": ["GPUFeatureCache"],
    })

    def md_files(self, root: Path) -> list[Path]:
        return [root / "README.md", *sorted((root / "docs").glob("*.md"))]


@dataclasses.dataclass
class Config:
    root: Path
    # files the code passes (lock/trace/callback/schema) analyze
    code_globs: list = dataclasses.field(default_factory=lambda: [
        "src/repro/**/*.py", "benchmarks/*.py", "examples/*.py"])

    # -- lock-discipline: (class, field) -> lock attribute ---------------
    # The copy-on-write publication protocol (docs/invariants.md#locks):
    # arrays are REPLACED never mutated, readers snapshot under the same
    # lock the publisher holds.
    guarded_fields: dict = dataclasses.field(default_factory=lambda: {
        "TieredFeatureStore": {
            # migration snapshot — published atomically by swap_assignments
            "hot": "_mig_lock", "warm": "_mig_lock", "host": "_mig_lock",
            "disk": "_mig_lock", "tier_t": "_mig_lock",
            "slot_t": "_mig_lock", "owner_t": "_mig_lock",
            "_stage": "_mig_lock", "cache": "_mig_lock",
            "migrated_rows": "_mig_lock",
            # dispatch accounting
            "stats": "_stats_lock", "_disk_miss_counts": "_stats_lock",
            "promoted_rows": "_stats_lock",
        },
        "GPUFeatureCache": {
            "_rows": "_lock", "_slot_of": "_lock", "_node_of": "_lock",
            "_ref": "_lock", "_hand": "_lock", "_free": "_lock",
            "stats": "_lock", "capacity": "_lock",
        },
        "Prefetcher": {
            "stats": "_lock", "_inflight": "_lock", "_error": "_lock",
            "_last_refresh_t": "_lock",
        },
        "ServingGateway": {
            # one condition guards all gateway state (docstring: the pump
            # re-entrancy flags, queue, counters and telemetry ring move
            # together)
            "stats": "_cv", "_queue": "_cv", "_seq": "_cv",
            "_gw_inflight": "_cv", "_pump_active": "_cv",
            "_pump_again": "_cv", "_telemetry": "_cv",
            "_last_sample_t": "_cv",
        },
        "ServingEngine": {
            "_error": "_lock", "_metrics": "_lock",
            "_inflight_batches": "_acct",
        },
        "AdaptiveController": {
            "samples": "_lock", "stats": "_lock", "_psgs_seen": "_lock",
            "_seeds_seen": "_lock", "_since_step": "_lock",
        },
        "FrequencySketch": {
            "counts": "_lock", "total_observed": "_lock",
        },
        "ShardedFeatureStore": {
            "stats": "_stats_lock",
            # staging snapshot — published atomically by publish_stage
            "_stage": "_stage_lock",
        },
    })
    # methods allowed to touch guarded fields lock-free (besides __init__):
    # documented lock-held-only helpers and build/teardown paths that run
    # before the object is shared
    lock_exempt_methods: dict = dataclasses.field(default_factory=lambda: {
        "GPUFeatureCache": {"_evict_slot"},  # called with _lock held only
        # swap_assignments is the designated single-publisher migration
        # helper: it reads pre-publish state lock-free by design (copy-on-
        # write — new arrays are built off-lock, published atomically under
        # _mig_lock; publisher serialization is the controller's _step_lock)
        "TieredFeatureStore": {"build", "swap_assignments"},
        "ShardedFeatureStore": {"build"},
        # called with _cv held only (documented lock-held-only helpers)
        "ServingGateway": {"_select_locked", "_pop_stale_locked"},
    })

    # -- trace-safety -----------------------------------------------------
    trace_wrappers: frozenset = frozenset({
        "jax.jit", "jit", "shard_map", "jax.shard_map",
        "jax.experimental.shard_map.shard_map",
        "pl.pallas_call", "pallas_call", "jax.pmap", "pmap",
    })
    np_aliases: frozenset = frozenset({"np", "numpy", "onp"})

    # -- callback-budget --------------------------------------------------
    callback_names: frozenset = frozenset({"io_callback", "pure_callback"})
    # steady-state hot path entry points (qualnames)
    hot_path_roots: frozenset = frozenset({
        "TieredFeatureStore.lookup", "TieredFeatureStore.lookup_hops",
        "TieredFeatureStore.lookup_aggregate",
        "ShardedFeatureStore.lookup", "ShardedFeatureStore.lookup_hops",
        "GPUFeatureCache.query",
        "BaseExecutor.submit", "BaseExecutor._collect",
        "HostExecutor.process", "DeviceExecutor.process",
        "ShardedExecutor.process",
    })
    # the one designated host-fetch fallback
    callback_gateways: frozenset = frozenset({
        "TieredFeatureStore._host_fetch",
    })
    # designated host-data routes that must stay plain numpy: each must
    # resolve, must NOT contain a direct io_callback/pure_callback, and
    # the hot-path BFS stops at them (they are the boundary where device
    # code hands cold ids to the host tiers)
    fetch_gateways: frozenset = frozenset({
        "TieredFeatureStore.read_cold_rows",
        "ShardedFeatureStore.read_cold_rows",
    })
    # roots that must never reach the listed qualnames even transitively:
    # the sharded hot path resolves cold rows through read_cold_rows (its
    # host callback budget is zero by construction — misses merge on the
    # host side of the shard_map, never via the tiered io_callback gateway)
    restricted_roots: dict = dataclasses.field(default_factory=lambda: {
        "ShardedFeatureStore.lookup": ("TieredFeatureStore._host_fetch",),
        "ShardedFeatureStore.lookup_hops": (
            "TieredFeatureStore._host_fetch",),
    })

    schema: SchemaSpec = dataclasses.field(default_factory=SchemaSpec)
    docs: DocsSpec = dataclasses.field(default_factory=DocsSpec)


def build(root: Path) -> Config:
    return Config(root=root)

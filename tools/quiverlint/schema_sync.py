"""Pass ``schema`` — one canonical stats schema, everywhere.

``STATS_SCHEMA`` in ``src/repro/core/feature_store.py`` is the single
source of truth for the tiered store's per-request counters. This pass
cross-checks, without importing anything:

* every ``self._count(key=...)`` increment in ``TieredFeatureStore``
  names a schema key, and every schema key is incremented somewhere
  (a key nobody produces is dead telemetry);
* per-class stats dicts (``GPUFeatureCache``, ``Prefetcher``,
  ``AdaptiveController``, ``ShardedFeatureStore``) declare exactly the
  keys their class reads/writes via ``self.stats["..."]``;
* the store mirrors the device cache: each ``cache_<k>`` in
  ``STATS_SCHEMA`` corresponds to a ``<k>`` in the cache's own schema;
* docs stay in sync: every schema key appears as a ``code span`` in the
  documentation, and the table between the
  ``<!-- quiverlint:stats-schema -->`` markers in ``docs/invariants.md``
  lists exactly the schema keys;
* auxiliary schema constants registered in ``SchemaSpec.aux_schemas``
  (gateway counters, telemetry sample keys, per-class sample keys) each
  match their own marked table in ``docs/invariants.md`` — and, when the
  entry names a stats class, that class's ``self.stats`` declaration
  equals the constant exactly.
"""
from __future__ import annotations

import ast
import re

from quiverlint.driver import Finding, SourceFile

RULE = "schema-sync"


def _find_class(files: list[SourceFile], rel_suffix: str, cls_name: str
                ) -> tuple[SourceFile, ast.ClassDef] | None:
    for sf in files:
        if not sf.rel.endswith(rel_suffix):
            continue
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.ClassDef) and node.name == cls_name:
                return sf, node
    return None


def _const_str_keys(node: ast.AST) -> set[str]:
    return {c.value for c in ast.walk(node)
            if isinstance(c, ast.Constant) and isinstance(c.value, str)}


def _schema_constant(files: list[SourceFile], rel_suffix: str,
                     const_name: str) -> tuple[SourceFile, int, set[str]] | None:
    for sf in files:
        if not sf.rel.endswith(rel_suffix):
            continue
        for node in sf.tree.body:
            targets = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                targets = [node.target]
            for t in targets:
                if isinstance(t, ast.Name) and t.id == const_name:
                    return sf, node.lineno, _const_str_keys(node.value)
    return None


def _stats_decl(sf: SourceFile, cls: ast.ClassDef) -> tuple[int, set[str]] | None:
    """Keys of ``self.stats = {...}`` (or ``= factory()``) in a class."""
    for node in ast.walk(cls):
        if not isinstance(node, ast.Assign):
            continue
        if not any(isinstance(t, ast.Attribute) and t.attr == "stats"
                   and isinstance(t.value, ast.Name) and t.value.id == "self"
                   for t in node.targets):
            continue
        value = node.value
        if isinstance(value, ast.Dict):
            return node.lineno, _const_str_keys(value)
        if isinstance(value, ast.Call) and isinstance(value.func, ast.Name):
            fname = value.func.id
            for fn in ast.walk(sf.tree):
                if isinstance(fn, ast.FunctionDef) and fn.name == fname:
                    for ret in ast.walk(fn):
                        if isinstance(ret, ast.Return) and ret.value is not None:
                            return node.lineno, _const_str_keys(ret.value)
    return None


def _stats_uses(cls: ast.ClassDef) -> dict[str, int]:
    """{key: first line} of ``self.stats["key"]`` subscripts in a class."""
    out: dict[str, int] = {}
    for node in ast.walk(cls):
        if (isinstance(node, ast.Subscript)
                and isinstance(node.value, ast.Attribute)
                and node.value.attr == "stats"
                and isinstance(node.value.value, ast.Name)
                and node.value.value.id == "self"
                and isinstance(node.slice, ast.Constant)
                and isinstance(node.slice.value, str)):
            out.setdefault(node.slice.value, node.lineno)
    return out


def _count_kwargs(cls: ast.ClassDef) -> dict[str, int]:
    """{key: first line} of ``self._count(key=...)`` keyword increments."""
    out: dict[str, int] = {}
    for node in ast.walk(cls):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "_count"
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == "self"):
            for kw in node.keywords:
                if kw.arg:
                    out.setdefault(kw.arg, node.lineno)
    return out


def run(config, files: list[SourceFile]) -> list[Finding]:
    spec = config.schema
    findings: list[Finding] = []

    found = _schema_constant(files, spec.schema_file, spec.schema_const)
    if found is None:
        findings.append(Finding(
            rule=RULE, path=spec.schema_file, line=1, symbol=spec.schema_const,
            message=f"canonical `{spec.schema_const}` constant not found "
                    f"in {spec.schema_file}"))
        return findings
    schema_sf, schema_line, schema = found

    # producer/consumer agreement for the store itself
    hit = _find_class(files, spec.schema_file, spec.store_class)
    if hit is not None:
        sf, cls = hit
        produced = _count_kwargs(cls)
        for key, line in sorted(produced.items()):
            if key not in schema:
                findings.append(Finding(
                    rule=RULE, path=sf.rel, line=line,
                    symbol=f"{cls.name}._count",
                    message=f"stats key `{key}` incremented but absent "
                            f"from {spec.schema_const}"))
        for key in sorted(schema - set(produced)):
            findings.append(Finding(
                rule=RULE, path=schema_sf.rel, line=schema_line,
                symbol=spec.schema_const,
                message=f"schema key `{key}` is never incremented by "
                        f"`{cls.name}._count` (dead telemetry)"))

    # per-class declared-vs-used stats keys
    class_schemas: dict[str, set[str]] = {}
    for rel_suffix, cls_name in spec.stats_classes:
        hit = _find_class(files, rel_suffix, cls_name)
        if hit is None:
            findings.append(Finding(
                rule=RULE, path=rel_suffix, line=1, symbol=cls_name,
                message=f"registered stats class `{cls_name}` not found"))
            continue
        sf, cls = hit
        decl = _stats_decl(sf, cls)
        if decl is None:
            findings.append(Finding(
                rule=RULE, path=sf.rel, line=cls.lineno, symbol=cls_name,
                message="no `self.stats = {...}` declaration found"))
            continue
        decl_line, declared = decl
        class_schemas[cls_name] = declared
        used = _stats_uses(cls)
        for key, line in sorted(used.items()):
            if key not in declared:
                findings.append(Finding(
                    rule=RULE, path=sf.rel, line=line, symbol=cls_name,
                    message=f"`self.stats[{key!r}]` used but not declared "
                            f"in the class stats dict"))
        for key in sorted(declared - set(used)):
            findings.append(Finding(
                rule=RULE, path=sf.rel, line=decl_line, symbol=cls_name,
                message=f"declared stats key `{key}` is never read or "
                        f"written by {cls_name}"))

    # store's cache_* mirror of the device-cache schema
    if spec.cache_class in class_schemas:
        cache_keys = class_schemas[spec.cache_class]
        for key in sorted(schema):
            if key.startswith("cache_") and key[len("cache_"):] not in cache_keys:
                findings.append(Finding(
                    rule=RULE, path=schema_sf.rel, line=schema_line,
                    symbol=spec.schema_const,
                    message=f"`{key}` mirrors no `{key[len('cache_'):]}` "
                            f"counter in {spec.cache_class}"))

    # docs agreement
    findings.extend(_check_docs(config, schema))

    # auxiliary schema constants (gateway / telemetry / per-class samples)
    findings.extend(_check_aux_schemas(config, files))
    return findings


def _marker_block(marker: str) -> re.Pattern:
    return re.compile(
        rf"<!--\s*quiverlint:{marker}\s*-->(.*?)"
        rf"<!--\s*/quiverlint:{marker}\s*-->", re.S)


MARKER_RE = _marker_block("stats-schema")
# inside the marker block only first-column table cells count as schema
# entries (prose in other columns may legitimately mention other spans)
CODE_SPAN_RE = re.compile(r"^\|\s*`([A-Za-z_][A-Za-z0-9_]*)`", re.M)


def _check_aux_schemas(config, files: list[SourceFile]) -> list[Finding]:
    """Each registered aux constant: keys == its marked doc table, and —
    when a stats class is registered — == that class's stats declaration."""
    spec = config.schema
    findings: list[Finding] = []
    marker_path = config.root / spec.marker_doc
    doc_text = marker_path.read_text() if marker_path.exists() else ""
    for rel_suffix, const, cls_name, marker in getattr(spec, "aux_schemas",
                                                       ()):
        found = _schema_constant(files, rel_suffix, const)
        if found is None:
            findings.append(Finding(
                rule=RULE, path=rel_suffix, line=1, symbol=const,
                message=f"registered aux schema constant `{const}` not "
                        f"found in {rel_suffix}"))
            continue
        sf, line, keys = found
        if cls_name is not None:
            hit = _find_class(files, rel_suffix, cls_name)
            decl = _stats_decl(*hit) if hit is not None else None
            if decl is None:
                findings.append(Finding(
                    rule=RULE, path=rel_suffix, line=line, symbol=cls_name,
                    message=f"aux schema `{const}` names stats class "
                            f"`{cls_name}` but its `self.stats = {{...}}` "
                            f"declaration was not found"))
            else:
                decl_line, declared = decl
                for key in sorted(keys - declared):
                    findings.append(Finding(
                        rule=RULE, path=sf.rel, line=decl_line,
                        symbol=cls_name,
                        message=f"`{const}` key `{key}` missing from "
                                f"{cls_name}'s stats declaration"))
                for key in sorted(declared - keys):
                    findings.append(Finding(
                        rule=RULE, path=sf.rel, line=decl_line,
                        symbol=cls_name,
                        message=f"{cls_name} stats key `{key}` is absent "
                                f"from `{const}`"))
        m = _marker_block(marker).search(doc_text)
        if m is None:
            findings.append(Finding(
                rule=RULE, path=spec.marker_doc, line=1, symbol=marker,
                message=f"no `<!-- quiverlint:{marker} -->` block found"))
            continue
        doc_line = doc_text.count("\n", 0, m.start()) + 1
        listed = set(CODE_SPAN_RE.findall(m.group(1)))
        for key in sorted(keys - listed):
            findings.append(Finding(
                rule=RULE, path=spec.marker_doc, line=doc_line,
                symbol=marker,
                message=f"`{const}` key `{key}` missing from the "
                        f"{marker} table"))
        for key in sorted(listed - keys):
            findings.append(Finding(
                rule=RULE, path=spec.marker_doc, line=doc_line,
                symbol=marker,
                message=f"documented key `{key}` is not in `{const}` "
                        f"(stale docs)"))
    return findings


def _check_docs(config, schema: set[str]) -> list[Finding]:
    spec = config.schema
    findings: list[Finding] = []
    texts: dict[str, str] = {}
    for path in spec.doc_files(config.root):
        if path.exists():
            texts[path.relative_to(config.root).as_posix()] = path.read_text()
    everywhere = "\n".join(texts.values())
    for key in sorted(schema):
        if f"`{key}`" not in everywhere:
            findings.append(Finding(
                rule=RULE, path=spec.marker_doc, line=1, symbol=key,
                message=f"schema key `{key}` is not documented as a "
                        f"code span in any docs page"))

    marker_rel = spec.marker_doc
    text = texts.get(marker_rel)
    if text is None:
        findings.append(Finding(
            rule=RULE, path=marker_rel, line=1, symbol="stats-schema",
            message="stats-schema doc page missing"))
        return findings
    m = MARKER_RE.search(text)
    if m is None:
        findings.append(Finding(
            rule=RULE, path=marker_rel, line=1, symbol="stats-schema",
            message="no `<!-- quiverlint:stats-schema -->` block found"))
        return findings
    line = text.count("\n", 0, m.start()) + 1
    listed = set(CODE_SPAN_RE.findall(m.group(1)))
    for key in sorted(schema - listed):
        findings.append(Finding(
            rule=RULE, path=marker_rel, line=line, symbol="stats-schema",
            message=f"schema key `{key}` missing from the stats-schema "
                    f"table"))
    for key in sorted(listed - schema):
        findings.append(Finding(
            rule=RULE, path=marker_rel, line=line, symbol="stats-schema",
            message=f"documented key `{key}` is not in "
                    f"{spec.schema_const} (stale docs)"))
    return findings

#!/usr/bin/env python3
"""Thin shim: the docs gate now lives in quiverlint as the ``docs`` pass
(one entry point, one CI invocation — see tools/quiverlint/).

    python tools/check_docs.py  ==  python tools/quiverlint --pass docs
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

from quiverlint.driver import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main(["--pass", "docs"]))

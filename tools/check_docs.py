#!/usr/bin/env python3
"""Docs gate (CI `docs` job): fails on broken intra-repo markdown links in
README.md / docs/*.md and on missing docstrings in the public serving API.

Pure stdlib (``ast`` + ``re``) so the CI job needs no dependencies — API
files are parsed, never imported.

    python tools/check_docs.py
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

MD_FILES = [REPO / "README.md", *sorted((REPO / "docs").glob("*.md"))]

# Public serving API surface whose docstrings are load-bearing (referenced
# from docs/architecture.md). A bare class name means "class docstring +
# every public method"; "Class.method" pins specific methods only.
API = {
    "src/repro/serving/engine.py": ["ServingEngine", "MicroBatcher"],
    "src/repro/serving/executors.py": ["Executor", "BaseExecutor",
                                       "HostExecutor", "DeviceExecutor",
                                       "ShardedExecutor"],
    "src/repro/serving/router.py": ["CostModelRouter"],
    "src/repro/serving/registry.py": ["ModelRegistry", "ModelEntry"],
    "src/repro/serving/adaptive.py": ["AdaptiveController",
                                      "FrequencySketch"],
    "src/repro/core/feature_store.py": [
        "TieredFeatureStore.lookup", "TieredFeatureStore.lookup_hops",
        "TieredFeatureStore.swap_assignments",
        "TieredFeatureStore.publish_stage",
        "TieredFeatureStore.promote_misses", "DiskSpillTier"],
    "src/repro/core/prefetch.py": ["Prefetcher"],
    "src/repro/core/gpu_cache.py": ["GPUFeatureCache"],
}

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def check_links() -> list[str]:
    errors = []
    for md in MD_FILES:
        if not md.exists():
            errors.append(f"{md.relative_to(REPO)}: file missing")
            continue
        # scan the whole text, not line-by-line: [text](target) may wrap
        # across a line break inside the bracketed text
        text = md.read_text()
        for m in LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "#", "mailto:")):
                continue
            lineno = text.count("\n", 0, m.start()) + 1
            path = (md.parent / target.split("#", 1)[0]).resolve()
            if not path.exists():
                errors.append(f"{md.relative_to(REPO)}:{lineno}: "
                              f"broken link -> {target}")
    return errors


def _methods(cls: ast.ClassDef):
    for node in cls.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def check_docstrings() -> list[str]:
    errors = []
    for rel, names in API.items():
        path = REPO / rel
        tree = ast.parse(path.read_text())
        classes = {n.name: n for n in ast.walk(tree)
                   if isinstance(n, ast.ClassDef)}
        for name in names:
            cls_name, _, meth_name = name.partition(".")
            cls = classes.get(cls_name)
            if cls is None:
                errors.append(f"{rel}: class {cls_name} not found")
                continue
            if not ast.get_docstring(cls):
                errors.append(f"{rel}: {cls_name} has no class docstring")
            wanted = ([m for m in _methods(cls) if m.name == meth_name]
                      if meth_name else
                      [m for m in _methods(cls)
                       if not m.name.startswith("_")])
            if meth_name and not wanted:
                errors.append(f"{rel}: {cls_name}.{meth_name} not found")
            for m in wanted:
                if not ast.get_docstring(m):
                    errors.append(f"{rel}:{m.lineno}: {cls_name}.{m.name} "
                                  f"has no docstring")
    return errors


def main() -> int:
    errors = check_links() + check_docstrings()
    for e in errors:
        print(f"ERROR: {e}")
    n_md = len(MD_FILES)
    n_api = sum(len(v) for v in API.values())
    print(f"checked {n_md} markdown files, {n_api} API surfaces: "
          f"{len(errors)} problem(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())

"""Real memory hierarchy (PR 5): the mmap-backed DISK spill tier and the
sketch-driven prefetcher, proven correct by tier equivalence — staged
lookups bit-identical to unstaged ones (and to the raw features), hit/miss
accounting, miss-driven promotion, the pinned dispatch-counter schema, and
snapshot consistency under prefetch refresh racing live migration."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DiskSpillTier, Prefetcher, Request,
                        TieredFeatureStore, TopologySpec, compute_fap,
                        compute_psgs, migration_pairs, quiver_placement)
from repro.core.placement import TIER_DISK, TIER_HOST, TIER_HOT, TIER_WARM
from repro.graph import power_law_graph
from repro.models.gnn_basic import sage_init, sage_layered
from repro.serving import (AdaptiveConfig, AdaptiveController, HostExecutor,
                           ServingEngine, StaticScheduler)

# The canonical dispatch-stats schema: ServeMetrics.summary()["store"]
# relies on these exact counters (benchmarks/prefetch.py + fused_gather.py
# read them). One source of truth — quiverlint's schema-sync pass keeps
# producers and docs aligned with it.
from repro.core import STATS_SCHEMA as _SCHEMA  # noqa: E402

STATS_SCHEMA = set(_SCHEMA)


# ---------------------------------------------------------------------------
# Fixtures (the test_fused_gather sweep harness, spill-backed)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def stack():
    n, d, fan = 900, 12, (4, 3)
    g = power_law_graph(n, 6.0, seed=0)
    feats = np.random.default_rng(1).normal(size=(n, d)).astype(np.float32)
    fap = compute_fap(g, fan)
    topo = TopologySpec(num_pods=1, devices_per_pod=1, rows_per_device=220,
                        rows_host=330, hot_replicate_fraction=0.3)
    return g, fan, feats, fap, topo


def _fresh_store(stack, spill_path=None):
    g, fan, feats, fap, topo = stack
    return TieredFeatureStore.build(feats, quiver_placement(fap, topo),
                                    spill_path=spill_path)


def _rand_hops(n, sizes, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(-1, n, size=s).astype(np.int32) for s in sizes]


def _stage_all_cold(store, n, budget=None):
    """Stage every cold-tier row (uniform scores) and return the prefetcher."""
    pf = Prefetcher(store, budget=budget or n)
    pf.refresh(scores=np.ones(n))
    return pf


# ---------------------------------------------------------------------------
# DISK spill tier: the mmap file is real and bit-identical
# ---------------------------------------------------------------------------
def test_spill_file_written_and_rows_bit_identical(stack, tmp_path):
    g, fan, feats, fap, topo = stack
    path = str(tmp_path / "feat.spill")
    store = _fresh_store(stack, spill_path=path)
    plan = store.plan
    disk_ids = np.flatnonzero(plan.tier == TIER_DISK)
    assert disk_ids.size > 0
    # the spill file itself holds the real rows (not zeros)
    mm = np.memmap(path, dtype=feats.dtype, mode="r",
                   shape=(disk_ids.size, feats.shape[1]))
    assert np.array_equal(np.asarray(mm)[plan.slot[disk_ids]],
                          feats[disk_ids])
    # and lookups through the store return them bit for bit
    out = np.asarray(store.lookup(jnp.asarray(disk_ids, jnp.int32)))
    assert np.array_equal(out, feats[disk_ids])
    assert store.disk.path == path


def test_disk_spill_tier_copy_on_write_overlay(tmp_path):
    rows = np.arange(12, dtype=np.float32).reshape(4, 3)
    tier = DiskSpillTier.build(rows, str(tmp_path / "t.spill"))
    assert np.array_equal(tier[np.array([2, 0])], rows[[2, 0]])
    clone = tier.copy()
    clone[np.array([1])] = np.full((1, 3), 9.0, np.float32)
    # the original (an in-flight snapshot) is untouched; the file too
    assert np.array_equal(tier[1], rows[1])
    assert np.array_equal(clone[1], np.full(3, 9.0))
    assert clone.overlay_rows == 1 and tier.overlay_rows == 0
    assert np.array_equal(np.asarray(tier), rows)
    got = np.asarray(clone)
    assert np.array_equal(got[1], np.full(3, 9.0))
    assert np.array_equal(got[[0, 2, 3]], rows[[0, 2, 3]])


def test_disk_spill_tier_compaction_bounds_overlay(tmp_path):
    rows = np.arange(40, dtype=np.float32).reshape(10, 4)
    tier = DiskSpillTier.build(rows, str(tmp_path / "c.spill"))
    snap = tier.copy()                       # an in-flight snapshot
    tier[np.array([1, 3])] = np.stack([np.full(4, 7.0), np.full(4, 8.0)])
    compacted = tier.compact()
    # merged rows live in a fresh generation file; overlay is gone
    assert compacted.overlay_rows == 0
    assert compacted.path.endswith(".g1") and compacted.path != tier.path
    want = rows.copy()
    want[1], want[3] = 7.0, 8.0
    assert np.array_equal(np.asarray(compacted), want)
    # the old snapshot still reads the ORIGINAL rows (file unlinked but
    # kept alive by its mapping — POSIX semantics)
    assert np.array_equal(np.asarray(snap), rows)
    # resident accounting: spill-backed tiers count only the overlay
    assert compacted.resident_nbytes == 0
    assert tier.resident_nbytes == 2 * 4 * 4
    assert DiskSpillTier.build(rows, None).resident_nbytes == rows.nbytes


def test_swap_assignments_auto_compacts_spill_overlay(stack, tmp_path):
    """Demotion churn must not grow the spill overlay without bound: once
    it exceeds len//8 the migration publish path folds it into a fresh
    spill-file generation (lookups stay exact throughout)."""
    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack, spill_path=str(tmp_path / "churn.spill"))
    tier = np.asarray(store.tier_t)
    disk_ids = np.flatnonzero(tier == TIER_DISK)
    host_ids = np.flatnonzero(tier == TIER_HOST)
    limit = max(64, len(store.disk) // 8)
    swaps = min(limit + 8, disk_ids.size, host_ids.size)
    for lo in range(0, swaps, 16):   # bounded steps, like the controller
        pairs = list(zip(host_ids[lo:lo + 16].tolist(),
                         disk_ids[lo:lo + 16].tolist()))
        store.swap_assignments(pairs)
    assert store.disk.overlay_rows <= limit   # compaction kicked in
    assert store.disk.path.endswith(".g1")
    ids = jnp.asarray(np.arange(g.num_nodes), jnp.int32)
    assert np.array_equal(np.asarray(store.lookup(ids)), feats)


def test_standalone_prefetcher_decays_owned_sketch(stack):
    """Regression: without decay the standalone sketch freezes on the
    all-time hot set and periodic refreshes re-stage stale predictions."""
    from repro.serving import FrequencySketch

    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack)
    pf = Prefetcher(store, FrequencySketch(g.num_nodes, decay=0.5),
                    budget=4, refresh_every=2)
    pf.sketch.observe(np.array([3, 3]))
    for _ in range(2):
        pf.on_batch_complete("host", np.array([0]), 1e-3)
    assert pf.sketch.counts[3] == pytest.approx(1.0)  # decayed once
    pf.close()


# ---------------------------------------------------------------------------
# Tier equivalence: prefetch on vs off, per-hop and fused
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("sizes", [(16,), (16, 64), (16, 64, 192), (1, 1)])
def test_lookup_bit_identical_prefetch_on_vs_off(stack, tmp_path, sizes):
    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack, spill_path=str(tmp_path / "s.spill"))
    hops = _rand_hops(g.num_nodes, sizes, seed=sum(sizes))
    plain = [np.asarray(store.lookup(jnp.asarray(h))) for h in hops]
    plain_fused = [np.asarray(o) for o in store.lookup_hops(hops)]
    _stage_all_cold(store, g.num_nodes)
    staged = [np.asarray(store.lookup(jnp.asarray(h))) for h in hops]
    staged_fused = [np.asarray(o) for o in store.lookup_hops(hops)]
    for a, b, c, d_ in zip(plain, staged, plain_fused, staged_fused):
        assert np.array_equal(a, b)   # bit-identical, not close
        assert np.array_equal(c, d_)
        assert np.array_equal(a, c)


def test_partial_stage_falls_back_bit_identical(stack, tmp_path):
    """A stage covering only SOME cold rows must mix device-staged rows and
    host-callback rows without changing a single bit."""
    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack, spill_path=str(tmp_path / "p.spill"))
    cold = np.flatnonzero(plan_tier := np.asarray(store.tier_t) >= TIER_HOST)
    scores = np.zeros(g.num_nodes)
    scores[cold[::2]] = 1.0   # stage every other cold node
    Prefetcher(store, budget=g.num_nodes).refresh(scores=scores)
    ids = _rand_hops(g.num_nodes, (256,), seed=5)[0]
    out = np.asarray(store.lookup(jnp.asarray(ids)))
    exp = np.where((ids >= 0)[:, None], feats[np.maximum(ids, 0)], 0.0)
    assert np.array_equal(out, exp)
    stats = store.reset_stats()
    assert stats["prefetch_hits"] > 0 and stats["prefetch_misses"] > 0
    assert stats["host_fetches"] > 0   # the fallback really was exercised


def test_include_host_false_ignores_stage(stack):
    """Device-only probes must stay zeros for cold tiers even when staged —
    otherwise the two paths diverge."""
    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack)
    ids = _rand_hops(g.num_nodes, (128,), seed=3)[0]
    want = np.asarray(store.lookup(jnp.asarray(ids), include_host=False))
    _stage_all_cold(store, g.num_nodes)
    got = np.asarray(store.lookup(jnp.asarray(ids), include_host=False))
    assert np.array_equal(want, got)


# ---------------------------------------------------------------------------
# Accounting: staged hits, fallback misses, disk misses, spill reads
# ---------------------------------------------------------------------------
def test_stage_hit_miss_accounting_exact(stack):
    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack)
    tier = np.asarray(store.tier_t)
    hot_id = int(np.flatnonzero(tier == TIER_HOT)[0])
    host_ids = np.flatnonzero(tier == TIER_HOST)[:4]
    disk_ids = np.flatnonzero(tier == TIER_DISK)[:4]
    # stage exactly two host rows and one disk row
    scores = np.zeros(g.num_nodes)
    scores[host_ids[:2]] = 1.0
    scores[disk_ids[:1]] = 1.0
    Prefetcher(store, budget=8).refresh(scores=scores)
    store.reset_stats()
    ids = np.concatenate([[hot_id], host_ids, disk_ids, [-1]])
    out = np.asarray(store.lookup(jnp.asarray(ids, jnp.int32)))
    exp = np.where((ids >= 0)[:, None], feats[np.maximum(ids, 0)], 0.0)
    assert np.array_equal(out, exp)
    stats = store.reset_stats()
    assert stats["prefetch_hits"] == 3        # 2 host + 1 disk staged
    assert stats["prefetch_misses"] == 5      # 2 host + 3 disk fell back
    assert stats["disk_misses"] == 3          # the unstaged disk rows
    assert stats["spill_reads"] == 3          # critical-path spill reads
    assert stats["host_fetches"] == 1         # one fallback callback


def test_full_stage_skips_host_callback(stack):
    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack)
    _stage_all_cold(store, g.num_nodes)
    store.reset_stats()
    hops = _rand_hops(g.num_nodes, (64, 128), seed=9)
    store.lookup_hops(hops)
    stats = store.reset_stats()
    assert stats["host_fetches"] == 0         # zero critical-path callbacks
    assert stats["prefetch_misses"] == 0
    assert stats["prefetch_hits"] > 0
    assert stats["disk_misses"] == 0


def test_counter_schema_pinned(stack):
    store = _fresh_store(stack)
    assert set(store.reset_stats()) == STATS_SCHEMA


def test_serve_metrics_summary_reports_counters(stack):
    """Regression (satellite): the engine's summary must expose the DISK
    and prefetch counters as distinct fields under the store snapshot."""
    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack)
    psgs = compute_psgs(g, fan)
    params = sage_init(jax.random.key(0), [feats.shape[1], 16, 16])

    @jax.jit
    def infer_fn(hop_feats, hop_ids):
        masks = [(h >= 0).astype(jnp.float32)[:, None] for h in hop_ids]
        return sage_layered(params, hop_feats, fan, hop_masks=masks)

    host = HostExecutor(g, store, fan, infer_fn, psgs_table=psgs)
    engine = ServingEngine({"host": host}, StaticScheduler("host"))
    store.reset_stats()
    cold = np.argsort(fap)[:8]
    m = engine.run([[Request(0, cold.copy(), time.perf_counter())]])
    got = m.summary()["store"]["TieredFeatureStore"]
    # the snapshot is the schema counters plus the executors' active
    # feature-collection mode (never written into store.stats itself)
    assert set(got) == STATS_SCHEMA | {"collect_mode"}
    assert got["collect_mode"] == "fused"
    assert got["fused_calls"] >= 1
    engine.close()


# ---------------------------------------------------------------------------
# Miss-driven promotion
# ---------------------------------------------------------------------------
def test_promote_misses_moves_hammered_disk_rows(stack, tmp_path):
    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack, spill_path=str(tmp_path / "m.spill"))
    tier = np.asarray(store.tier_t)
    hammered = np.flatnonzero(tier == TIER_DISK)[:6]
    counts_before = store.plan.tier_counts()
    for _ in range(3):
        store.lookup(jnp.asarray(hammered, jnp.int32))
    moved = store.promote_misses(budget=6)
    assert moved == 12 and store.promoted_rows == 12
    assert (np.asarray(store.tier_t)[hammered] == TIER_HOST).all()
    assert store.plan.tier_counts() == counts_before  # swap preserves counts
    store.plan.validate()
    # counts were consumed: a second promote with no new misses is a no-op
    assert store.promote_misses(budget=6) == 0
    # lookup equivalence: every row still resolves to its exact features
    ids = jnp.asarray(np.arange(g.num_nodes), jnp.int32)
    assert np.array_equal(np.asarray(store.lookup(ids)), feats)


def test_promote_misses_without_traffic_is_noop(stack):
    store = _fresh_store(stack)
    assert store.promote_misses(budget=8) == 0


def test_controller_step_promotes_and_refreshes(stack):
    """AdaptiveController integration: each control step promotes missed
    DISK rows and re-stages the prefetcher with the fresh FAP."""
    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack)
    pf = Prefetcher(store, budget=g.num_nodes)
    ctl = AdaptiveController(
        g, fan, store, prefetcher=pf,
        config=AdaptiveConfig(rows_per_step=2, promote_budget=8))
    assert pf.sketch is ctl.sketch           # shared sketch
    disk_ids = np.flatnonzero(np.asarray(store.tier_t) == TIER_DISK)[:4]
    for _ in range(2):
        store.lookup(jnp.asarray(disk_ids, jnp.int32))
    ctl.on_admit("host", disk_ids)
    r = ctl.step()
    # the FAP migration step (budget 1 pair) may grab one hammered node
    # first; the remaining ≥3 are promoted by the miss-driven pass
    assert r["promoted_rows"] >= 6 and r["prefetched"]
    assert ctl.stats["promoted_rows"] == r["promoted_rows"]
    assert ctl.stats["prefetch_refreshes"] == 1
    # the async refresh eventually publishes a stage
    deadline = time.time() + 30
    while store.staged_rows() == 0 and time.time() < deadline:
        time.sleep(0.01)
    assert store.staged_rows() > 0
    pf.close()
    assert store.staged_rows() == 0          # close clears the stage


# ---------------------------------------------------------------------------
# Prefetcher unit behavior
# ---------------------------------------------------------------------------
def test_predict_cold_only_budget_and_zero_scores(stack):
    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack)
    tier = np.asarray(store.tier_t)
    pf = Prefetcher(store, budget=5)
    ids = pf.predict(scores=np.ones(g.num_nodes))
    assert ids.size == 5 and (tier[ids] >= TIER_HOST).all()
    assert pf.predict(scores=np.zeros(g.num_nodes)).size == 0  # cold start
    with pytest.raises(ValueError, match="scores or a sketch"):
        pf.predict()
    with pytest.raises(ValueError, match="budget"):
        Prefetcher(store, budget=0)


def test_prefetcher_standalone_engine_hook(stack):
    """Standalone mode: as an engine hook the prefetcher feeds its own
    sketch and refreshes every ``refresh_every`` completed batches."""
    from repro.serving import FrequencySketch

    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack)
    psgs = compute_psgs(g, fan)
    params = sage_init(jax.random.key(0), [feats.shape[1], 16, 16])

    @jax.jit
    def infer_fn(hop_feats, hop_ids):
        masks = [(h >= 0).astype(jnp.float32)[:, None] for h in hop_ids]
        return sage_layered(params, hop_feats, fan, hop_masks=masks)

    host = HostExecutor(g, store, fan, infer_fn, psgs_table=psgs)
    pf = Prefetcher(store, FrequencySketch(g.num_nodes), budget=64,
                    refresh_every=3)
    engine = ServingEngine({"host": host}, StaticScheduler("host"),
                           hooks=[pf])
    cold = np.argsort(fap)[:8]
    m = engine.run([[Request(i, cold.copy(), time.perf_counter())]
                    for i in range(9)])
    assert m.requests == 9
    deadline = time.time() + 30
    while pf.report()["refreshes"] == 0 and time.time() < deadline:
        time.sleep(0.01)
    rep = pf.report()
    assert rep["batches_seen"] == 9 and rep["refreshes"] >= 1
    assert pf.sketch.total_observed == 9 * 8
    engine.close()
    pf.close()


# ---------------------------------------------------------------------------
# Property: promotion + prefetch never change lookup results (hypothesis)
# ---------------------------------------------------------------------------
@pytest.mark.hypothesis
def test_promotion_prefetch_lookup_invariance_property(stack):
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    g, fan, feats, fap, topo = stack
    n = g.num_nodes

    @settings(max_examples=10, deadline=None)
    @given(st.lists(st.integers(-1, n - 1), min_size=1, max_size=60),
           st.lists(st.integers(0, n - 1), min_size=0, max_size=30,
                    unique=True),
           st.integers(0, 12))
    def prop(id_mix, staged_ids, promote_budget):
        store = _fresh_store(stack)
        # arbitrary staged subset (cold-filtered by predict), arbitrary
        # miss traffic, arbitrary promotion budget — results never change
        scores = np.zeros(n)
        scores[np.asarray(staged_ids, dtype=np.int64)] = 1.0
        Prefetcher(store, budget=n).refresh(scores=scores)
        ids = np.asarray(id_mix, dtype=np.int32)
        exp = np.where((ids >= 0)[:, None], feats[np.maximum(ids, 0)], 0.0)
        assert np.array_equal(np.asarray(store.lookup(jnp.asarray(ids))),
                              exp)
        store.promote_misses(budget=promote_budget)
        assert np.array_equal(np.asarray(store.lookup(jnp.asarray(ids))),
                              exp)
        hops = [ids, np.asarray(staged_ids or [-1], np.int32)]
        got = store.lookup_hops(hops)
        assert np.array_equal(np.asarray(got[0]), exp)

    prop()


# ---------------------------------------------------------------------------
# Concurrency stress: refresh × migration × fused lookups (satellite)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_prefetch_refresh_racing_migration_and_lookups(stack, tmp_path):
    """Extension of the tests/test_adaptive.py concurrent-migration harness:
    one thread runs fused lookups, one re-publishes the staging buffer with
    random score vectors, while the main thread migrates rows AND promotes
    misses on the same store — every observed row must stay exact."""
    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack, spill_path=str(tmp_path / "race.spill"))
    rng = np.random.default_rng(7)
    hops = [rng.integers(0, g.num_nodes, 16).astype(np.int32),
            rng.integers(0, g.num_nodes, 48).astype(np.int32)]
    expected = [feats[h] for h in hops]
    stop = threading.Event()
    errors: list[str] = []
    pf = Prefetcher(store, budget=g.num_nodes)

    def reader():
        while not stop.is_set():
            got = store.lookup_hops(hops)
            for e, o in zip(expected, got):
                if not np.array_equal(np.asarray(o), e):
                    errors.append("torn staged lookup during migration")
                    return

    def refresher():
        rrng = np.random.default_rng(13)
        while not stop.is_set():
            scores = rrng.random(g.num_nodes)
            scores[scores < 0.5] = 0.0   # vary the staged subset
            try:
                pf.refresh(scores=scores)
            except BaseException as exc:  # surface, don't hang the test
                errors.append(f"refresh raised: {exc!r}")
                return

    threads = [threading.Thread(target=reader),
               threading.Thread(target=refresher)]
    for t in threads:
        t.start()
    try:
        drifted = fap.copy()
        drifted[np.argsort(fap)[:80]] += fap.max() * 3
        tgt = quiver_placement(drifted, topo)
        for _ in range(10):
            pairs = migration_pairs(store.plan.tier, tgt.tier, drifted,
                                    budget=20)
            if pairs:
                store.swap_assignments(pairs)
            store.promote_misses(budget=4)
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=60)
    assert not errors, errors
    for e, o in zip(expected, store.lookup_hops(hops)):
        assert np.array_equal(np.asarray(o), e)
    pf.close()

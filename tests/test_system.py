"""End-to-end behaviour tests for the full Quiver serving system."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DynamicBatcher, HybridScheduler, ServingEngine,
                        StaticScheduler, TieredFeatureStore, TopologySpec,
                        WorkloadGenerator, compute_fap, compute_psgs,
                        quiver_placement)
from repro.graph import power_law_graph
from repro.models.gnn_basic import sage_init, sage_layered


def _stack(nodes=1500, fanouts=(4, 3), d=16, seed=0):
    g = power_law_graph(nodes, 6.0, seed=seed)
    feats = np.random.default_rng(seed + 1).normal(
        size=(nodes, d)).astype(np.float32)
    psgs = compute_psgs(g, fanouts)
    gen = WorkloadGenerator(nodes, g.out_degree, seed=seed + 2)
    fap = compute_fap(g, fanouts, seed_prob=gen.p)
    topo = TopologySpec(num_pods=1, devices_per_pod=1,
                        rows_per_device=nodes // 3, rows_host=nodes // 2,
                        hot_replicate_fraction=0.3)
    store = TieredFeatureStore.build(feats, quiver_placement(fap, topo))
    params = sage_init(jax.random.key(seed), [d, 32, 32])

    @jax.jit
    def infer_fn(hop_feats, hop_ids):
        masks = [(h >= 0).astype(jnp.float32)[:, None] for h in hop_ids]
        return sage_layered(params, hop_feats, fanouts, hop_masks=masks)

    return g, store, fanouts, infer_fn, psgs, gen


def test_full_pipeline_hybrid_routing_and_latency_accounting():
    g, store, fan, infer_fn, psgs, gen = _stack()
    sched = HybridScheduler(psgs, float(np.median(psgs)) * 24)
    engine = ServingEngine(g, store, fan, infer_fn, sched, num_workers=2,
                           max_batch=16)
    batches = [[r] for r in gen.stream(20, seeds_per_request=6)]
    engine.warmup(batches[0])
    m = engine.run(batches)
    s = m.summary()
    assert s["requests"] == 20
    assert s["routed_host"] + s["routed_device"] == 20
    assert 0 < s["p50_ms"] <= s["p99_ms"] <= s["max_ms"]


def test_stream_serving_with_psgs_budget_batcher():
    g, store, fan, infer_fn, psgs, gen = _stack(seed=3)
    engine = ServingEngine(g, store, fan, infer_fn,
                           StaticScheduler("host"), num_workers=2,
                           max_batch=32)
    reqs = list(gen.stream(30, seeds_per_request=2))
    engine.warmup([reqs[0]])
    batcher = DynamicBatcher(deadline_s=0.05,
                             psgs_budget=float(np.median(psgs)) * 12,
                             psgs_table=psgs, max_batch=32)
    m = engine.serve_stream(reqs, batcher, gap_s=0.001)
    assert m.summary()["requests"] == 30


def test_host_and_device_paths_produce_embeddings_for_same_seeds():
    g, store, fan, infer_fn, psgs, gen = _stack(seed=5)
    engine = ServingEngine(g, store, fan, infer_fn,
                           StaticScheduler("host"), max_batch=16)
    seeds = np.arange(12)
    out_h = np.asarray(engine._host_path(seeds))
    out_d = np.asarray(engine._device_path(seeds))
    assert np.isfinite(out_h).all() and np.isfinite(out_d).all()
    # embeddings are sampling-stochastic, but magnitudes must be comparable
    assert 0.2 < np.linalg.norm(out_h[:12]) / np.linalg.norm(out_d[:12]) < 5.0

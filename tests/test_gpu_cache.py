"""Request-granularity device cache (PR 6): query/replace semantics,
sketch-weighted CLOCK eviction, capacity bounds, store integration (cold
cache hits bypass the tier dispatch, results stay bit-identical to the
uncached path), invalidation on migration, cache correctness under
migration/prefetch churn, and the controller's bounded cold-path sizing."""
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (GPUFeatureCache, Prefetcher, TieredFeatureStore,
                        TopologySpec, compute_fap, migration_pairs,
                        quiver_placement)
from repro.core.placement import TIER_HOST
from repro.graph import power_law_graph
from repro.serving import AdaptiveConfig, AdaptiveController, FrequencySketch


# ---------------------------------------------------------------------------
# Fixtures (the test_prefetch harness sizes)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def stack():
    n, d, fan = 900, 12, (4, 3)
    g = power_law_graph(n, 6.0, seed=0)
    feats = np.random.default_rng(1).normal(size=(n, d)).astype(np.float32)
    fap = compute_fap(g, fan)
    topo = TopologySpec(num_pods=1, devices_per_pod=1, rows_per_device=220,
                        rows_host=330, hot_replicate_fraction=0.3)
    return g, fan, feats, fap, topo


def _fresh_store(stack, spill_path=None):
    g, fan, feats, fap, topo = stack
    return TieredFeatureStore.build(feats, quiver_placement(fap, topo),
                                    spill_path=spill_path)


def _rows(ids, d=4):
    """Deterministic distinct rows for unit tests: row i == float(i)."""
    ids = np.asarray(ids, np.int64)
    return np.broadcast_to(ids[:, None], (ids.size, d)).astype(np.float32)


# ---------------------------------------------------------------------------
# Unit: query/replace semantics
# ---------------------------------------------------------------------------
def test_query_empty_cache_all_miss_and_padding_ignored():
    c = GPUFeatureCache(num_nodes=32, capacity=4, feat_dim=4)
    ids = np.array([5, -1, 7, -1], np.int64)
    values, miss_index, miss_ids = c.query(ids)
    assert values.shape == (4, 4) and not np.asarray(values).any()
    assert miss_index.tolist() == [0, 2] and miss_ids.tolist() == [5, 7]
    assert c.stats["hits"] == 0 and c.stats["misses"] == 2  # -1s not counted


def test_replace_then_query_hits_exact_rows():
    c = GPUFeatureCache(num_nodes=32, capacity=4, feat_dim=4)
    c.replace(np.array([5, 7]), _rows([5, 7]))
    ids = np.array([7, -1, 5, 9], np.int64)
    values, miss_index, miss_ids = c.query(ids)
    assert miss_ids.tolist() == [9]
    got = np.asarray(values)
    assert np.array_equal(got[0], _rows([7])[0])
    assert np.array_equal(got[2], _rows([5])[0])
    assert not got[1].any() and not got[3].any()  # pad + miss rows zero
    assert c.stats["hits"] == 2 and c.report()["hit_rate"] > 0


def test_replace_skips_duplicates_residents_and_padding():
    c = GPUFeatureCache(num_nodes=32, capacity=8, feat_dim=4)
    c.replace(np.array([3, 3, -1, 4]), _rows([3, 3, 0, 4]))
    assert c.resident_rows() == 2 and c.stats["admitted"] == 2
    # re-admitting a resident is a no-op (a racing lane admitted first)
    c.replace(np.array([3]), _rows([99]))
    got, _, _ = c.query(np.array([3]))
    assert np.array_equal(np.asarray(got)[0], _rows([3])[0])


def test_capacity_bound_holds_under_overflow_admissions():
    c = GPUFeatureCache(num_nodes=256, capacity=8, feat_dim=4)
    for lo in range(0, 64, 16):
        ids = np.arange(lo, lo + 16)
        c.replace(ids, _rows(ids))
    assert c.resident_rows() <= 8
    assert c.stats["evictions"] == c.stats["admitted"] - 8


def test_clock_second_chance_protects_recently_hit_rows():
    c = GPUFeatureCache(num_nodes=32, capacity=2, feat_dim=4)
    c.replace(np.array([1, 2]), _rows([1, 2]))
    c.query(np.array([1]))              # sets node 1's second-chance bit
    c.replace(np.array([3]), _rows([3]))
    _, miss_index, _ = c.query(np.array([1, 2, 3]))
    assert miss_index.tolist() == [1]   # 2 evicted; 1 survived its ref bit


def test_sketch_protection_rejects_colder_candidates():
    sketch = FrequencySketch(32)
    c = GPUFeatureCache(num_nodes=32, capacity=2, feat_dim=4, sketch=sketch)
    sketch.counts[[1, 2]] = 10.0        # residents are hot
    c.replace(np.array([1, 2]), _rows([1, 2]))
    c.replace(np.array([3]), _rows([3]))   # cold scan: everyone is hotter
    assert c.stats["rejected"] == 1 and c.stats["evictions"] == 0
    _, miss_index, _ = c.query(np.array([1, 2, 3]))
    assert miss_index.tolist() == [2]   # residents intact, 3 not admitted
    sketch.counts[3] = 99.0             # now the candidate outranks one
    c.replace(np.array([3]), _rows([3]))
    _, miss_index, _ = c.query(np.array([3]))
    assert miss_index.size == 0 and c.stats["evictions"] == 1


def test_invalidate_frees_slots_for_readmission():
    c = GPUFeatureCache(num_nodes=32, capacity=2, feat_dim=4)
    c.replace(np.array([1, 2]), _rows([1, 2]))
    assert c.invalidate(np.array([2, 30, -1])) == 1   # non-resident ignored
    assert c.stats["invalidated"] == 1 and c.resident_rows() == 1
    c.replace(np.array([5]), _rows([5]))              # freed slot reused
    assert c.resident_rows() == 2 and c.stats["evictions"] == 0
    _, miss_index, _ = c.query(np.array([1, 5]))
    assert miss_index.size == 0


def test_resize_shrink_keeps_hottest_grow_keeps_all():
    sketch = FrequencySketch(32)
    c = GPUFeatureCache(num_nodes=32, capacity=4, feat_dim=4, sketch=sketch)
    ids = np.array([1, 2, 3, 4])
    sketch.counts[ids] = [5.0, 1.0, 9.0, 2.0]
    c.replace(ids, _rows(ids))
    assert c.resize(2) == 2             # dropped the two coldest
    got, miss_index, _ = c.query(ids)
    assert miss_index.tolist() == [1, 3]             # 2 and 4 dropped
    assert np.array_equal(np.asarray(got)[[0, 2]], _rows([1, 3]))
    assert c.resize(8) == 0 and c.capacity == 8      # grow keeps residents
    _, miss_index, _ = c.query(np.array([1, 3]))
    assert miss_index.size == 0
    assert c.stats["resizes"] == 2 and c.stats["evictions"] == 2
    with pytest.raises(ValueError):
        GPUFeatureCache(num_nodes=8, capacity=0, feat_dim=4)


# ---------------------------------------------------------------------------
# Store integration: hits bypass tier dispatch, results bit-identical
# ---------------------------------------------------------------------------
def test_cached_lookups_bit_identical_and_bypass_dispatch(stack, tmp_path):
    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack, spill_path=str(tmp_path / "c.spill"))
    rng = np.random.default_rng(3)
    hops = [rng.integers(-1, g.num_nodes, s).astype(np.int32)
            for s in (32, 96)]
    plain = [np.asarray(o) for o in store.lookup_hops(hops)]
    plain_flat = np.asarray(store.lookup(jnp.asarray(hops[1])))
    cache = GPUFeatureCache.for_store(store, 512)
    store.attach_cache(cache)
    for _ in range(2):                   # cold pass (misses), warm pass (hits)
        cached = [np.asarray(o) for o in store.lookup_hops(hops)]
        for a, b in zip(plain, cached):
            assert np.array_equal(a, b)
    assert np.array_equal(plain_flat,
                          np.asarray(store.lookup(jnp.asarray(hops[1]))))
    assert cache.stats["hits"] > 0 and cache.stats["misses"] > 0
    # the structural win: a lookup whose cold ids ALL hit the cache skips
    # the tier gather entirely (no device_gathers, no host callback)
    cold = np.flatnonzero(np.asarray(store.tier_t) >= TIER_HOST)[:16]
    store.lookup(jnp.asarray(cold, jnp.int32))       # admit
    store.reset_stats()
    out = np.asarray(store.lookup(jnp.asarray(cold, jnp.int32)))
    assert np.array_equal(out, feats[cold])
    stats = store.reset_stats()
    assert stats["cache_hits"] == cold.size and stats["cache_misses"] == 0
    assert stats["device_gathers"] == 0 and stats["host_fetches"] == 0


def test_include_host_false_ignores_cache(stack):
    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack)
    store.attach_cache(GPUFeatureCache.for_store(store, 256))
    ids = np.flatnonzero(np.asarray(store.tier_t) >= TIER_HOST)[:32]
    store.lookup(jnp.asarray(ids, jnp.int32))        # admit the cold rows
    got = np.asarray(store.lookup(jnp.asarray(ids, jnp.int32),
                                  include_host=False))
    assert not got.any()                 # device-only probes stay zeros


def test_swap_assignments_invalidates_migrated_rows(stack):
    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack)
    cache = GPUFeatureCache.for_store(store, 256)
    store.attach_cache(cache)
    cold = np.flatnonzero(np.asarray(store.tier_t) >= TIER_HOST)
    hot = np.flatnonzero(np.asarray(store.tier_t) < TIER_HOST)
    store.lookup(jnp.asarray(cold[:8], jnp.int32))   # admit 8 cold rows
    assert cache.resident_rows() == 8
    store.swap_assignments(list(zip(hot[:4].tolist(), cold[:4].tolist())))
    # both swap sides dropped: the promoted rows stop burning capacity
    assert cache.stats["invalidated"] >= 4 and cache.resident_rows() <= 4
    ids = jnp.asarray(np.arange(g.num_nodes), jnp.int32)
    assert np.array_equal(np.asarray(store.lookup(ids)), feats)


# ---------------------------------------------------------------------------
# Churn: cached lookups racing migration + prefetch publication (satellite)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_cached_lookups_racing_migration_and_stage_churn(stack, tmp_path):
    """The test_prefetch.py race harness with a device cache attached: one
    thread runs fused lookups through the cache, one re-publishes the
    staging buffer, while the main thread migrates rows on the same store —
    every observed row must stay exact (stale cache entries are
    value-correct by the lookup-equivalence invariant)."""
    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack, spill_path=str(tmp_path / "race.spill"))
    cache = GPUFeatureCache.for_store(store, 128)    # small: eviction churn
    store.attach_cache(cache)
    rng = np.random.default_rng(7)
    hops = [rng.integers(0, g.num_nodes, 16).astype(np.int32),
            rng.integers(0, g.num_nodes, 48).astype(np.int32)]
    expected = [feats[h] for h in hops]
    stop = threading.Event()
    errors: list[str] = []
    pf = Prefetcher(store, budget=g.num_nodes)

    def reader():
        while not stop.is_set():
            got = store.lookup_hops(hops)
            for e, o in zip(expected, got):
                if not np.array_equal(np.asarray(o), e):
                    errors.append("torn cached lookup during migration")
                    return

    def refresher():
        rrng = np.random.default_rng(13)
        while not stop.is_set():
            scores = rrng.random(g.num_nodes)
            scores[scores < 0.5] = 0.0
            try:
                pf.refresh(scores=scores)
            except BaseException as exc:  # surface, don't hang the test
                errors.append(f"refresh raised: {exc!r}")
                return

    threads = [threading.Thread(target=reader),
               threading.Thread(target=refresher)]
    for t in threads:
        t.start()
    try:
        drifted = fap.copy()
        drifted[np.argsort(fap)[:80]] += fap.max() * 3
        tgt = quiver_placement(drifted, topo)
        for _ in range(10):
            pairs = migration_pairs(store.plan.tier, tgt.tier, drifted,
                                    budget=20)
            if pairs:
                store.swap_assignments(pairs)
            store.promote_misses(budget=4)
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=60)
    assert not errors, errors
    for e, o in zip(expected, store.lookup_hops(hops)):
        assert np.array_equal(np.asarray(o), e)
    stats = store.reset_stats()
    assert stats["cache_hits"] > 0       # the cache really was on the path
    pf.close()


# ---------------------------------------------------------------------------
# Controller feedback: sizing stays bounded under any sketch (acceptance)
# ---------------------------------------------------------------------------
def test_cold_path_sizing_bounded_under_pathological_sketch(stack):
    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack)
    cache = GPUFeatureCache.for_store(store, 32)
    store.attach_cache(cache)
    pf = Prefetcher(store, budget=24)
    cfg = AdaptiveConfig(cache_rows_bounds=(16, 128),
                         stage_budget_bounds=(16, 96),
                         prefetch_cadence_bounds=(1, 4))
    ctl = AdaptiveController(g, fan, store, None, prefetcher=pf, config=cfg)
    # pathological sketch: every node looks infinitely hot — targets must
    # clamp to the configured upper bounds, never grow unboundedly
    ctl.sketch.counts[:] = 1e18
    for _ in range(12):
        r = ctl.tune_cold_path()
        assert 16 <= r["cache_rows"] <= 128
        assert 16 <= r["stage_budget"] <= 96
        assert 1 <= r["refresh_cadence"] <= 4
    assert cache.capacity == 128 and pf.budget == 96   # converged to caps
    # opposite pathology: a silent sketch shrinks toward the lower bounds
    ctl.sketch.counts[:] = 0.0
    for _ in range(12):
        r = ctl.tune_cold_path()
    assert r["cold_ws"] == 0
    assert cache.capacity == 16 and pf.budget == 16
    assert ctl.stats["cold_tunings"] == 24
    pf.close()


def test_controller_step_reports_cold_tuning(stack):
    """step() wires tune_cold_path into the control loop when a cache is
    attached (and skips it cleanly when neither cache nor prefetcher)."""
    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack)
    ctl = AdaptiveController(g, fan, store, None,
                             config=AdaptiveConfig(rows_per_step=2))
    assert ctl.step()["cold"] is None    # nothing to tune
    store.attach_cache(GPUFeatureCache.for_store(store, 64))
    r = ctl.step()
    assert r["cold"] is not None and "cache_rows" in r["cold"]

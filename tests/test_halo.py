"""Locality-sharded message passing (core/halo.py): unit tests + an
8-shard subprocess test that the halo-sharded GIN/Equiformer losses match
their global (single-device) counterparts exactly."""
import numpy as np
import pytest

from repro.core.halo import (partition_edges_by_dst, remote_fraction)
from tests.conftest import run_subprocess


def test_partition_edges_by_dst_alignment():
    rng = np.random.default_rng(0)
    n, e, shards = 64, 300, 8
    src = rng.integers(0, n, e)
    dst = rng.integers(0, n, e)
    ps, pd = partition_edges_by_dst(src, dst, n, shards)
    rows = n // shards
    pd2 = pd.reshape(shards, -1)
    for d in range(shards):
        v = pd2[d][pd2[d] >= 0]
        assert np.all(v // rows == d)
    # every original edge survives
    orig = sorted(zip(src.tolist(), dst.tolist()))
    kept = sorted((a, b) for a, b in zip(ps.tolist(), pd.tolist()) if a >= 0)
    assert orig == kept
    assert 0.0 <= remote_fraction(src, dst, n, shards) <= 1.0


@pytest.mark.subprocess
def test_halo_gather_exact_8_shards():
    code = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.halo import halo_gather
Pn, N, F = 8, 64, 5
x = np.arange(N*F, dtype=np.float32).reshape(N, F)
rng = np.random.default_rng(0)
want = rng.integers(-1, N, size=(Pn, 16)).astype(np.int32)
from repro.compat import make_mesh, shard_map
mesh = make_mesh((8,), ("x",))
def body(x_local, want_local):
    return halo_gather(x_local, want_local[0], axis="x", num_shards=Pn,
                       rows_per_shard=N // Pn, cap_pp=16)[None]
f = jax.jit(shard_map(body, mesh=mesh,
                          in_specs=(P("x", None), P("x", None)),
                          out_specs=P("x", None)))
out = np.asarray(f(jnp.asarray(x), jnp.asarray(want)))
expect = np.where((want >= 0)[..., None], x[np.maximum(want, 0)], 0.0)
assert np.allclose(out, expect), np.abs(out - expect).max()
print("HALO_OK")
"""
    r = run_subprocess(code, devices=8)
    assert "HALO_OK" in r.stdout, r.stderr[-2000:]


@pytest.mark.subprocess
def test_gin_halo_loss_matches_global():
    """The shard_map GIN loss (dst-aligned edges + halo gathers) equals the
    single-device global loss bit-for-bit-ish."""
    code = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.halo import HaloCtx, partition_edges_by_dst
from repro.configs.gin_tu import _init, _loss, _loss_sharded
shards, rows, d, classes = 8, 16, 12, 5
n = shards * rows
rng = np.random.default_rng(0)
src = rng.integers(0, n, 640)
dst = rng.integers(0, n, 640)
ps, pd = partition_edges_by_dst(src, dst, n, shards)
e = ps.shape[0]
batch = {
  "node_feat": jnp.asarray(rng.normal(size=(n, d)), jnp.float32),
  "positions": jnp.asarray(rng.normal(size=(n, 3)), jnp.float32),
  "species": jnp.asarray(rng.integers(0, 4, n), jnp.int32),
  "src": jnp.asarray(ps), "dst": jnp.asarray(pd),
  "labels": jnp.asarray(rng.integers(0, classes, n), jnp.int32),
}
info = dict(nodes=n, edges=e, d_feat=d, classes=classes, graphs=None)
params = _init(jax.random.key(0), d, classes, "ogb_products")
ref = float(_loss(params, batch, info, "ogb_products"))
from repro.compat import make_mesh, shard_map
mesh = make_mesh((8,), ("x",))
ctx = HaloCtx(("x",), dict(mesh.shape), rows, cap_pp=e // shards)
pspec = jax.tree_util.tree_map(lambda _: P(), params)
bspec = {k: P("x", None) if v.ndim == 2 else P("x")
         for k, v in batch.items()}
f = jax.jit(shard_map(
    lambda p, b: _loss_sharded(p, b, info, "ogb_products", ctx),
    mesh=mesh, in_specs=(pspec, bspec), out_specs=P()))
out = float(f(params, batch))
assert abs(out - ref) < 1e-4, (out, ref)
print("GIN_HALO_OK", out, ref)
"""
    r = run_subprocess(code, devices=8)
    assert "GIN_HALO_OK" in r.stdout, r.stderr[-2500:]


@pytest.mark.subprocess
def test_equiformer_halo_loss_matches_global():
    code = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.halo import HaloCtx, partition_edges_by_dst
from repro.configs.equiformer_v2 import (_reduced_init, _loss, _loss_sharded,
                                         EDGE_CHUNKS)
shards, rows, d, classes = 8, 8, 6, 4
n = shards * rows
rng = np.random.default_rng(1)
src = rng.integers(0, n, 256)
dst = rng.integers(0, n, 256)
ps, pd = partition_edges_by_dst(src, dst, n, shards)
e = ps.shape[0]
batch = {
  "node_feat": jnp.asarray(rng.normal(size=(n, d)), jnp.float32),
  "positions": jnp.asarray(rng.normal(size=(n, 3)), jnp.float32),
  "species": jnp.asarray(rng.integers(0, 4, n), jnp.int32),
  "src": jnp.asarray(ps), "dst": jnp.asarray(pd),
  "labels": jnp.asarray(rng.integers(0, classes, n), jnp.int32),
}
info = dict(nodes=n, edges=e, d_feat=d, classes=classes, graphs=None)
params = _reduced_init(jax.random.key(0), d, classes, "x")
EDGE_CHUNKS["unit"] = 1
ref = float(_loss(params, batch, info, "unit"))
from repro.compat import make_mesh, shard_map
mesh = make_mesh((8,), ("x",))
ctx = HaloCtx(("x",), dict(mesh.shape), rows, cap_pp=e // shards)
pspec = jax.tree_util.tree_map(lambda _: P(), params)
bspec = {k: P("x", None) if v.ndim == 2 else P("x")
         for k, v in batch.items()}
f = jax.jit(shard_map(
    lambda p, b: _loss_sharded(p, b, info, "unit", ctx),
    mesh=mesh, in_specs=(pspec, bspec), out_specs=P()))
out = float(f(params, batch))
assert abs(out - ref) < 2e-3, (out, ref)
print("EQ_HALO_OK", out, ref)
"""
    r = run_subprocess(code, devices=8, timeout=600)
    assert "EQ_HALO_OK" in r.stdout, r.stderr[-2500:]

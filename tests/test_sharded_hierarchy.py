"""Sharded store as a hierarchy member: the owner-sorted dedup exchange,
per-shard staging/spill, shape preconditions and the migration race —
world-8 paths in subprocesses, world-1 staging/executor paths in-process."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.core import (Prefetcher, ShardedFeatureStore, TieredFeatureStore,
                        TopologySpec, compute_fap, quiver_placement)
from repro.core.placement import TIER_HOST
from repro.graph import power_law_graph
from tests.conftest import run_subprocess

# Shared subprocess preamble: a tiered store with real HOST/DISK tiers and
# the sharded views over an 8-device mesh.
_SETUP = """
import numpy as np, jax, jax.numpy as jnp, tempfile, os
from repro.graph import power_law_graph
from repro.core.fap import compute_fap
from repro.core.placement import TopologySpec, quiver_placement
from repro.core.feature_store import TieredFeatureStore, ShardedFeatureStore
from repro.core.prefetch import Prefetcher
from repro.compat import make_mesh
n, d = 2400, 16
g = power_law_graph(n, 8.0, seed=0)
fap = compute_fap(g, (4, 3))
feats = np.random.default_rng(0).normal(size=(n, d)).astype(np.float32)
topo = TopologySpec(num_pods=2, devices_per_pod=4, rows_per_device=96,
                    rows_host=300, hot_replicate_fraction=0.25)
plan = quiver_placement(fap, topo)
store = TieredFeatureStore.build(feats, plan)
mesh = make_mesh((8,), ("x",))
"""


@pytest.mark.subprocess
def test_dedup_exchange_bit_identical_world8():
    """The alltoall exchange on a real 8-device mesh: bit-identical to
    per-hop lookups, to the allgather strategy and to the single-host
    store — cross-hop duplicates, -1 padding and HOST/DISK ids included,
    staged (per-shard spill files) and unstaged; a neighbor duplicated
    across hops is exchanged once (``exchanged_ids`` asserted)."""
    code = _SETUP + """
from repro.core.placement import TIER_WARM
spill_dir = tempfile.mkdtemp()
base = ShardedFeatureStore.from_tiered(store, mesh, "x",
                                       strategy="allgather")
ss = ShardedFeatureStore.from_tiered(store, mesh, "x", strategy="alltoall",
                                     spill_dir=spill_dir)
rng = np.random.default_rng(3)
hops = [rng.integers(0, n, size=s).astype(np.int32) for s in (16, 64, 256)]
hops[1][:8] = hops[0][:8]          # cross-hop duplicates
hops[2][:32] = hops[1][:32]
hops[0][3] = -1                    # padding
want = [np.asarray(store.lookup(jnp.asarray(h))) for h in hops]

def check(s, label):
    fused = s.lookup_hops([jnp.asarray(h) for h in hops])
    per = [s.lookup(jnp.asarray(h)) for h in hops]
    for k in range(len(hops)):
        assert np.array_equal(want[k], np.asarray(fused[k])), (label, k)
        assert np.array_equal(want[k], np.asarray(per[k])), (label, k)

check(base, "allgather")
check(ss, "alltoall")
pf = Prefetcher(ss, budget=n)
assert pf.refresh(scores=np.maximum(fap, 1e-12)) > 0
check(ss, "alltoall+staged")

# dedup accounting: distinct (device, id) pairs only, strictly below the
# raw occurrence count (the duplicates above guarantee a gap)
ss.reset_stats()
ss.lookup_hops([jnp.asarray(h) for h in hops])
st = ss.reset_stats()
cat = np.concatenate(hops).astype(np.int64)
dev = np.repeat(np.arange(8), cat.size // 8)
tier = ss.tier_table_host[np.maximum(cat, 0)]
elig = (cat >= 0) & ((tier == TIER_WARM) | (tier >= 2))  # all cold staged
distinct = len(set(zip(dev[elig].tolist(), cat[elig].tolist())))
assert st["exchanges"] == 1, st
assert st["exchanged_ids"] == distinct, (st, distinct)
assert distinct < int(elig.sum()), (distinct, int(elig.sum()))
assert st["host_fetches"] == 0 and st["stage_misses"] == 0, st
assert st["stage_hits"] > 0, st
print("DEDUP_OK")
"""
    r = run_subprocess(code, devices=8)
    assert "DEDUP_OK" in r.stdout, r.stderr[-2000:]


@pytest.mark.subprocess
def test_hop_length_and_ragged_warm_validation_world8():
    """Shape preconditions fail fast with clear ValueErrors, never inside
    shard_map: a hop whose length is not a multiple of the world size, an
    empty hop list, and a ragged warm buffer at construction."""
    code = _SETUP + """
ss = ShardedFeatureStore.from_tiered(store, mesh, "x")
for bad in (20, 0):
    try:
        ss.lookup_hops([np.zeros(32, np.int32), np.zeros(bad, np.int32)])
        raise AssertionError(f"hop of {bad} did not raise")
    except ValueError as e:
        assert "multiple of the mesh world size" in str(e), e
try:
    ss.lookup(np.zeros(13, np.int32))
    raise AssertionError("ragged lookup did not raise")
except ValueError as e:
    assert "multiple of the mesh world size" in str(e), e
try:
    ss.lookup_hops([])
    raise AssertionError("empty hops did not raise")
except ValueError as e:
    assert "at least one hop" in str(e), e
try:
    ShardedFeatureStore(mesh, "x", np.zeros((4, d), np.float32),
                        np.zeros((42, d), np.float32),  # 42 % 8 != 0
                        np.zeros(n, np.int32), np.zeros(n, np.int32),
                        np.zeros(n, np.int32))
    raise AssertionError("ragged warm did not raise")
except ValueError as e:
    assert "divisible by the mesh world size" in str(e), e
print("VALIDATION_OK")
"""
    r = run_subprocess(code, devices=8)
    assert "VALIDATION_OK" in r.stdout, r.stderr[-2000:]


@pytest.mark.subprocess
def test_dedup_exchange_under_migration_race_world8():
    """The migration-race harness, pointed at the dedup exchange: a thread
    hammers ``swap_assignments`` on the *source* store while sharded
    lookups run. The sharded tables are build-time copies and rows travel
    with nodes, so every lookup stays bit-identical to the features."""
    code = _SETUP + """
import threading
from repro.core.placement import migration_pairs
ss = ShardedFeatureStore.from_tiered(store, mesh, "x",
                                     strategy="alltoall")
stop = threading.Event()
def churn():
    rng = np.random.default_rng(9)
    while not stop.is_set():
        p0 = rng.dirichlet(np.ones(n))
        f2 = compute_fap(g, (4, 3), seed_prob=p0)
        target = quiver_placement(f2, topo)
        pairs = migration_pairs(store.plan.tier, target.tier, f2, budget=32)
        store.swap_assignments(pairs)
t = threading.Thread(target=churn)
t.start()
try:
    rng = np.random.default_rng(5)
    for _ in range(12):
        hops = [rng.integers(-1, n, size=s).astype(np.int32)
                for s in (32, 128)]
        hops[1][:16] = hops[0][:16]
        outs = ss.lookup_hops([jnp.asarray(h) for h in hops])
        for h, o in zip(hops, outs):
            expect = np.where((h >= 0)[:, None],
                              feats[np.maximum(h, 0)], 0.0)
            assert np.allclose(np.asarray(o), expect, atol=1e-5)
finally:
    stop.set(); t.join()
print("RACE_OK")
"""
    r = run_subprocess(code, devices=8, timeout=420)
    assert "RACE_OK" in r.stdout, r.stderr[-2000:]


# ---------------------------------------------------------------------------
# In-process (world-1 mesh) paths
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def world1_stack(tmp_path_factory):
    n, d = 600, 12
    g = power_law_graph(n, 6.0, seed=0)
    fap = compute_fap(g, (3, 2))
    feats = np.random.default_rng(1).normal(size=(n, d)).astype(np.float32)
    topo = TopologySpec(num_pods=1, devices_per_pod=1, rows_per_device=64,
                        rows_host=150, hot_replicate_fraction=0.25)
    store = TieredFeatureStore.build(feats, quiver_placement(fap, topo))
    mesh = make_mesh((1,), ("x",))
    spill_dir = str(tmp_path_factory.mktemp("shard_spill"))
    ss = ShardedFeatureStore.from_tiered(store, mesh, "x",
                                         spill_dir=spill_dir)
    return g, feats, fap, store, mesh, ss


def test_publish_stage_rebins_global_layout(world1_stack):
    """`publish_stage` accepts the prefetcher's global (N,) id→row layout,
    re-bins it per shard, and the exchange then serves staged cold ids
    from device with zero host fetches."""
    g, feats, fap, store, mesh, ss = world1_stack
    tier = ss.tier_table_host
    cold = np.flatnonzero(tier >= TIER_HOST)[:40]
    assert cold.size > 0
    stage_slot = np.full(feats.shape[0], -1, np.int32)
    stage_slot[cold] = np.arange(cold.size, dtype=np.int32)
    ss.publish_stage(stage_slot, jnp.asarray(feats[cold]))
    assert ss.staged_rows() == cold.size
    ss.reset_stats()
    out = np.asarray(ss.lookup(jnp.asarray(cold.astype(np.int32))))
    np.testing.assert_allclose(out, feats[cold], atol=1e-6)
    st = ss.reset_stats()
    assert st["stage_hits"] == cold.size and st["host_fetches"] == 0, st
    ss.publish_stage(None, None)
    assert ss.staged_rows() == 0


def test_spill_files_serve_disk_rows(world1_stack):
    """Per-shard spill files answer DISK reads through read_cold_rows
    (counted as spill_reads) with the exact feature values."""
    g, feats, fap, store, mesh, ss = world1_stack
    disk = np.flatnonzero(ss.tier_table_host == 3)
    if disk.size == 0:
        pytest.skip("placement produced no DISK tier at this size")
    ss.reset_stats()
    rows = ss.read_cold_rows(disk[:16])
    np.testing.assert_allclose(rows, feats[disk[:16]], atol=1e-6)
    assert ss.snapshot_stats()["spill_reads"] == min(disk.size, 16)


def test_fuse_aggregate_downgrade_warns_once(world1_stack):
    """ShardedExecutor accepts fuse_aggregate=True for construction-site
    symmetry but emits one RuntimeWarning and falls back to the fused
    path; collect_mode reports the active mode."""
    from repro.serving.executors import ShardedExecutor
    g, feats, fap, store, mesh, ss = world1_stack

    def infer_fn(hop_feats, hop_ids, deep_agg=None):
        return hop_feats[0]

    ShardedExecutor._warned_fuse_aggregate = False
    with pytest.warns(RuntimeWarning, match="fuse_aggregate=True has no"):
        ex = ShardedExecutor(mesh, "x", g.device_arrays(), ss, (3, 2),
                             infer_fn, max_batch=16, fuse_aggregate=True)
    assert ex.collect_mode(ss) == "fused"  # downgraded, and visible
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")    # second construction: silent
        ex2 = ShardedExecutor(mesh, "x", g.device_arrays(), ss, (3, 2),
                              infer_fn, max_batch=16, fuse_aggregate=True)
    assert not [w for w in rec if "fuse_aggregate" in str(w.message)]
    assert ex2.collect_mode(ss) == "fused"


def test_collect_mode_strings_cover_matrix(world1_stack):
    """collect_mode maps the (flags, store capability) matrix exactly."""
    from repro.serving.executors import HostExecutor
    g, feats, fap, store, mesh, ss = world1_stack

    def infer_fn(hop_feats, hop_ids, deep_agg=None):
        return hop_feats[0]

    host = HostExecutor(g, store, (3, 2), infer_fn, fused=True,
                        fuse_aggregate=True)
    assert host.collect_mode(store) == "fuse_aggregate"
    assert host.collect_mode(ss) == "fused"  # sharded: no lookup_aggregate
    host2 = HostExecutor(g, store, (3, 2), infer_fn, fused=False)
    assert host2.collect_mode(store) == "per_hop"

"""Feature placement invariants (paper §5.2) + baselines + expert placement."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
pytestmark = pytest.mark.hypothesis
from hypothesis import given, settings, strategies as st

from repro.core import (TopologySpec, degree_placement, expert_placement,
                        freq_placement, hash_placement, p3_placement,
                        quiver_placement)
from repro.core.placement import TIER_HOT, TIER_WARM, TIER_HOST, TIER_DISK


def _fap(n, seed=0):
    return np.random.default_rng(seed).exponential(size=n).astype(np.float32)


@given(st.integers(min_value=1, max_value=3),
       st.integers(min_value=1, max_value=8),
       st.sampled_from([0.0, 0.25, 1.0]),
       st.booleans(), st.booleans())
@settings(max_examples=40, deadline=None)
def test_quiver_placement_invariants(pods, devs, hot_frac, ici, dcn):
    n = 500
    topo = TopologySpec(num_pods=pods, devices_per_pod=devs,
                        rows_per_device=32, rows_host=64,
                        has_fast_intrapod=ici, has_fast_interpod=dcn,
                        hot_replicate_fraction=hot_frac)
    plan = quiver_placement(_fap(n), topo)
    plan.validate()  # capacity + ownership invariants
    counts = plan.tier_counts()
    assert sum(counts.values()) == n
    if not ici:
        # paper's no-NVLink case: everything device-resident is replicated
        assert counts["warm"] == 0


def test_placement_ranks_by_fap():
    fap = _fap(1000, seed=1)
    topo = TopologySpec(num_pods=2, devices_per_pod=4, rows_per_device=50,
                        rows_host=100, hot_replicate_fraction=0.2)
    plan = quiver_placement(fap, topo)
    order = np.argsort(-fap)
    tiers_in_order = plan.tier[order]
    # tier id must be monotone along descending FAP (hot→warm→host→disk)
    assert (np.diff(tiers_in_order.astype(int)) >= 0).all()


def test_placement_balances_fap_across_devices():
    fap = _fap(2000, seed=2)
    topo = TopologySpec(num_pods=1, devices_per_pod=8, rows_per_device=100,
                        rows_host=0, hot_replicate_fraction=0.0)
    plan = quiver_placement(fap, topo)
    warm = plan.tier == TIER_WARM
    sums = np.array([fap[warm & (plan.device_owner == d)].sum()
                     for d in range(8)])
    assert sums.max() / max(sums.min(), 1e-9) < 1.25  # snake balance


def test_interpod_partition_vs_replicate():
    fap = _fap(1000, seed=3)
    base = dict(num_pods=2, devices_per_pod=4, rows_per_device=40,
                rows_host=50, hot_replicate_fraction=0.0)
    with_ib = quiver_placement(fap, TopologySpec(**base,
                                                 has_fast_interpod=True))
    without = quiver_placement(fap, TopologySpec(**base,
                                                 has_fast_interpod=False))
    # with fast inter-pod links the warm tier is partitioned across pods →
    # twice the distinct device-resident rows (paper Fig. 8 c/d)
    assert with_ib.tier_counts()["warm"] == 2 * without.tier_counts()["warm"]
    assert (without.pod_owner[without.tier == TIER_WARM] == -1).all()


def test_baselines_interface():
    n = 500
    topo = TopologySpec(num_pods=2, devices_per_pod=4, rows_per_device=32,
                        rows_host=64)
    deg = np.random.default_rng(0).integers(0, 50, n)
    for plan in (hash_placement(n, topo), degree_placement(deg, topo),
                 freq_placement(deg.astype(float), topo),
                 p3_placement(n, topo)):
        assert plan.tier.shape == (n,)
        assert plan.name in ("hash", "degree", "freq", "p3")
    assert p3_placement(n, topo).dim_sharded


def test_hash_placement_is_workload_agnostic():
    n = 300
    topo = TopologySpec(num_pods=1, devices_per_pod=4, rows_per_device=1000,
                        rows_host=0)
    p1 = hash_placement(n, topo)
    p2 = hash_placement(n, topo)
    assert np.array_equal(p1.device_owner, p2.device_owner)


@given(st.integers(min_value=2, max_value=64),
       st.integers(min_value=0, max_value=32))
@settings(max_examples=30, deadline=None)
def test_expert_placement_budget(experts, budget):
    prob = np.random.default_rng(experts).exponential(size=experts)
    reps = expert_placement(prob, num_devices=64, replication_budget=budget)
    assert (reps >= 1).all() and (reps <= 64).all()
    assert reps.sum() == min(experts + budget,
                             reps.sum())  # ≥1 each, ≤ budget extras
    assert reps.sum() <= experts + budget
    # hottest expert gets at least as many replicas as the coldest
    assert reps[np.argmax(prob)] >= reps[np.argmin(prob)]

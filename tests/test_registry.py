"""Multi-model serving: ModelRegistry, per-model routing/metrics through one
shared engine, model-pure batching (micro-batches never mix models), the
per-model tail-flush regression, per-model adaptive refits, and micro-batch
auto-tuning."""
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro.core import (DEFAULT_MODEL, DynamicBatcher, MicroBatcher, Request,
                        WorkloadGenerator)
from repro.serving import (AdaptiveConfig, AdaptiveController,
                           CostModelRouter, LatencyCurve, ModelEntry,
                           ModelRegistry, ServingEngine, StaticScheduler)


# ---------------------------------------------------------------------------
# Light fakes: registry/engine semantics don't need the real GNN stack
# ---------------------------------------------------------------------------
class FakeExecutor:
    kind = "device"

    def __init__(self, name, *, capacity=2, gate=None, d_out=4):
        self.name = name
        self.capacity = capacity
        self.gate = gate            # optional Event: _work blocks until set
        self.d_out = d_out
        self.inflight = 0
        self.batches: list[np.ndarray] = []
        self._pool = ThreadPoolExecutor(max_workers=capacity)

    def cost(self, seeds):
        return float((np.asarray(seeds) >= 0).sum())

    def _work(self, seeds):
        if self.gate is not None:
            self.gate.wait()
        return np.zeros((len(seeds), self.d_out), np.float32)

    def submit(self, seeds):
        self.batches.append(np.asarray(seeds).copy())
        return self._pool.submit(self._work, seeds)

    def run(self, seeds):
        return self._work(seeds)

    def close(self):
        self._pool.shutdown(wait=True)


def _flat_curve(cost: float) -> LatencyCurve:
    return LatencyCurve(psgs=np.array([0.0, 100.0]),
                        avg=np.array([cost, cost]),
                        mx=np.array([cost, cost]))


def _router(table, prefer: str, other: str) -> CostModelRouter:
    r = CostModelRouter(table, "latency_preferred")
    r.register(prefer, _flat_curve(1e-4))
    r.register(other, _flat_curve(1e-2))
    return r


def _req(i, seeds, model=DEFAULT_MODEL):
    return Request(i, np.asarray(seeds, np.int64), time.perf_counter(),
                   model=model)


def _two_model_engine(table, **engine_kw):
    """Model 'a' prefers 'host', model 'b' prefers 'device' — same seeds,
    opposite decisions (the per-model divergence under test)."""
    ex_a = {"host": FakeExecutor("host"), "device": FakeExecutor("device")}
    ex_b = {"host": FakeExecutor("host"), "device": FakeExecutor("device")}
    reg = ModelRegistry()
    reg.register("a", ex_a, _router(table, "host", "device"))
    reg.register("b", ex_b, _router(table, "device", "host"))
    return ServingEngine(reg, **engine_kw), reg, ex_a, ex_b


# ---------------------------------------------------------------------------
# Registry basics + single-model special case
# ---------------------------------------------------------------------------
def test_registry_register_get_names():
    reg = ModelRegistry()
    ex = {"host": FakeExecutor("host")}
    reg.register("m1", ex, StaticScheduler("host"))
    reg.register("m2", [FakeExecutor("dev")], StaticScheduler("dev"))
    assert reg.names == ["m1", "m2"] and len(reg) == 2
    assert "m1" in reg and "nope" not in reg
    assert reg.get("m1").executors is not reg.get("m2").executors
    assert reg.get("m2").executors["dev"].name == "dev"
    assert set(reg.routers()) == {"m1", "m2"}
    assert {m for m, _n, _e in reg.all_executors()} == {"m1", "m2"}
    with pytest.raises(KeyError, match="m1"):   # names listed in the error
        reg.get("typo")
    with pytest.raises(ValueError, match="at least one executor"):
        reg.add(ModelEntry("empty", {}, StaticScheduler("host")))


def test_single_model_engine_is_one_entry_registry():
    ex = {"host": FakeExecutor("host")}
    engine = ServingEngine(ex, StaticScheduler("host"))
    assert engine.registry.names == [DEFAULT_MODEL]
    assert engine.executors is engine.registry.get(DEFAULT_MODEL).executors
    assert isinstance(engine.router, StaticScheduler)
    m = engine.run([[_req(0, [1, 2])]])   # untagged request → default model
    assert m.requests == 1
    assert m.models[DEFAULT_MODEL].requests == 1
    engine.close()


def test_engine_constructor_validation():
    ex = {"host": FakeExecutor("host")}
    reg = ModelRegistry.single(ex, StaticScheduler("host"))
    with pytest.raises(ValueError, match="not both"):
        ServingEngine(reg, StaticScheduler("host"))
    with pytest.raises(ValueError, match="not both"):
        ServingEngine(ex, StaticScheduler("host"), registry=reg)
    with pytest.raises(ValueError, match="needs"):
        ServingEngine(ex)           # router missing
    with pytest.raises(ValueError, match="at least one model"):
        ServingEngine(ModelRegistry())


def test_engine_register_adds_to_named_model():
    engine, reg, *_ = _two_model_engine(np.full(8, 1.0))
    late = FakeExecutor("late")
    engine.register(late, model="b")
    assert reg.get("b").executors["late"] is late
    engine.close()


# ---------------------------------------------------------------------------
# Per-model routing divergence through one engine
# ---------------------------------------------------------------------------
def test_interleaved_stream_routes_per_model():
    table = np.full(16, 1.0)
    engine, _reg, ex_a, ex_b = _two_model_engine(table)
    batches = []
    for i in range(12):
        model = "a" if i % 2 == 0 else "b"
        batches.append([_req(i, [i % 16, (i + 3) % 16], model)])
    m = engine.run(batches)
    assert m.requests == 12
    # identical seeds, opposite routing — decided by the model tag alone
    assert m.models["a"].routed == {"host": 6}
    assert m.models["b"].routed == {"device": 6}
    assert len(ex_a["host"].batches) == 6 and not ex_a["device"].batches
    assert len(ex_b["device"].batches) == 6 and not ex_b["host"].batches
    # aggregate view preserved: sums over models, merged executor names
    assert m.routed == {"host": 6, "device": 6}
    assert m.requests == sum(s.requests for s in m.models.values())
    engine.close()


def test_different_curves_give_different_cutpoints():
    """Two models over the same PSGS table: a higher fixed device offset
    pushes the host→device crossover right — per-model calibration yields
    per-model PSGS cut-points."""
    table = np.linspace(1, 100, 50)

    def router_with_offset(offset):
        r = CostModelRouter(table, "latency_preferred")
        q = np.linspace(1.0, 100.0, 32)
        r.register("host", LatencyCurve.fit(q, 1e-4 * q, bins=8),
                   kind="host")
        r.register("device", LatencyCurve.fit(q, offset + 1e-6 * q, bins=8))
        return r

    cut_small = router_with_offset(2e-3).crossover("host", "device")
    cut_wide = router_with_offset(6e-3).crossover("host", "device")
    assert cut_small < cut_wide
    # the cut-point is where the decision actually flips
    r = router_with_offset(2e-3)
    below = np.flatnonzero(table < cut_small * 0.9)[:1]
    above = np.flatnonzero(table > cut_small * 1.2)[:1]
    assert r.route(below) == "host" and r.route(above) == "device"


def test_shed_counted_per_model():
    gate = threading.Event()        # holds the first batch on the executor
    ex = {"host": FakeExecutor("host", capacity=1, gate=gate)}
    reg = ModelRegistry().register("only", ex, StaticScheduler("host"))
    engine = ServingEngine(reg, max_inflight=1, admission="shed")
    m = engine.begin_run()
    assert engine.submit_batch([_req(0, [0], "only")]) is not None
    for i in range(1, 5):           # window pinned full: every submit sheds
        assert engine.submit_batch([_req(i, [0], "only")]) is None
    gate.set()
    engine.drain()
    engine.end_run(m)
    assert m.shed == 4
    assert m.models["only"].shed == m.shed
    assert m.models["only"].requests + m.models["only"].shed == 5
    engine.close()


# ---------------------------------------------------------------------------
# Batches and micro-batches never mix models
# ---------------------------------------------------------------------------
def test_submit_batch_rejects_mixed_models():
    engine, *_ = _two_model_engine(np.full(8, 1.0))
    with pytest.raises(ValueError, match="mixes models"):
        engine.submit_batch([_req(0, [0], "a"), _req(1, [1], "b")])
    engine.drain()
    engine.close()


def test_dynamic_batcher_closes_on_model_change():
    b = DynamicBatcher(deadline_s=10.0, max_batch=100)
    assert b.add(_req(0, [0], "a")) is None
    out = b.add(_req(1, [1], "b"))      # model boundary closes a's batch
    assert out is not None and [r.model for r in out] == ["a"]
    tail = b.flush()
    assert [r.model for r in tail] == ["b"]


def test_micro_batcher_never_coalesces_across_models():
    micro = MicroBatcher(deadline_s=10.0, max_seeds=10**6)
    assert micro.add([_req(0, [0], "a")]) is None
    assert micro.add([_req(1, [1], "a")]) is None   # same model: coalesces
    out = micro.add([_req(2, [2], "b")])   # boundary emits a's super-batch
    assert out is not None and {r.model for r in out} == {"a"}
    assert len(out) == 2
    tail = micro.flush()
    assert {r.model for r in tail} == {"b"}
    assert micro.emitted == 2 and micro.coalesced == 1


def test_batcher_clones_are_fresh_and_configured():
    table = np.full(4, 2.0)
    b = DynamicBatcher(deadline_s=0.5, psgs_budget=9.0, max_batch=7,
                       psgs_table=table)
    b.add(_req(0, [0]))
    c = b.clone()
    assert (c.deadline_s, c.psgs_budget, c.max_batch) == (0.5, 9.0, 7)
    assert c.flush() is None            # fresh: no pending leaked
    m = MicroBatcher(deadline_s=0.3, max_seeds=11, psgs_budget=5.0,
                     psgs_table=table)
    m.add([_req(1, [1])])
    m2 = m.clone()
    assert (m2.deadline_s, m2.max_seeds, m2.psgs_budget) == (0.3, 11, 5.0)
    assert m2.flush() is None


def test_serve_stream_keeps_models_pure_under_micro():
    """Interleaved 2-model stream through serve_stream with coalescing
    bounds wide open: every executor-level batch must be model-pure (a
    shared stage would have mixed them and raised in submit_batch)."""
    table = np.full(32, 1.0)
    engine, _reg, ex_a, ex_b = _two_model_engine(table)
    reqs = [_req(i, [i % 32], "a" if i % 2 == 0 else "b")
            for i in range(20)]
    micro = MicroBatcher(deadline_s=10.0, max_seeds=6)
    m = engine.serve_stream(reqs, DynamicBatcher(deadline_s=0.0,
                                                 max_batch=1), micro=micro)
    assert m.requests == 20
    assert m.models["a"].requests == 10 and m.models["b"].requests == 10
    for ex_set, n in ((ex_a, 10), (ex_b, 10)):
        served = sum(len(b) for e in ex_set.values() for b in e.batches)
        assert served == n
    engine.close()


def test_serve_stream_flushes_micro_tail_per_model():
    """Regression (satellite): a tail super-batch below the PSGS budget —
    for EVERY model on the stream — must be flushed on drain, not dropped."""
    table = np.full(8, 1.0)
    engine, *_ = _two_model_engine(table)
    # bounds no batch can hit: everything becomes a held tail super-batch
    micro = MicroBatcher(deadline_s=10**6, max_seeds=10**6,
                         psgs_budget=10**9, psgs_table=table)
    reqs = [_req(i, [i % 8], "a" if i < 3 else "b") for i in range(6)]
    m = engine.serve_stream(reqs, DynamicBatcher(deadline_s=0.0,
                                                 max_batch=1), micro=micro)
    assert m.requests == 6                      # nothing dropped
    assert m.models["a"].requests == 3 and m.models["b"].requests == 3
    engine.close()


def test_serve_stream_multi_model_needs_clonable_stage():
    engine, *_ = _two_model_engine(np.full(8, 1.0))

    class NoClone:
        def add(self, req):
            return [req]

        def flush(self):
            return None

    reqs = [_req(0, [0], "a"), _req(1, [1], "b")]
    with pytest.raises(TypeError, match="clone"):
        engine.serve_stream(reqs, NoClone())
    engine.drain()
    engine.close()


# ---------------------------------------------------------------------------
# Per-model metrics + executor percentiles in summary()
# ---------------------------------------------------------------------------
def test_summary_has_models_executors_and_store_sections():
    table = np.full(8, 1.0)
    engine, *_ = _two_model_engine(table)
    m = engine.run([[_req(0, [0, 1], "a")], [_req(1, [2], "b")]])
    s = m.summary()
    assert s["models"]["a"]["requests"] == 1
    assert s["models"]["b"]["routed"] == {"device": 1}
    assert s["models"]["a"]["p99_ms"] >= s["models"]["a"]["p50_ms"] >= 0
    ex = s["executors"]
    assert set(ex) == {"a/host", "b/device"}   # model-qualified keys
    for v in ex.values():
        assert v["batches"] == 1 and v["p99_ms"] >= v["p50_ms"] > 0
    assert s["store"] == {}      # fakes expose no store stats
    engine.close()


def test_summary_store_stats_from_real_store(tmp_path):
    """Real stack: summary()['store'] carries the fused-gather dispatch
    counters, and default-model executor keys stay unqualified."""
    import jax
    import jax.numpy as jnp
    from repro.core import (TieredFeatureStore, TopologySpec, compute_fap,
                            compute_psgs, quiver_placement)
    from repro.graph import power_law_graph
    from repro.models.gnn_basic import sage_init, sage_layered
    from repro.serving import HostExecutor

    n, d, fan = 400, 8, (3, 2)
    g = power_law_graph(n, 5.0, seed=0)
    feats = np.random.default_rng(0).normal(size=(n, d)).astype(np.float32)
    fap = compute_fap(g, fan)
    topo = TopologySpec(num_pods=1, devices_per_pod=1, rows_per_device=128,
                        rows_host=200, hot_replicate_fraction=0.3)
    store = TieredFeatureStore.build(feats, quiver_placement(fap, topo))
    params = sage_init(jax.random.key(0), [d, 16, 16])

    @jax.jit
    def infer_fn(hop_feats, hop_ids):
        masks = [(h >= 0).astype(jnp.float32)[:, None] for h in hop_ids]
        return sage_layered(params, hop_feats, fan, hop_masks=masks)

    psgs = compute_psgs(g, fan)
    ex = {"host": HostExecutor(g, store, fan, infer_fn, psgs_table=psgs)}
    engine = ServingEngine(ex, StaticScheduler("host"))
    store.reset_stats()
    m = engine.run([[_req(0, [1, 2, 3])]])
    s = m.summary()
    assert s["store"]["TieredFeatureStore"]["fused_calls"] >= 1
    assert set(s["executors"]) == {"host"}     # default model: bare names
    assert m.models[DEFAULT_MODEL].exec_latencies["host"]
    engine.close()


# ---------------------------------------------------------------------------
# Adaptive controller: shared sketch, per-model refits, micro auto-tuning
# ---------------------------------------------------------------------------
class _G:
    num_nodes = 8


def _store_stub():
    return type("S", (), {"plan": None})()


def test_adaptive_refits_per_model_router():
    """Two models sharing executor names: only the drifted model's router
    swaps, keyed 'model/executor' in last_drift."""
    table = np.full(8, 10.0, np.float32)
    cheap, slow = _flat_curve(1e-3), _flat_curve(5e-3)

    def make_router():
        r = CostModelRouter(table, "latency_preferred")
        r.register("host", cheap, kind="host")
        r.register("device", slow, kind="device")
        return r

    routers = {"m1": make_router(), "m2": make_router()}
    ctl = AdaptiveController(
        _G(), (2,), _store_stub(), routers, psgs_table=table,
        config=AdaptiveConfig(min_refit_samples=8, drift_threshold=0.25,
                              curve_bins=4, interval_batches=10**9))
    seeds = np.array([0, 1])
    for i in range(16):
        # m1's host drifted 10x; m2's telemetry matches its calibration
        ctl.on_batch_complete("host", np.array([i % 8]), 1e-2 + i * 1e-5,
                              "m1")
        ctl.on_batch_complete("host", np.array([i % 8]), 1e-3, "m2")
    swapped = ctl.refit_curves()
    assert swapped == 1
    assert ctl.stats["last_drift"]["m1/host"] > 0.25
    assert ctl.stats["last_drift"]["m2/host"] < 0.25
    assert routers["m1"].route(seeds) == "device"   # m1 flipped
    assert routers["m2"].route(seeds) == "host"     # m2 untouched


def test_adaptive_accepts_registry_and_keeps_default_router_view():
    table = np.full(8, 1.0)
    reg = ModelRegistry()
    r1 = _router(table, "host", "device")
    reg.register(DEFAULT_MODEL, {"host": FakeExecutor("host"),
                                 "device": FakeExecutor("device")}, r1)
    ctl = AdaptiveController(_G(), (2,), _store_stub(), reg,
                             psgs_table=table)
    assert ctl.routers == {DEFAULT_MODEL: r1}
    assert ctl.router is r1                 # pre-multi-model view


def test_legacy_hook_arity_still_supported():
    """Hooks written before the model tag (2-/3-arg signatures) keep
    working: the engine trims the trailing model argument."""
    calls = {}

    class OldHook:
        def on_admit(self, name, seeds):
            calls["admit"] = (name, len(seeds))

        def on_batch_complete(self, name, seeds, latency_s):
            calls["complete"] = name

    class NewHook:
        def on_admit(self, name, seeds, model):
            calls["admit_model"] = model

    engine, *_ = _two_model_engine(np.full(8, 1.0),
                                   hooks=[OldHook(), NewHook()])
    m = engine.run([[_req(0, [0, 1], "a")]])
    assert m.requests == 1                  # no hook TypeError surfaced
    assert calls["admit"] == ("host", 2)
    assert calls["complete"] == "host"
    assert calls["admit_model"] == "a"
    engine.close()


def test_micro_autotune_nudges_toward_knee_within_bounds():
    """Samples with a fixed per-batch overhead: latency/psgs keeps falling
    with batch size, so the knee sits at the top of the observed range and
    the tuner must grow max_seeds toward it (never past the bounds)."""
    table = np.full(64, 1.0, np.float32)
    micro = MicroBatcher(deadline_s=0.05, max_seeds=16)
    ctl = AdaptiveController(
        _G(), (2,), _store_stub(), None, psgs_table=table, micro=micro,
        config=AdaptiveConfig(min_refit_samples=8, curve_bins=6,
                              interval_batches=10**9, micro_step=1.0,
                              micro_seeds_bounds=(4, 48),
                              micro_deadline_bounds=(1e-3, 2e-2)))
    rng = np.random.default_rng(0)
    for _ in range(40):
        n = int(rng.integers(1, 60))
        seeds = rng.integers(0, 64, size=n)
        ctl.on_batch_complete("host", seeds, 5e-3 + 1e-5 * n)
    targets = ctl.micro_targets()
    assert targets is not None
    out = ctl.tune_micro()
    assert out is not None and ctl.stats["micro_tunings"] == 1
    # knee is at the top of the range; step=1.0 jumps straight to the
    # target, clamped into the configured bounds
    assert micro.max_seeds > 16
    assert 4 <= micro.max_seeds <= 48
    assert 1e-3 <= micro.deadline_s <= 2e-2


def test_micro_autotune_respects_sample_floor_and_detach():
    ctl = AdaptiveController(_G(), (2,), _store_stub(), None,
                             psgs_table=np.full(8, 1.0),
                             config=AdaptiveConfig(min_refit_samples=8))
    assert ctl.tune_micro() is None         # no micro attached
    ctl.attach_micro(MicroBatcher())
    assert ctl.tune_micro() is None         # not enough samples yet
    assert ctl.stats["micro_tunings"] == 0


def test_workload_generator_round_robin_models():
    gen = WorkloadGenerator(16, np.ones(16), distribution="uniform", seed=0)
    reqs = list(gen.stream(7, models=["x", "y", "z"]))
    assert [r.model for r in reqs] == ["x", "y", "z", "x", "y", "z", "x"]
    assert all(r.model == DEFAULT_MODEL for r in gen.stream(2))

"""Executor-graph serving stack: pluggable executors, N-way cost routing
(and its reduction to the paper's binary PSGS threshold), admission control,
the no-silent-truncation regression, batcher/padding boundary cases, and the
3-executor (host+device+sharded) integration path."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DynamicBatcher, Request, TieredFeatureStore,
                        TopologySpec, WorkloadGenerator, compute_fap,
                        compute_psgs, quiver_placement)
from repro.graph import power_law_graph
from repro.models.gnn_basic import sage_init, sage_layered
from repro.serving import (POLICIES, CalibrationResult, CostModelRouter,
                           DeviceExecutor, Executor, HostExecutor,
                           HybridScheduler, LatencyCurve, ServingEngine,
                           StaticScheduler, calibrate_executors,
                           pad_to_bucket)
from tests.conftest import run_subprocess


# ---------------------------------------------------------------------------
# pad_to_bucket edge cases (satellite: serving-layer coverage)
# ---------------------------------------------------------------------------
def test_pad_to_bucket_empty_array():
    out = pad_to_bucket(np.empty((0,), np.int32), min_size=8)
    assert out.shape == (8,) and (out == -1).all()


def test_pad_to_bucket_exact_power_of_two():
    a = np.arange(32, dtype=np.int64)
    out = pad_to_bucket(a, min_size=4)
    assert out.shape == (32,) and (out == a).all()


def test_pad_to_bucket_reexported_from_core():
    from repro.core import pad_to_bucket as core_pad
    from repro.core.serving import pad_to_bucket as serving_pad
    assert core_pad is pad_to_bucket and serving_pad is pad_to_bucket


# ---------------------------------------------------------------------------
# DynamicBatcher boundaries
# ---------------------------------------------------------------------------
def test_dynamic_batcher_zero_deadline_closes_each_add():
    b = DynamicBatcher(deadline_s=0.0, max_batch=100)
    for i in range(4):
        out = b.add(Request(i, np.array([i]), time.perf_counter()))
        assert out is not None and len(out) == 1
    assert b.flush() is None


def test_dynamic_batcher_exact_psgs_budget_boundary():
    table = np.full(10, 10.0, np.float32)
    b = DynamicBatcher(deadline_s=10.0, psgs_budget=30.0, max_batch=100,
                       psgs_table=table)
    assert b.add(Request(0, np.array([0]), time.perf_counter())) is None
    assert b.add(Request(1, np.array([1]), time.perf_counter())) is None
    out = b.add(Request(2, np.array([2]), time.perf_counter()))
    assert out is not None and len(out) == 3  # 30 >= 30: budget is inclusive


def test_dynamic_batcher_padded_seed_ids_do_not_count():
    table = np.full(10, 10.0, np.float32)
    b = DynamicBatcher(deadline_s=10.0, psgs_budget=25.0, max_batch=100,
                       psgs_table=table)
    r = Request(0, np.array([1, -1, -1, 2]), time.perf_counter())
    assert b.add(r) is None  # only the two valid seeds (20.0) accumulate


# ---------------------------------------------------------------------------
# Serving stack fixture
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def stack():
    n, d, fan = 1200, 16, (4, 3)
    g = power_law_graph(n, 6.0, seed=0)
    feats = np.random.default_rng(0).normal(size=(n, d)).astype(np.float32)
    fap = compute_fap(g, fan)
    topo = TopologySpec(num_pods=1, devices_per_pod=1, rows_per_device=400,
                        rows_host=600, hot_replicate_fraction=0.3)
    store = TieredFeatureStore.build(feats, quiver_placement(fap, topo))
    params = sage_init(jax.random.key(0), [d, 32, 32])

    @jax.jit
    def infer_fn(hop_feats, hop_ids):
        masks = [(h >= 0).astype(jnp.float32)[:, None] for h in hop_ids]
        return sage_layered(params, hop_feats, fan, hop_masks=masks)

    psgs = compute_psgs(g, fan)
    return dict(graph=g, store=store, fan=fan, infer_fn=infer_fn, psgs=psgs)


def _executors(stack, *, max_batch=16, capacity=1):
    g = stack["graph"]
    return {
        "host": HostExecutor(g, stack["store"], stack["fan"],
                             stack["infer_fn"], capacity=capacity,
                             psgs_table=stack["psgs"]),
        "device": DeviceExecutor(g.device_arrays(), stack["store"],
                                 stack["fan"], stack["infer_fn"],
                                 max_batch=max_batch, capacity=capacity,
                                 psgs_table=stack["psgs"]),
    }


# ---------------------------------------------------------------------------
# Executors
# ---------------------------------------------------------------------------
def test_executor_protocol_and_futures(stack):
    ex = _executors(stack)
    for e in ex.values():
        assert isinstance(e, Executor)
        assert e.cost(np.array([1, 2, -1])) > 0
    fut = ex["device"].submit(np.arange(4))
    out = np.asarray(fut.result())
    assert out.shape[0] == 4 and np.isfinite(out).all()


def test_device_executor_chunks_oversized_batch_no_silent_drop(stack):
    """Regression: the old _device_path zero-filled max_batch and dropped
    every seed beyond it; oversized batches must chunk instead."""
    ex = _executors(stack, max_batch=8)["device"]
    seeds = np.arange(20)
    out = np.asarray(ex.process(seeds))
    assert out.shape[0] == 20  # one row per seed, nothing truncated
    assert np.isfinite(out).all()
    # seeds beyond the old cutoff produce real (not zero-filled) outputs
    assert np.abs(out[8:]).sum() > 0


def test_legacy_engine_serves_request_larger_than_max_batch(stack):
    """End-to-end no-drop regression through the legacy shim engine."""
    from repro.core.pipeline import ServingEngine as LegacyEngine
    engine = LegacyEngine(stack["graph"], stack["store"], stack["fan"],
                          stack["infer_fn"], StaticScheduler("device"),
                          num_workers=1, max_batch=8)
    out = np.asarray(engine._device_path(np.arange(20)))
    assert out.shape[0] == 20
    req = Request(0, np.arange(20), time.perf_counter())
    m = engine.run([[req]])
    assert m.requests == 1 and m.summary()["routed_device"] == 1


# ---------------------------------------------------------------------------
# N-way router ↔ binary threshold reduction
# ---------------------------------------------------------------------------
def _binary_calib():
    q = np.linspace(1, 100, 400)
    host_lat = 1e-4 * q                      # linear in work
    dev_lat = 2e-3 + 1e-5 * q                # offset + shallow slope
    return CalibrationResult(host=LatencyCurve.fit(q, host_lat, bins=8),
                             device=LatencyCurve.fit(q, dev_lat, bins=8))


@pytest.mark.parametrize("policy", POLICIES)
def test_cost_router_reduces_to_threshold_rule(policy):
    calib = _binary_calib()
    table = np.linspace(1, 100, 200)  # psgs_table: seed i costs table[i]
    hybrid = HybridScheduler(table, calib.threshold(policy), policy)
    router = CostModelRouter.from_calibration(table, calib, policy)
    for i in range(0, 200, 3):
        seeds = np.array([i])
        assert hybrid.route(seeds) == router.route(seeds), (policy, i)
    assert hybrid.routed == router.routed


def test_engine_nway_matches_binary_engine_routing(stack):
    """Integration: with only host+device registered, the cost-model engine
    routes exactly like the paper's binary PSGS-threshold engine."""
    psgs = stack["psgs"]
    gen = WorkloadGenerator(stack["graph"].num_nodes,
                            stack["graph"].out_degree, seed=3)
    reqs = list(gen.stream(24, seeds_per_request=4))
    costs = [float(psgs[r.seeds].sum()) for r in reqs]
    mid = float(np.median(costs)) * 1.01  # avoid an exact-boundary tie
    cmax = max(costs) + 1.0
    curves = {
        "host": LatencyCurve(psgs=np.array([0.0, cmax]),
                             avg=np.array([0.0, cmax]),
                             mx=np.array([0.0, cmax])),
        "device": LatencyCurve(psgs=np.array([0.0, cmax]),
                               avg=np.array([mid, mid]),
                               mx=np.array([mid, mid])),
    }
    calib = CalibrationResult(host=curves["host"], device=curves["device"])
    thr = calib.threshold("latency_preferred")

    m_bin = ServingEngine(_executors(stack),
                          HybridScheduler(psgs, thr)).run([[r] for r in reqs])
    m_nway = ServingEngine(
        _executors(stack),
        CostModelRouter.from_curves(psgs, curves, "latency_preferred")
    ).run([[r] for r in reqs])
    assert m_bin.requests == m_nway.requests == 24
    assert m_bin.routed == m_nway.routed
    assert m_bin.routed_host > 0 and m_bin.routed_device > 0


def test_calibrate_executors_fits_curve_per_executor(stack):
    ex = _executors(stack)
    batches = [np.arange(i, i + 4) for i in (0, 40, 80)]
    curves = calibrate_executors(ex, batches, stack["psgs"], repeats=1,
                                 warmup=1)
    assert set(curves) == {"host", "device"}
    for c in curves.values():
        assert c.psgs.size >= 1 and (c.avg > 0).all()


# ---------------------------------------------------------------------------
# Admission control
# ---------------------------------------------------------------------------
def test_engine_shed_policy_drops_over_window(stack):
    slow = dict(stack)
    base = stack["infer_fn"]

    def slow_infer(hop_feats, hop_ids):
        out = base(hop_feats, hop_ids)
        jax.block_until_ready(out)
        time.sleep(0.15)
        return out

    slow["infer_fn"] = slow_infer
    engine = ServingEngine(_executors(slow), StaticScheduler("host"),
                           max_inflight=1, admission="shed")
    reqs = [Request(i, np.array([i]), time.perf_counter()) for i in range(6)]
    m = engine.run([[r] for r in reqs])
    assert m.shed >= 1
    assert m.requests + m.shed == 6
    assert m.summary()["shed"] == m.shed


def test_engine_wait_policy_serves_everything(stack):
    engine = ServingEngine(_executors(stack), StaticScheduler("host"),
                           max_inflight=1, admission="wait")
    reqs = [Request(i, np.array([i]), time.perf_counter()) for i in range(5)]
    m = engine.run([[r] for r in reqs])
    assert m.shed == 0 and m.requests == 5


def test_engine_propagates_executor_failure(stack):
    bad = dict(stack)

    def boom(hop_feats, hop_ids):
        raise RuntimeError("executor exploded")

    bad["infer_fn"] = boom
    engine = ServingEngine(_executors(bad), StaticScheduler("device"))
    with pytest.raises(RuntimeError, match="executor exploded"):
        engine.run([[Request(0, np.array([1]), time.perf_counter())]])


def test_engine_releases_window_when_router_raises(stack):
    """Regression: a router failure must not leak an admission permit."""
    class FlakyRouter:
        def __init__(self):
            self.calls = 0

        def route(self, seeds):
            self.calls += 1
            if self.calls == 1:
                raise IndexError("bad seed id")
            return "host"

    engine = ServingEngine(_executors(stack), FlakyRouter(), max_inflight=1)
    with pytest.raises(IndexError):
        engine.submit_batch([Request(0, np.array([0]),
                                     time.perf_counter())])
    # with the permit leaked this run() would deadlock on the window
    m = engine.run([[Request(1, np.array([1]), time.perf_counter())]])
    assert m.requests == 1


def test_empty_summary_reports_zeroed_not_perfect_profile():
    from repro.serving import ServeMetrics
    s = ServeMetrics(shed=5).summary()
    assert s["requests"] == 0 and s["shed"] == 5
    assert s["p50_ms"] == 0.0
    assert s["pct_in_400ms"] == 0.0  # must not claim a met SLO for 0 served


def test_router_skips_unsupported_executor():
    table = np.full(8, 10.0, np.float32)
    flat = LatencyCurve(psgs=np.array([0.0, 100.0]),
                        avg=np.array([1.0, 1.0]), mx=np.array([1.0, 1.0]))
    slow = LatencyCurve(psgs=np.array([0.0, 100.0]),
                        avg=np.array([9.0, 9.0]), mx=np.array([9.0, 9.0]))

    class Fake:
        kind = "device"
        capacity = 1
        inflight = 0

        def __init__(self, ok):
            self.ok = ok

        def supports(self, seeds):
            return self.ok

    router = CostModelRouter(table, "latency_preferred")
    router.register("cheap", flat, executor=Fake(ok=False))
    router.register("pricey", slow, executor=Fake(ok=True))
    assert router.route(np.array([0])) == "pricey"  # cheap is ineligible
    # nothing supports the batch → degrade to considering every executor
    router2 = CostModelRouter(table, "latency_preferred")
    router2.register("a", flat, executor=Fake(ok=False))
    router2.register("b", slow, executor=Fake(ok=False))
    assert router2.route(np.array([0])) == "a"


def test_metrics_clean_after_failed_run(stack):
    """Stragglers/accounting from a failed run must not pollute the next
    run's ServeMetrics, and drain must not swallow late failures."""
    flaky = dict(stack)
    base = stack["infer_fn"]
    fail = {"on": True}

    def maybe_boom(hop_feats, hop_ids):
        if fail["on"]:
            time.sleep(0.05)  # fail after the run loop has moved on
            raise RuntimeError("flaky")
        return base(hop_feats, hop_ids)

    flaky["infer_fn"] = maybe_boom
    engine = ServingEngine(_executors(flaky, capacity=2),
                           StaticScheduler("host"))
    reqs = [Request(i, np.array([i]), time.perf_counter()) for i in range(4)]
    with pytest.raises(RuntimeError, match="flaky"):
        engine.run([[r] for r in reqs])
    fail["on"] = False
    m = engine.run([[Request(9, np.array([9]), time.perf_counter())]])
    assert m.requests == 1 and len(m.latencies) == 1
    assert m.routed == {"host": 1}


# ---------------------------------------------------------------------------
# 3-executor integration: host + device + sharded over a CPU mesh
# ---------------------------------------------------------------------------
@pytest.mark.subprocess
def test_three_executor_engine_with_sharded_mesh():
    code = """
import time
import numpy as np, jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core import (TieredFeatureStore, TopologySpec, compute_fap,
                        compute_psgs, quiver_placement)
from repro.core.feature_store import ShardedFeatureStore
from repro.core.serving import Request
from repro.graph import power_law_graph
from repro.models.gnn_basic import sage_init, sage_layered
from repro.serving import (CostModelRouter, DeviceExecutor, HostExecutor,
                           LatencyCurve, ServingEngine, ShardedExecutor)

n, d, fan = 2000, 16, (4, 3)
g = power_law_graph(n, 8.0, seed=0)
fap = compute_fap(g, fan)
psgs = compute_psgs(g, fan)
feats = np.random.default_rng(0).normal(size=(n, d)).astype(np.float32)
topo = TopologySpec(num_pods=2, devices_per_pod=4, rows_per_device=128,
                    rows_host=256, hot_replicate_fraction=0.25)
store = TieredFeatureStore.build(feats, quiver_placement(fap, topo))
mesh = make_mesh((8,), ("x",))
sstore = ShardedFeatureStore.from_tiered(store, mesh, "x")
params = sage_init(jax.random.key(0), [d, 32, 32])

@jax.jit
def infer_fn(hop_feats, hop_ids):
    masks = [(h >= 0).astype(jnp.float32)[:, None] for h in hop_ids]
    return sage_layered(params, hop_feats, fan, hop_masks=masks)

gd = g.device_arrays()
ex = {
    "host": HostExecutor(g, store, fan, infer_fn, psgs_table=psgs),
    "device": DeviceExecutor(gd, store, fan, infer_fn, max_batch=16,
                             psgs_table=psgs),
    "sharded": ShardedExecutor(mesh, "x", gd, sstore, fan, infer_fn,
                               max_batch=16, psgs_table=psgs),
}
# the sharded executor's static shape is a multiple of the mesh world
assert ex["sharded"].max_batch % 8 == 0

# give each executor a sweet spot at a real workload cost so N-way routing
# provably exercises all three
order = np.argsort(psgs)
s_lo, s_mid, s_hi = int(order[0]), int(order[n // 2]), int(order[-1])
p_lo, p_mid, p_hi = (float(psgs[s]) for s in (s_lo, s_mid, s_hi))
assert p_lo < p_mid < p_hi
qmax = p_hi + 1.0

def vcurve(center):
    xs = np.array([0.0, center, qmax])
    ys = np.abs(xs - center) + 1e-6
    return LatencyCurve(psgs=xs, avg=ys, mx=ys)

router = CostModelRouter(psgs, "latency_preferred")
router.register("host", vcurve(p_lo), kind="host", executor=ex["host"])
router.register("device", vcurve(p_mid), executor=ex["device"])
router.register("sharded", vcurve(p_hi), executor=ex["sharded"])

engine = ServingEngine(ex, router, max_inflight=8)
reqs = [Request(i, np.array([s]), time.perf_counter())
        for i, s in enumerate([s_lo, s_mid, s_hi] * 4)]
m = engine.run([[r] for r in reqs])
assert m.requests == 12, m.requests
assert all(m.routed.get(k, 0) == 4 for k in ("host", "device", "sharded")), \\
    m.routed

# the sharded path itself chunks oversized batches and returns finite rows
out = np.asarray(ex["sharded"].run(np.arange(24)))
assert out.shape == (24, 32) and np.isfinite(out).all()
print("THREE_EXEC_OK", m.routed)
"""
    r = run_subprocess(code, devices=8)
    assert "THREE_EXEC_OK" in r.stdout, r.stderr[-3000:]

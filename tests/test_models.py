"""Model-level tests: attention exactness, MoE dispatch oracle, equiformer
equivariance, LM decode≡forward consistency, DIN."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.graph import molecule_batch, power_law_graph
from repro.models import (DINConfig, LMConfig, blockwise_attention,
                          din_forward, din_init, din_loss, embedding_bag,
                          equiformer_forward, equiformer_init,
                          init_decode_cache, lm_decode_step, lm_forward,
                          lm_init, lm_loss, reference_attention)
from repro.models.moe import MoEConfig, moe_apply, moe_init
from repro.models.so3 import (edge_rotation_blocks, l1_embedding,
                              num_coeffs, rotation_matrix_zyz, wigner_zyz)
from repro.models.transformer import lm_param_count, lm_prefill


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,s,h,kv,dh", [(2, 128, 4, 2, 32), (1, 96, 8, 8, 16),
                                         (1, 130, 2, 1, 8)])
def test_blockwise_attention_exact(b, s, h, kv, dh):
    ks = jax.random.split(jax.random.key(s + h), 3)
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, kv, dh))
    v = jax.random.normal(ks[2], (b, s, kv, dh))
    o1 = blockwise_attention(q, k, v, causal=True, q_chunk=32, kv_chunk=48)
    o2 = reference_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-6)


# ---------------------------------------------------------------------------
# SO(3) / Wigner conventions
# ---------------------------------------------------------------------------
def test_wigner_orthogonal_and_l1():
    lmax = 4
    a, b, g = 0.3, 1.1, -0.7
    D = np.asarray(wigner_zyz(a, b, g, lmax))
    assert np.abs(D @ D.T - np.eye(num_coeffs(lmax))).max() < 1e-5
    R = rotation_matrix_zyz(a, b, g)
    P = np.zeros((3, 3))
    P[0, 1] = P[1, 2] = P[2, 0] = 1  # (y, z, x) real-SH ordering
    np.testing.assert_allclose(D[1:4, 1:4], P @ R @ P.T, atol=1e-5)


def test_edge_rotation_aligns_to_z():
    rng = np.random.default_rng(0)
    r = rng.normal(size=(20, 3))
    r /= np.linalg.norm(r, axis=-1, keepdims=True)
    D, Dinv = edge_rotation_blocks(jnp.asarray(r, jnp.float32), 3)
    emb = np.asarray(l1_embedding(jnp.asarray(r, jnp.float32)))
    out = np.einsum("eij,ej->ei", np.asarray(D[1]), emb)
    np.testing.assert_allclose(out, np.tile([0, 1, 0], (20, 1)), atol=1e-5)
    for l in range(4):
        eye = np.einsum("eij,ejk->eik", np.asarray(D[l]), np.asarray(Dinv[l]))
        np.testing.assert_allclose(eye, np.tile(np.eye(2 * l + 1),
                                                (20, 1, 1)), atol=1e-5)


def test_equiformer_rotation_invariance():
    g, pos, mol_id = molecule_batch(4, 8, seed=0, cutoff=2.5)
    src, dst = g.to_coo()
    species = np.random.default_rng(0).integers(0, 5, size=g.num_nodes)
    params = equiformer_init(jax.random.key(0), n_layers=2, channels=16,
                             l_max=3, m_max=2, n_heads=4, n_rbf=8, d_out=2)
    kw = dict(num_nodes=g.num_nodes, mol_id=jnp.asarray(mol_id),
              num_graphs=4)
    out = equiformer_forward(params, jnp.asarray(species),
                             jnp.asarray(pos, jnp.float32), jnp.asarray(src),
                             jnp.asarray(dst), **kw)
    R = rotation_matrix_zyz(0.4, 1.0, -0.3).astype(np.float32)
    out_r = equiformer_forward(params, jnp.asarray(species),
                               jnp.asarray(pos @ R.T, jnp.float32),
                               jnp.asarray(src), jnp.asarray(dst), **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_r), atol=1e-4)


def test_equiformer_edge_chunking_exact():
    g, pos, mol_id = molecule_batch(3, 10, seed=1, cutoff=2.5)
    src, dst = g.to_coo()
    species = np.random.default_rng(1).integers(0, 5, size=g.num_nodes)
    params = equiformer_init(jax.random.key(1), n_layers=2, channels=16,
                             l_max=2, m_max=1, n_heads=4, n_rbf=8)
    kw = dict(num_nodes=g.num_nodes)
    a = equiformer_forward(params, jnp.asarray(species), jnp.asarray(pos),
                           jnp.asarray(src), jnp.asarray(dst), **kw)
    b = equiformer_forward(params, jnp.asarray(species), jnp.asarray(pos),
                           jnp.asarray(src), jnp.asarray(dst),
                           edge_chunks=4, **kw)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------
def test_moe_matches_dense_oracle():
    cfg = MoEConfig(num_experts=4, top_k=2, d_ff=16, capacity_factor=8.0)
    p = moe_init(jax.random.key(0), 8, cfg)
    x = jax.random.normal(jax.random.key(1), (32, 8))
    y, stats = moe_apply(p, x, cfg)
    logits = x @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    tw, te = jax.lax.top_k(probs, 2)
    tw = tw / tw.sum(-1, keepdims=True)
    yo = jnp.zeros_like(x)
    for e in range(4):
        h = jax.nn.silu(x @ p["w1"][e]) * (x @ p["w3"][e])
        w = jnp.where(te == e, tw, 0.0).sum(-1)
        yo = yo + (h @ p["w2"][e]) * w[:, None]
    np.testing.assert_allclose(np.asarray(y), np.asarray(yo), atol=1e-5)
    assert int(stats["dropped"]) == 0


def test_moe_capacity_drops_overflow():
    cfg = MoEConfig(num_experts=4, top_k=1, d_ff=8, capacity_factor=0.25)
    p = moe_init(jax.random.key(0), 8, cfg)
    x = jax.random.normal(jax.random.key(2), (64, 8))
    y, stats = moe_apply(p, x, cfg)
    assert int(stats["dropped"]) > 0
    assert bool(jnp.isfinite(y).all())
    assert stats["expert_load"].sum() + stats["dropped"] == 64


def test_moe_router_stats_feed_expert_placement():
    from repro.core import expert_placement
    cfg = MoEConfig(num_experts=8, top_k=2, d_ff=8)
    p = moe_init(jax.random.key(0), 8, cfg)
    x = jax.random.normal(jax.random.key(3), (128, 8))
    _, stats = moe_apply(p, x, cfg)
    reps = expert_placement(np.asarray(stats["expert_load"]), 8, 4)
    assert reps.sum() == 12


# ---------------------------------------------------------------------------
# LM: decode matches teacher-forced forward
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("qkv_bias,qk_norm,moe", [
    (True, False, False), (False, True, False), (False, False, True)])
def test_lm_decode_consistency(qkv_bias, qk_norm, moe):
    mcfg = (MoEConfig(num_experts=4, top_k=2, d_ff=32, capacity_factor=8.0)
            if moe else None)
    cfg = LMConfig(vocab=64, d_model=32, n_layers=2, n_heads=4, n_kv=2,
                   head_dim=8, d_ff=64 if not moe else 0, qkv_bias=qkv_bias,
                   qk_norm=qk_norm, moe=mcfg, q_chunk=8, kv_chunk=8)
    params = lm_init(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab)
    # teacher-forced logits at the last position
    h, _ = lm_forward(params, toks, cfg)
    full_logits = h[:, -1, :] @ params["unembed"]
    # decode step-by-step
    cache = init_decode_cache(cfg, 2, 16, jnp.float32)
    for t in range(12):
        logits, cache = lm_decode_step(params, toks[:, t:t + 1], cache,
                                       jnp.asarray(t + 1, jnp.int32), cfg)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full_logits),
                               rtol=2e-4, atol=2e-4)


def test_lm_prefill_matches_decode_cache():
    cfg = LMConfig(vocab=64, d_model=32, n_layers=2, n_heads=4, n_kv=4,
                   head_dim=8, d_ff=64, q_chunk=8, kv_chunk=8)
    params = lm_init(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(2), (1, 8), 0, cfg.vocab)
    logits_p, cache_p = lm_prefill(params, toks, cfg)
    cache = init_decode_cache(cfg, 1, 8, jnp.float32)
    for t in range(8):
        logits_d, cache = lm_decode_step(params, toks[:, t:t + 1], cache,
                                         jnp.asarray(t + 1, jnp.int32), cfg)
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(logits_d),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(
        np.asarray(cache_p["k"], np.float32),
        np.asarray(cache["k"], np.float32), rtol=2e-2, atol=2e-2)


def test_lm_param_count_formula():
    cfg = LMConfig(vocab=128, d_model=64, n_layers=3, n_heads=4, n_kv=2,
                   head_dim=16, d_ff=256, qkv_bias=False)
    params = lm_init(jax.random.key(0), cfg)
    actual = sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(params))
    # formula excludes tiny norm scales per layer; allow <1% slack
    assert abs(actual - lm_param_count(cfg)) / actual < 0.02


def test_lm_train_loss_decreases():
    cfg = LMConfig(vocab=64, d_model=32, n_layers=2, n_heads=4, n_kv=4,
                   head_dim=8, d_ff=64, q_chunk=16, kv_chunk=16)
    params = lm_init(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(3), (4, 32), 0, cfg.vocab)
    from repro.training import AdamW
    opt = AdamW(lr=3e-3, weight_decay=0.0, warmup_steps=1)
    state = opt.init(params)
    loss_fn = jax.jit(jax.value_and_grad(
        lambda p: lm_loss(p, toks, toks, cfg)))
    first = None
    for _ in range(20):
        loss, grads = loss_fn(params)
        params, state = opt.update(grads, state, params)
        first = first if first is not None else float(loss)
    assert float(loss) < first * 0.8, (first, float(loss))


# ---------------------------------------------------------------------------
# DIN
# ---------------------------------------------------------------------------
def test_embedding_bag_modes():
    tbl = jnp.asarray(np.random.default_rng(0).normal(size=(50, 8)),
                      jnp.float32)
    ids = jnp.asarray([[1, 2, -1], [4, -1, -1]], jnp.int32)
    s = embedding_bag(tbl, ids, mode="sum")
    m = embedding_bag(tbl, ids, mode="mean")
    np.testing.assert_allclose(np.asarray(s[0]),
                               np.asarray(tbl[1] + tbl[2]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(m[0]),
                               np.asarray((tbl[1] + tbl[2]) / 2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(m[1]), np.asarray(tbl[4]),
                               rtol=1e-6)


def test_din_attention_masks_padding():
    cfg = DINConfig(n_items=100, n_cates=10, hist_len=5, n_dense_feat=2)
    params = din_init(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    base = dict(
        target_item=jnp.asarray([3]), target_cate=jnp.asarray([1]),
        dense_feat=jnp.asarray(rng.normal(size=(1, 2)), jnp.float32))
    hist = jnp.asarray([[5, 9, -1, -1, -1]])
    cats = jnp.asarray([[1, 2, 0, 0, 0]])
    out1 = din_forward(params, cfg, base["target_item"], base["target_cate"],
                       hist, cats, base["dense_feat"])
    # changing *padded* history slots must not change the output
    hist2 = jnp.asarray([[5, 9, -1, -1, -1]])
    cats2 = jnp.asarray([[1, 2, 7, 8, 9]])
    out2 = din_forward(params, cfg, base["target_item"], base["target_cate"],
                       hist2, cats2, base["dense_feat"])
    # NOTE: categories of padded items are still embedded in this impl only
    # when item id >= 0; padded ids are masked in _embed_pair
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)

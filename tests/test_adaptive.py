"""Online workload adaptation (serving/adaptive.py) + the serving-metrics
and calibration bugfixes that ride along: lookup-equivalence across live
tier migration, router drift refit, empty-percentile regression, degenerate
LatencyCurve fits, and router/engine counter agreement on failed submits."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Request, TieredFeatureStore, TopologySpec,
                        compute_fap, compute_psgs, migration_pairs,
                        quiver_placement)
from repro.core.placement import TIER_HOST, TIER_HOT
from repro.graph import power_law_graph
from repro.models.gnn_basic import sage_init, sage_layered
from repro.serving import (AdaptiveConfig, AdaptiveController,
                           CostModelRouter, FrequencySketch, HostExecutor,
                           LatencyCurve, ServeMetrics, ServingEngine,
                           StaticScheduler)


# ---------------------------------------------------------------------------
# Fixtures
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def migr_stack():
    n, d, fan = 900, 12, (4, 3)
    g = power_law_graph(n, 6.0, seed=0)
    feats = np.random.default_rng(1).normal(size=(n, d)).astype(np.float32)
    fap = compute_fap(g, fan)
    topo = TopologySpec(num_pods=1, devices_per_pod=1, rows_per_device=220,
                        rows_host=330, hot_replicate_fraction=0.3)
    return g, fan, feats, fap, topo


def _fresh_store(migr_stack):
    g, fan, feats, fap, topo = migr_stack
    return TieredFeatureStore.build(feats, quiver_placement(fap, topo))


# ---------------------------------------------------------------------------
# Migration: lookup equivalence before / during / after
# ---------------------------------------------------------------------------
def test_migration_pairs_preserve_tier_counts(migr_stack):
    g, fan, feats, fap, topo = migr_stack
    cur = quiver_placement(fap, topo)
    drifted = fap.copy()
    rng = np.random.default_rng(3)
    drifted[rng.permutation(g.num_nodes)[:50]] += fap.max() * 2
    tgt = quiver_placement(drifted, topo)
    pairs = migration_pairs(cur.tier, tgt.tier, drifted, budget=30)
    assert 0 < len(pairs) <= 30
    flat = [n for ab in pairs for n in ab]
    assert len(set(flat)) == len(flat)  # disjoint
    for a, b in pairs:
        assert cur.tier[a] > cur.tier[b]          # promote into hotter tier
        assert tgt.tier[a] == cur.tier[b]         # a lands on its target


def test_swap_assignments_lookup_equivalence_and_validity(migr_stack):
    g, fan, feats, fap, topo = migr_stack
    store = _fresh_store(migr_stack)
    ids = jnp.asarray(np.arange(g.num_nodes), jnp.int32)
    before = np.asarray(store.lookup(ids))
    np.testing.assert_allclose(before, feats, rtol=1e-6)

    drifted = fap.copy()
    cold = np.argsort(fap)[:60]
    drifted[cold] += fap.max() * 3
    tgt = quiver_placement(drifted, topo)
    total = 0
    for _ in range(12):  # bounded steps until convergence
        pairs = migration_pairs(store.plan.tier, tgt.tier, drifted, budget=25)
        if not pairs:
            break
        total += store.swap_assignments(pairs)
        after = np.asarray(store.lookup(ids))
        np.testing.assert_allclose(after, feats, rtol=1e-6)  # during
    assert total > 0 and store.migrated_rows == total
    assert (store.plan.tier == tgt.tier).all()  # converged
    store.plan.validate()                       # capacity invariants hold
    assert store.tier_histogram(cold)["hot"] + \
        store.tier_histogram(cold)["warm"] == 60


def test_swap_assignments_rejects_overlapping_pairs(migr_stack):
    store = _fresh_store(migr_stack)
    hot = int(np.flatnonzero(store.plan.tier == TIER_HOT)[0])
    host = np.flatnonzero(store.plan.tier == TIER_HOST)[:2]
    with pytest.raises(ValueError, match="disjoint"):
        store.swap_assignments([(int(host[0]), hot), (int(host[1]), hot)])


def test_lookup_equivalence_under_concurrent_migration(migr_stack):
    """Property: a reader thread doing lookups while the main thread runs
    migration steps must only ever observe the exact features (a torn
    tier/slot/array mix would surface as wrong rows)."""
    g, fan, feats, fap, topo = migr_stack
    store = _fresh_store(migr_stack)
    probe = np.random.default_rng(7).integers(0, g.num_nodes, 64)
    probe_j = jnp.asarray(probe, jnp.int32)
    expected = feats[probe]
    stop = threading.Event()
    errors: list[str] = []

    def reader():
        while not stop.is_set():
            got = np.asarray(store.lookup(probe_j))
            if not np.allclose(got, expected, rtol=1e-5):
                errors.append("torn lookup during migration")
                return

    t = threading.Thread(target=reader)
    t.start()
    try:
        drifted = fap.copy()
        drifted[np.argsort(fap)[:80]] += fap.max() * 3
        tgt = quiver_placement(drifted, topo)
        for _ in range(10):
            pairs = migration_pairs(store.plan.tier, tgt.tier, drifted,
                                    budget=20)
            if not pairs:
                break
            store.swap_assignments(pairs)
    finally:
        stop.set()
        t.join(timeout=30)
    assert not errors
    np.testing.assert_allclose(np.asarray(store.lookup(probe_j)), expected,
                               rtol=1e-6)


@pytest.mark.hypothesis
def test_migration_property_hypothesis(migr_stack):
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    g, fan, feats, fap, topo = migr_stack

    @settings(max_examples=10, deadline=None)
    @given(st.lists(st.integers(0, g.num_nodes - 1), min_size=1, max_size=40,
                    unique=True),
           st.integers(1, 20))
    def prop(hot_ids, budget):
        store = _fresh_store(migr_stack)
        drifted = fap.copy()
        drifted[np.asarray(hot_ids)] += fap.max() * 2
        tgt = quiver_placement(drifted, topo)
        counts_before = store.plan.tier_counts()
        store.swap_assignments(
            migration_pairs(store.plan.tier, tgt.tier, drifted,
                            budget=budget))
        assert store.plan.tier_counts() == counts_before
        ids = jnp.asarray(np.arange(g.num_nodes), jnp.int32)
        np.testing.assert_allclose(np.asarray(store.lookup(ids)), feats,
                                   rtol=1e-6)

    prop()


# ---------------------------------------------------------------------------
# FrequencySketch + controller control loop
# ---------------------------------------------------------------------------
def test_frequency_sketch_decay_and_prior():
    s = FrequencySketch(10, decay=0.5)
    assert np.allclose(s.empirical_prob(), 0.1)  # cold start → uniform
    s.observe(np.array([3, 3, 3, 3, -1]))        # padding ignored
    assert s.total_observed == 4
    p = s.empirical_prob(prior_weight=0.0)
    assert p[3] == pytest.approx(1.0)
    s.decay_step()
    s.observe(np.array([5, 5]))
    p = s.empirical_prob(prior_weight=0.0)
    assert p[3] == pytest.approx(0.5) and p[5] == pytest.approx(0.5)
    p = s.empirical_prob(prior_weight=0.2)
    assert p.sum() == pytest.approx(1.0) and p[0] > 0  # never-seen kept warm


def test_controller_migrates_hotspot_into_hbm(migr_stack):
    g, fan, feats, fap, topo = migr_stack
    store = _fresh_store(migr_stack)
    cold = np.argsort(fap)[:30]
    assert (store.plan.tier[cold] >= TIER_HOST).all()
    ctl = AdaptiveController(
        g, fan, store, config=AdaptiveConfig(rows_per_step=1000,
                                             prior_weight=0.1))
    for _ in range(4):
        ctl.on_admit("host", np.repeat(cold, 4))
    for _ in range(4):
        r = ctl.step()
        if r["pending"] == 0:
            break
    hist = store.tier_histogram(cold)
    assert hist["hot"] + hist["warm"] == 30  # hotspot now lives in HBM
    assert ctl.report()["migrated_rows"] == store.migrated_rows > 0


def test_engine_hooks_drive_controller_live(migr_stack):
    """End-to-end: ServingEngine hooks feed the sketch and trigger control
    steps while serving; the hot-spotted cold nodes end up in HBM tiers."""
    g, fan, feats, fap, topo = migr_stack
    store = _fresh_store(migr_stack)
    psgs = compute_psgs(g, fan)
    params = sage_init(jax.random.key(0), [feats.shape[1], 16, 16])

    @jax.jit
    def infer_fn(hop_feats, hop_ids):
        masks = [(h >= 0).astype(jnp.float32)[:, None] for h in hop_ids]
        return sage_layered(params, hop_feats, fan, hop_masks=masks)

    host = HostExecutor(g, store, fan, infer_fn, psgs_table=psgs)
    ctl = AdaptiveController(
        g, fan, store, psgs_table=psgs,
        config=AdaptiveConfig(interval_batches=4, rows_per_step=400))
    engine = ServingEngine({"host": host}, StaticScheduler("host"),
                           hooks=[ctl])
    cold = np.argsort(fap)[:16]
    reqs = [[Request(i, cold.copy(), time.perf_counter())]
            for i in range(12)]
    m = engine.run(reqs)
    assert m.requests == 12
    assert ctl.stats["steps"] >= 2          # control loop ran mid-serving
    assert store.migrated_rows > 0
    hist = store.tier_histogram(cold)
    assert hist["hot"] + hist["warm"] == 16


def test_router_switches_executor_after_drift_refit():
    """Satellite: live samples contradicting the offline curves must flip
    the routing decision once refit_curves swaps the drifted curve in."""
    class _G:  # controller only needs num_nodes for the sketch here
        num_nodes = 8

    table = np.full(8, 10.0, np.float32)
    flat = LatencyCurve(psgs=np.array([0.0, 100.0]),
                        avg=np.array([1e-3, 1e-3]), mx=np.array([1e-3, 1e-3]))
    slow = LatencyCurve(psgs=np.array([0.0, 100.0]),
                        avg=np.array([5e-3, 5e-3]), mx=np.array([5e-3, 5e-3]))
    router = CostModelRouter(table, "latency_preferred")
    router.register("host", flat, kind="host")
    router.register("device", slow, kind="device")
    seeds = np.array([0, 1])
    assert router.route(seeds) == "host"  # offline curves: host is cheap

    store = type("S", (), {"plan": None})()
    ctl = AdaptiveController(_G(), (2,), store, router, psgs_table=table,
                             config=AdaptiveConfig(min_refit_samples=8,
                                                   drift_threshold=0.25,
                                                   curve_bins=4,
                                                   interval_batches=10**9))
    # live telemetry: host now 10x slower than calibrated, device unchanged
    for i in range(16):
        ctl.on_batch_complete("host", np.array([i % 8]), 1e-2 + i * 1e-5)
        ctl.on_batch_complete("device", np.array([i % 8]), 5e-3)
    swapped = ctl.refit_curves()
    assert swapped >= 1
    assert ctl.stats["last_drift"]["host"] > 0.25
    assert router.route(seeds) == "device"  # refit flipped the decision


# ---------------------------------------------------------------------------
# Satellite: ServeMetrics.percentile on an all-shed run
# ---------------------------------------------------------------------------
def test_percentile_empty_latencies_returns_zero():
    m = ServeMetrics(shed=7)
    assert m.percentile(0.99) == 0.0  # crashed before the fix
    assert m.summary()["p99_ms"] == 0.0


def test_percentile_nonempty_still_exact():
    m = ServeMetrics(latencies=[0.1, 0.2, 0.3, 0.4])
    assert m.percentile(0.5) == pytest.approx(0.25)


# ---------------------------------------------------------------------------
# Satellite: LatencyCurve degenerate fits + out-of-range extrapolation
# ---------------------------------------------------------------------------
def test_latency_curve_fit_fewer_samples_than_bins():
    c = LatencyCurve.fit([1.0, 2.0, 3.0], [0.1, 0.2, 0.3], bins=12)
    assert c.psgs.size >= 2
    assert float(c.eval_avg(2.0)) == pytest.approx(0.2, rel=0.2)


def test_latency_curve_fit_constant_psgs():
    c = LatencyCurve.fit([5.0] * 10, np.linspace(0.1, 0.2, 10), bins=8)
    assert c.psgs.size == 1
    assert float(c.eval_avg(5.0)) == pytest.approx(0.15)
    assert float(c.eval_max(123.0)) == pytest.approx(0.2)


def test_latency_curve_extrapolates_beyond_calibrated_range():
    q = np.linspace(10, 100, 200)
    c = LatencyCurve.fit(q, 1e-4 * q, bins=8)
    hi = float(c.psgs[-1])
    # np.interp alone would return the flat edge value (~1e-2) at 10x range
    far = float(c.eval_avg(hi * 10))
    assert far > float(c.eval_avg(hi)) * 5
    assert far == pytest.approx(1e-4 * hi * 10, rel=0.1)
    assert c.covers(hi) and not c.covers(hi * 10)
    # noisy decreasing tail must not extrapolate downward
    dec = LatencyCurve(psgs=np.array([1.0, 2.0]), avg=np.array([2.0, 1.0]),
                       mx=np.array([2.0, 1.0]))
    assert float(dec.eval_avg(100.0)) == pytest.approx(1.0)


def test_latency_curve_single_sample():
    c = LatencyCurve.fit([4.0], [0.5], bins=6)
    assert float(c.eval_avg(4.0)) == pytest.approx(0.5)
    assert float(c.eval_avg(400.0)) == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# Satellite: router/engine counter divergence on failed submit
# ---------------------------------------------------------------------------
class _BoomExecutor:
    """Quacks like an Executor but always fails at submit()."""
    name = "boom"
    kind = "device"
    capacity = 1
    inflight = 0

    def cost(self, seeds):
        return 1.0

    def submit(self, seeds):
        raise RuntimeError("submit rejected")


def test_router_count_rolled_back_when_submit_raises():
    router = StaticScheduler("boom")
    engine = ServingEngine({"boom": _BoomExecutor()}, router)
    with pytest.raises(RuntimeError, match="submit rejected"):
        engine.submit_batch([Request(0, np.array([0]), time.perf_counter())])
    # the router must not count work that never executed
    assert router.routed == {"boom": 0}


def test_metrics_finished_stamped_when_drain_reraises():
    router = StaticScheduler("boom")
    engine = ServingEngine({"boom": _BoomExecutor()}, router)
    with pytest.raises(RuntimeError):
        engine.run([[Request(0, np.array([0]), time.perf_counter())]])
    m = engine._metrics
    assert m.finished > m.started > 0  # throughput denominator is real time

"""End-to-end serving stack: scheduler calibration/crossovers, dynamic
batcher, workload generator, pipeline throughput/latency accounting."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CalibrationResult, DynamicBatcher, HybridScheduler,
                        LatencyCurve, Request, ServingEngine, StaticScheduler,
                        TieredFeatureStore, TopologySpec, WorkloadGenerator,
                        batch_seeds, compute_fap, compute_psgs, pad_to_bucket,
                        quiver_placement)
from repro.graph import power_law_graph
from repro.models.gnn_basic import sage_init, sage_layered


def _curve(psgs, lat):
    return LatencyCurve.fit(psgs, lat, bins=6)


def test_latency_curve_fit_monotone_interp():
    psgs = np.linspace(1, 100, 200)
    lat = 0.001 + psgs * 1e-5
    c = _curve(psgs, lat + np.random.default_rng(0).normal(0, 1e-6, 200))
    assert c.eval_avg(50.0) == pytest.approx(0.0015, rel=0.1)


def test_crossover_points_ordering():
    """Host is flat-cheap; device has fixed overhead but lower slope — the
    four thresholds of paper Fig. 6 exist and are ordered sensibly."""
    psgs = np.linspace(1, 100, 400)
    host_lat = 1e-4 * psgs                       # linear in work
    dev_lat = 2e-3 + 1e-5 * psgs                 # offset + shallow slope
    calib = CalibrationResult(
        host=_curve(psgs, host_lat), device=_curve(psgs, dev_lat))
    thr = {p: calib.threshold(p) for p in
           ("cpu_preferred", "gpu_preferred", "latency_preferred",
            "throughput_preferred")}
    assert 10 < thr["throughput_preferred"] < 40
    # all four thresholds agree here since curves have no noise spread
    for v in thr.values():
        assert 10 < v < 40


def test_no_intersection_cases():
    psgs = np.linspace(1, 10, 50)
    always_host = CalibrationResult(host=_curve(psgs, 0.001 + 0 * psgs),
                                    device=_curve(psgs, 0.01 + 0 * psgs))
    assert always_host.threshold("throughput_preferred") == float("inf")
    always_dev = CalibrationResult(host=_curve(psgs, 0.01 + 0 * psgs),
                                   device=_curve(psgs, 0.001 + 0 * psgs))
    assert always_dev.threshold("throughput_preferred") == 0.0


def test_hybrid_scheduler_routes_by_psgs():
    table = np.array([1.0, 10.0, 100.0, 1000.0], np.float32)
    s = HybridScheduler(table, threshold=50.0)
    assert s.route(np.array([0, 1])) == "host"      # 11 < 50
    assert s.route(np.array([2])) == "device"       # 100 ≥ 50
    assert s.routed == {"host": 1, "device": 1}


def test_dynamic_batcher_psgs_budget():
    table = np.full(100, 10.0, np.float32)
    b = DynamicBatcher(deadline_s=10.0, psgs_budget=35.0, max_batch=100,
                       psgs_table=table)
    out = None
    for i in range(10):
        out = b.add(Request(i, np.array([i]), time.perf_counter()))
        if out is not None:
            break
    assert out is not None and len(out) == 4      # 4×10 ≥ 35


def test_dynamic_batcher_max_batch():
    b = DynamicBatcher(deadline_s=10.0, max_batch=3)
    outs = []
    for i in range(7):
        r = b.add(Request(i, np.array([i]), time.perf_counter()))
        if r:
            outs.append(r)
    assert [len(o) for o in outs] == [3, 3]
    assert len(b.flush()) == 1


def test_workload_generator_distributions():
    g = power_law_graph(500, 6.0, seed=0)
    deg_gen = WorkloadGenerator(500, g.out_degree, distribution="degree",
                                seed=1)
    uni_gen = WorkloadGenerator(500, g.out_degree, distribution="uniform",
                                seed=1)
    deg_seeds = np.concatenate([r.seeds for r in deg_gen.stream(400, 4)])
    uni_seeds = np.concatenate([r.seeds for r in uni_gen.stream(400, 4)])
    # degree-weighted seeds hit high-degree nodes more often
    hi = np.argsort(-g.out_degree)[:50]
    assert np.isin(deg_seeds, hi).mean() > np.isin(uni_seeds, hi).mean() * 1.5


def test_pad_to_bucket_shapes():
    a = pad_to_bucket(np.arange(5), min_size=4)
    assert a.shape == (8,) and (a[5:] == -1).all()
    assert pad_to_bucket(np.arange(4), min_size=4).shape == (4,)


@pytest.fixture(scope="module")
def serving_stack():
    g = power_law_graph(1200, 6.0, seed=0)
    fan = (4, 3)
    feats = np.random.default_rng(0).normal(size=(1200, 16)).astype(
        np.float32)
    fap = compute_fap(g, fan)
    topo = TopologySpec(num_pods=1, devices_per_pod=1, rows_per_device=400,
                        rows_host=600, hot_replicate_fraction=0.3)
    store = TieredFeatureStore.build(feats, quiver_placement(fap, topo))
    params = sage_init(jax.random.key(0), [16, 32, 32])

    @jax.jit
    def infer_fn(hop_feats, hop_ids):
        masks = [(h >= 0).astype(jnp.float32)[:, None] for h in hop_ids]
        return sage_layered(params, hop_feats, fan, hop_masks=masks)

    psgs = compute_psgs(g, fan)
    return g, store, fan, infer_fn, psgs


def test_pipeline_end_to_end(serving_stack):
    g, store, fan, infer_fn, psgs = serving_stack
    engine = ServingEngine(g, store, fan, infer_fn,
                           HybridScheduler(psgs, np.median(psgs) * 8),
                           num_workers=2, max_batch=16)
    gen = WorkloadGenerator(g.num_nodes, g.out_degree, seed=3)
    batches = [[r] for r in gen.stream(24, seeds_per_request=8)]
    m = engine.run(batches)
    s = m.summary()
    assert s["requests"] == 24
    assert s["routed_host"] + s["routed_device"] == 24
    assert s["p99_ms"] >= s["p50_ms"] > 0


def test_pipeline_host_and_device_paths_agree_on_seeds(serving_stack):
    """Both executors produce finite outputs with identical leading shape
    semantics (same seeds → same output rows)."""
    g, store, fan, infer_fn, psgs = serving_stack
    engine = ServingEngine(g, store, fan, infer_fn, StaticScheduler("host"),
                           max_batch=16)
    seeds = np.arange(10)
    out_h = np.asarray(engine._host_path(seeds))
    out_d = np.asarray(engine._device_path(seeds))
    assert np.isfinite(out_h).all() and np.isfinite(out_d).all()
    assert out_h.shape[1] == out_d.shape[1]

"""Tiered feature store: exactness across tiers, dedup path, sharded
(shard_map) one-sided reads in a subprocess with 8 fake devices."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (TieredFeatureStore, TopologySpec, compute_fap,
                        quiver_placement)
from repro.graph import power_law_graph
from tests.conftest import run_subprocess


@pytest.fixture(scope="module")
def store_and_feats():
    n, d = 1500, 24
    g = power_law_graph(n, 6.0, seed=0)
    fap = compute_fap(g, (4, 3))
    feats = np.random.default_rng(1).normal(size=(n, d)).astype(np.float32)
    topo = TopologySpec(num_pods=2, devices_per_pod=4, rows_per_device=64,
                        rows_host=256, hot_replicate_fraction=0.25)
    plan = quiver_placement(fap, topo)
    return TieredFeatureStore.build(feats, plan), feats


def test_lookup_exact_all_tiers(store_and_feats):
    store, feats = store_and_feats
    ids = np.random.default_rng(2).integers(0, feats.shape[0], 128)
    ids[7] = -1
    ids[50] = ids[3]  # duplicate
    out = np.asarray(store.lookup(jnp.asarray(ids, jnp.int32)))
    expected = np.where((ids >= 0)[:, None], feats[np.maximum(ids, 0)], 0.0)
    np.testing.assert_allclose(out, expected, rtol=1e-6)


def test_lookup_without_dedup_matches(store_and_feats):
    store, feats = store_and_feats
    ids = np.random.default_rng(3).integers(0, feats.shape[0], 64)
    a = np.asarray(store.lookup(jnp.asarray(ids, jnp.int32), dedup=True))
    b = np.asarray(store.lookup(jnp.asarray(ids, jnp.int32), dedup=False))
    np.testing.assert_allclose(a, b, rtol=1e-6)


def test_device_only_path_zeroes_cold(store_and_feats):
    store, feats = store_and_feats
    plan = store.plan
    ids = np.arange(feats.shape[0])[::7]
    out = np.asarray(store.lookup(jnp.asarray(ids, jnp.int32),
                                  include_host=False))
    cold = plan.tier[ids] >= 2
    assert np.allclose(out[cold], 0.0)
    np.testing.assert_allclose(out[~cold], feats[ids[~cold]], rtol=1e-6)


def test_tier_histogram(store_and_feats):
    store, feats = store_and_feats
    hist = store.tier_histogram(np.arange(200))
    assert sum(hist.values()) == 200


@pytest.mark.subprocess
def test_sharded_store_one_sided_reads():
    code = """
import numpy as np, jax, jax.numpy as jnp
from repro.graph import power_law_graph
from repro.core.fap import compute_fap
from repro.core.placement import TopologySpec, quiver_placement
from repro.core.feature_store import TieredFeatureStore, ShardedFeatureStore
n, d = 2000, 16
g = power_law_graph(n, 8.0, seed=0)
fap = compute_fap(g, (4, 3))
feats = np.random.default_rng(0).normal(size=(n, d)).astype(np.float32)
topo = TopologySpec(num_pods=2, devices_per_pod=4, rows_per_device=128,
                    rows_host=256, hot_replicate_fraction=0.25)
plan = quiver_placement(fap, topo)
store = TieredFeatureStore.build(feats, plan)
from repro.compat import make_mesh
mesh = make_mesh((8,), ("x",))
ss = ShardedFeatureStore.from_tiered(store, mesh, "x")
ids = np.random.default_rng(2).integers(0, n, size=8 * 32).astype(np.int32)
tt = plan.tier[ids]
ids = np.where(tt <= 1, ids, -1).astype(np.int32)
out = np.asarray(ss.lookup(jnp.asarray(ids)))
expect = np.where((ids >= 0)[:, None], feats[np.maximum(ids, 0)], 0.0)
assert np.allclose(out, expect, atol=1e-5), np.abs(out - expect).max()
print("SHARDED_OK")
"""
    r = run_subprocess(code, devices=8)
    assert "SHARDED_OK" in r.stdout, r.stderr[-2000:]


@pytest.mark.subprocess
def test_sharded_store_cold_rows_exact_not_zeros():
    """Regression: HOST/DISK ids through the sharded store used to resolve
    silently to zeros. The cold fallback must return the exact feature rows
    (bit-identical to the single-host tiered store), count its host fetches,
    and leave -1 padding and HBM-tier rows untouched."""
    code = """
import numpy as np, jax, jax.numpy as jnp
from repro.graph import power_law_graph
from repro.core.fap import compute_fap
from repro.core.placement import TopologySpec, quiver_placement
from repro.core.feature_store import TieredFeatureStore, ShardedFeatureStore
n, d = 2000, 16
g = power_law_graph(n, 8.0, seed=0)
fap = compute_fap(g, (4, 3))
feats = np.random.default_rng(0).normal(size=(n, d)).astype(np.float32)
topo = TopologySpec(num_pods=2, devices_per_pod=4, rows_per_device=128,
                    rows_host=256, hot_replicate_fraction=0.25)
plan = quiver_placement(fap, topo)
store = TieredFeatureStore.build(feats, plan)
from repro.compat import make_mesh
mesh = make_mesh((8,), ("x",))
ss = ShardedFeatureStore.from_tiered(store, mesh, "x")
ids = np.random.default_rng(2).integers(0, n, size=8 * 32).astype(np.int32)
ids[5] = -1                                  # padding stays zero
assert (plan.tier[np.maximum(ids, 0)] >= 2).any()   # cold really sampled
out = np.asarray(ss.lookup(jnp.asarray(ids)))
want = np.asarray(store.lookup(jnp.asarray(ids)))   # single-host reference
assert np.array_equal(out, want), np.abs(out - want).max()
expect = np.where((ids >= 0)[:, None], feats[np.maximum(ids, 0)], 0.0)
assert np.allclose(out, expect, atol=1e-5)
assert ss.stats["host_fetches"] > 0 and ss.stats["cold_rows"] > 0
print("SHARDED_COLD_OK")
"""
    r = run_subprocess(code, devices=8)
    assert "SHARDED_COLD_OK" in r.stdout, r.stderr[-2000:]

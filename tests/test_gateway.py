"""SLO serving gateway: FakeClock-driven deadline/aging semantics, the
three-way outcome partition, telemetry schema/monotonicity, adaptive
admission tuning, and the BENCH row-schema pin of gateway_soak.

Every timing-sensitive test here injects `repro.testing.FakeClock` and
gates executors on `threading.Event` — there is deliberately no
`time.sleep` anywhere in this module (the flake class the injectable
clock exists to kill)."""
import threading
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro.core import (DEFAULT_MODEL, PRIORITIES, DynamicBatcher,
                        Prefetcher, Request, WorkloadGenerator)
from repro.serving import (CLASS_SAMPLE_SCHEMA, GATEWAY_SCHEMA,
                           TELEMETRY_SAMPLE_SCHEMA, AdaptiveConfig,
                           AdaptiveController, ClassStats, CostModelRouter,
                           GatewayConfig, LatencyCurve, ModelRegistry,
                           ServingEngine, ServingGateway, StaticScheduler)
from repro.testing import FakeClock


# ---------------------------------------------------------------------------
# Fakes: gateway semantics need executors that block on command, not timers
# ---------------------------------------------------------------------------
class GatedExecutor:
    """Executor whose work blocks until `gate` is set (deterministic
    occupancy without sleeping)."""
    kind = "device"

    def __init__(self, name, *, capacity=2, gate=None, d_out=4):
        self.name = name
        self.capacity = capacity
        self.gate = gate
        self.d_out = d_out
        self.batches: list[np.ndarray] = []
        self._pool = ThreadPoolExecutor(max_workers=capacity)

    def cost(self, seeds):
        return float((np.asarray(seeds) >= 0).sum())

    def _work(self, seeds):
        if self.gate is not None:
            self.gate.wait()
        return np.zeros((len(seeds), self.d_out), np.float32)

    def submit(self, seeds):
        self.batches.append(np.asarray(seeds).copy())
        return self._pool.submit(self._work, seeds)

    def run(self, seeds):
        return self._work(seeds)

    def close(self):
        self._pool.shutdown(wait=True)


def _flat_curve(cost: float) -> LatencyCurve:
    return LatencyCurve(psgs=np.array([0.0, 100.0]),
                        avg=np.array([cost, cost]),
                        mx=np.array([cost, cost]))


def _req(i, *, priority="batch", deadline_s=None, model=DEFAULT_MODEL):
    return Request(i, np.asarray([i % 8], np.int64), 0.0, model=model,
                   priority=priority, deadline_s=deadline_s)


def _gateway(*, clk=None, gate=None, max_inflight=1, admission="wait",
             est_s=None, **cfg_kw):
    """Single gated executor behind a gateway sharing one FakeClock.
    `est_s` switches the router to a calibrated CostModelRouter whose flat
    curve makes `estimate_seconds` return ~est_s per seed."""
    clk = clk or FakeClock()
    gate = gate if gate is not None else threading.Event()
    ex = {"host": GatedExecutor("host", capacity=4, gate=gate)}
    if est_s is None:
        router = StaticScheduler("host")
    else:
        router = CostModelRouter(np.full(8, 1.0), "latency_preferred")
        router.register("host", _flat_curve(est_s), kind="host")
    reg = ModelRegistry().register(DEFAULT_MODEL, ex, router)
    engine = ServingEngine(reg, max_inflight=max_inflight,
                           admission=admission, clock=clk)
    gw = ServingGateway(engine, config=GatewayConfig(**cfg_kw))
    return gw, engine, ex["host"], gate, clk


def _close(gw):
    gw.engine.close()


# ---------------------------------------------------------------------------
# FakeClock itself
# ---------------------------------------------------------------------------
def test_fake_clock_advances_and_never_rewinds():
    clk = FakeClock(start=5.0)
    assert clk() == 5.0
    assert clk.advance(0.25) == 5.25
    clk.sleep(0.75)                     # time.sleep drop-in moves the clock
    assert clk() == 6.0
    with pytest.raises(ValueError, match="backwards"):
        clk.advance(-0.1)
    assert "FakeClock" in repr(clk)


def test_dynamic_batcher_deadline_via_fake_clock():
    clk = FakeClock()
    b = DynamicBatcher(deadline_s=0.01, max_batch=100, clock=clk)
    assert b.add(_req(0)) is None
    clk.advance(0.02)                   # deadline passes without sleeping
    out = b.add(_req(1))                # deadline hit closes at add time
    assert out is not None and [r.req_id for r in out] == [0, 1]
    assert b.clone().clock is clk       # clones keep the injected clock


def test_prefetcher_time_cadence_via_fake_clock():
    refreshed = []

    class _Probe(Prefetcher):
        def refresh_async(self, scores=None):
            refreshed.append(self.clock())
            return None

    clk = FakeClock()
    store = type("S", (), {"publish_stage": staticmethod(lambda *a: None)})()
    pf = _Probe(store, budget=4, refresh_every_s=1.0, clock=clk)
    seeds = np.array([1])
    pf.on_batch_complete("host", seeds, 1e-3)
    assert refreshed == []              # cadence not yet due
    clk.advance(1.5)
    pf.on_batch_complete("host", seeds, 1e-3)
    assert refreshed == [1.5]           # due purely by fake elapsed time
    pf.on_batch_complete("host", seeds, 1e-3)
    assert refreshed == [1.5]           # stamp advanced: not due again
    assert pf.report()["batches_seen"] == 3


# ---------------------------------------------------------------------------
# Admission outcomes: queued / shed_window / shed_deadline
# ---------------------------------------------------------------------------
def test_gateway_completes_open_stream_fifo():
    gw, _eng, ex, gate, _clk = _gateway()
    gate.set()                          # executors never block
    reqs = [_req(i) for i in range(6)]
    m = gw.serve(reqs)
    assert m.requests == 6 and m.shed == 0 and m.shed_deadline == 0
    assert all(r.outcome == "completed" for r in reqs)
    # one class, no deadlines: dequeue order degenerates to FIFO
    assert [int(b[0]) for b in ex.batches] == [i % 8 for i in range(6)]
    rep = gw.report()
    assert rep["admitted"] == rep["dispatched"] == rep["completed"] == 6
    assert rep["shed_window"] == rep["shed_deadline"] == 0
    assert rep["queue_depth"] == 0
    _close(gw)


def test_admission_sheds_hopeless_deadline_without_dispatch():
    gw, eng, ex, gate, _clk = _gateway()
    gate.set()
    m = eng.begin_run()
    doomed = _req(0, priority="interactive", deadline_s=-1.0)
    assert gw.submit(doomed) == "shed_deadline"
    eng.end_run(m)
    assert doomed.outcome == "shed_deadline"
    assert not hasattr(doomed, "dispatched")    # never reached an executor
    assert ex.batches == []
    assert m.shed_deadline == 1 and m.shed == 0
    assert m.for_class("interactive").shed_deadline == 1
    assert gw.report()["shed_deadline"] == 1
    _close(gw)


def test_admission_sheds_window_when_queue_full():
    gw, eng, _ex, gate, _clk = _gateway(queue_limit=2)
    m = eng.begin_run()
    held = _req(0)
    assert gw.submit(held) == "queued"          # dispatched, gated in-flight
    assert gw.submit(_req(1)) == "queued"
    assert gw.submit(_req(2)) == "queued"       # queue now at queue_limit
    spilled = _req(3)
    assert gw.submit(spilled) == "shed_window"
    assert spilled.outcome == "shed_window"
    assert gw.queue_depth == 2
    gate.set()
    gw.drain()
    eng.end_run(m)
    assert m.shed == 1 and m.requests == 3
    rep = gw.report()
    assert rep["shed_window"] == 1 and rep["max_queue_depth"] == 2
    assert rep["completed"] == 3 and rep["queue_depth"] == 0
    _close(gw)


def test_dequeue_recheck_sheds_request_gone_stale_in_queue():
    gw, eng, ex, gate, clk = _gateway()
    m = eng.begin_run()
    assert gw.submit(_req(0)) == "queued"       # occupies the single slot
    stale = _req(1, priority="interactive", deadline_s=0.05)
    assert gw.submit(stale) == "queued"         # meetable at admission...
    clk.advance(0.1)                            # ...expired while queued
    gate.set()
    gw.drain()
    eng.end_run(m)
    assert stale.outcome == "shed_deadline"
    assert not hasattr(stale, "dispatched")     # zero expired dispatches
    assert len(ex.batches) == 1                 # only request 0 ran
    rep = gw.report()
    assert rep["dispatched"] == 1 and rep["shed_deadline"] == 1
    assert rep["admitted"] == 2                 # stale WAS admitted
    _close(gw)


def test_slack_ordering_dispatches_tightest_deadline_first():
    gw, _eng, ex, gate, _clk = _gateway()
    _ = gw.engine.begin_run()
    gw.submit(_req(0))                          # holds the slot (gated)
    gw.submit(_req(1, deadline_s=20.0))
    gw.submit(_req(2, deadline_s=5.0))          # tightest slack
    gw.submit(_req(3))                          # no deadline: slack cap
    gate.set()
    gw.drain()
    assert [int(b[0]) for b in ex.batches] == [0, 2, 1, 3]
    _close(gw)


def test_aging_bound_promotes_interactive_over_batch():
    gw, _eng, ex, gate, clk = _gateway(aging_bound_s=0.25)
    _ = gw.engine.begin_run()
    gw.submit(_req(0))                          # gated slot holder
    gw.submit(_req(1, deadline_s=1.0))          # batch, tight-ish slack
    gw.submit(_req(2, priority="interactive"))  # no deadline: loses on slack
    # below the aging bound the batch request's 1.0s slack beats the
    # interactive request's capped slack; past the bound the interactive
    # request is tier-promoted and preempts outright
    clk.advance(0.3)
    gate.set()
    gw.drain()
    assert [int(b[0]) for b in ex.batches] == [0, 2, 1]
    assert gw.report()["aged_dispatches"] >= 1
    _close(gw)


def test_batch_bias_breaks_fresh_ties_interactive_first():
    gw, _eng, ex, gate, _clk = _gateway()
    _ = gw.engine.begin_run()
    gw.submit(_req(0))                          # gated slot holder
    gw.submit(_req(1, priority="batch"))        # same (capped) slack…
    gw.submit(_req(2, priority="interactive"))  # …but no batch_bias_s
    gate.set()
    gw.drain()
    assert [int(b[0]) for b in ex.batches] == [0, 2, 1]
    _close(gw)


def test_estimate_seconds_feeds_slack_check():
    # flat 2s service estimate: a 1s deadline is hopeless at admission even
    # though it has not yet expired; a 5s deadline clears the slack check
    gw, eng, _ex, gate, _clk = _gateway(est_s=2.0)
    gate.set()
    m = eng.begin_run()
    router = eng.registry.router_for(DEFAULT_MODEL)
    assert router.estimate_seconds(np.array([1])) == pytest.approx(2.0)
    assert gw.submit(_req(0, deadline_s=1.0)) == "shed_deadline"
    assert gw.submit(_req(1, deadline_s=5.0)) == "queued"
    gw.drain()
    eng.end_run(m)
    assert m.shed_deadline == 1 and m.requests == 1
    _close(gw)


def test_workload_generator_tags_priority_and_deadline():
    gen = WorkloadGenerator(16, np.ones(16), distribution="uniform", seed=0)
    reqs = list(gen.stream(4, priorities=PRIORITIES,
                           deadlines=(0.2, None)))
    assert [r.priority for r in reqs] == ["interactive", "batch"] * 2
    assert [r.deadline_s for r in reqs] == [0.2, None, 0.2, None]
    assert all(r.priority == "batch" and r.deadline_s is None
               for r in gen.stream(2))


# ---------------------------------------------------------------------------
# Telemetry: schema, monotonicity, pollable stream
# ---------------------------------------------------------------------------
def test_telemetry_samples_schema_and_monotone_timestamps():
    gw, eng, _ex, gate, clk = _gateway()
    gate.set()
    m = eng.begin_run()
    for i in range(4):
        gw.submit(_req(i, priority=PRIORITIES[i % 2]))
        clk.advance(0.01)
    gw.drain()
    eng.end_run(m)
    samples = gw.telemetry_samples()
    assert samples and len(samples) <= GatewayConfig().telemetry_capacity
    ts = [s["t"] for s in samples]
    assert ts == sorted(ts)                     # monotone non-decreasing
    for s in samples:
        assert set(s) == set(TELEMETRY_SAMPLE_SCHEMA)
        for block in s["classes"].values():
            assert set(block) == set(CLASS_SAMPLE_SCHEMA)
    last = gw.sample_telemetry()                # explicit poll mid-idle
    assert last["queue_depth"] == 0 and last["inflight"] == 0
    assert gw.report()["telemetry_samples"] == len(gw.telemetry_samples())
    _close(gw)


def test_telemetry_min_interval_rate_limits_auto_samples():
    gw, eng, _ex, gate, clk = _gateway(telemetry_min_interval_s=10.0)
    gate.set()
    m = eng.begin_run()
    for i in range(5):                          # clock frozen: one sample
        gw.submit(_req(i))
    gw.drain()
    eng.end_run(m)
    assert gw.report()["telemetry_samples"] == 1
    clk.advance(11.0)
    gw.submit(_req(9))
    gw.drain()
    assert gw.report()["telemetry_samples"] == 2
    _close(gw)


def test_telemetry_stream_drains_buffer_then_stops():
    gw, eng, _ex, gate, _clk = _gateway()
    gate.set()
    m = eng.begin_run()
    for i in range(3):
        gw.submit(_req(i))
    gw.drain()
    eng.end_run(m)
    got = list(gw.telemetry_stream(stop=lambda: True))
    assert got == gw.telemetry_samples()        # everything buffered, once
    assert all(set(s) == set(TELEMETRY_SAMPLE_SCHEMA) for s in got)
    _close(gw)


# ---------------------------------------------------------------------------
# Adaptive admission tuning
# ---------------------------------------------------------------------------
def test_tune_admission_tightens_on_sheds_then_relaxes_when_idle():
    gw, eng, _ex, gate, _clk = _gateway(queue_limit=256)
    gate.set()
    ctl = AdaptiveController(
        type("_G", (), {"num_nodes": 8})(), (2,),
        type("S", (), {"plan": None})(), None, psgs_table=np.full(8, 1.0),
        config=AdaptiveConfig(admission_step=0.5,
                              queue_limit_bounds=(16, 4096)))
    assert ctl.tune_admission() is None         # no gateway attached yet
    assert ctl.attach_gateway(gw) is ctl
    m = eng.begin_run()
    gw.submit(_req(0, deadline_s=-1.0))         # one deadline shed
    out = ctl.tune_admission()
    assert out["deadline_sheds"] == 1
    # halve-target under sheds, half-step: 256 → 192
    assert out["queue_limit"] == gw.config.queue_limit == 192
    gw.drain()
    eng.end_run(m)
    out2 = ctl.tune_admission()                 # shed-free + idle: relax
    assert out2["deadline_sheds"] == 0 and out2["saturation"] == 0.0
    assert 192 < out2["queue_limit"] <= 4096
    _close(gw)


# ---------------------------------------------------------------------------
# Schema pins: constants, stats dicts and the BENCH row format
# ---------------------------------------------------------------------------
def test_gateway_stats_keys_pin_gateway_schema():
    gw, *_ = _gateway()
    assert tuple(gw.stats) == GATEWAY_SCHEMA
    assert set(gw.report()) == set(GATEWAY_SCHEMA) | {"queue_depth",
                                                      "saturation"}
    _close(gw)


def test_class_stats_summary_pins_class_sample_schema():
    assert tuple(ClassStats().summary()) == CLASS_SAMPLE_SCHEMA
    gw, eng, _ex, gate, _clk = _gateway()
    gate.set()
    m = eng.begin_run()
    gw.submit(_req(0, priority="interactive"))
    gw.drain()
    eng.end_run(m)
    for block in eng.class_summaries().values():
        assert tuple(block) == CLASS_SAMPLE_SCHEMA
    _close(gw)


def test_gateway_soak_row_schema_is_pinned():
    """Regression pin of the BENCH_gateway_soak.json row format: CI smokes
    the benchmark with --json-out, so its schema drifting silently would
    break downstream consumers before anything failed loudly."""
    gs = pytest.importorskip("benchmarks.gateway_soak")
    assert gs.ROW_SCHEMA == (
        "mode", "requests", "completed", "shed_window", "shed_deadline",
        "expired_dispatches", "max_queue_depth", "interactive_p50_ms",
        "interactive_p99_ms", "batch_p50_ms", "batch_p99_ms", "wall_s")
    row = gs.build_row(**{k: 0 for k in gs.ROW_SCHEMA})
    assert tuple(row) == gs.ROW_SCHEMA          # emitted in schema order
    with pytest.raises(ValueError, match="missing"):
        gs.build_row(mode="fifo")
    with pytest.raises(ValueError, match="extra=\\['bogus'\\]"):
        gs.build_row(bogus=1, **{k: 0 for k in gs.ROW_SCHEMA})


# ---------------------------------------------------------------------------
# Slow tier: the flash-crowd soak through the real serving stack
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_gateway_soak_dry_run_end_to_end(tmp_path, monkeypatch):
    """Drive the full gateway_soak benchmark (dry-run sizing): its in-run
    asserts cover bounded queue depth, zero expired dispatches, the doomed
    shed and the interactive-p99 win over FIFO; here we re-check the
    emitted rows against the pinned schema."""
    gs = pytest.importorskip("benchmarks.gateway_soak")
    monkeypatch.setenv("BENCH_JSON_DIR", str(tmp_path))
    rows = gs.run(dry_run=True, json_out=str(tmp_path / "soak.json"))
    assert list(rows) == ["fifo", "gateway"]
    for r in rows.values():
        assert tuple(r) == gs.ROW_SCHEMA and r["mode"] in rows
    fifo, gw_row = rows["fifo"], rows["gateway"]
    assert gw_row["expired_dispatches"] == 0
    assert gw_row["max_queue_depth"] <= 256
    assert gw_row["interactive_p99_ms"] < fifo["interactive_p99_ms"]
    assert (tmp_path / "soak.json").exists()
    assert (tmp_path / "BENCH_gateway_soak.json").exists()

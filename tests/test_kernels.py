"""Per-kernel shape/dtype sweeps, interpret mode vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.embedding_bag import embedding_bag_pallas, embedding_bag_ref
from repro.kernels.flash_attention import attention_ref, flash_attention_pallas
from repro.kernels.segment_spmm import (coo_to_ell, segment_spmm_pallas,
                                        segment_spmm_ref)
from repro.kernels.tiered_gather import tiered_gather_pallas, tiered_gather_ref

# bf16 oracles: refs are evaluated on f32-cast inputs (the kernel accumulates
# in f32 — the jnp ref in raw bf16 would be the *less* accurate side), with
# tolerance sized to bf16 output rounding.
TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=1.5e-1)}


@pytest.mark.parametrize("b,sq,h,kv,dh,causal", [
    (1, 128, 4, 4, 64, True),
    (2, 256, 4, 2, 64, True),
    (1, 128, 8, 1, 128, True),
    (2, 96, 4, 4, 32, False),    # non-multiple-of-block seq
    (1, 257, 2, 2, 64, True),    # odd seq
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, sq, h, kv, dh, causal, dtype):
    ks = jax.random.split(jax.random.key(sq * h + dh), 3)
    q = jax.random.normal(ks[0], (b, sq, h, dh), dtype)
    k = jax.random.normal(ks[1], (b, sq, kv, dh), dtype)
    v = jax.random.normal(ks[2], (b, sq, kv, dh), dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, block_q=64,
                                 block_kv=64)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


@pytest.mark.parametrize("block_q,block_kv", [(32, 32), (128, 64), (64, 128)])
def test_flash_attention_block_shapes(block_q, block_kv):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (1, 192, 4, 64))
    k = jax.random.normal(ks[1], (1, 192, 2, 64))
    v = jax.random.normal(ks[2], (1, 192, 2, 64))
    out = flash_attention_pallas(q, k, v, causal=True, block_q=block_q,
                                 block_kv=block_kv)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("n,dmax,m,d,weighted", [
    (37, 9, 50, 128, True), (8, 1, 10, 256, False), (65, 16, 200, 32, True),
    (16, 5, 16, 8, False),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_spmm_sweep(n, dmax, m, d, weighted, dtype):
    rng = np.random.default_rng(n * dmax + d)
    ids = rng.integers(-1, m, size=(n, dmax)).astype(np.int32)
    feat = jnp.asarray(rng.normal(size=(m, d)), dtype)
    w = (jnp.asarray(rng.normal(size=(n, dmax)), dtype) if weighted
         else None)
    out = segment_spmm_pallas(jnp.asarray(ids), feat, w)
    ref = segment_spmm_ref(jnp.asarray(ids), feat.astype(jnp.float32),
                           None if w is None else w.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


def test_segment_spmm_equals_coo_scatter():
    from repro.graph import power_law_graph, scatter_spmm
    g = power_law_graph(80, 4.0, seed=5)
    src, dst = g.to_coo()
    feat = jnp.asarray(np.random.default_rng(0).normal(size=(80, 16)),
                       jnp.float32)
    ell = coo_to_ell(src, dst, 80)
    out = segment_spmm_pallas(jnp.asarray(ell), feat)
    ref = scatter_spmm(feat, jnp.asarray(src), jnp.asarray(dst), 80)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("bsz,bag,v,d,mode,weighted", [
    (21, 7, 100, 64, "sum", False), (21, 7, 100, 64, "mean", False),
    (8, 20, 1000, 18, "sum", True), (64, 3, 50, 128, "mean", True),
])
def test_embedding_bag_sweep(bsz, bag, v, d, mode, weighted):
    rng = np.random.default_rng(bsz * bag)
    tbl = jnp.asarray(rng.normal(size=(v, d)), jnp.float32)
    ids = rng.integers(-1, v, size=(bsz, bag)).astype(np.int32)
    w = (jnp.asarray(rng.normal(size=(bsz, bag)), jnp.float32) if weighted
         else None)
    out = embedding_bag_pallas(tbl, jnp.asarray(ids), w, mode=mode)
    ref = embedding_bag_ref(tbl, jnp.asarray(ids), w, mode=mode)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("m,h,w,d", [(50, 16, 40, 32), (7, 4, 4, 128),
                                     (130, 64, 64, 8)])
def test_tiered_gather_sweep(m, h, w, d):
    rng = np.random.default_rng(m + d)
    hot = jnp.asarray(rng.normal(size=(h, d)), jnp.float32)
    warm = jnp.asarray(rng.normal(size=(w, d)), jnp.float32)
    tier = rng.integers(0, 3, size=m).astype(np.int32)
    slot = np.where(tier == 0, rng.integers(0, h, m),
                    rng.integers(0, w, m)).astype(np.int32)
    out = tiered_gather_pallas(jnp.asarray(tier), jnp.asarray(slot), hot,
                               warm)
    ref = tiered_gather_ref(jnp.asarray(tier), jnp.asarray(slot), hot, warm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


def test_blockwise_attention_matches_flash():
    """The XLA blockwise path (models/attention.py) and the Pallas kernel
    implement the same contraction."""
    from repro.models.attention import blockwise_attention
    ks = jax.random.split(jax.random.key(9), 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 64))
    k = jax.random.normal(ks[1], (2, 128, 2, 64))
    v = jax.random.normal(ks[2], (2, 128, 2, 64))
    a = blockwise_attention(q, k, v, causal=True, q_chunk=32, kv_chunk=32)
    b = flash_attention_pallas(q, k, v, causal=True, block_q=32, block_kv=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)

"""PSGS and FAP metric tests (paper §4.1, §5.1) — Monte-Carlo oracles +
hypothesis invariants."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
pytestmark = pytest.mark.hypothesis
from hypothesis import given, settings, strategies as st

from repro.core import (batch_psgs, compute_fap, compute_psgs,
                        monte_carlo_fap, monte_carlo_psgs)
from repro.graph import power_law_graph, uniform_graph


@pytest.fixture(scope="module")
def graph():
    # low avg degree → real degree variance (PSGS non-constant)
    return power_law_graph(300, 2.5, seed=7)


def _spearman(a, b):
    ra = np.argsort(np.argsort(a)).astype(float)
    rb = np.argsort(np.argsort(b)).astype(float)
    return np.corrcoef(ra, rb)[0, 1]


def test_psgs_branching_matches_monte_carlo(graph):
    fan = (3, 2)
    q = compute_psgs(graph, fan, mode="branching")
    for node in [0, 11, 42, 137, 255]:
        mc = monte_carlo_psgs(graph, node, fan, trials=600, seed=node)
        assert q[node] == pytest.approx(mc, rel=0.08), node


def test_psgs_paper_mode_is_single_walk(graph):
    """Paper formula sums expected per-hop fan-in of one walk → bounded by
    1 + Σ l_k, and equals branching mode when all fanouts are 1."""
    q1 = compute_psgs(graph, (1, 1, 1), mode="paper")
    q2 = compute_psgs(graph, (1, 1, 1), mode="branching")
    np.testing.assert_allclose(q1, q2, rtol=1e-5)
    qp = compute_psgs(graph, (5, 4), mode="paper")
    assert qp.max() <= 1 + 5 + 4 + 1e-5


def test_psgs_lower_bound_and_isolated(graph):
    q = compute_psgs(graph, (4, 3))
    assert (q >= 1.0 - 1e-6).all()
    deg = graph.out_degree
    if (deg == 0).any():
        assert np.allclose(q[deg == 0], 1.0)


@given(st.integers(min_value=1, max_value=6))
@settings(max_examples=8, deadline=None)
def test_psgs_monotone_in_fanout(fan):
    g = power_law_graph(200, 3.0, seed=3)
    q_small = compute_psgs(g, (fan,))
    q_big = compute_psgs(g, (fan + 1,))
    assert (q_big >= q_small - 1e-5).all()


def test_batch_psgs_accumulates(graph):
    q = compute_psgs(graph, (4, 3))
    seeds = np.array([3, 5, 8, -1])
    assert batch_psgs(q, seeds) == pytest.approx(q[[3, 5, 8]].sum())


def test_fap_is_probability_like(graph):
    p = compute_fap(graph, (4, 3))
    assert (p >= -1e-7).all()
    # p_0 sums to 1; each subsequent hop adds ≤1 of mass (transition is
    # sub-stochastic on dangling nodes)
    K = 2
    assert p.sum() <= (K + 1) + 1e-4


def test_fap_identifies_hot_set(graph):
    """What placement needs from FAP is the hot set: the top-k FAP nodes
    must overlap heavily with the top-k empirically-accessed nodes."""
    fan = (4, 3)
    p = compute_fap(graph, fan)
    mc = monte_carlo_fap(graph, fan, requests=8000, seed=1)
    k = graph.num_nodes // 10
    top_p = set(np.argsort(-p)[:k].tolist())
    top_mc = set(np.argsort(-mc)[:k].tolist())
    overlap = len(top_p & top_mc) / k
    assert overlap > 0.6, overlap
    # and rank correlation stays clearly positive despite tie mass
    assert _spearman(p, mc) > 0.4


def test_fap_respects_seed_distribution(graph):
    """Skewed seed distribution must shift FAP mass (the paper's argument
    against training-time frequency ranking, §2.3)."""
    n = graph.num_nodes
    skew = np.zeros(n)
    skew[:10] = 1.0  # all requests hit 10 seeds
    p_skew = compute_fap(graph, (4,), seed_prob=skew)
    p_unif = compute_fap(graph, (4,))
    assert p_skew[:10].sum() > p_unif[:10].sum() * 5


def test_fap_truncated_leq_untruncated_transition(graph):
    p_t = compute_fap(graph, (2,), truncated=True)
    p_u = compute_fap(graph, (2,), truncated=False)
    # truncation can only boost per-edge acceptance (min(deg,l)/deg ≥ 1/deg)
    assert (p_t >= p_u - 1e-6).all()


def test_dispatch_stats_schema_pinned_with_cache_counters():
    """The dispatch-stats schema is load-bearing: benchmarks and the
    engine's ``summary()["store"]`` snapshot read these exact keys. The
    canonical ``STATS_SCHEMA`` constant is the single source of truth —
    ``_new_stats()`` must produce exactly those keys, zeroed, and the
    ``cache_*`` counters must mirror the device cache's own schema."""
    from repro.core import STATS_SCHEMA
    from repro.core.feature_store import _new_stats
    from repro.core.gpu_cache import _new_cache_stats

    stats = _new_stats()
    assert set(stats) == set(STATS_SCHEMA)
    assert len(STATS_SCHEMA) == len(set(STATS_SCHEMA))
    assert all(v == 0 for v in stats.values())
    cache_keys = set(_new_cache_stats())
    for key in STATS_SCHEMA:
        if key.startswith("cache_"):
            assert key[len("cache_"):] in cache_keys

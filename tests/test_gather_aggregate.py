"""Fused gather→aggregate path (PR 9): the gather_aggregate kernel is
bit-identical to the tiered_gather+segment_spmm composition across an
embedding-dim sweep, lookup_aggregate matches the unfused layer-1 path
(incl. all-cold batches and under concurrent migration), executors hand
models pre-aggregated inputs without changing outputs, and the empty-shape
regressions for segment_spmm / embedding_bag."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (TieredFeatureStore, TopologySpec, compute_fap,
                        migration_pairs, quiver_placement)
from repro.core.placement import TIER_HOST
from repro.graph import power_law_graph
from repro.kernels.embedding_bag.kernel import embedding_bag_pallas
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.gather_aggregate import (autotune_gather_aggregate,
                                            gather_aggregate,
                                            gather_aggregate_pallas,
                                            gather_aggregate_ref)
from repro.kernels.segment_spmm.kernel import segment_spmm_pallas
from repro.kernels.segment_spmm.ref import segment_spmm_ref
from repro.kernels.tiered_gather.kernel import tiered_gather_pallas
from repro.models.gnn_basic import sage_init, sage_layered
from repro.serving import DeviceExecutor, HostExecutor

TOL = dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Fixtures (mirrors tests/test_fused_gather.py)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def stack():
    n, d, fan = 900, 12, (4, 3)
    g = power_law_graph(n, 6.0, seed=0)
    feats = np.random.default_rng(1).normal(size=(n, d)).astype(np.float32)
    fap = compute_fap(g, fan)
    topo = TopologySpec(num_pods=1, devices_per_pod=1, rows_per_device=220,
                        rows_host=330, hot_replicate_fraction=0.3)
    return g, fan, feats, fap, topo


def _fresh_store(stack):
    g, fan, feats, fap, topo = stack
    return TieredFeatureStore.build(feats, quiver_placement(fap, topo))


def _hops(n, fan, batch, seed=0, pool=None):
    """Layered (seeds, hop1, hop2) sample with -1 padding mixed in."""
    rng = np.random.default_rng(seed)
    draw = ((lambda s: rng.integers(-1, n, size=s)) if pool is None
            else (lambda s: rng.choice(pool, size=s)))
    return [jnp.asarray(draw(batch).astype(np.int32)),
            jnp.asarray(draw(batch * fan[0]).astype(np.int32)),
            jnp.asarray(draw(batch * fan[0] * fan[1]).astype(np.int32))]


def _addresses(rng, s, fan, h, w, k, *, ragged=True):
    """Random (tier, slot) segment matrix over 3 sources + invalid pads."""
    tier = rng.choice([0, 1, 2, 99], size=(s, fan),
                      p=[.4, .3, .2, .1]).astype(np.int32)
    if ragged:
        tier[0] = 99                         # degree-0 segment
        tier[1, 1:] = 99                     # degree-1 segment
    slot = np.zeros((s, fan), np.int32)
    slot[tier == 0] = rng.integers(0, h, (tier == 0).sum())
    slot[tier == 1] = rng.integers(0, w, (tier == 1).sum())
    slot[tier == 2] = rng.integers(0, k, (tier == 2).sum())
    return jnp.asarray(tier), jnp.asarray(slot)


# ---------------------------------------------------------------------------
# Kernel-level: gather_aggregate vs tiered_gather+segment_spmm, dim sweep
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("d", [16, 64, 256])
def test_kernel_bit_identical_to_composition(d):
    """The fused kernel accumulates in the same fp32 order as the
    tiered_gather → segment_spmm composition, so interpret-mode outputs are
    bitwise equal — the perf claim never trades numerics."""
    rng = np.random.default_rng(d)
    s, fan, h, w, k = 37, 5, 50, 40, 9
    hot = jnp.asarray(rng.normal(size=(h, d)).astype(np.float32))
    warm = jnp.asarray(rng.normal(size=(w, d)).astype(np.float32))
    cold = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
    tier, slot = _addresses(rng, s, fan, h, w, k)
    fused = gather_aggregate_pallas(tier, slot, hot, warm, cold,
                                    block_rows=8, interpret=True)
    # the unfused reference: dense gather (cold rows substituted — copies,
    # so no arithmetic differs), then the segment reduction kernel
    dense = tiered_gather_pallas(tier.reshape(-1), slot.reshape(-1), hot,
                                 warm, interpret=True)
    cold_rows = jnp.take(cold, jnp.minimum(jnp.maximum(
        slot.reshape(-1), 0), k - 1), axis=0)
    dense = jnp.where((tier.reshape(-1) == 2)[:, None], cold_rows, dense)
    pos = np.arange(s * fan, dtype=np.int32).reshape(s, fan)
    pos = np.where(np.asarray(tier) <= 2, pos, -1).astype(np.int32)
    comp = segment_spmm_pallas(jnp.asarray(pos), dense, block_rows=8,
                               interpret=True)
    assert np.array_equal(np.asarray(fused), np.asarray(comp))
    # oracle within kernel tolerance, and bitwise vs itself under jit
    ref = gather_aggregate_ref(tier, slot, hot, warm, cold)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref), **TOL)
    via_ops = gather_aggregate(tier, slot, hot, warm, cold,
                               use_pallas=False)
    assert np.array_equal(np.asarray(via_ops), np.asarray(ref))


@pytest.mark.parametrize("block_rows,block_dim", [(4, 0), (8, 8), (16, 4),
                                                  (32, 16)])
def test_kernel_tiling_never_changes_bits(block_rows, block_dim):
    """block_rows/block_dim only re-tile the grid; per-column accumulation
    order is untouched, so every config is bitwise identical."""
    rng = np.random.default_rng(3)
    s, fan, d = 19, 4, 32
    hot = jnp.asarray(rng.normal(size=(30, d)).astype(np.float32))
    warm = jnp.asarray(rng.normal(size=(20, d)).astype(np.float32))
    cold = jnp.asarray(rng.normal(size=(5, d)).astype(np.float32))
    tier, slot = _addresses(rng, s, fan, 30, 20, 5)
    base = gather_aggregate_pallas(tier, slot, hot, warm, cold,
                                   block_rows=8, interpret=True)
    tiled = gather_aggregate_pallas(tier, slot, hot, warm, cold,
                                    block_rows=block_rows,
                                    block_dim=block_dim, interpret=True)
    assert np.array_equal(np.asarray(base), np.asarray(tiled))


def test_kernel_empty_and_ragged_segments():
    d = 8
    hot = jnp.ones((4, d), jnp.float32)
    warm = jnp.ones((4, d), jnp.float32)
    cold = jnp.ones((1, d), jnp.float32)
    for s, fan in ((0, 3), (5, 0)):
        tier = jnp.zeros((s, fan), jnp.int32)
        out = gather_aggregate_pallas(tier, tier, hot, warm, cold,
                                      interpret=True)
        assert out.shape == (s, d) and not np.asarray(out).any()
        ref = gather_aggregate_ref(tier, tier, hot, warm, cold)
        assert ref.shape == (s, d) and not np.asarray(ref).any()
    # all-invalid (degree-0) segments are exact zeros, never NaN
    tier = jnp.full((6, 3), 99, jnp.int32)
    out = gather_aggregate_pallas(tier, jnp.zeros_like(tier), hot, warm,
                                  cold, interpret=True)
    assert not np.asarray(out).any()
    assert np.isfinite(np.asarray(out)).all()


def test_autotune_returns_valid_config():
    rng = np.random.default_rng(0)
    hot = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))
    tier, slot = _addresses(rng, 12, 3, 16, 16, 1, ragged=False)
    tune = autotune_gather_aggregate(
        tier, slot, hot, hot, jnp.zeros((1, 8), jnp.float32),
        block_rows_candidates=(4, 8), block_dim_candidates=(0,), repeats=1)
    assert tune["best"]["block_rows"] in (4, 8)
    assert len(tune["timings_us"]) == 2
    assert tune["interpret"] is (jax.default_backend() != "tpu")


# ---------------------------------------------------------------------------
# Store-level: lookup_aggregate vs lookup_hops + model aggregation
# ---------------------------------------------------------------------------
def _expected_agg(store, hops, fan):
    """The unfused layer-1 path: gather, then the model's exact masked-mean
    numerator ``(child * m).sum(1)``."""
    feats_u = store.lookup_hops(hops)
    p = int(hops[-2].shape[0])
    child = feats_u[-1].reshape(p, fan[-1], -1)
    m = (hops[-1] >= 0).astype(jnp.float32).reshape(p, fan[-1], 1)
    return feats_u, (child * m).sum(1)


@pytest.mark.parametrize("use_pallas", [None, True])
def test_lookup_aggregate_matches_unfused(stack, use_pallas):
    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack)
    hops = _hops(g.num_nodes, fan, 16, seed=1)
    feats_u, expected = _expected_agg(store, hops, fan)
    feats_f, agg = store.lookup_aggregate(hops, use_pallas=use_pallas)
    assert len(feats_f) == len(hops) - 1
    for a, b in zip(feats_u[:-1], feats_f):
        if use_pallas is None:  # CPU dispatches the model-identical oracle
            assert np.array_equal(np.asarray(a), np.asarray(b))
        else:
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), **TOL)
    if use_pallas is None:
        assert np.array_equal(np.asarray(agg), np.asarray(expected))
    else:
        np.testing.assert_allclose(np.asarray(agg), np.asarray(expected),
                                   **TOL)


def test_lookup_aggregate_all_cold_batch(stack):
    """Every sampled id on the HOST/DISK tiers: the whole aggregate flows
    through the pre-resolved cold side-table (and one callback)."""
    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack)
    cold_pool = np.flatnonzero(np.asarray(store.plan.tier) >= TIER_HOST)
    assert cold_pool.size > 0
    hops = _hops(g.num_nodes, fan, 8, seed=2, pool=cold_pool)
    feats_u, expected = _expected_agg(store, hops, fan)
    store.reset_stats()
    feats_f, agg = store.lookup_aggregate(hops)
    stats = store.reset_stats()
    assert np.array_equal(np.asarray(agg), np.asarray(expected))
    for a, b in zip(feats_u[:-1], feats_f):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert stats["host_fetches"] == 1       # one gateway round-trip
    assert stats["device_gathers"] == 1     # one fused kernel dispatch
    assert stats["fused_aggregates"] == 1


def test_lookup_aggregate_exclude_host_and_errors(stack):
    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack)
    hops = _hops(g.num_nodes, fan, 8, seed=3)
    feats_u, _ = _expected_agg(store, hops, fan)  # warm the jit caches
    feats_un = store.lookup_hops(hops, include_host=False)
    p = int(hops[-2].shape[0])
    child = feats_un[-1].reshape(p, fan[-1], -1)
    m = (hops[-1] >= 0).astype(jnp.float32).reshape(p, fan[-1], 1)
    feats_f, agg = store.lookup_aggregate(hops, include_host=False)
    assert np.array_equal(np.asarray(agg), np.asarray((child * m).sum(1)))
    for a, b in zip(feats_un[:-1], feats_f):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError, match="frontier"):
        store.lookup_aggregate([hops[0]])
    with pytest.raises(ValueError, match="P\\*fan"):
        store.lookup_aggregate([hops[0], hops[1][:-1]])


def test_lookup_aggregate_model_output_bit_identical(stack):
    """The full serve contract: sage_layered(deep_agg=...) on the fused
    collect equals the unfused forward bit for bit."""
    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack)
    params = sage_init(jax.random.key(0), [feats.shape[1], 16, 16])

    @jax.jit
    def infer(hop_feats, hop_ids, deep_agg=None):
        masks = [(h >= 0).astype(jnp.float32)[:, None] for h in hop_ids]
        return sage_layered(params, hop_feats, fan, hop_masks=masks,
                            deep_agg=deep_agg)

    hops = _hops(g.num_nodes, fan, 16, seed=4)
    feats_u = store.lookup_hops(hops)
    feats_f, agg = store.lookup_aggregate(hops)
    out_u = infer(feats_u, hops)
    out_f = infer(feats_f, hops, deep_agg=agg)
    assert np.array_equal(np.asarray(out_u), np.asarray(out_f))


def test_lookup_aggregate_under_concurrent_migration(stack):
    """Migration-race harness (tests/test_fused_gather.py): a reader doing
    fused gather→aggregate lookups while rows migrate between tiers must
    only ever see exact aggregates — one snapshot covers resolve + kernel."""
    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack)
    rng = np.random.default_rng(7)
    hops = [jnp.asarray(rng.integers(0, g.num_nodes, 8).astype(np.int32)),
            jnp.asarray(rng.integers(0, g.num_nodes, 8 * fan[0])
                        .astype(np.int32)),
            jnp.asarray(rng.integers(0, g.num_nodes, 8 * fan[0] * fan[1])
                        .astype(np.int32))]
    p = 8 * fan[0]
    exp_feats = [feats[np.asarray(h)] for h in hops[:-1]]
    exp_agg = feats[np.asarray(hops[-1])].reshape(p, fan[1], -1).sum(1)
    stop = threading.Event()
    errors: list[str] = []

    def reader():
        while not stop.is_set():
            got, agg = store.lookup_aggregate(hops)
            for e, o in zip(exp_feats, got):
                if not np.allclose(np.asarray(o), e, rtol=1e-5):
                    errors.append("torn outer rows during migration")
                    return
            if not np.allclose(np.asarray(agg), exp_agg, rtol=1e-4,
                               atol=1e-5):
                errors.append("torn aggregate during migration")
                return

    t = threading.Thread(target=reader)
    t.start()
    try:
        drifted = fap.copy()
        drifted[np.argsort(fap)[:80]] += fap.max() * 3
        tgt = quiver_placement(drifted, topo)
        for _ in range(10):
            pairs = migration_pairs(store.plan.tier, tgt.tier, drifted,
                                    budget=20)
            if not pairs:
                break
            store.swap_assignments(pairs)
    finally:
        stop.set()
        t.join(timeout=30)
    assert not errors
    _, agg = store.lookup_aggregate(hops)
    np.testing.assert_allclose(np.asarray(agg), exp_agg, rtol=1e-4,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# Executor-level: fuse_aggregate vs fused output equivalence
# ---------------------------------------------------------------------------
def _infer(stack):
    g, fan, feats, fap, topo = stack
    params = sage_init(jax.random.key(0), [feats.shape[1], 16, 16])

    @jax.jit
    def infer_fn(hop_feats, hop_ids, deep_agg=None):
        masks = [(h >= 0).astype(jnp.float32)[:, None] for h in hop_ids]
        return sage_layered(params, hop_feats, fan, hop_masks=masks,
                            deep_agg=deep_agg)

    return infer_fn


def test_host_executor_fuse_aggregate_matches_fused(stack):
    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack)
    infer_fn = _infer(stack)
    seeds = np.arange(12)
    outs = {}
    for fa in (False, True):
        ex = HostExecutor(g, store, fan, infer_fn, rng_seed=5,
                          fuse_aggregate=fa)
        outs[fa] = np.asarray(ex.run(seeds))
        ex.close()
    assert np.array_equal(outs[False], outs[True])  # same rng → same sample


def test_device_executor_fuse_aggregate_matches_fused(stack):
    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack)
    infer_fn = _infer(stack)
    seeds = np.arange(10)
    outs = {}
    for fa in (False, True):
        ex = DeviceExecutor(g.device_arrays(), store, fan, infer_fn,
                            max_batch=16, rng_seed=5, fuse_aggregate=fa)
        outs[fa] = np.asarray(ex.run(seeds))
        ex.close()
    assert np.array_equal(outs[False], outs[True])


def test_fuse_aggregate_dispatch_stats(stack):
    """Structural accounting: the fused path folds the aggregation into its
    single device gather and counts one fused_aggregates entry."""
    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack)
    hops = _hops(g.num_nodes, fan, 16, seed=6)
    store.reset_stats()
    store.lookup_aggregate(hops)
    s = store.reset_stats()
    assert s["fused_aggregates"] == 1 and s["fused_calls"] == 1
    assert s["device_gathers"] == 1 and s["host_fetches"] <= 1
    store.lookup_hops(hops)
    s = store.reset_stats()
    assert s["fused_aggregates"] == 0 and s["fused_calls"] == 1


# ---------------------------------------------------------------------------
# Satellite regressions: empty shapes in segment_spmm / embedding_bag
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,dmax,d", [(0, 4, 8), (5, 0, 8), (5, 4, 0),
                                      (0, 0, 0)])
def test_segment_spmm_empty_shapes(n, dmax, d):
    ids = jnp.full((n, dmax), -1, jnp.int32)
    feat = jnp.ones((max(n, 1), d), jnp.float32)
    out = segment_spmm_pallas(ids, feat, interpret=True)
    ref = segment_spmm_ref(ids, feat)
    assert out.shape == (n, d) == ref.shape
    assert np.array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("b,bag,d", [(0, 4, 8), (5, 0, 8), (5, 4, 0)])
@pytest.mark.parametrize("mode", ["sum", "mean"])
def test_embedding_bag_empty_shapes(b, bag, d, mode):
    ids = jnp.full((b, bag), -1, jnp.int32)
    table = jnp.ones((4, d), jnp.float32)
    out = embedding_bag_pallas(table, ids, mode=mode, interpret=True)
    ref = embedding_bag_ref(table, ids, mode=mode)
    assert out.shape == (b, d) == ref.shape
    assert np.array_equal(np.asarray(out), np.asarray(ref))


def test_degree_zero_rows_mean_is_zero_not_nan():
    """All-padding rows (degree 0) must reduce to exact zeros under mean —
    the divide guards in kernel and oracle."""
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(6, 8)).astype(np.float32))
    ids = np.array([[0, 1, -1], [-1, -1, -1], [2, -1, -1]], np.int32)
    for fn in (lambda: embedding_bag_pallas(table, jnp.asarray(ids),
                                            mode="mean", interpret=True),
               lambda: embedding_bag_ref(table, ids, mode="mean")):
        out = np.asarray(fn())
        assert np.isfinite(out).all()
        assert not out[1].any()
    spmm = np.asarray(segment_spmm_pallas(jnp.asarray(ids), table,
                                          interpret=True))
    assert np.isfinite(spmm).all() and not spmm[1].any()

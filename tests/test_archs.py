"""Per-arch smoke tests (reduced configs, one real step on CPU) + registry
coverage of the assigned architecture × shape matrix."""
import jax
import pytest

import repro.configs as C

ASSIGNED = [
    "qwen1.5-4b", "qwen3-4b", "codeqwen1.5-7b", "deepseek-moe-16b",
    "phi3.5-moe-42b", "equiformer-v2", "gin-tu", "schnet", "meshgraphnet",
    "din",
]

LM_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
GNN_SHAPES = ("full_graph_sm", "minibatch_lg", "ogb_products", "molecule")
RECSYS_SHAPES = ("train_batch", "serve_p99", "serve_bulk", "retrieval_cand")


def test_registry_complete():
    archs = C.list_archs()
    for a in ASSIGNED:
        assert a in archs, a
    assert len(archs) == 10


@pytest.mark.parametrize("name", ASSIGNED)
def test_shape_matrix(name):
    arch = C.get_arch(name)
    expected = {"lm": LM_SHAPES, "moe_lm": LM_SHAPES, "gnn": GNN_SHAPES,
                "recsys": RECSYS_SHAPES}[arch.family]
    assert tuple(arch.shape_names) == expected


@pytest.mark.parametrize("name", ASSIGNED)
def test_smoke(name):
    """Reduced config, real forward/train step on CPU, finite outputs."""
    out = C.get_arch(name).smoke()
    assert isinstance(out, dict) and out


@pytest.mark.parametrize("name", ASSIGNED)
def test_cells_build_abstract(name):
    """Every (arch × shape) cell builds its abstract specs without a mesh
    (full dims, zero allocation)."""
    arch = C.get_arch(name)
    for shape in arch.shape_names:
        cell = arch.build_cell(shape, None)
        assert cell.args, (name, shape)
        leaves = jax.tree_util.tree_leaves(cell.args)
        assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)


def test_lm_full_param_counts():
    """Full configs match their nominal sizes (the 'did you actually build
    a 4B/16B/42B model' check)."""
    from repro.configs import (codeqwen15_7b, deepseek_moe_16b,
                               phi35_moe_42b, qwen15_4b, qwen3_4b)
    from repro.models.transformer import (lm_active_param_count,
                                          lm_param_count)
    assert 3.5e9 < lm_param_count(qwen15_4b.CONFIG) < 4.5e9
    assert 3.8e9 < lm_param_count(qwen3_4b.CONFIG) < 4.8e9
    assert 6.5e9 < lm_param_count(codeqwen15_7b.CONFIG) < 8.5e9
    assert 14e9 < lm_param_count(deepseek_moe_16b.CONFIG) < 18e9
    assert 39e9 < lm_param_count(phi35_moe_42b.CONFIG) < 45e9
    assert 5.5e9 < lm_active_param_count(phi35_moe_42b.CONFIG) < 7.5e9

"""quiverlint (PR 7): positive + negative fixtures for every rule, the
suppression and baseline mechanics, the full-repo zero-findings gate, and
behavioral regression tests for the genuine bugs the first full-repo run
surfaced (torn snapshot reads, stats/metrics published outside their
locks)."""
import json
import sys
import textwrap
import threading
from pathlib import Path

import numpy as np
import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

from quiverlint import driver, repo_config  # noqa: E402
from quiverlint.driver import SourceFile  # noqa: E402


# ---------------------------------------------------------------------------
# Harness: lint a fixture snippet with a minimal config
# ---------------------------------------------------------------------------
def lint(tmp_path, source, passes, *, configure=None, name="mod.py",
         baseline=None, extra_files=()):
    cfg = repo_config.Config(root=tmp_path)
    if configure:
        configure(cfg)
    paths = [(name, source), *extra_files]
    files = []
    for rel, text in paths:
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text))
        if rel.endswith(".py"):  # docs files are read from disk, not parsed
            files.append(SourceFile.load(p, tmp_path))
    return driver.run(cfg, files,
                      {n: repo_config.PASSES[n] for n in passes},
                      baseline_path=baseline)


def rules(result):
    return [f.rule for f in result.findings]


LOCK_GUARD = {"C": {"x": "_lock"}}


def lock_cfg(cfg):
    cfg.guarded_fields = LOCK_GUARD


# ---------------------------------------------------------------------------
# lock-discipline
# ---------------------------------------------------------------------------
class TestLockDiscipline:
    def test_flags_unguarded_access(self, tmp_path):
        res = lint(tmp_path, """
            class C:
                def read(self):
                    return self.x
        """, ["lock"], configure=lock_cfg)
        assert rules(res) == ["lock-discipline"]
        assert res.findings[0].symbol == "C.read"
        assert "_lock" in res.findings[0].message

    def test_clean_inside_with_lock(self, tmp_path):
        res = lint(tmp_path, """
            class C:
                def read(self):
                    with self._lock:
                        return self.x
        """, ["lock"], configure=lock_cfg)
        assert res.findings == []

    def test_wrong_lock_still_flags(self, tmp_path):
        res = lint(tmp_path, """
            class C:
                def read(self):
                    with self._other:
                        return self.x
        """, ["lock"], configure=lock_cfg)
        assert rules(res) == ["lock-discipline"]

    def test_init_and_exempt_methods_skipped(self, tmp_path):
        def cfg(c):
            c.guarded_fields = LOCK_GUARD
            c.lock_exempt_methods = {"C": {"publish"}}
        res = lint(tmp_path, """
            class C:
                def __init__(self):
                    self.x = 0
                def publish(self):
                    self.x = 1
        """, ["lock"], configure=cfg)
        assert res.findings == []

    def test_nested_function_does_not_inherit_lock(self, tmp_path):
        # a closure may run after the lock is released (executor callback)
        res = lint(tmp_path, """
            class C:
                def read(self):
                    with self._lock:
                        def cb():
                            return self.x
                        return cb
        """, ["lock"], configure=lock_cfg)
        assert rules(res) == ["lock-discipline"]

    def test_wait_for_predicate_counts_as_held(self, tmp_path):
        def cfg(c):
            c.guarded_fields = {"C": {"x": "_acct"}}
        res = lint(tmp_path, """
            class C:
                def drain(self):
                    with self._acct:
                        self._acct.wait_for(lambda: self.x == 0)
        """, ["lock"], configure=cfg)
        assert res.findings == []

    def test_suppression_with_reason(self, tmp_path):
        res = lint(tmp_path, """
            class C:
                def read(self):
                    return self.x  # quiverlint: disable=lock-discipline atomic ref read
        """, ["lock"], configure=lock_cfg)
        assert res.findings == []
        assert len(res.suppressed) == 1

    def test_suppression_without_reason_is_a_finding(self, tmp_path):
        res = lint(tmp_path, """
            class C:
                def read(self):
                    return self.x  # quiverlint: disable=lock-discipline
        """, ["lock"], configure=lock_cfg)
        assert sorted(rules(res)) == ["bad-suppression", "lock-discipline"]

    def test_own_line_suppression_covers_next_line(self, tmp_path):
        res = lint(tmp_path, """
            class C:
                def read(self):
                    # quiverlint: disable=lock-discipline snapshot not needed here
                    return self.x
        """, ["lock"], configure=lock_cfg)
        assert res.findings == []
        assert len(res.suppressed) == 1

    def test_suppression_for_other_rule_does_not_apply(self, tmp_path):
        res = lint(tmp_path, """
            class C:
                def read(self):
                    return self.x  # quiverlint: disable=trace-safety wrong rule
        """, ["lock"], configure=lock_cfg)
        assert rules(res) == ["lock-discipline"]


# ---------------------------------------------------------------------------
# trace-safety
# ---------------------------------------------------------------------------
class TestTraceSafety:
    def test_flags_branch_coercion_numpy_and_mask(self, tmp_path):
        res = lint(tmp_path, """
            import jax
            import numpy as np

            @jax.jit
            def f(x):
                if x > 0:
                    x = x + 1
                y = float(x)
                z = np.maximum(x, 0)
                m = x > 0
                w = x[m]
                s = x + 2
                return s.item()
        """, ["trace"])
        msgs = " | ".join(f.message for f in res.findings)
        assert len(res.findings) == 5
        assert "control flow" in msgs and "float()" in msgs
        assert "numpy" in msgs and "boolean-mask" in msgs
        assert ".item()" in msgs

    def test_clean_static_and_shape_idioms(self, tmp_path):
        res = lint(tmp_path, """
            import jax
            import jax.numpy as jnp
            import numpy as np
            from functools import partial

            @partial(jax.jit, static_argnames=("mode", "fanouts"))
            def f(x, w=None, *, mode="sum", fanouts=(4, 3)):
                if mode == "mean":          # static_argnames: not traced
                    x = x / 2
                if w is not None:           # identity test never concretizes
                    x = x * w
                n = int(x.shape[0])         # shapes are static under jit
                k = float(fanouts[-1])
                pad = np.zeros((4,))        # numpy on non-traced values
                return jnp.minimum(x, k) + n + jnp.asarray(pad)
        """, ["trace"])
        assert res.findings == []

    def test_reaches_helpers_called_from_jitted_body(self, tmp_path):
        res = lint(tmp_path, """
            import jax

            def helper(y, fanout: int):
                if fanout > 2:              # scalar annotation: static
                    y = y * 2
                return int(y)               # traced! flagged in the helper

            @jax.jit
            def f(x):
                return helper(x, 4)
        """, ["trace"])
        assert rules(res) == ["trace-safety"]
        assert res.findings[0].symbol == "helper"

    def test_pallas_kernel_via_partial_binding(self, tmp_path):
        res = lint(tmp_path, """
            import functools
            from jax.experimental import pallas as pl

            def _kernel(x_ref, o_ref, *, rows: int):
                o_ref[...] = float(x_ref[...])

            def call(x, block_rows: int = 8):
                kernel = functools.partial(_kernel, rows=block_rows)
                return pl.pallas_call(kernel, out_shape=None)(x)
        """, ["trace"])
        assert rules(res) == ["trace-safety"]
        assert "_kernel" in res.findings[0].symbol

    def test_io_callback_host_body_excluded(self, tmp_path):
        res = lint(tmp_path, """
            import jax
            import numpy as np
            from jax.experimental import io_callback

            @jax.jit
            def f(x):
                def cb(x_np):
                    return np.asarray(x_np) * 2   # host code: fine
                return io_callback(cb, x, x)
        """, ["trace"])
        assert res.findings == []

    def test_shard_map_body_checked(self, tmp_path):
        res = lint(tmp_path, """
            import jax

            def body(block):
                while block.sum() > 0:
                    block = block - 1
                return block

            def run(x, mesh):
                return jax.shard_map(body, mesh=mesh, in_specs=None,
                                     out_specs=None)(x)
        """, ["trace"])
        assert rules(res) == ["trace-safety"]
        assert res.findings[0].symbol == "body"


# ---------------------------------------------------------------------------
# callback-budget
# ---------------------------------------------------------------------------
CB_STORE_OK = """
    from jax.experimental import io_callback

    class Store:
        def lookup(self, ids):
            return self._resolve(ids)
        def _resolve(self, ids):
            return self._host_fetch(ids)
        def _host_fetch(self, ids):
            return io_callback(lambda x: x, None, ids)
"""

CB_STORE_BAD = """
    from jax.experimental import io_callback

    class Store:
        def lookup(self, ids):
            return self._resolve(ids)
        def _resolve(self, ids):
            return io_callback(lambda x: x, None, ids)
        def _host_fetch(self, ids):
            return io_callback(lambda x: x, None, ids)
"""


def cb_cfg(c):
    c.hot_path_roots = frozenset({"Store.lookup"})
    c.callback_gateways = frozenset({"Store._host_fetch"})
    c.fetch_gateways = frozenset()
    c.restricted_roots = {}


def cb_cfg_sharded(c):
    # the sharded shape: a host-data fetch gateway (plain numpy, never a
    # callback) plus a root forbidden from reaching the tiered gateway
    cb_cfg(c)
    c.fetch_gateways = frozenset({"Store.read_cold_rows"})
    c.restricted_roots = {"Sharded.lookup": ("Store._host_fetch",)}


class TestCallbackBudget:
    def test_gateway_only_path_is_clean(self, tmp_path):
        res = lint(tmp_path, CB_STORE_OK, ["callback"], configure=cb_cfg)
        assert res.findings == []

    def test_direct_callback_outside_gateway_flagged_with_chain(
            self, tmp_path):
        res = lint(tmp_path, CB_STORE_BAD, ["callback"], configure=cb_cfg)
        assert rules(res) == ["callback-budget"]
        msg = res.findings[0].message
        assert "Store.lookup -> Store._resolve" in msg

    def test_callback_hidden_behind_partial_still_caught(self, tmp_path):
        # broad reference-based edges: storing the method is enough
        res = lint(tmp_path, """
            import functools
            from jax.experimental import io_callback

            class Store:
                def lookup(self, ids):
                    fn = functools.partial(self._fetch_now, ids)
                    return fn()
                def _fetch_now(self, ids):
                    return io_callback(lambda x: x, None, ids)
                def _host_fetch(self, ids):
                    return io_callback(lambda x: x, None, ids)
        """, ["callback"], configure=cb_cfg)
        assert rules(res) == ["callback-budget"]

    def test_missing_root_is_config_drift(self, tmp_path):
        res = lint(tmp_path, """
            class Store:
                def renamed_lookup(self, ids):
                    return ids
        """, ["callback"], configure=cb_cfg)
        assert any("not found" in f.message for f in res.findings)

    def test_vacuous_gateway_flagged(self, tmp_path):
        res = lint(tmp_path, """
            class Store:
                def lookup(self, ids):
                    return self._host_fetch(ids)
                def _host_fetch(self, ids):
                    return ids      # no io_callback: proof is vacuous
        """, ["callback"], configure=cb_cfg)
        assert any("vacuous" in f.message for f in res.findings)

    def test_fetch_gateway_clean_and_stops_restricted_walk(self, tmp_path):
        # a restricted root may *call* the fetch gateway — the walk stops
        # there, so the forbidden qualname behind it is never reached
        res = lint(tmp_path, """
            from jax.experimental import io_callback

            class Store:
                def lookup(self, ids):
                    return self._host_fetch(ids)
                def _host_fetch(self, ids):
                    return io_callback(lambda x: x, None, ids)
                def read_cold_rows(self, ids):
                    return ids          # plain numpy, no callback

            class Sharded:
                def lookup(self, ids):
                    return self.read_cold_rows(ids)
                def read_cold_rows(self, ids):
                    return ids
        """, ["callback"], configure=cb_cfg_sharded)
        assert res.findings == []

    def test_fetch_gateway_with_direct_callback_flagged(self, tmp_path):
        res = lint(tmp_path, """
            from jax.experimental import io_callback

            class Store:
                def lookup(self, ids):
                    return self._host_fetch(ids)
                def _host_fetch(self, ids):
                    return io_callback(lambda x: x, None, ids)
                def read_cold_rows(self, ids):
                    return io_callback(lambda x: x, None, ids)

            class Sharded:
                def lookup(self, ids):
                    return ids
        """, ["callback"], configure=cb_cfg_sharded)
        assert rules(res) == ["callback-budget"]
        assert any("direct io_callback" in f.message for f in res.findings)

    def test_restricted_root_reaching_forbidden_flagged(self, tmp_path):
        res = lint(tmp_path, """
            from jax.experimental import io_callback

            class Store:
                def lookup(self, ids):
                    return self._host_fetch(ids)
                def _host_fetch(self, ids):
                    return io_callback(lambda x: x, None, ids)
                def read_cold_rows(self, ids):
                    return ids

            class Sharded:
                def lookup(self, ids):
                    return self._merge(ids)
                def _merge(self, ids):
                    return Store._host_fetch(self, ids)
        """, ["callback"], configure=cb_cfg_sharded)
        assert rules(res) == ["callback-budget"]
        msg = res.findings[0].message
        assert "Sharded.lookup" in msg and "_host_fetch" in msg

    def test_missing_fetch_gateway_is_config_drift(self, tmp_path):
        res = lint(tmp_path, """
            from jax.experimental import io_callback

            class Store:
                def lookup(self, ids):
                    return self._host_fetch(ids)
                def _host_fetch(self, ids):
                    return io_callback(lambda x: x, None, ids)

            class Sharded:
                def lookup(self, ids):
                    return ids
        """, ["callback"], configure=cb_cfg_sharded)
        assert any("fetch gateway" in f.message and "not found" in f.message
                   for f in res.findings)


# ---------------------------------------------------------------------------
# schema-sync
# ---------------------------------------------------------------------------
SCHEMA_DOC = """
    stats: `a_hits` `a_misses`
    <!-- quiverlint:stats-schema -->
    | `a_hits` | hits |
    | `a_misses` | misses |
    <!-- /quiverlint:stats-schema -->
"""


def schema_cfg(c):
    c.schema = repo_config.SchemaSpec(
        schema_file="store.py", schema_const="STATS_SCHEMA",
        store_class="Store", cache_class="Cache",
        stats_classes=(("store.py", "Cache"),),
        aux_schemas=(),
        marker_doc="docs/invariants.md")


class TestSchemaSync:
    def run_schema(self, tmp_path, source, doc=SCHEMA_DOC):
        return lint(tmp_path, source, ["schema"], configure=schema_cfg,
                    name="store.py",
                    extra_files=[("docs/invariants.md", doc)])

    CLEAN = """
        STATS_SCHEMA = ("a_hits", "a_misses")

        class Store:
            def hit(self):
                self._count(a_hits=1)
            def miss(self):
                self._count(a_misses=1)

        class Cache:
            def __init__(self):
                self.stats = {"hits": 0, "misses": 0}
            def touch(self):
                self.stats["hits"] += 1
                self.stats["misses"] += 1
    """

    def test_clean_schema(self, tmp_path):
        res = self.run_schema(tmp_path, self.CLEAN)
        assert res.findings == []

    def test_unknown_count_key_flagged(self, tmp_path):
        res = self.run_schema(tmp_path, self.CLEAN.replace(
            "self._count(a_hits=1)", "self._count(b_hits=1)"))
        msgs = [f.message for f in res.findings]
        assert any("`b_hits` incremented but absent" in m for m in msgs)
        assert any("`a_hits` is never incremented" in m for m in msgs)

    def test_undeclared_class_stats_key_flagged(self, tmp_path):
        res = self.run_schema(tmp_path, self.CLEAN.replace(
            'self.stats["hits"] += 1', 'self.stats["hitz"] += 1'))
        msgs = [f.message for f in res.findings]
        assert any("'hitz'" in m and "not declared" in m for m in msgs)
        assert any("`hits` is never read" in m for m in msgs)

    def test_cache_mirror_checked(self, tmp_path):
        src = self.CLEAN.replace('"a_hits", "a_misses"',
                                 '"a_hits", "a_misses", "cache_evictions"')
        src = src.replace("self._count(a_misses=1)",
                          "self._count(a_misses=1, cache_evictions=1)")
        res = self.run_schema(tmp_path, src, doc=SCHEMA_DOC.replace(
            "| `a_misses` | misses |",
            "| `a_misses` | misses |\n    | `cache_evictions` | ev |"))
        msgs = [f.message for f in res.findings]
        assert any("mirrors no `evictions` counter" in m for m in msgs)

    def test_docs_table_out_of_sync_flagged(self, tmp_path):
        res = self.run_schema(tmp_path, self.CLEAN, doc="""
            stats: `a_hits` `a_misses`
            <!-- quiverlint:stats-schema -->
            | `a_hits` | hits |
            | `stale_key` | gone |
            <!-- /quiverlint:stats-schema -->
        """)
        msgs = [f.message for f in res.findings]
        assert any("`a_misses` missing from" in m for m in msgs)
        assert any("`stale_key` is not in STATS_SCHEMA" in m for m in msgs)

    AUX_DOC = SCHEMA_DOC + """
    <!-- quiverlint:aux-x -->
    | `hits` | h |
    | `misses` | m |
    <!-- /quiverlint:aux-x -->
    """

    def aux_cfg(self, c):
        schema_cfg(c)
        c.schema.aux_schemas = (("store.py", "AUX_SCHEMA", "Cache",
                                 "aux-x"),)

    def test_aux_schema_clean(self, tmp_path):
        src = self.CLEAN + '\n        AUX_SCHEMA = ("hits", "misses")\n'
        res = lint(tmp_path, src, ["schema"], configure=self.aux_cfg,
                   name="store.py",
                   extra_files=[("docs/invariants.md", self.AUX_DOC)])
        assert res.findings == []

    def test_aux_schema_drift_flagged_everywhere(self, tmp_path):
        """One drifted aux constant fires on all three surfaces: the stats
        declaration, the doc table, and a missing constant entirely."""
        src = self.CLEAN + '\n        AUX_SCHEMA = ("hits", "evictions")\n'
        res = lint(tmp_path, src, ["schema"], configure=self.aux_cfg,
                   name="store.py",
                   extra_files=[("docs/invariants.md", self.AUX_DOC)])
        msgs = [f.message for f in res.findings]
        assert any("key `evictions` missing from Cache's stats declaration"
                   in m for m in msgs)
        assert any("stats key `misses` is absent from `AUX_SCHEMA`" in m
                   for m in msgs)
        assert any("key `evictions` missing from the aux-x table" in m
                   for m in msgs)
        assert any("documented key `misses` is not in `AUX_SCHEMA`" in m
                   for m in msgs)
        # constant deleted outright -> flagged, not silently skipped
        res = lint(tmp_path / "gone", self.CLEAN, ["schema"],
                   configure=self.aux_cfg, name="store.py",
                   extra_files=[("docs/invariants.md", self.AUX_DOC)])
        assert any("aux schema constant `AUX_SCHEMA` not found" in
                   f.message for f in res.findings)

    def test_aux_schema_missing_marker_block_flagged(self, tmp_path):
        src = self.CLEAN + '\n        AUX_SCHEMA = ("hits", "misses")\n'
        res = lint(tmp_path, src, ["schema"], configure=self.aux_cfg,
                   name="store.py",
                   extra_files=[("docs/invariants.md", SCHEMA_DOC)])
        assert any("no `<!-- quiverlint:aux-x -->` block found" in
                   f.message for f in res.findings)


# ---------------------------------------------------------------------------
# docs pass (folded-in check_docs)
# ---------------------------------------------------------------------------
class TestDocsPass:
    def test_broken_link_and_missing_docstring(self, tmp_path):
        def cfg(c):
            c.docs = repo_config.DocsSpec(api={"api.py": ["Thing"]})
        res = lint(tmp_path, """
            class Thing:
                def run(self):
                    return 1
        """, ["docs"], configure=cfg, name="api.py",
            extra_files=[("README.md", "[dead](missing.md)\n")])
        got = sorted(rules(res))
        assert got == ["docs-docstring", "docs-docstring", "docs-link"]

    def test_clean_docs(self, tmp_path):
        def cfg(c):
            c.docs = repo_config.DocsSpec(api={"api.py": ["Thing.run"]})
        res = lint(tmp_path, '''
            class Thing:
                """A thing."""
                def run(self):
                    """Runs."""
        ''', ["docs"], configure=cfg, name="api.py",
            extra_files=[("README.md", "[ok](api.py) [web](https://x)\n")])
        assert res.findings == []


# ---------------------------------------------------------------------------
# baseline round-trip
# ---------------------------------------------------------------------------
class TestBaseline:
    SRC = """
        class C:
            def read(self):
                return self.x
    """
    FIXED = """
        class C:
            def read(self):
                with self._lock:
                    return self.x
    """

    def test_round_trip_then_stale(self, tmp_path):
        baseline = tmp_path / "baseline.json"
        res = lint(tmp_path, self.SRC, ["lock"], configure=lock_cfg,
                   baseline=baseline)
        assert rules(res) == ["lock-discipline"]
        driver.write_baseline(baseline, res.findings)
        assert json.loads(baseline.read_text())["findings"]

        # baselined: finding demoted, run is ok
        res = lint(tmp_path, self.SRC, ["lock"], configure=lock_cfg,
                   baseline=baseline)
        assert res.ok and res.findings == [] and len(res.baselined) == 1

        # the line-independent key survives code shifting down the file
        res = lint(tmp_path, "\n\n" + textwrap.dedent(self.SRC), ["lock"],
                   configure=lock_cfg, baseline=baseline)
        assert res.ok and len(res.baselined) == 1

        # fixed code -> the baseline entry goes stale and fails the run
        res = lint(tmp_path, self.FIXED, ["lock"], configure=lock_cfg,
                   baseline=baseline)
        assert not res.ok and res.findings == []
        assert len(res.stale_baseline) == 1


# ---------------------------------------------------------------------------
# the full-repo gate: the tool's own CI contract
# ---------------------------------------------------------------------------
class TestFullRepo:
    def test_repo_is_clean_via_main(self, capsys):
        rc = driver.main(["--root", str(REPO), "--json"])
        out = json.loads(capsys.readouterr().out)
        assert rc == 0, out["findings"]
        assert out["ok"] and out["findings"] == []
        assert out["stale_baseline"] == []
        assert set(out["passes"]) == set(repo_config.PASSES)

    def test_callback_budget_proves_gateway_property(self):
        """The zero-callback property: io_callback appears in exactly one
        function of src/repro, and that function is the registered
        gateway reachable from the hot-path roots."""
        from quiverlint import callback_budget, callgraph
        cfg = repo_config.build(REPO)
        files = driver.collect_files(REPO, ["src/repro/**/*.py"])
        index = callgraph.Index(files)
        direct = callback_budget._direct_callers(cfg, index)
        assert sorted(r.split("::")[1] for r in direct) == \
            ["TieredFeatureStore._host_fetch"]
        roots = [f for q in cfg.hot_path_roots
                 for f in index.by_qualname.get(q, [])]
        reached = callgraph.reachable_broad(
            index, roots, stop=set(cfg.callback_gateways))
        assert any(r.endswith("TieredFeatureStore._host_fetch")
                   for r in reached), "gateway unreachable from hot path"


# ---------------------------------------------------------------------------
# regression tests for the true positives the first full-repo run found
# ---------------------------------------------------------------------------
class LockProbe:
    """threading.Lock wrapper recording acquisitions and held state."""

    def __init__(self):
        self._lock = threading.Lock()
        self.acquired = 0
        self.held = False

    def __enter__(self):
        self._lock.acquire()
        self.acquired += 1
        self.held = True
        return self

    def __exit__(self, *exc):
        self.held = False
        self._lock.release()


class TestLockRegressions:
    def test_cache_report_snapshots_under_lock(self):
        """GPUFeatureCache.report read `capacity` outside _lock; a
        concurrent resize could pair old capacity with new stats."""
        from repro.core import GPUFeatureCache
        cache = GPUFeatureCache(num_nodes=16, capacity=4, feat_dim=2)
        probe = LockProbe()
        cache._lock = probe
        rep = cache.report()
        assert probe.acquired >= 1
        assert rep["capacity"] == 4 and rep["resident"] == 0

    def test_engine_reset_publishes_metrics_under_lock(self):
        """ServingEngine._reset assigned self._metrics without _lock,
        racing submit_batch's bind of the current run's metrics."""
        import time

        from repro.serving.engine import ServingEngine
        eng = ServingEngine.__new__(ServingEngine)
        probe = LockProbe()
        eng._lock = probe
        eng._metrics = None
        eng.clock = time.monotonic    # normally injected by __init__
        metrics = eng._reset()
        assert probe.acquired == 1
        assert eng._metrics is metrics and metrics.started > 0

    def test_adaptive_report_snapshots_stats_under_lock(self):
        """AdaptiveController.report iterated self.stats['last_drift']
        unlocked while refit_curves mutates it -> possible
        dictionary-changed-size-during-iteration."""
        from repro.serving.adaptive import AdaptiveController

        class _Sketch:
            total_observed = 7

        ctl = AdaptiveController.__new__(AdaptiveController)
        probe = LockProbe()
        ctl._lock = probe
        ctl.sketch = _Sketch()
        ctl.stats = {"steps": 3, "last_drift": {"host": 0.5}}
        rep = ctl.report()
        assert probe.acquired == 1
        assert rep["steps"] == 3 and rep["last_drift"] == {"host": 0.5}
        assert rep["seeds_observed"] == 7

    def test_refit_writes_last_drift_under_lock(self):
        """refit_curves wrote stats['last_drift'][key] outside _lock."""
        import collections

        from repro.core.serving import DEFAULT_MODEL
        from repro.serving.adaptive import AdaptiveConfig, AdaptiveController
        from repro.serving.router import LatencyCurve

        probe = LockProbe()

        class GuardedDict(dict):
            def __setitem__(self, key, value):
                assert probe.held, \
                    "last_drift written without holding _lock"
                super().__setitem__(key, value)

        class _Router:
            def curve(self, name):
                return LatencyCurve.fit([1, 2, 3, 4], [1, 2, 3, 4], bins=2)

            def update_curve(self, name, curve):
                pass

        ctl = AdaptiveController.__new__(AdaptiveController)
        ctl._lock = probe
        ctl.config = AdaptiveConfig(min_refit_samples=4)
        ctl.routers = {DEFAULT_MODEL: _Router()}
        drift_log = GuardedDict()
        ctl.stats = {"refits": 0, "last_drift": drift_log}
        ctl.samples = {(DEFAULT_MODEL, "host"): collections.deque(
            [(1.0, 5.0), (2.0, 9.0), (3.0, 14.0), (4.0, 20.0)])}
        ctl.refit_curves()
        assert list(drift_log) == ["host"]

    def test_host_fetch_default_args_match_explicit_snapshot(self, tmp_path):
        """_host_fetch's fallback read self.host and self.disk in two
        separate loads (could tear across a migration publish) and sized
        its result from self.hot's dtype; it must behave exactly as if
        handed one coherent snapshot."""
        import jax.numpy as jnp

        from repro.core import (TieredFeatureStore, TopologySpec,
                                compute_fap, quiver_placement)
        from repro.graph import power_law_graph

        n, d = 400, 6
        g = power_law_graph(n, 6.0, seed=0)
        feats = np.random.default_rng(0).normal(size=(n, d)) \
            .astype(np.float32)
        topo = TopologySpec(num_pods=1, devices_per_pod=1,
                            rows_per_device=80, rows_host=120,
                            hot_replicate_fraction=0.2)
        store = TieredFeatureStore.build(
            feats, quiver_placement(compute_fap(g, (4, 3)), topo))
        hot, warm, host, disk, tier_t, slot_t, _ = store._snapshot()
        cold = np.flatnonzero(np.asarray(tier_t) >= 2)[:16]
        ids = jnp.asarray(cold, jnp.int32)
        tier = jnp.asarray(np.asarray(tier_t)[cold].astype(np.int32))
        slot = jnp.asarray(np.asarray(slot_t)[cold].astype(np.int32))
        via_default = np.asarray(store._host_fetch(ids, tier, slot))
        via_explicit = np.asarray(
            store._host_fetch(ids, tier, slot, host, disk))
        np.testing.assert_array_equal(via_default, via_explicit)
        np.testing.assert_allclose(via_default, feats[cold])

    def test_promote_misses_consistent_under_migration_churn(self):
        """promote_misses read tier_t and slot_t in two separate attribute
        loads — pairing a node's new tier with its old slot across a
        migration publish. Smoke the production shape: publishers
        (swap_assignments / promote_misses) serialized by a step lock as
        the adaptive controller does, lookups concurrent and unserialized
        — every lookup must stay bit-equivalent throughout."""
        import jax.numpy as jnp

        from repro.core import (TieredFeatureStore, TopologySpec,
                                compute_fap, quiver_placement)
        from repro.core.placement import TIER_DISK, TIER_HOST
        from repro.graph import power_law_graph

        n, d = 500, 4
        g = power_law_graph(n, 6.0, seed=1)
        feats = np.random.default_rng(1).normal(size=(n, d)) \
            .astype(np.float32)
        topo = TopologySpec(num_pods=1, devices_per_pod=1,
                            rows_per_device=90, rows_host=140,
                            hot_replicate_fraction=0.2)
        store = TieredFeatureStore.build(
            feats, quiver_placement(compute_fap(g, (4, 3)), topo))
        disk_ids = np.flatnonzero(store.plan.tier == TIER_DISK)
        host_ids = np.flatnonzero(store.plan.tier == TIER_HOST)
        assert disk_ids.size >= 8 and host_ids.size >= 8
        with store._stats_lock:
            store._disk_miss_counts[disk_ids[:8]] = 50

        step_lock = threading.Lock()
        stop = threading.Event()
        errors = []
        probe = np.concatenate([disk_ids[:8], host_ids[:8]])
        probe_ids = jnp.asarray(probe, jnp.int32)

        def reader():
            try:
                while not stop.is_set():
                    got = np.asarray(store.lookup(probe_ids))
                    np.testing.assert_allclose(got, feats[probe])
            except BaseException as exc:  # pragma: no cover
                errors.append(exc)

        def churn():
            try:
                for k in range(6):
                    a = int(host_ids[2 * k]); b = int(host_ids[2 * k + 1])
                    with step_lock:
                        store.swap_assignments([(a, b)])
            except BaseException as exc:  # pragma: no cover
                errors.append(exc)

        threads = [threading.Thread(target=reader),
                   threading.Thread(target=churn)]
        for t in threads:
            t.start()
        moved = 0
        for _ in range(4):
            with step_lock:
                moved += store.promote_misses(budget=2, min_misses=10)
        threads[1].join()
        stop.set()
        threads[0].join()
        assert not errors, errors[0]
        assert moved > 0 and store.promoted_rows == moved
        got = np.asarray(store.lookup(probe_ids))
        np.testing.assert_allclose(got, feats[probe])

"""Property tests of the SLO gateway's admission invariants (hypothesis).

Driven against a synchronous instant-dispatch engine stand-in and a
FakeClock, so every example is deterministic and sleep-free. The three
pinned invariants:

  1. outcome partition — every submitted request terminates in exactly one
     of {completed, shed_window, shed_deadline}, and the gateway counters
     agree with the per-request outcomes;
  2. admission-window bound — the queue never exceeds ``queue_limit`` and
     the overflow verdict is exactly ``shed_window``;
  3. aging bound — once every queued interactive request has waited past
     ``aging_bound_s``, no batch request is dispatched before any of them.
"""
from concurrent.futures import Future
from types import SimpleNamespace

import numpy as np
import pytest

pytest.importorskip("hypothesis")
pytestmark = pytest.mark.hypothesis
from hypothesis import given, settings, strategies as st

from repro.core import Request
from repro.serving import GatewayConfig, ServingGateway
from repro.testing import FakeClock


class InstantEngine:
    """Engine stand-in: dispatch completes inline on the submitting thread.

    Implements exactly the surface the gateway touches (`submit_batch`,
    `record_shed`, `max_inflight`, `clock`, `registry.router_for`,
    `inflight`/`saturation`/`class_summaries`, `drain`). `max_inflight`
    is a plain attribute the tests flip between 0 (queue builds) and huge
    (everything drains synchronously)."""

    def __init__(self, clock, max_inflight=1):
        self.clock = clock
        self.max_inflight = max_inflight
        self.registry = SimpleNamespace(router_for=lambda name: object())
        self.dispatched: list = []
        self.inflight = 0
        self.saturation = 0.0

    def class_summaries(self):
        return {}

    def record_shed(self, batch, model=None, *, reason="window"):
        for r in batch:
            r.outcome = ("shed_window" if reason == "window"
                         else "shed_deadline")

    def submit_batch(self, batch):
        for r in batch:
            r.outcome = "completed"
        self.dispatched.extend(batch)
        fut = Future()
        fut.set_result(np.zeros((len(batch), 1), np.float32))
        return fut

    def drain(self):
        pass


def _gateway(clk, *, max_inflight, **cfg_kw):
    eng = InstantEngine(clk, max_inflight=max_inflight)
    return ServingGateway(eng, config=GatewayConfig(**cfg_kw),
                          clock=clk), eng


def _req(i, priority="batch", deadline_s=None):
    return Request(i, np.array([i % 8], np.int64), 0.0, priority=priority,
                   deadline_s=deadline_s)


OUTCOMES = ("completed", "shed_window", "shed_deadline")

# (priority, relative deadline or None, clock advance before the submit)
ARRIVALS = st.lists(
    st.tuples(st.sampled_from(("interactive", "batch")),
              st.sampled_from((None, -0.01, 0.05, 0.5, 5.0)),
              st.floats(min_value=0.0, max_value=0.2)),
    min_size=1, max_size=30)


@settings(max_examples=40, deadline=None)
@given(arrivals=ARRIVALS, queue_limit=st.integers(1, 8))
def test_every_request_terminates_in_exactly_one_outcome(arrivals,
                                                         queue_limit):
    clk = FakeClock()
    gw, eng = _gateway(clk, max_inflight=0, queue_limit=queue_limit)
    reqs = []
    for i, (priority, dl, dt) in enumerate(arrivals):
        clk.advance(dt)                 # queue ages between arrivals
        r = _req(i, priority, dl)
        reqs.append(r)
        verdict = gw.submit(r)
        assert verdict in ("queued", "shed_window", "shed_deadline")
        assert gw.queue_depth <= queue_limit
    eng.max_inflight = len(reqs) + 1    # open the window: drain everything
    gw.pump()
    gw.drain()
    assert gw.queue_depth == 0
    # exactly one terminal outcome each, and never shed_deadline without one
    assert all(r.outcome in OUTCOMES for r in reqs)
    counts = {o: sum(r.outcome == o for r in reqs) for o in OUTCOMES}
    assert sum(counts.values()) == len(reqs)
    assert all(r.outcome != "shed_deadline" for r in reqs
               if r.deadline_s is None)
    # gateway counters agree with the per-request outcomes
    rep = gw.report()
    assert rep["completed"] == counts["completed"] == len(eng.dispatched)
    assert rep["shed_window"] == counts["shed_window"]
    assert rep["shed_deadline"] == counts["shed_deadline"]
    assert rep["dispatched"] == rep["completed"]
    # conservation: every submit either dispatched or shed, and requests
    # shed at dequeue time were admitted first
    assert (rep["dispatched"] + rep["shed_window"]
            + rep["shed_deadline"] == len(reqs))
    assert rep["admitted"] >= rep["dispatched"]
    assert rep["max_queue_depth"] <= queue_limit


@settings(max_examples=40, deadline=None)
@given(n=st.integers(1, 24), queue_limit=st.integers(1, 8))
def test_admission_window_bound_and_fifo_drain(n, queue_limit):
    clk = FakeClock()
    gw, eng = _gateway(clk, max_inflight=0, queue_limit=queue_limit)
    reqs = [_req(i) for i in range(n)]  # one class, no deadlines
    verdicts = [gw.submit(r) for r in reqs]
    kept = min(n, queue_limit)
    assert verdicts == ["queued"] * kept + ["shed_window"] * (n - kept)
    assert gw.queue_depth == kept
    assert gw.report()["max_queue_depth"] == kept <= queue_limit
    eng.max_inflight = n + 1
    gw.pump()
    gw.drain()
    # homogeneous queue degenerates to FIFO: admitted order == dispatch order
    assert [r.req_id for r in eng.dispatched] == [r.req_id
                                                  for r in reqs[:kept]]
    assert all(r.outcome == "completed" for r in reqs[:kept])
    assert all(r.outcome == "shed_window" for r in reqs[kept:])


@settings(max_examples=40, deadline=None)
@given(classes=st.lists(st.booleans(), min_size=2, max_size=20).filter(
    lambda c: any(c) and not all(c)))
def test_aged_interactive_is_never_passed_over_for_batch(classes):
    clk = FakeClock()
    gw, eng = _gateway(clk, max_inflight=0, aging_bound_s=0.25,
                       queue_limit=64)
    reqs = [_req(i, "interactive" if inter else "batch")
            for i, inter in enumerate(classes)]
    for r in reqs:
        assert gw.submit(r) == "queued"
    clk.advance(0.3)                    # every interactive is past the bound
    eng.max_inflight = len(reqs) + 1
    gw.pump()
    gw.drain()
    order = [r.priority for r in eng.dispatched]
    n_inter = sum(classes)
    # tier promotion: ALL aged interactive requests precede ALL batch ones
    assert order == ["interactive"] * n_inter + \
        ["batch"] * (len(reqs) - n_inter)
    assert gw.report()["aged_dispatches"] == n_inter

"""Extra pipeline behaviours: GAT serving (the paper's second model),
shared-queue straggler absorption, calibration-driven engine wiring, and
the deprecation contract of the repro.core.{pipeline,scheduler} shims."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (HybridScheduler, ServingEngine, StaticScheduler,
                        TieredFeatureStore, TopologySpec, compute_fap,
                        compute_psgs, quiver_placement)
from repro.core.serving import Request
from repro.graph import power_law_graph
from repro.models.gnn_basic import gat_init, sage_init, sage_layered
from tests.conftest import run_subprocess


def test_gat_full_graph_served_via_store():
    """GAT (paper §6.1 model #2) end to end: features fetched through the
    tiered store, full-graph attention forward on the sampled subgraph."""
    from repro.models.gnn_basic import gat_full_graph
    g = power_law_graph(600, 5.0, seed=0)
    fan = (4, 3)
    feats = np.random.default_rng(0).normal(size=(600, 16)).astype(
        np.float32)
    fap = compute_fap(g, fan)
    topo = TopologySpec(num_pods=1, devices_per_pod=1, rows_per_device=200,
                        rows_host=300, hot_replicate_fraction=0.3)
    store = TieredFeatureStore.build(feats, quiver_placement(fap, topo))
    params = gat_init(jax.random.key(0), [16, 8, 8], heads=4)
    src, dst = map(jnp.asarray, g.to_coo())
    x = store.lookup(jnp.arange(600, dtype=jnp.int32))
    out = gat_full_graph(params, x, src, dst, num_nodes=600)
    assert out.shape == (600, 32) and bool(jnp.isfinite(out).all())


def test_shared_queue_absorbs_stragglers():
    """Paper §4.3(2): with a shared queue, one slow batch only occupies one
    worker — small batches behind it still complete promptly."""
    g = power_law_graph(800, 5.0, seed=1)
    fan = (3, 2)
    feats = np.random.default_rng(1).normal(size=(800, 8)).astype(np.float32)
    fap = compute_fap(g, fan)
    topo = TopologySpec(num_pods=1, devices_per_pod=1, rows_per_device=400,
                        rows_host=400)
    store = TieredFeatureStore.build(feats, quiver_placement(fap, topo))
    params = sage_init(jax.random.key(0), [8, 16, 16])
    slow_calls = {"n": 0}

    @jax.jit
    def base_infer(hop_feats, hop_ids):
        masks = [(h >= 0).astype(jnp.float32)[:, None] for h in hop_ids]
        return sage_layered(params, hop_feats, fan, hop_masks=masks)

    def infer_fn(hop_feats, hop_ids):
        out = base_infer(hop_feats, hop_ids)
        if hop_ids[0].shape[0] >= 64:      # the straggler batch
            time.sleep(0.4)
            slow_calls["n"] += 1
        return out

    engine = ServingEngine(g, store, fan, infer_fn,
                           StaticScheduler("device"), num_workers=2,
                           max_batch=64)
    engine.warmup([Request(0, np.arange(4), time.perf_counter())])
    # one big straggler + many small requests
    batches = [[Request(0, np.arange(64), time.perf_counter())]]
    batches += [[Request(i + 1, np.array([i % 100]), time.perf_counter())]
                for i in range(10)]
    m = engine.run(batches)
    assert slow_calls["n"] >= 1
    lat = np.sort(np.asarray(m.latencies))
    # the straggler is the tail; the majority finished well under its time
    assert np.median(lat) < lat[-1]


def test_scheduler_threshold_infinity_routes_host():
    g = power_law_graph(300, 4.0, seed=2)
    psgs = compute_psgs(g, (3, 2))
    s = HybridScheduler(psgs, float("inf"))
    for _ in range(5):
        assert s.route(np.array([1, 2, 3])) == "host"
    assert s.routed["device"] == 0


# ---------------------------------------------------------------------------
# Deprecation shims (satellite): import-time warning exactly once + re-exports
# ---------------------------------------------------------------------------
@pytest.mark.subprocess
def test_shim_imports_warn_exactly_once_and_reexport():
    """Importing repro.core.{pipeline,scheduler} must emit ONE
    DeprecationWarning each (re-imports hit the sys.modules cache) while a
    plain `import repro.core` stays silent; the shims re-export the
    canonical serving-layer objects. Subprocess: import-time behavior needs
    a fresh interpreter."""
    code = """
import warnings
with warnings.catch_warnings(record=True) as w:
    warnings.simplefilter("always")
    import repro.core                      # package import: no warning
    base = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert not base, [str(x.message) for x in base]
    import repro.core.pipeline as p1
    import repro.core.scheduler as s1
    import repro.core.pipeline             # cached: must not warn again
    import repro.core.scheduler
dep = [str(x.message) for x in w
       if issubclass(x.category, DeprecationWarning)]
pipe = [m for m in dep if "repro.core.pipeline" in m]
sched = [m for m in dep if "repro.core.scheduler" in m]
assert len(pipe) == 1, pipe
assert len(sched) == 1, sched

import repro.serving as serving
# shims re-export the canonical serving-layer objects (same identity)
assert p1.ServeMetrics is serving.ServeMetrics
assert issubclass(p1.ServingEngine, serving.ServingEngine)
for name in ("LatencyCurve", "CalibrationResult", "CostModelRouter",
             "HybridScheduler", "StaticScheduler", "calibrate",
             "calibrate_executors"):
    assert getattr(s1, name) is getattr(serving, name), name
# the lazy repro.core.ServingEngine attribute resolves to the legacy shim
assert repro.core.ServingEngine is p1.ServingEngine
print("SHIM_OK")
"""
    r = run_subprocess(code, devices=1)
    assert "SHIM_OK" in r.stdout, r.stderr[-3000:]


def test_legacy_engine_construction_warns_with_specific_message():
    """The legacy two-executor constructor keeps its own per-instantiation
    warning on top of the import-time one."""
    import warnings

    g = power_law_graph(200, 4.0, seed=3)
    feats = np.random.default_rng(3).normal(size=(200, 8)).astype(np.float32)
    fap = compute_fap(g, (2,))
    topo = TopologySpec(num_pods=1, devices_per_pod=1, rows_per_device=100,
                        rows_host=100)
    store = TieredFeatureStore.build(feats, quiver_placement(fap, topo))
    params = sage_init(jax.random.key(0), [8, 8])

    def infer_fn(hop_feats, hop_ids):
        return sage_layered(params, hop_feats, (2,))

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        ServingEngine(g, store, (2,), infer_fn, StaticScheduler("host"),
                      num_workers=1)
    msgs = [str(x.message) for x in w
            if issubclass(x.category, DeprecationWarning)]
    assert any("repro.core.pipeline.ServingEngine" in m for m in msgs), msgs

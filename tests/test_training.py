"""Training substrate: optimizer, checkpoint fault tolerance, elastic
restore, gradient compression, multi-device train step."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
pytestmark = pytest.mark.hypothesis
from hypothesis import given, settings, strategies as st

from repro.training import (AdamW, CheckpointManager, compress_int8,
                            global_norm, run_training)
from tests.conftest import run_subprocess


def test_adamw_converges_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0, warmup_steps=1)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(150):
        grads = {"w": 2 * (params["w"] - 1.0)}
        params, state = opt.update(grads, state, params)
    np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 1.0],
                               atol=0.05)


def test_grad_clipping_bounds_update():
    opt = AdamW(lr=1.0, clip_norm=1e-3, weight_decay=0.0, warmup_steps=1)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    huge = {"w": jnp.full(4, 1e9)}
    p2, _ = opt.update(huge, state, params)
    assert float(jnp.abs(p2["w"]).max()) < 10.0


def test_checkpoint_roundtrip_and_gc():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        tree = {"a": jnp.arange(6.0).reshape(2, 3),
                "b": {"c": jnp.asarray([1, 2, 3], jnp.int32)}}
        for step in (10, 20, 30):
            mgr.save(step, tree, metadata={"step": step})
        assert mgr.latest_step() == 30
        # keep=2 → step 10 garbage-collected
        assert not os.path.exists(os.path.join(d, "step_000000000010"))
        out = mgr.restore(30, jax.eval_shape(lambda: tree), verify=True)
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(tree["a"]))
        assert mgr.metadata(30)["step"] == 30


def test_checkpoint_ignores_incomplete(tmp_path):
    d = str(tmp_path)
    mgr = CheckpointManager(d)
    mgr.save(5, {"x": jnp.ones(3)})
    # simulate a crashed writer: tmp dir + a step dir without manifest
    os.makedirs(os.path.join(d, "tmp_000000000009_123"))
    os.makedirs(os.path.join(d, "step_000000000009"))
    assert mgr.latest_step() == 5
    # a new manager GC's the stale tmp dir
    CheckpointManager(d)
    assert not any(n.startswith("tmp_") for n in os.listdir(d))


def test_checkpoint_async_writer(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=True)
    mgr.save(1, {"x": jnp.ones(128)}, block=False)
    mgr.save(2, {"x": jnp.ones(128) * 2}, block=False)
    mgr.wait()
    assert mgr.latest_step() == 2


def test_checkpoint_detects_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"x": jnp.arange(16.0)}
    mgr.save(1, tree)
    leaf = os.path.join(str(tmp_path), "step_000000000001", "leaf_00000.npy")
    arr = np.load(leaf)
    arr[0] = 999.0
    np.save(leaf, arr)
    with pytest.raises(AssertionError, match="corrupt"):
        mgr.restore(1, jax.eval_shape(lambda: tree), verify=True)


def test_resume_mid_run(tmp_path):
    """Kill-and-restart: a second run resumes from the checkpoint and ends
    at the same params as an uninterrupted run (deterministic batches)."""
    opt = AdamW(lr=0.05, weight_decay=0.0, warmup_steps=1)
    params0 = {"w": jnp.asarray([4.0])}

    def loss_fn(p, b):
        return jnp.sum((p["w"] - b["t"]) ** 2)

    def batch_fn(s):
        return {"t": jnp.asarray([1.0 + 0.01 * (s % 3)])}

    # uninterrupted
    ref = run_training(loss_fn=loss_fn, params=params0, opt=opt,
                       batch_fn=batch_fn, steps=60, log_every=1000)
    # interrupted at 30 then resumed
    d = str(tmp_path)
    run_training(loss_fn=loss_fn, params=params0, opt=opt,
                 batch_fn=batch_fn, steps=30, ckpt=CheckpointManager(d),
                 ckpt_every=30, log_every=1000)
    resumed = run_training(loss_fn=loss_fn, params=params0, opt=opt,
                           batch_fn=batch_fn, steps=60,
                           ckpt=CheckpointManager(d), ckpt_every=30,
                           log_every=1000)
    np.testing.assert_allclose(np.asarray(resumed.params["w"]),
                               np.asarray(ref.params["w"]), rtol=1e-5)


@pytest.mark.subprocess
def test_elastic_restore_resharding():
    """Checkpoint written single-device restores onto an 8-device mesh."""
    code = """
import tempfile, numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.training import CheckpointManager
d = tempfile.mkdtemp()
mgr = CheckpointManager(d)
tree = {"w": jnp.arange(64.0).reshape(8, 8)}
mgr.save(1, tree)
mesh = jax.make_mesh((8,), ("x",), axis_types=(jax.sharding.AxisType.Auto,))
sh = {"w": NamedSharding(mesh, P("x", None))}
out = mgr.restore(1, jax.eval_shape(lambda: tree), shardings=sh)
assert out["w"].sharding == sh["w"]
assert np.allclose(np.asarray(out["w"]), np.arange(64.0).reshape(8, 8))
print("ELASTIC_OK")
"""
    r = run_subprocess(code, devices=8)
    assert "ELASTIC_OK" in r.stdout, r.stderr[-2000:]


@given(st.floats(min_value=1e-6, max_value=1e3))
@settings(max_examples=20, deadline=None)
def test_int8_compression_bounded_error(scale):
    g = jnp.asarray(np.random.default_rng(42).normal(size=256) * scale,
                    jnp.float32)
    q, s, err = compress_int8(g, jnp.zeros_like(g))
    deq = q.astype(jnp.float32) * s
    # per-step quantization error ≤ half a quantization bin
    assert float(jnp.abs(deq - g).max()) <= float(s) * 0.5 + 1e-9
    # error feedback carries the residual exactly
    np.testing.assert_allclose(np.asarray(err), np.asarray(g - deq),
                               rtol=1e-5, atol=1e-8)


def test_int8_error_feedback_unbiased_over_time():
    g = jnp.asarray(np.random.default_rng(1).normal(size=512) * 1e-4)
    err = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    for _ in range(100):
        q, s, err = compress_int8(g, err)
        acc = acc + q.astype(jnp.float32) * s
    rel = float(jnp.abs(acc - 100 * g).max() / jnp.abs(100 * g).max())
    assert rel < 1e-3


@pytest.mark.subprocess
def test_data_parallel_train_step_multidevice():
    """pjit train step on an 8-device mesh: loss matches single-device."""
    code = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.training import AdamW
from repro.models import LMConfig, lm_init, lm_loss
cfg = LMConfig(vocab=64, d_model=32, n_layers=2, n_heads=4, n_kv=4,
               head_dim=8, d_ff=64, q_chunk=16, kv_chunk=16)
params = lm_init(jax.random.key(0), cfg)
toks = jax.random.randint(jax.random.key(1), (8, 32), 0, 64)
ref = float(lm_loss(params, toks, toks, cfg))
mesh = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
bs = NamedSharding(mesh, P("data", None))
ps = jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), params)
f = jax.jit(lambda p, t: lm_loss(p, t, t, cfg),
            in_shardings=(ps, bs))
with mesh:
    out = float(f(params, jax.device_put(toks, bs)))
assert abs(out - ref) < 1e-3, (out, ref)
print("DP_OK")
"""
    r = run_subprocess(code, devices=8)
    assert "DP_OK" in r.stdout, r.stderr[-2000:]


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)

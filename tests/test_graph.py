"""Graph substrate: CSR, generators, samplers, segment ops (+ property
tests via hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
pytestmark = pytest.mark.hypothesis
from hypothesis import given, settings, strategies as st

from repro.graph import (CSRGraph, fixed_size_unique, grid_mesh_graph,
                         host_sample, host_sample_dense, molecule_batch,
                         power_law_graph, sample_khop, scatter_spmm,
                         segment_mean, segment_softmax, segment_sum)


@pytest.fixture(scope="module")
def graph():
    g = power_law_graph(400, 6.0, seed=1)
    g.validate()
    return g


def test_csr_roundtrip(graph):
    src, dst = graph.to_coo()
    g2 = CSRGraph.from_edge_index(src, dst, graph.num_nodes)
    assert np.array_equal(g2.indptr, graph.indptr)
    # indices within each row may be permuted but sets match
    for i in range(graph.num_nodes):
        a = np.sort(graph.indices[graph.indptr[i]:graph.indptr[i + 1]])
        b = np.sort(g2.indices[g2.indptr[i]:g2.indptr[i + 1]])
        assert np.array_equal(a, b)


def test_reverse_degree(graph):
    rev = graph.reverse()
    assert rev.num_edges == graph.num_edges
    src, dst = graph.to_coo()
    assert np.array_equal(rev.out_degree,
                          np.bincount(dst, minlength=graph.num_nodes))


def test_generators_shapes():
    gm = grid_mesh_graph(5, 7)
    assert gm.num_nodes == 35
    assert gm.num_edges == 2 * ((5 - 1) * 7 + 5 * (7 - 1))
    g, pos, mol = molecule_batch(3, 8, seed=0)
    assert g.num_nodes == 24 and pos.shape == (24, 3)
    # block-diagonal: no cross-molecule edges
    src, dst = g.to_coo()
    assert np.array_equal(mol[src], mol[dst])


def test_device_sampler_valid_edges(graph):
    gd = graph.device_arrays()
    seeds = jnp.arange(32, dtype=jnp.int32)
    s = sample_khop(jax.random.key(0), gd, seeds, (5, 3))
    hops = [np.asarray(h) for h in s.hops]
    indptr, indices = graph.indptr, graph.indices
    for k in range(1, len(hops)):
        fan = s.fanouts[k - 1]
        parents = hops[k - 1]
        for i, v in enumerate(parents):
            for j in range(fan):
                u = hops[k][i * fan + j]
                if u < 0:
                    continue
                assert v >= 0
                assert u in indices[indptr[v]:indptr[v + 1]]


def test_device_sampler_respects_fanout_bound(graph):
    gd = graph.device_arrays()
    seeds = jnp.arange(16, dtype=jnp.int32)
    s = sample_khop(jax.random.key(1), gd, seeds, (4,))
    nbrs = np.asarray(s.hops[1]).reshape(16, 4)
    deg = graph.out_degree[:16]
    valid_counts = (nbrs >= 0).sum(1)
    assert np.all(valid_counts == np.minimum(deg, 4))


def test_host_samplers_agree_on_sizes(graph):
    rng = np.random.default_rng(0)
    seeds = np.arange(8)
    ragged = host_sample(rng, graph, seeds, (4, 3))
    dense = host_sample_dense(np.random.default_rng(0), graph,
                              seeds.astype(np.int32), (4, 3))
    # same realized count per hop (exactness of both)
    for r, d in zip(ragged, dense):
        assert (np.asarray(d) >= 0).sum() == r.size


@given(st.lists(st.integers(min_value=-1, max_value=30), min_size=1,
                max_size=64))
@settings(max_examples=30, deadline=None)
def test_fixed_size_unique_property(ids):
    ids = jnp.asarray(np.asarray(ids, np.int32))
    uniq, inv = fixed_size_unique(ids, int(ids.shape[0]))
    uniq_np = np.asarray(uniq)
    valid = uniq_np[uniq_np >= 0]
    expected = np.unique(np.asarray(ids)[np.asarray(ids) >= 0])
    assert np.array_equal(np.sort(valid), expected)
    restored = np.asarray(uniq)[np.asarray(inv)]
    mask = np.asarray(ids) >= 0
    assert np.array_equal(restored[mask], np.asarray(ids)[mask])


@given(st.integers(min_value=2, max_value=40),
       st.integers(min_value=1, max_value=200))
@settings(max_examples=20, deadline=None)
def test_segment_sum_matches_dense(n_seg, n_items):
    rng = np.random.default_rng(n_seg * 1000 + n_items)
    seg = rng.integers(0, n_seg, n_items)
    data = rng.normal(size=(n_items, 3)).astype(np.float32)
    out = np.asarray(segment_sum(jnp.asarray(data), jnp.asarray(seg), n_seg))
    dense = np.zeros((n_seg, 3), np.float32)
    np.add.at(dense, seg, data)
    np.testing.assert_allclose(out, dense, rtol=1e-5, atol=1e-5)


def test_segment_softmax_normalizes(graph):
    src, dst = graph.to_coo()
    scores = jnp.asarray(np.random.default_rng(0).normal(size=src.shape[0]),
                         jnp.float32)
    sm = segment_softmax(scores, jnp.asarray(dst), graph.num_nodes)
    sums = np.asarray(segment_sum(sm, jnp.asarray(dst), graph.num_nodes))
    has_edge = np.bincount(dst, minlength=graph.num_nodes) > 0
    np.testing.assert_allclose(sums[has_edge], 1.0, atol=1e-5)


def test_scatter_spmm_masks_invalid(graph):
    src, dst = graph.to_coo()
    src = src.astype(np.int64)
    src[::5] = -1
    feat = jnp.ones((graph.num_nodes, 2))
    out = scatter_spmm(feat, jnp.asarray(src), jnp.asarray(dst),
                       graph.num_nodes)
    expected = np.zeros(graph.num_nodes)
    valid = src >= 0
    np.add.at(expected, dst[valid], 1.0)
    np.testing.assert_allclose(np.asarray(out[:, 0]), expected, rtol=1e-6)

"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(only launch/dryrun.py forces 512 host devices, in its own process).
Multi-device tests spawn subprocesses with their own XLA_FLAGS."""
import os
import subprocess
import sys

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_subprocess(code: str, *, devices: int = 8, timeout: int = 300
                   ) -> subprocess.CompletedProcess:
    """Run a python snippet in a fresh process with N fake devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)

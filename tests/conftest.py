"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(only launch/dryrun.py forces 512 host devices, in its own process).
Multi-device tests spawn subprocesses with their own XLA_FLAGS."""
import os
import subprocess
import sys

import numpy as np
import pytest


def pytest_collection_modifyitems(items):
    """Subprocess tests pay a fresh interpreter + jax init each — they are
    the slow tail of the suite, so they ride in the CI `slow` job too."""
    for item in items:
        if "subprocess" in item.keywords:
            item.add_marker(pytest.mark.slow)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_subprocess(code: str, *, devices: int = 8, timeout: int = 300
                   ) -> subprocess.CompletedProcess:
    """Run a python snippet in a fresh process with N fake devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)

"""Fused feature-collection path (PR 3): lookup_hops bit-identical to the
per-hop path (incl. under concurrent live migration), the Pallas
tiered_gather dispatch it rides on, executor-level fused/legacy output
equivalence, the MicroBatcher coalescing stage, and dispatch accounting."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DynamicBatcher, MicroBatcher, Request,
                        TieredFeatureStore, TopologySpec, compute_fap,
                        compute_psgs, migration_pairs, quiver_placement)
from repro.graph import power_law_graph
from repro.kernels.tiered_gather.ops import tiered_gather
from repro.kernels.tiered_gather.ref import tiered_gather_ref
from repro.models.gnn_basic import sage_init, sage_layered
from repro.serving import (DeviceExecutor, HostExecutor, ServingEngine,
                           StaticScheduler)


# ---------------------------------------------------------------------------
# Fixtures
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def stack():
    n, d, fan = 900, 12, (4, 3)
    g = power_law_graph(n, 6.0, seed=0)
    feats = np.random.default_rng(1).normal(size=(n, d)).astype(np.float32)
    fap = compute_fap(g, fan)
    topo = TopologySpec(num_pods=1, devices_per_pod=1, rows_per_device=220,
                        rows_host=330, hot_replicate_fraction=0.3)
    return g, fan, feats, fap, topo


def _fresh_store(stack):
    g, fan, feats, fap, topo = stack
    return TieredFeatureStore.build(feats, quiver_placement(fap, topo))


def _rand_hops(n, sizes, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(-1, n, size=s).astype(np.int32) for s in sizes]


# ---------------------------------------------------------------------------
# lookup_hops: bit-identical to the per-hop path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("sizes", [(16,), (16, 64), (16, 64, 192), (1, 1)])
def test_lookup_hops_bit_identical(stack, sizes):
    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack)
    hops = _rand_hops(g.num_nodes, sizes, seed=sum(sizes))
    per_hop = [np.asarray(store.lookup(jnp.asarray(h))) for h in hops]
    fused = store.lookup_hops(hops)
    assert len(fused) == len(hops)
    for a, b in zip(per_hop, fused):
        assert np.array_equal(a, np.asarray(b))  # bit-identical, not close


def test_lookup_hops_pallas_interpret_bit_identical(stack):
    """The fused path with the Pallas kernel forced on (interpret mode off
    TPU) must still match the per-hop path bit for bit."""
    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack)
    hops = _rand_hops(g.num_nodes, (16, 48), seed=9)
    per_hop = [np.asarray(store.lookup(jnp.asarray(h))) for h in hops]
    fused = store.lookup_hops(hops, use_pallas=True)
    for a, b in zip(per_hop, fused):
        assert np.array_equal(a, np.asarray(b))


def test_lookup_hops_all_padding_and_exclude_host(stack):
    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack)
    hops = [np.full(8, -1, np.int32), np.full(24, -1, np.int32)]
    for out in store.lookup_hops(hops):
        assert not np.asarray(out).any()            # padding rows are zeros
    with pytest.raises(ValueError, match="non-empty"):
        store.lookup_hops([])
    # include_host=False zeroes the slow tiers in both paths identically
    ids = _rand_hops(g.num_nodes, (64,), seed=3)[0]
    a = np.asarray(store.lookup(jnp.asarray(ids), include_host=False))
    [b] = store.lookup_hops([ids], include_host=False)
    assert np.array_equal(a, np.asarray(b))


def test_lookup_hops_bit_identical_under_concurrent_migration(stack):
    """Reuse of the snapshot-consistency harness (tests/test_adaptive.py):
    a reader doing *fused* lookups while the main thread migrates rows must
    only ever see exact features — the fused path takes ONE snapshot for
    the entire multi-hop gather."""
    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack)
    rng = np.random.default_rng(7)
    hops = [rng.integers(0, g.num_nodes, 16).astype(np.int32),
            rng.integers(0, g.num_nodes, 48).astype(np.int32)]
    expected = [feats[h] for h in hops]
    stop = threading.Event()
    errors: list[str] = []

    def reader():
        while not stop.is_set():
            got = store.lookup_hops(hops)
            for e, o in zip(expected, got):
                if not np.allclose(np.asarray(o), e, rtol=1e-5):
                    errors.append("torn fused lookup during migration")
                    return

    t = threading.Thread(target=reader)
    t.start()
    try:
        drifted = fap.copy()
        drifted[np.argsort(fap)[:80]] += fap.max() * 3
        tgt = quiver_placement(drifted, topo)
        for _ in range(10):
            pairs = migration_pairs(store.plan.tier, tgt.tier, drifted,
                                    budget=20)
            if not pairs:
                break
            store.swap_assignments(pairs)
    finally:
        stop.set()
        t.join(timeout=30)
    assert not errors
    for e, o in zip(expected, store.lookup_hops(hops)):
        np.testing.assert_allclose(np.asarray(o), e, rtol=1e-6)


def test_dispatch_stats_reduction(stack):
    """The structural claim: per-hop pays 2 gathers + 1 host fetch per hop,
    fused pays 1 + 1 for the whole sample."""
    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack)
    hops = _rand_hops(g.num_nodes, (16, 64, 192), seed=2)
    store.reset_stats()
    [store.lookup(jnp.asarray(h)) for h in hops]
    old = store.reset_stats()
    assert old["device_gathers"] == 2 * len(hops)
    assert old["host_fetches"] == len(hops)
    store.lookup_hops(hops)
    new = store.reset_stats()
    assert new["device_gathers"] == 1 and new["host_fetches"] == 1
    assert new["fused_calls"] == 1 and new["lookup_calls"] == 0


# ---------------------------------------------------------------------------
# tiered_gather dispatch entry (ops): Pallas-interpret vs ref on CPU
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,h,w,d", [(33, 16, 40, 32), (128, 8, 8, 16)])
def test_tiered_gather_ops_pallas_vs_ref_cpu(m, h, w, d):
    rng = np.random.default_rng(m)
    hot = jnp.asarray(rng.normal(size=(h, d)), jnp.float32)
    warm = jnp.asarray(rng.normal(size=(w, d)), jnp.float32)
    tier = rng.integers(0, 4, size=m).astype(np.int32)
    slot = np.where(tier == 0, rng.integers(0, h, m),
                    rng.integers(0, w, m)).astype(np.int32)
    via_pallas = tiered_gather(jnp.asarray(tier), jnp.asarray(slot), hot,
                               warm, use_pallas=True)   # interpret off-TPU
    via_ref = tiered_gather_ref(jnp.asarray(tier), jnp.asarray(slot), hot,
                                warm)
    assert np.array_equal(np.asarray(via_pallas), np.asarray(via_ref))


# ---------------------------------------------------------------------------
# Executor-level: fused vs legacy output equivalence
# ---------------------------------------------------------------------------
def _infer(stack):
    g, fan, feats, fap, topo = stack
    params = sage_init(jax.random.key(0), [feats.shape[1], 16, 16])

    @jax.jit
    def infer_fn(hop_feats, hop_ids):
        masks = [(h >= 0).astype(jnp.float32)[:, None] for h in hop_ids]
        return sage_layered(params, hop_feats, fan, hop_masks=masks)

    return infer_fn


def test_host_executor_fused_matches_legacy(stack):
    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack)
    infer_fn = _infer(stack)
    seeds = np.arange(12)
    outs = {}
    for fused in (False, True):
        ex = HostExecutor(g, store, fan, infer_fn, rng_seed=5, fused=fused)
        outs[fused] = np.asarray(ex.run(seeds))
        ex.close()
    assert np.array_equal(outs[False], outs[True])  # same rng → same sample


def test_device_executor_fused_matches_legacy(stack):
    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack)
    infer_fn = _infer(stack)
    seeds = np.arange(10)
    outs = {}
    for fused in (False, True):
        ex = DeviceExecutor(g.device_arrays(), store, fan, infer_fn,
                            max_batch=16, rng_seed=5, fused=fused)
        outs[fused] = np.asarray(ex.run(seeds))
        ex.close()
    assert np.array_equal(outs[False], outs[True])


# ---------------------------------------------------------------------------
# MicroBatcher: coalescing / deadline / budget unit tests
# ---------------------------------------------------------------------------
def _req(i, n_seeds=4):
    return Request(i, np.arange(n_seeds, dtype=np.int64),
                   time.perf_counter())


def test_micro_batcher_coalesces_until_max_seeds():
    mb = MicroBatcher(deadline_s=60.0, max_seeds=12)
    assert mb.add([_req(0)]) is None          # 4 seeds queued
    assert mb.add([_req(1)]) is None          # 8
    out = mb.add([_req(2)])                   # 12 → closes
    assert out is not None and len(out) == 3
    assert mb.emitted == 1 and mb.coalesced == 1
    assert mb.flush() is None                 # state fully reset


def test_micro_batcher_deadline_closes():
    from repro.testing import FakeClock
    clk = FakeClock()
    mb = MicroBatcher(deadline_s=0.01, max_seeds=10**6, clock=clk)
    assert mb.add([_req(0)]) is None
    clk.advance(0.02)
    out = mb.add([_req(1)])                   # deadline hit at add time
    assert out is not None and len(out) == 2


def test_micro_batcher_psgs_budget_closes():
    table = np.full(8, 5.0)
    mb = MicroBatcher(deadline_s=60.0, max_seeds=10**6, psgs_budget=30.0,
                      psgs_table=table)
    assert mb.add([_req(0)]) is None          # 20 PSGS
    out = mb.add([_req(1)])                   # 40 ≥ 30 → closes
    assert out is not None and len(out) == 2


def test_micro_batcher_single_batch_not_counted_coalesced():
    mb = MicroBatcher(deadline_s=60.0, max_seeds=4)
    out = mb.add([_req(0)])                   # closes immediately, 1 source
    assert out is not None
    assert mb.emitted == 1 and mb.coalesced == 0


@pytest.mark.subprocess
def test_sharded_lookup_hops_matches_per_hop():
    """ShardedFeatureStore.lookup_hops (one shard_map exchange for the whole
    sample) must return the same rows as per-hop lookups, regardless of how
    concatenation re-partitions ids over the mesh."""
    from conftest import run_subprocess
    code = """
import numpy as np, jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core import (ShardedFeatureStore, TieredFeatureStore,
                        TopologySpec, compute_fap, quiver_placement)
from repro.graph import power_law_graph
n, d, fan = 640, 8, (3, 2)
g = power_law_graph(n, 5.0, seed=0)
feats = np.random.default_rng(1).normal(size=(n, d)).astype(np.float32)
topo = TopologySpec(num_pods=2, devices_per_pod=4, rows_per_device=64,
                    rows_host=128, hot_replicate_fraction=0.2)
store = TieredFeatureStore.build(feats, quiver_placement(
    compute_fap(g, fan), topo))
mesh = make_mesh((8,), ("x",))
sstore = ShardedFeatureStore.from_tiered(store, mesh, "x")
rng = np.random.default_rng(3)
hops = [jnp.asarray(rng.integers(-1, n, size=s).astype(np.int32))
        for s in (16, 48, 96)]
per_hop = [np.asarray(sstore.lookup(h)) for h in hops]
fused = sstore.lookup_hops(hops)
for a, b in zip(per_hop, fused):
    assert np.array_equal(a, np.asarray(b))
print("SHARDED_FUSED_OK")
"""
    r = run_subprocess(code, devices=8)
    assert "SHARDED_FUSED_OK" in r.stdout, r.stderr


def test_serve_stream_with_micro_batcher(stack):
    """End-to-end: the coalescing stage feeds fewer, larger batches into the
    engine and every request still completes exactly once."""
    g, fan, feats, fap, topo = stack
    store = _fresh_store(stack)
    infer_fn = _infer(stack)
    psgs = compute_psgs(g, fan)
    host = HostExecutor(g, store, fan, infer_fn, psgs_table=psgs)
    engine = ServingEngine({"host": host}, StaticScheduler("host"))
    reqs = [Request(i, np.arange(4, dtype=np.int64), 0.0) for i in range(9)]
    micro = MicroBatcher(deadline_s=60.0, max_seeds=12)
    m = engine.serve_stream(reqs, DynamicBatcher(deadline_s=0.0, max_batch=1),
                            micro=micro)
    assert m.requests == 9
    assert micro.emitted == 3                  # 9 requests → 3 super-batches
    assert micro.coalesced == 3
    assert sum(m.routed.values()) == 3         # engine saw super-batches
    engine.close()

"""AdamW + gradient clipping + (optional) int8 error-feedback gradient
compression for the data-parallel all-reduce. Self-contained (no optax).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100

    def init(self, params) -> AdamWState:
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                          nu=jax.tree_util.tree_map(jnp.copy, zeros))

    def schedule(self, step: jnp.ndarray) -> jnp.ndarray:
        warm = jnp.minimum(step.astype(jnp.float32)
                           / max(self.warmup_steps, 1), 1.0)
        return self.lr * warm

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        if self.clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
        b1, b2 = self.b1, self.b2
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2)
            * jnp.square(g.astype(jnp.float32)), state.nu, grads)
        t = step.astype(jnp.float32)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t
        lr = self.schedule(step)

        def upd(p, m, v):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, mu, nu)
        return new_params, AdamWState(step=step, mu=mu, nu=nu)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


# ---------------------------------------------------------------------------
# Int8 error-feedback gradient compression (distributed-optimization trick):
# quantize per-tensor before the DP all-reduce, accumulate the quantization
# residual locally and re-inject next step — convergence-neutral in practice,
# cuts DP collective bytes 4×. Validated against fp32 in tests.
# ---------------------------------------------------------------------------
def compress_int8(g: jnp.ndarray, err: jnp.ndarray
                  ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (q int8, scale f32 scalar, new_err)."""
    gc = g.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(gc)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gc / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, gc - deq


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_grad_tree(grads, err_tree):
    """Tree-wise compress; returns (quantized tree, scales, new err tree).
    The quantized tree is what crosses the DP axis (psum of int8 requires
    widening — we psum the dequantized value but *communicate* int8 by
    constraining the all-reduce input dtype; on TPU this is a bf16/int8
    reduce-scatter + all-gather pair in the perf iteration)."""
    qs, scales, errs = {}, {}, {}
    flat, treedef = jax.tree_util.tree_flatten_with_path(grads)
    err_flat = dict(jax.tree_util.tree_flatten_with_path(err_tree)[0])
    out_q, out_s, out_e = [], [], []
    for path, g in flat:
        e = err_flat[path]
        q, s, ne = compress_int8(g, e)
        out_q.append(q)
        out_s.append(s)
        out_e.append(ne)
    unflatten = lambda leaves: jax.tree_util.tree_unflatten(treedef, leaves)
    return unflatten(out_q), unflatten(out_s), unflatten(out_e)


def decompress_grad_tree(q_tree, s_tree):
    return jax.tree_util.tree_map(decompress_int8, q_tree, s_tree)

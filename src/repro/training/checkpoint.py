"""Fault-tolerant checkpointing: atomic (tmp+rename), async writer thread,
keep-K garbage collection, manifest with integrity hashes, and **elastic
restore** (a checkpoint written under one mesh restores under any other —
arrays are saved unsharded per-leaf and re-placed with the new sharding).

Restart semantics: `latest_step()` scans for the newest *complete* checkpoint
(incomplete tmp dirs from a crashed writer are ignored and GC'd), so a
preempted pod resumes from the last durable step — the checkpoint/restart
half of the fault-tolerance story (the serving half is the shared-queue
pipeline; see core/pipeline.py).
"""
from __future__ import annotations

import hashlib
import json
import os
import queue
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 async_write: bool = False):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._gc_incomplete()
        self.async_write = async_write
        self._queue: "queue.Queue" = queue.Queue()
        self._worker: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        if async_write:
            self._worker = threading.Thread(target=self._writer_loop,
                                            daemon=True)
            self._worker.start()

    # ---- paths ----------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:012d}")

    def _gc_incomplete(self) -> None:
        for name in os.listdir(self.dir):
            if name.startswith("tmp_"):
                shutil.rmtree(os.path.join(self.dir, name),
                              ignore_errors=True)

    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                manifest = os.path.join(self.dir, name, "MANIFEST.json")
                if os.path.exists(manifest):
                    steps.append(int(name.split("_")[1]))
        return max(steps) if steps else None

    # ---- save -----------------------------------------------------------
    def save(self, step: int, tree, *, metadata: Optional[dict] = None,
             block: bool = True) -> None:
        # Gather to host *now* (cheap on CPU; on TPU this is device→host DMA)
        host_leaves = [(name, np.asarray(leaf))
                       for name, leaf in _flatten(tree)]
        if self.async_write and not block:
            self._queue.put((step, host_leaves, metadata))
            return
        self._write(step, host_leaves, metadata)

    def _writer_loop(self) -> None:
        while True:
            item = self._queue.get()
            if item is None:
                return
            try:
                self._write(*item)
            except BaseException as e:  # surfaced on next wait()
                self._error = e
            finally:
                self._queue.task_done()

    def wait(self) -> None:
        if self.async_write:
            self._queue.join()
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write(self, step: int, host_leaves, metadata) -> None:
        tmp = os.path.join(self.dir, f"tmp_{step:012d}_{os.getpid()}")
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "time": time.time(),
                    "metadata": metadata or {}, "leaves": []}
        for i, (name, arr) in enumerate(host_leaves):
            fn = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"].append({
                "name": name, "file": fn, "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "sha1": hashlib.sha1(arr.tobytes()).hexdigest(),
            })
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
        final = self._step_dir(step)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc_old()

    def _gc_old(self) -> None:
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.dir)
            if n.startswith("step_")
            and os.path.exists(os.path.join(self.dir, n, "MANIFEST.json")))
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ---- restore ----------------------------------------------------------
    def restore(self, step: int, template, *, shardings=None,
                verify: bool = False):
        """Restore into the structure of ``template``. ``shardings``: optional
        pytree (same structure) of jax.sharding.Sharding — this is the
        elastic path: any mesh works because leaves are stored whole."""
        d = self._step_dir(step)
        with open(os.path.join(d, "MANIFEST.json")) as f:
            manifest = json.load(f)
        by_name = {l["name"]: l for l in manifest["leaves"]}
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                      if shardings is not None else [None] * len(flat))
        out = []
        for (path, leaf), sh in zip(flat, shard_flat):
            name = jax.tree_util.keystr(path)
            rec = by_name[name]
            arr = np.load(os.path.join(d, rec["file"]))
            if verify:
                assert hashlib.sha1(arr.tobytes()).hexdigest() == rec["sha1"], \
                    f"corrupt leaf {name}"
            assert list(arr.shape) == list(leaf.shape), (name, arr.shape,
                                                         leaf.shape)
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef, out)

    def metadata(self, step: int) -> dict:
        with open(os.path.join(self._step_dir(step), "MANIFEST.json")) as f:
            return json.load(f)["metadata"]

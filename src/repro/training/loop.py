"""Generic fault-tolerant training loop used by the example drivers.

* deterministic per-step data keys (restart-safe: step n always sees batch n)
* periodic async checkpointing + resume from the latest durable step
* optional int8 error-feedback gradient compression across the DP axis
* throughput/loss logging
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.training.checkpoint import CheckpointManager
from repro.training.optimizer import AdamW, AdamWState


@dataclasses.dataclass
class TrainState:
    params: object
    opt_state: AdamWState
    step: int


def make_train_step(loss_fn: Callable, opt: AdamW, *,
                    donate: bool = True) -> Callable:
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    return jax.jit(step, donate_argnums=(0, 1) if donate else ())


def run_training(*, loss_fn: Callable, params, opt: AdamW,
                 batch_fn: Callable[[int], dict], steps: int,
                 ckpt: Optional[CheckpointManager] = None,
                 ckpt_every: int = 50, log_every: int = 10,
                 log_fn: Callable[[str], None] = print) -> TrainState:
    # donated buffers must be owned by this loop — never consume the caller's
    params = jax.tree_util.tree_map(jnp.copy, params)
    opt_state = opt.init(params)
    start = 0
    if ckpt is not None:
        latest = ckpt.latest_step()
        if latest is not None:
            state = ckpt.restore(latest, {"params": params,
                                          "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start = latest
            log_fn(f"[resume] restored step {latest}")
    step_fn = make_train_step(loss_fn, opt)
    t0 = time.perf_counter()
    losses = []
    for s in range(start, steps):
        batch = batch_fn(s)  # deterministic per-step → restart-safe
        params, opt_state, loss = step_fn(params, opt_state, batch)
        losses.append(loss)
        if (s + 1) % log_every == 0:
            l = float(jnp.mean(jnp.stack([jnp.asarray(x) for x in losses])))
            dt = time.perf_counter() - t0
            log_fn(f"step {s+1}/{steps} loss={l:.4f} "
                   f"steps/s={log_every/dt:.2f}")
            losses, t0 = [], time.perf_counter()
        if ckpt is not None and (s + 1) % ckpt_every == 0:
            ckpt.save(s + 1, {"params": params, "opt": opt_state},
                      metadata={"loss": float(loss)}, block=False)
    if ckpt is not None:
        ckpt.save(steps, {"params": params, "opt": opt_state}, block=True)
        ckpt.wait()
    return TrainState(params=params, opt_state=opt_state, step=steps)

from repro.training.checkpoint import CheckpointManager
from repro.training.loop import TrainState, make_train_step, run_training
from repro.training.optimizer import (AdamW, AdamWState, compress_int8,
                                      compressed_grad_tree,
                                      decompress_grad_tree, decompress_int8,
                                      global_norm)

__all__ = ["AdamW", "AdamWState", "global_norm", "compress_int8",
           "decompress_int8", "compressed_grad_tree", "decompress_grad_tree",
           "CheckpointManager", "TrainState", "make_train_step",
           "run_training"]

"""Logical→physical sharding rules.

Model code annotates activations with *logical* axis names ("batch", "tp",
"expert", ...); each arch config binds those names to mesh axes for a given
mesh, producing (a) a ``shard`` callable (with_sharding_constraint) threaded
through the model and (b) PartitionSpec trees for params / inputs / outputs.
Binding is divisibility-aware: a logical axis whose dimension does not divide
the mesh axis is left unsharded (GSPMD would pad; we prefer explicit specs).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["Rules", "make_shard_fn", "named", "spec", "tree_shardings",
           "mesh_axis_size"]


@dataclasses.dataclass(frozen=True)
class Rules:
    """Map logical names → mesh axis (or tuple of axes) or None."""

    table: dict

    def axis(self, name: Optional[str]):
        if name is None:
            return None
        return self.table.get(name)

    def spec(self, *names) -> P:
        return P(*[self.axis(n) for n in names])


def mesh_axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def make_shard_fn(mesh: Optional[Mesh], rules: Rules):
    """Returns shard(x, *logical_names) usable inside jit. mesh=None → noop
    (single-device smoke tests)."""
    if mesh is None:
        return lambda x, *names: x

    def shard(x, *names):
        assert len(names) == x.ndim, (names, x.shape)
        resolved = []
        for dim, n in zip(x.shape, names):
            ax = rules.axis(n)
            if ax is not None and dim % mesh_axis_size(mesh, ax) != 0:
                ax = None  # divisibility-aware fallback
            resolved.append(ax)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*resolved)))

    return shard


def named(mesh: Optional[Mesh], s: P):
    return NamedSharding(mesh, s) if mesh is not None else None


def spec(mesh: Optional[Mesh], rules: Rules, dims, *names) -> P:
    """Divisibility-aware PartitionSpec for an array of shape ``dims``."""
    out = []
    for d, n in zip(dims, names):
        ax = rules.axis(n)
        if mesh is not None and ax is not None \
                and d % mesh_axis_size(mesh, ax) != 0:
            ax = None
        out.append(ax)
    return P(*out)


def tree_shardings(mesh: Optional[Mesh], spec_tree):
    """Map a pytree of PartitionSpec to NamedShardings (or None mesh→None)."""
    if mesh is None:
        return None
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P))

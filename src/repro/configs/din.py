"""din [recsys] embed_dim=18 seq_len=100 attn_mlp=80-40 mlp=200-80
interaction=target-attn [arXiv:1706.06978].

Shapes: train_batch (B=65,536 train step), serve_p99 (B=512 online forward),
serve_bulk (B=262,144 offline scoring), retrieval_cand (1 user × 1,000,000
candidates, scanned batched-dot — no loops).

The item table (10⁷ rows × 18) is the hot path; it is row-sharded over the
"model" axis (batch over pod/data) — the cross-shard gather is the roofline
collective. FAP-style placement of hot items is the paper's technique applied
to recsys (benchmarks/placement_compare.py exercises it on this table).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import Arch, CellSpec, register
from repro.models.din import (DINConfig, din_forward, din_init, din_loss,
                              din_score_candidates)
from repro.sharding import Rules, make_shard_fn, spec, tree_shardings
from repro.training.optimizer import AdamW

CONFIG = DINConfig(n_items=10_000_000, n_cates=10_000, embed_dim=18,
                   hist_len=100, attn_mlp=(80, 40), mlp=(200, 80),
                   n_dense_feat=8)

SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="retrieval", batch=1, candidates=1_000_000),
}


def din_rules(mesh) -> Rules:
    if mesh is None:
        return Rules({})
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return Rules({"batch": dp, "rows": "model", "cand": dp})


def _param_specs(cfg: DINConfig, mesh, rules):
    s = partial(spec, mesh, rules)
    rep = lambda tree: jax.tree_util.tree_map(lambda _: P(), tree)
    abstract = jax.eval_shape(lambda: din_init(jax.random.key(0), cfg))
    specs = rep(abstract)
    specs["item_embed"] = s((cfg.n_items, cfg.embed_dim), "rows", None)
    specs["cate_embed"] = s((cfg.n_cates, cfg.embed_dim), "rows", None)
    return abstract, specs


def _batch_abstract(cfg: DINConfig, b: int) -> dict:
    return {
        "target_item": jax.ShapeDtypeStruct((b,), jnp.int32),
        "target_cate": jax.ShapeDtypeStruct((b,), jnp.int32),
        "hist_items": jax.ShapeDtypeStruct((b, cfg.hist_len), jnp.int32),
        "hist_cates": jax.ShapeDtypeStruct((b, cfg.hist_len), jnp.int32),
        "dense_feat": jax.ShapeDtypeStruct((b, cfg.n_dense_feat),
                                           jnp.float32),
        "label": jax.ShapeDtypeStruct((b,), jnp.int32),
    }


def _batch_specs(cfg: DINConfig, b: int, mesh, rules) -> dict:
    s = partial(spec, mesh, rules)
    return {
        "target_item": s((b,), "batch"),
        "target_cate": s((b,), "batch"),
        "hist_items": s((b, cfg.hist_len), "batch", None),
        "hist_cates": s((b, cfg.hist_len), "batch", None),
        "dense_feat": s((b, cfg.n_dense_feat), "batch", None),
        "label": s((b,), "batch"),
    }


def build_din_cell(cfg: DINConfig, shape: str, mesh) -> CellSpec:
    info = SHAPES[shape]
    rules = din_rules(mesh)
    params_a, pspecs = _param_specs(cfg, mesh, rules)
    psh = tree_shardings(mesh, pspecs)

    if info["kind"] == "train":
        opt = AdamW(lr=1e-3, weight_decay=0.0)
        opt_a = jax.eval_shape(opt.init, params_a)
        ospecs = jax.tree_util.tree_map(lambda _: P(), opt_a)
        ospecs = ospecs._replace(
            mu=jax.tree_util.tree_map(lambda s: s, pspecs,
                                      is_leaf=lambda s: isinstance(s, P)),
            nu=jax.tree_util.tree_map(lambda s: s, pspecs,
                                      is_leaf=lambda s: isinstance(s, P)))
        osh = tree_shardings(mesh, ospecs)
        b = info["batch"]
        batch_a = _batch_abstract(cfg, b)
        bsh = tree_shardings(mesh, _batch_specs(cfg, b, mesh, rules))

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: din_loss(p, cfg, batch))(params)
            params, opt_state = opt.update(grads, opt_state, params)
            return params, opt_state, loss

        return CellSpec(step_fn=step, args=(params_a, opt_a, batch_a),
                        in_shardings=((psh, osh, bsh)
                                      if mesh is not None else None),
                        out_shardings=((psh, osh, tree_shardings(mesh, P()))
                                       if mesh is not None else None),
                        donate_argnums=(0, 1), kind="train")

    if info["kind"] == "serve":
        b = info["batch"]
        batch_a = _batch_abstract(cfg, b)
        batch_a.pop("label")
        bspecs = _batch_specs(cfg, b, mesh, rules)
        bspecs.pop("label")
        bsh = tree_shardings(mesh, bspecs)

        def step(params, batch):
            return din_forward(params, cfg, batch["target_item"],
                               batch["target_cate"], batch["hist_items"],
                               batch["hist_cates"], batch["dense_feat"])

        return CellSpec(step_fn=step, args=(params_a, batch_a),
                        in_shardings=((psh, bsh)
                                      if mesh is not None else None),
                        out_shardings=(tree_shardings(
                            mesh, spec(mesh, rules, (b,), "batch"))
                            if mesh is not None else None),
                        kind="serve")

    # retrieval: one user, 1M candidates
    n = info["candidates"]
    args_a = (params_a,
              jax.ShapeDtypeStruct((cfg.hist_len,), jnp.int32),
              jax.ShapeDtypeStruct((cfg.hist_len,), jnp.int32),
              jax.ShapeDtypeStruct((cfg.n_dense_feat,), jnp.float32),
              jax.ShapeDtypeStruct((n,), jnp.int32),
              jax.ShapeDtypeStruct((n,), jnp.int32))
    s = partial(spec, mesh, rules)
    in_sh = ((psh, tree_shardings(mesh, P()), tree_shardings(mesh, P()),
              tree_shardings(mesh, P()),
              tree_shardings(mesh, s((n,), "cand")),
              tree_shardings(mesh, s((n,), "cand")))
             if mesh is not None else None)

    def step(params, hi, hc, df, ci, cc):
        return din_score_candidates(params, cfg, hi, hc, df, ci, cc,
                                    chunk=31250)

    return CellSpec(step_fn=step, args=args_a, in_shardings=in_sh,
                    out_shardings=(tree_shardings(mesh, s((n,), "cand"))
                                   if mesh is not None else None),
                    kind="serve")


def din_smoke() -> dict:
    cfg = DINConfig(n_items=2000, n_cates=64, embed_dim=18, hist_len=20,
                    n_dense_feat=8)
    rng = np.random.default_rng(0)
    params = din_init(jax.random.key(0), cfg)
    b = 32
    batch = {
        "target_item": jnp.asarray(rng.integers(0, 2000, b), jnp.int32),
        "target_cate": jnp.asarray(rng.integers(0, 64, b), jnp.int32),
        "hist_items": jnp.asarray(rng.integers(-1, 2000, (b, 20)), jnp.int32),
        "hist_cates": jnp.asarray(rng.integers(0, 64, (b, 20)), jnp.int32),
        "dense_feat": jnp.asarray(rng.normal(size=(b, 8)), jnp.float32),
        "label": jnp.asarray(rng.integers(0, 2, b), jnp.int32),
    }
    opt = AdamW(lr=1e-3, weight_decay=0.0)
    opt_state = opt.init(params)
    loss, grads = jax.value_and_grad(
        lambda p: din_loss(p, cfg, batch))(params)
    params, opt_state = opt.update(grads, opt_state, params)
    scores = din_score_candidates(params, cfg, batch["hist_items"][0],
                                  batch["hist_cates"][0],
                                  batch["dense_feat"][0],
                                  jnp.asarray(rng.integers(0, 2000, 1000)),
                                  jnp.asarray(rng.integers(0, 64, 1000)),
                                  chunk=256)
    assert bool(jnp.isfinite(loss)) and bool(jnp.isfinite(scores).all())
    return {"loss": float(loss), "n_scores": int(scores.shape[0])}


ARCH = register(Arch(
    name="din", family="recsys", shape_names=tuple(SHAPES),
    build_cell=lambda shape, mesh: build_din_cell(CONFIG, shape, mesh),
    smoke=din_smoke,
    description="Deep Interest Network: target attention over user history, "
                "10M-row item table through the tiered store."))

"""codeqwen1.5-7b [dense] 32L d_model=4096 32H (GQA kv=32) d_ff=13440
vocab=92416 — qwen1.5 arch. [hf:Qwen/CodeQwen1.5-7B; hf]"""
from repro.configs.base import register
from repro.configs.lm_common import make_lm_arch
from repro.models.transformer import LMConfig

CONFIG = LMConfig(vocab=92416, d_model=4096, n_layers=32, n_heads=32,
                  n_kv=32, head_dim=128, d_ff=13440, qkv_bias=True,
                  qk_norm=False, rope_theta=1e6, dtype="bfloat16")

ARCH = register(make_lm_arch(
    "codeqwen1.5-7b", CONFIG,
    description="Dense decoder LM (qwen1.5 family), code vocab 92416."))

"""meshgraphnet [gnn] n_layers=15 d_hidden=128 aggregator=sum mlp_layers=2
[arXiv:2010.03409]. Edge features are built from relative positions
(Δpos ⊕ ‖Δpos‖), the standard MGN encoding."""
import jax.numpy as jnp

from repro.configs.base import register
from repro.configs.gnn_common import (GNNAdapter, classification_loss,
                                      make_gnn_arch, regression_loss)
from repro.graph.segment import segment_sum
from repro.models.meshgraphnet import mgn_forward, mgn_init

N_LAYERS, D_HIDDEN, MLP_LAYERS = 15, 128, 2


def _init(key, d_feat, n_out, shape):
    return mgn_init(key, d_node_in=d_feat, d_edge_in=4, d_hidden=D_HIDDEN,
                    n_layers=N_LAYERS, d_out=n_out, mlp_layers=MLP_LAYERS)


def _edge_feat(batch):
    s = jnp.maximum(batch["src"], 0)
    d = jnp.maximum(batch["dst"], 0)
    rel = batch["positions"][d] - batch["positions"][s]
    dist = jnp.sqrt((rel ** 2).sum(-1, keepdims=True) + 1e-12)
    return jnp.concatenate([rel, dist], axis=-1)


def _loss(params, batch, info, shape, shard=lambda x, *n: x):
    out = mgn_forward(params, batch["node_feat"], _edge_feat(batch),
                      batch["src"], batch["dst"], num_nodes=info["nodes"],
                      shard=shard)
    if info["graphs"] is not None:
        pooled = segment_sum(out, jnp.maximum(batch["mol_id"], 0),
                             info["graphs"])
        return regression_loss(pooled, batch["labels"])
    return classification_loss(out, batch["labels"])


ARCH = register(make_gnn_arch(GNNAdapter(
    name="meshgraphnet", init=_init, loss=_loss,
    description="Encode-process-decode mesh GNN, 15 blocks, 128 hidden.")))

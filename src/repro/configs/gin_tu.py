"""gin-tu [gnn] n_layers=5 d_hidden=64 aggregator=sum eps=learnable
[arXiv:1810.00826]."""
import jax.numpy as jnp

from repro.configs.base import register
from repro.configs.gnn_common import (GNNAdapter, classification_loss,
                                      make_gnn_arch, regression_loss)
from repro.models.gnn_basic import gin_full_graph, gin_graph_readout, gin_init

N_LAYERS, D_HIDDEN = 5, 64


def _init(key, d_feat, n_out, shape):
    return gin_init(key, d_feat, D_HIDDEN, N_LAYERS, n_out)


def _loss(params, batch, info, shape, shard=lambda x, *n: x):
    if info["graphs"] is not None:
        pred = gin_graph_readout(params, batch["node_feat"], batch["src"],
                                 batch["dst"], batch["mol_id"],
                                 num_nodes=info["nodes"],
                                 num_graphs=info["graphs"], shard=shard)
        return regression_loss(pred, batch["labels"])
    logits = gin_full_graph(params, batch["node_feat"], batch["src"],
                            batch["dst"], num_nodes=info["nodes"], shard=shard)
    return classification_loss(logits, batch["labels"])


def _loss_sharded(params, batch, info, shape, ctx):
    """Inside shard_map with dst-aligned edges: all scatters are local; the
    only communication is the per-layer halo gather of remote source rows
    (repro.core.halo) — O(remote rows · d_hidden), not O(N · d_hidden)."""
    import jax
    import jax.numpy as jnp
    from repro.graph.segment import segment_sum
    from repro.models.common import dense, layer_norm

    src, dst = batch["src"], batch["dst"]
    valid = (src >= 0) & (dst >= 0)
    d_loc = jnp.clip(jnp.maximum(dst, 0) - ctx.offset(), 0, ctx.rows - 1)
    h = batch["node_feat"]
    for p in params["layers"]:
        h_src = ctx.gather(h, jnp.where(valid, src, -1))   # halo exchange
        agg = segment_sum(jnp.where(valid[:, None], h_src, 0.0), d_loc,
                          ctx.rows)
        z = (1.0 + p["eps"]) * h + agg
        z = jax.nn.relu(dense(p["mlp1"], z))
        z = dense(p["mlp2"], z)
        h = jax.nn.relu(layer_norm(p["ln"], z))
    logits = dense(params["readout"], h).astype(jnp.float32)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[:, None],
                              axis=-1)[..., 0]
    ok = (labels >= 0).astype(jnp.float32)
    return ctx.mean(((lse - tgt) * ok).sum(), ok.sum())


ARCH = register(make_gnn_arch(GNNAdapter(
    name="gin-tu", init=_init, loss=_loss,
    description="GIN-ε, 5 layers, 64 hidden, sum aggregation.",
    loss_sharded=_loss_sharded)))

"""Architecture registry: importing this package registers every assigned
arch (5 LM + 4 GNN + 1 recsys) plus the paper's own serving models."""
from repro.configs import (codeqwen15_7b, deepseek_moe_16b, din,  # noqa: F401
                           equiformer_v2, gin_tu, meshgraphnet,
                           phi35_moe_42b, qwen15_4b, qwen3_4b, schnet)
from repro.configs.base import Arch, CellSpec, get_arch, list_archs

ALL_ARCHS = list_archs()

__all__ = ["Arch", "CellSpec", "get_arch", "list_archs", "ALL_ARCHS"]

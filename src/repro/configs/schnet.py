"""schnet [gnn] n_interactions=3 d_hidden=64 rbf=300 cutoff=10
[arXiv:1706.08566]. Non-geometric shapes feed node features through a
learned projection added to the species embedding (positions are provided
by the data pipeline in every shape)."""
from repro.configs.base import register
from repro.configs.gnn_common import (GNNAdapter, classification_loss,
                                      make_gnn_arch, regression_loss)
from repro.models.schnet import schnet_forward, schnet_init

D_HIDDEN, N_INTER, N_RBF, CUTOFF = 64, 3, 300, 10.0


def _init(key, d_feat, n_out, shape):
    return schnet_init(key, d_hidden=D_HIDDEN, n_interactions=N_INTER,
                       n_rbf=N_RBF, cutoff=CUTOFF, d_out=n_out,
                       d_feat_in=d_feat)


def _loss(params, batch, info, shape, shard=lambda x, *n: x):
    common = dict(num_nodes=info["nodes"], node_feat=batch["node_feat"],
                  shard=shard)
    if info["graphs"] is not None:
        pred = schnet_forward(params, batch["species"], batch["positions"],
                              batch["src"], batch["dst"],
                              mol_id=batch["mol_id"],
                              num_graphs=info["graphs"], **common)
        return regression_loss(pred, batch["labels"])
    logits = schnet_forward(params, batch["species"], batch["positions"],
                            batch["src"], batch["dst"], **common)
    return classification_loss(logits, batch["labels"])


ARCH = register(make_gnn_arch(GNNAdapter(
    name="schnet", init=_init, loss=_loss,
    description="SchNet continuous-filter convolutions, 300 RBF.")))

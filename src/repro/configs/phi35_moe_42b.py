"""phi3.5-moe-42b-a6.6b [moe] 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16e top-2. [hf:microsoft/Phi-3.5-MoE-instruct]"""
from repro.configs.base import register
from repro.configs.lm_common import make_lm_arch
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

CONFIG = LMConfig(vocab=32064, d_model=4096, n_layers=32, n_heads=32,
                  n_kv=8, head_dim=128, d_ff=0, qkv_bias=False,
                  qk_norm=False, rope_theta=1e6, dtype="bfloat16",
                  moe=MoEConfig(num_experts=16, top_k=2, d_ff=6400,
                                capacity_factor=1.25))

ARCH = register(make_lm_arch(
    "phi3.5-moe-42b", CONFIG, family="moe_lm",
    description="16-expert top-2 MoE, GQA kv=8, 6.6B active params."))

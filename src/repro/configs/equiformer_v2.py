"""equiformer-v2 [gnn] n_layers=12 d_hidden=128 l_max=6 m_max=2 n_heads=8
equivariance=SO(2)-eSCN [arXiv:2306.12059].

The big shapes scan over edge chunks (ogb_products: 64 chunks) to bound the
(E, (l_max+1)², C) message working set; reduced smoke configs use a smaller
l_max so CPU tests stay fast while the full config keeps l_max=6.
"""
from repro.configs.base import register
from repro.configs.gnn_common import (GNNAdapter, classification_loss,
                                      make_gnn_arch, regression_loss)
from repro.models.equiformer_v2 import equiformer_forward, equiformer_init

N_LAYERS, CHANNELS, L_MAX, M_MAX, N_HEADS = 12, 128, 6, 2, 8

EDGE_CHUNKS = {"full_graph_sm": 1, "minibatch_lg": 8, "ogb_products": 64,
               "molecule": 1}


def _init(key, d_feat, n_out, shape):
    return equiformer_init(key, n_layers=N_LAYERS, channels=CHANNELS,
                           l_max=L_MAX, m_max=M_MAX, n_heads=N_HEADS,
                           n_rbf=32, d_feat_in=d_feat, d_out=n_out)


def _reduced_init(key, d_feat, n_out, shape):
    return equiformer_init(key, n_layers=2, channels=16, l_max=2, m_max=1,
                           n_heads=4, n_rbf=8, d_feat_in=d_feat, d_out=n_out)


def _loss(params, batch, info, shape, shard=lambda x, *n: x):
    kw = dict(num_nodes=info["nodes"], node_feat=batch["node_feat"],
              edge_chunks=EDGE_CHUNKS.get(shape, 1), shard=shard)
    if info["graphs"] is not None:
        pred = equiformer_forward(params, batch["species"],
                                  batch["positions"], batch["src"],
                                  batch["dst"], mol_id=batch["mol_id"],
                                  num_graphs=info["graphs"], **kw)
        return regression_loss(pred, batch["labels"])
    logits = equiformer_forward(params, batch["species"], batch["positions"],
                                batch["src"], batch["dst"], **kw)
    return classification_loss(logits, batch["labels"])


def _loss_sharded(params, batch, info, shape, ctx):
    """Inside shard_map: batch arrays are this shard's slices; edges are
    dst-aligned (data pipeline contract, repro.core.halo)."""
    import jax
    import jax.numpy as jnp
    from repro.models.equiformer_v2 import equiformer_forward_local

    pos_g = ctx.all_gather(batch["positions"])   # (N,3) is tiny — replicate
    logits = equiformer_forward_local(
        params, batch["species"], pos_g, batch["node_feat"], batch["src"],
        batch["dst"], rows=ctx.rows, offset=ctx.offset(),
        halo_fn=ctx.gather, edge_chunks=EDGE_CHUNKS.get(shape, 1))
    labels = batch["labels"]
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    tgt = jnp.take_along_axis(lg, jnp.maximum(labels, 0)[:, None],
                              axis=-1)[..., 0]
    valid = (labels >= 0).astype(jnp.float32)
    return ctx.mean(((lse - tgt) * valid).sum(), valid.sum())


ARCH = register(make_gnn_arch(GNNAdapter(
    name="equiformer-v2", init=_init, loss=_loss,
    description="eSCN SO(2)-convolution equivariant graph attention.",
    loss_sharded=_loss_sharded),
    reduced_init=_reduced_init))

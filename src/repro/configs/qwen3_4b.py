"""qwen3-4b [dense] 36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936
— qk_norm, GQA. [hf:Qwen/Qwen3-*; hf]"""
from repro.configs.base import register
from repro.configs.lm_common import make_lm_arch
from repro.models.transformer import LMConfig

CONFIG = LMConfig(vocab=151936, d_model=2560, n_layers=36, n_heads=32,
                  n_kv=8, head_dim=128, d_ff=9728, qkv_bias=False,
                  qk_norm=True, rope_theta=1e6, dtype="bfloat16")

ARCH = register(make_lm_arch(
    "qwen3-4b", CONFIG,
    description="Dense decoder LM with qk-norm and GQA kv=8 (H·dh≠d)."))

"""qwen1.5-4b [dense] 40L d_model=2560 20H (GQA kv=20) d_ff=6912
vocab=151936 — QKV bias. [hf:Qwen/Qwen1.5-*; hf]"""
from repro.configs.base import register
from repro.configs.lm_common import make_lm_arch
from repro.models.transformer import LMConfig

CONFIG = LMConfig(vocab=151936, d_model=2560, n_layers=40, n_heads=20,
                  n_kv=20, head_dim=128, d_ff=6912, qkv_bias=True,
                  qk_norm=False, rope_theta=1e6, dtype="bfloat16")

ARCH = register(make_lm_arch(
    "qwen1.5-4b", CONFIG,
    description="Dense decoder LM, MHA-style GQA (kv=heads), QKV bias."))

"""deepseek-moe-16b [moe] 28L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400, MoE 64e top-6 — 2 shared + 64 routed, fine-grained
[arXiv:2401.06066]. Simplification (documented in DESIGN.md): DeepSeek's
dense layer-0 is made MoE like the rest so layers stay scan-homogeneous."""
from repro.configs.base import register
from repro.configs.lm_common import make_lm_arch
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

CONFIG = LMConfig(vocab=102400, d_model=2048, n_layers=28, n_heads=16,
                  n_kv=16, head_dim=128, d_ff=0, qkv_bias=False,
                  qk_norm=False, rope_theta=1e6, dtype="bfloat16",
                  moe=MoEConfig(num_experts=64, top_k=6, d_ff=1408,
                                n_shared=2, d_ff_shared=2 * 1408,
                                capacity_factor=1.25))

ARCH = register(make_lm_arch(
    "deepseek-moe-16b", CONFIG, family="moe_lm",
    description="Fine-grained MoE: 2 shared + 64 routed experts, top-6."))

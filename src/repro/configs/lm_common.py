"""Shared cell builders for the 5 assigned LM architectures.

Shapes (per assignment):
  train_4k    — train_step,  seq 4096,   global_batch 256
  prefill_32k — serve prefill, seq 32768, global_batch 32
  decode_32k  — serve decode (1 new token, 32k KV cache), batch 128
  long_500k   — serve decode, 524288 KV cache, batch 1 (cache seq-sharded)

Sharding: params are 2-D sharded — FSDP over ("pod","data") × TP over
"model" (vocab-parallel embeddings/logits, head-parallel attention, expert-
parallel MoE); activations batch-sharded; the long_500k cell re-binds the
cache sequence dimension to the data axis since batch=1.
All five archs are pure full attention; ``long_500k`` is *decode* (O(L) per
token), so it lowers fine — no 500k prefill is attempted (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import Arch, CellSpec
from repro.models.moe import MoEConfig
from repro.models.transformer import (LMConfig, init_decode_cache,
                                      lm_decode_step, lm_init, lm_loss,
                                      lm_prefill)
from repro.sharding import Rules, make_shard_fn, spec, tree_shardings
from repro.training.optimizer import AdamW

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def lm_rules(mesh: Optional[Mesh], shape: str,
             cfg: Optional[LMConfig] = None) -> Rules:
    if mesh is None:
        return Rules({})
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    table = {
        "batch": dp, "fsdp": dp, "tp": "model", "tp_kv": "model",
        "expert": "model", "vocab_tp": "model", "seq": None,
    }
    kind = SHAPES[shape]["kind"]
    seq_axes: list = []
    if cfg is not None and kind in ("decode", "prefill") \
            and cfg.n_kv % mesh.shape["model"] != 0:
        # KV heads don't divide the tp axis (qwen1.5 kv=20, qwen3/phi kv=8
        # on model=16): a head-sharded cache would replicate → the per-step
        # cache reshard was 3.2 s of collectives (§Perf iteration 2).
        # Shard the cache SEQUENCE dim over the tp axis instead; decode
        # attention reduces over seq with one small psum.
        table["tp_kv"] = None
        seq_axes.append("model")
    if SHAPES[shape]["batch"] == 1:       # long-context decode: shard seq
        table["batch"] = None
        seq_axes = list(dp) + seq_axes
    table["seq"] = tuple(seq_axes) if seq_axes else None
    return Rules(table)


def lm_param_specs(cfg: LMConfig, mesh: Optional[Mesh], rules: Rules):
    """PartitionSpec tree mirroring lm_init's structure (divisibility-aware)."""
    d, h, kv, dh, L = (cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim,
                       cfg.n_layers)
    s = partial(spec, mesh, rules)
    specs = {
        "embed": s((cfg.vocab, d), "vocab_tp", "fsdp"),
        "unembed": s((d, cfg.vocab), "fsdp", "vocab_tp"),
        "final_ln": P(),
        "layers": {
            "ln1": P(), "ln2": P(),
            "wq": s((L, d, h * dh), None, "fsdp", "tp"),
            "wk": s((L, d, kv * dh), None, "fsdp", "tp_kv"),
            "wv": s((L, d, kv * dh), None, "fsdp", "tp_kv"),
            "wo": s((L, h * dh, d), None, "tp", "fsdp"),
        },
    }
    lay = specs["layers"]
    if cfg.qkv_bias:
        lay["bq"] = s((L, h * dh), None, "tp")
        lay["bk"] = s((L, kv * dh), None, "tp_kv")
        lay["bv"] = s((L, kv * dh), None, "tp_kv")
    if cfg.qk_norm:
        lay["q_norm"] = P()
        lay["k_norm"] = P()
    if cfg.moe is None:
        lay["w1"] = s((L, d, cfg.d_ff), None, "fsdp", "tp")
        lay["w3"] = s((L, d, cfg.d_ff), None, "fsdp", "tp")
        lay["w2"] = s((L, cfg.d_ff, d), None, "tp", "fsdp")
    else:
        m = cfg.moe
        moe = {
            "router": s((L, d, m.num_experts), None, "fsdp", None),
            "w1": s((L, m.num_experts, d, m.d_ff), None, "expert", "fsdp",
                    None),
            "w3": s((L, m.num_experts, d, m.d_ff), None, "expert", "fsdp",
                    None),
            "w2": s((L, m.num_experts, m.d_ff, d), None, "expert", None,
                    "fsdp"),
        }
        if m.n_shared:
            moe["shared"] = {
                "w1": s((L, d, m.d_ff_shared), None, "fsdp", "tp"),
                "w3": s((L, d, m.d_ff_shared), None, "fsdp", "tp"),
                "w2": s((L, m.d_ff_shared, d), None, "tp", "fsdp"),
            }
        lay["moe"] = moe
    return specs


def _abstract(fn, *args):
    return jax.eval_shape(fn, *args)


def _opt_specs(param_specs):
    from repro.training.optimizer import AdamWState
    return AdamWState(step=P(),
                      mu=jax.tree_util.tree_map(
                          lambda s: s, param_specs,
                          is_leaf=lambda s: isinstance(s, P)),
                      nu=jax.tree_util.tree_map(
                          lambda s: s, param_specs,
                          is_leaf=lambda s: isinstance(s, P)))


def build_lm_cell(cfg: LMConfig, shape: str,
                  mesh: Optional[Mesh]) -> CellSpec:
    info = SHAPES[shape]
    rules = lm_rules(mesh, shape, cfg)
    shard = make_shard_fn(mesh, rules)
    pspecs = lm_param_specs(cfg, mesh, rules)
    psh = tree_shardings(mesh, pspecs)

    if info["kind"] == "train":
        opt = AdamW(lr=3e-4)
        params_a = _abstract(lambda: lm_init(jax.random.key(0), cfg))
        opt_a = _abstract(opt.init, params_a)
        # ZeRO-1 for dense archs (params ≤8B): replicate params over dp —
        # kills the per-layer FSDP weight all-gathers (365 ms → §Perf) —
        # while the optimizer state stays dp-sharded. MoE archs keep full
        # FSDP (42B f32 params would not fit replicated-over-dp).
        ospecs = _opt_specs(pspecs)
        if cfg.moe is None:
            rules_zero1 = Rules({**rules.table, "fsdp": None})
            pspecs = lm_param_specs(cfg, mesh, rules_zero1)
            psh = tree_shardings(mesh, pspecs)
        osh = tree_shardings(mesh, ospecs)
        B, S = info["batch"], info["seq"]
        batch_a = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                   "targets": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        bspec = {"tokens": spec(mesh, rules, (B, S), "batch", None),
                 "targets": spec(mesh, rules, (B, S), "batch", None)}
        bsh = tree_shardings(mesh, bspec)
        # gradient-accumulation microbatching: divides the activation-carry
        # footprint (40 layers × (B,S,d) residuals dominated train peak HBM)
        # by `micro` at the cost of `micro`× more (tiny) optimizer-side
        # collectives. §Perf iteration 5.
        micro = 4 if (mesh is not None and B % 4 == 0) else 1
        if micro and cfg.moe is not None and cfg.d_model >= 4096 \
                and B % 8 == 0:
            micro = 8  # 42B MoE: dispatch buffers + FSDP args need more headroom

        def step(params, opt_state, batch):
            def loss_fn(p, toks, tgts):
                return lm_loss(p, toks, tgts, cfg, shard)

            if micro == 1:
                loss, grads = jax.value_and_grad(loss_fn)(
                    params, batch["tokens"], batch["targets"])
            else:
                toks = batch["tokens"].reshape(micro, B // micro, S)
                tgts = batch["targets"].reshape(micro, B // micro, S)

                def mstep(acc, xs):
                    l, g = jax.value_and_grad(loss_fn)(params, xs[0], xs[1])
                    return jax.tree_util.tree_map(jnp.add, acc, g), l

                zeros = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                grads, losses = jax.lax.scan(mstep, zeros, (toks, tgts))
                grads = jax.tree_util.tree_map(lambda g: g / micro, grads)
                loss = losses.mean()
            params, opt_state = opt.update(grads, opt_state, params)
            return params, opt_state, loss

        in_sh = (psh, osh, bsh) if mesh is not None else None
        out_sh = ((psh, osh, tree_shardings(mesh, P()))
                  if mesh is not None else None)
        return CellSpec(step_fn=step, args=(params_a, opt_a, batch_a),
                        in_shardings=in_sh, out_shardings=out_sh,
                        donate_argnums=(0, 1), kind="train")

    dtype = jnp.bfloat16  # serving weights
    params_a = _abstract(lambda: lm_init(jax.random.key(0), cfg,
                                         dtype=dtype))
    B, S = info["batch"], info["seq"]
    if info["kind"] == "prefill":
        tokens_a = jax.ShapeDtypeStruct((B, S), jnp.int32)
        tsh = tree_shardings(mesh, spec(mesh, rules, (B, S), "batch", None))
        cache_spec = spec(mesh, rules,
                          (cfg.n_layers, B, S, cfg.n_kv, cfg.head_dim),
                          None, "batch", "seq", "tp_kv", None)
        out_sh = ((tree_shardings(mesh, spec(mesh, rules, (B, cfg.vocab),
                                             "batch", "vocab_tp")),
                   {"k": tree_shardings(mesh, cache_spec),
                    "v": tree_shardings(mesh, cache_spec)})
                  if mesh is not None else None)

        def step(params, tokens):
            return lm_prefill(params, tokens, cfg, shard)

        return CellSpec(step_fn=step, args=(params_a, tokens_a),
                        in_shardings=(psh, tsh) if mesh is not None else None,
                        out_shardings=out_sh, kind="serve")

    # decode
    cache_a = _abstract(lambda: init_decode_cache(cfg, B, S, jnp.bfloat16))
    cache_spec_p = spec(mesh, rules,
                        (cfg.n_layers, B, S, cfg.n_kv, cfg.head_dim),
                        None, "batch", "seq", "tp_kv", None)
    csh = ({"k": tree_shardings(mesh, cache_spec_p),
            "v": tree_shardings(mesh, cache_spec_p)}
           if mesh is not None else None)
    token_a = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    toksh = tree_shardings(mesh, spec(mesh, rules, (B, 1), "batch", None))
    len_a = jax.ShapeDtypeStruct((), jnp.int32)
    lsh = tree_shardings(mesh, P())

    def step(params, cache, token, cache_len):
        return lm_decode_step(params, token, cache, cache_len, cfg, shard)

    out_sh = ((tree_shardings(mesh, spec(mesh, rules, (B, cfg.vocab),
                                         "batch", "vocab_tp")), csh)
              if mesh is not None else None)
    return CellSpec(step_fn=step, args=(params_a, cache_a, token_a, len_a),
                    in_shardings=((psh, csh, toksh, lsh)
                                  if mesh is not None else None),
                    out_shardings=out_sh, donate_argnums=(1,), kind="serve")


# ---------------------------------------------------------------------------
# Smoke runner shared by all LM archs (reduced dims, CPU-concrete)
# ---------------------------------------------------------------------------
def lm_smoke(cfg_full: LMConfig) -> dict:
    moe = cfg_full.moe
    if moe is not None:
        moe = dataclasses.replace(moe, num_experts=min(moe.num_experts, 8),
                                  top_k=min(moe.top_k, 2), d_ff=64,
                                  d_ff_shared=64 if moe.n_shared else 0)
    cfg = dataclasses.replace(
        cfg_full, vocab=512, d_model=64, n_layers=2,
        n_heads=4, n_kv=max(1, 4 * cfg_full.n_kv // cfg_full.n_heads),
        head_dim=16, d_ff=128 if cfg_full.moe is None else 0, moe=moe,
        dtype="float32", q_chunk=32, kv_chunk=32)
    key = jax.random.key(0)
    params = lm_init(key, cfg)
    toks = jax.random.randint(key, (2, 64), 0, cfg.vocab)
    loss = lm_loss(params, toks, toks, cfg)
    cache = init_decode_cache(cfg, 2, 32, jnp.float32)
    logits, cache = lm_decode_step(params, toks[:, :1], cache,
                                   jnp.asarray(1, jnp.int32), cfg)
    pl, pc = lm_prefill(params, toks[:, :16], cfg)
    assert logits.shape == (2, cfg.vocab) and pl.shape == (2, cfg.vocab)
    assert bool(jnp.isfinite(loss)) and bool(jnp.isfinite(logits).all())
    return {"loss": float(loss), "logits_shape": tuple(logits.shape),
            "prefill_cache_k": tuple(pc["k"].shape)}


def make_lm_arch(name: str, cfg: LMConfig, family: str = "lm",
                 description: str = "") -> Arch:
    return Arch(
        name=name, family=family, shape_names=tuple(SHAPES),
        build_cell=lambda shape, mesh: build_lm_cell(cfg, shape, mesh),
        smoke=lambda: lm_smoke(cfg), description=description)

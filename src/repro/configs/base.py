"""Arch/shape registry — the ``--arch <id>`` surface of the framework.

Each architecture module registers an :class:`Arch` whose ``build_cell``
returns everything the launcher needs to lower one (arch × shape) cell:
the step function, abstract input specs (ShapeDtypeStruct — never
allocated), in/out shardings for the given mesh, and donation hints. Reduced
("smoke") variants return *concrete* inputs for CPU execution in tests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

from jax.sharding import Mesh


@dataclasses.dataclass
class CellSpec:
    """One lowered (arch × shape × mesh) combination."""

    step_fn: Callable
    args: tuple                        # pytrees of ShapeDtypeStruct
    in_shardings: Optional[tuple]      # matching pytrees of NamedSharding
    out_shardings: Any = None
    donate_argnums: tuple = ()
    kind: str = "train"                # "train" | "serve"
    notes: str = ""


@dataclasses.dataclass
class Arch:
    name: str
    family: str                        # lm | moe_lm | gnn | recsys
    shape_names: tuple[str, ...]
    build_cell: Callable[[str, Optional[Mesh]], CellSpec]
    smoke: Callable[[], dict]          # runs a reduced step, returns outputs
    description: str = ""


_REGISTRY: dict[str, Arch] = {}


def register(arch: Arch) -> Arch:
    _REGISTRY[arch.name] = arch
    return arch


def get_arch(name: str) -> Arch:
    if name not in _REGISTRY:
        import repro.configs  # noqa: F401  (trigger registration)
    return _REGISTRY[name]


def list_archs() -> list[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)

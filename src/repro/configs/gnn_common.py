"""Shared cell builders for the 4 assigned GNN architectures.

Shapes (per assignment):
  full_graph_sm — full-batch train, N=2,708 / E=10,556 / d=1,433  (Cora-scale)
  minibatch_lg  — sampled train on Reddit-scale graph: 1,024 seed nodes,
                  fanout 15-10 ⇒ sampled subgraph of 1,024+15,360+153,600 =
                  169,984 nodes and 1,024·15 + 15,360·10 = 168,960 edges
                  (the real neighbor sampler in repro.graph produces exactly
                  this padded layout; d=300 per the paper's Reddit row)
  ogb_products  — full-batch train, N=2,449,029 / E=61,859,140 / d=100
  molecule      — batched small graphs, 128 mols × 30 atoms / 64 edges

All four cells are train steps (the assignment marks every GNN shape as a
training regime); serving of GNN models is exercised end-to-end by the
Quiver serving engine benchmarks/examples. The unified batch is
{node_feat, positions, species, src, dst, labels(, mol_id)}: every arch
consumes the subset it needs, so one builder covers the whole family.
Sharding: nodes/edges row-sharded over ("pod","data") — the segment_sum
scatter across shards is the collective the roofline analysis tracks;
GNN params are small and stay replicated.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import Arch, CellSpec
from repro.sharding import Rules, make_shard_fn, spec, tree_shardings
from repro.training.optimizer import AdamW

SHAPES = {
    # padded from N=2,708 / E=10,556 to multiples of 32 (pipeline pads -1)
    "full_graph_sm": dict(nodes=2720, edges=10560, d_feat=1433, classes=7,
                          graphs=None),
    "minibatch_lg": dict(nodes=1024 + 15360 + 153600,
                         edges=1024 * 15 + 15360 * 10, d_feat=300,
                         classes=41, graphs=None, seeds=1024),
    # padded from N=2,449,029 / E=61,859,140 to multiples of 512 so node/edge
    # arrays shard evenly across a 512-chip mesh (pipeline pads with -1 ids)
    "ogb_products": dict(nodes=2449408, edges=61859840, d_feat=100,
                         classes=47, graphs=None),
    "molecule": dict(nodes=128 * 30, edges=128 * 64, d_feat=16, classes=None,
                     graphs=128),
}


@dataclasses.dataclass(frozen=True)
class GNNAdapter:
    """Per-arch bridge: build params for (d_feat, n_out) and compute the
    per-shape loss from the unified batch."""

    name: str
    init: Callable  # (key, d_feat, n_out, shape_name) -> params
    loss: Callable  # (params, batch, shape_info, shape_name, shard) -> scalar
    description: str = ""
    # optional locality-sharded path (runs inside shard_map with dst-aligned
    # edges; see repro.core.halo): (params, batch_local, info, shape, ctx)
    # -> replicated scalar loss
    loss_sharded: Optional[Callable] = None
    sharded_shapes: tuple = ("ogb_products",)


def gnn_rules(mesh: Optional[Mesh]) -> Rules:
    """GNNs have no tensor-parallel dimension (params are small and
    replicated), so node/edge rows shard over the ENTIRE mesh — 256/512-way
    instead of only the dp axes. Divisibility-aware fallback keeps small
    shapes (cora, molecule×multi-pod) replicated."""
    if mesh is None:
        return Rules({})
    all_axes = tuple(mesh.shape.keys())
    return Rules({"nodes": all_axes, "edges": all_axes, "graphs": all_axes})


def _batch_abstract(info) -> dict:
    n, e = info["nodes"], info["edges"]
    batch = {
        "node_feat": jax.ShapeDtypeStruct((n, info["d_feat"]), jnp.float32),
        "positions": jax.ShapeDtypeStruct((n, 3), jnp.float32),
        "species": jax.ShapeDtypeStruct((n,), jnp.int32),
        "src": jax.ShapeDtypeStruct((e,), jnp.int32),
        "dst": jax.ShapeDtypeStruct((e,), jnp.int32),
    }
    if info["graphs"] is not None:
        batch["mol_id"] = jax.ShapeDtypeStruct((n,), jnp.int32)
        batch["labels"] = jax.ShapeDtypeStruct((info["graphs"],), jnp.float32)
    else:
        n_lab = info.get("seeds", n)
        batch["labels"] = jax.ShapeDtypeStruct((n_lab,), jnp.int32)
    return batch


def _batch_specs(mesh, rules, info):
    n, e = info["nodes"], info["edges"]
    s = partial(spec, mesh, rules)
    out = {
        "node_feat": s((n, info["d_feat"]), "nodes", None),
        "positions": s((n, 3), "nodes", None),
        "species": s((n,), "nodes"),
        "src": s((e,), "edges"),
        "dst": s((e,), "edges"),
    }
    if info["graphs"] is not None:
        out["mol_id"] = s((n,), "nodes")
        out["labels"] = s((info["graphs"],), "graphs")
    else:
        n_lab = info.get("seeds", n)
        out["labels"] = s((n_lab,), "nodes")
    return out


def make_concrete_batch(info, *, seed: int = 0) -> dict:
    """Small concrete batch for smoke tests (reduced dims only)."""
    rng = np.random.default_rng(seed)
    n, e = info["nodes"], info["edges"]
    batch = {
        "node_feat": jnp.asarray(rng.normal(size=(n, info["d_feat"])),
                                 jnp.float32),
        "positions": jnp.asarray(rng.normal(size=(n, 3)), jnp.float32),
        "species": jnp.asarray(rng.integers(0, 8, n), jnp.int32),
        "src": jnp.asarray(rng.integers(0, n, e), jnp.int32),
        "dst": jnp.asarray(rng.integers(0, n, e), jnp.int32),
    }
    if info["graphs"] is not None:
        per = n // info["graphs"]
        batch["mol_id"] = jnp.asarray(np.repeat(np.arange(info["graphs"]),
                                                per), jnp.int32)
        batch["labels"] = jnp.asarray(rng.normal(size=(info["graphs"],)),
                                      jnp.float32)
    else:
        n_lab = info.get("seeds", n)
        batch["labels"] = jnp.asarray(
            rng.integers(0, info["classes"], n_lab), jnp.int32)
    return batch


def classification_loss(logits: jnp.ndarray, labels: jnp.ndarray
                        ) -> jnp.ndarray:
    logits = logits[:labels.shape[0]].astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, labels[:, None], axis=-1)[..., 0]
    return (lse - tgt).mean()


def regression_loss(pred: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean((pred[..., 0].astype(jnp.float32) - labels) ** 2)


def build_gnn_cell(adapter: GNNAdapter, shape: str,
                   mesh: Optional[Mesh]) -> CellSpec:
    info = SHAPES[shape]
    rules = gnn_rules(mesh)
    shard = make_shard_fn(mesh, rules)
    n_out = info["classes"] if info["classes"] is not None else 1
    opt = AdamW(lr=1e-3, weight_decay=0.0)

    params_a = jax.eval_shape(
        lambda: adapter.init(jax.random.key(0), info["d_feat"], n_out, shape))
    opt_a = jax.eval_shape(opt.init, params_a)
    batch_a = _batch_abstract(info)
    # GNN params are small → replicated
    psh = tree_shardings(mesh, jax.tree_util.tree_map(lambda _: P(),
                                                      params_a))
    osh = tree_shardings(mesh, jax.tree_util.tree_map(lambda _: P(), opt_a))
    bsh = tree_shardings(mesh, _batch_specs(mesh, rules, info))

    world = (int(np.prod(list(mesh.shape.values())))
             if mesh is not None else 1)
    use_halo = (mesh is not None and adapter.loss_sharded is not None
                and shape in adapter.sharded_shapes
                and info["nodes"] % world == 0 and info["edges"] % world == 0)
    if use_halo:
        from repro.core.halo import HaloCtx
        axes = tuple(mesh.shape.keys())
        rows = info["nodes"] // world
        e_local = info["edges"] // world
        # per-peer request capacity sized from the partitioner's remote
        # fraction (0.4 margin over a ~0.25–0.3 locality partition)
        cap_pp = max(16, int(e_local * 0.4 / world))
        ctx = HaloCtx(axes, dict(mesh.shape), rows, cap_pp)
        pspec_tree = jax.tree_util.tree_map(lambda _: P(), params_a)
        bspec_tree = _batch_specs(mesh, rules, info)

        sm_loss = jax.shard_map(
            lambda p, b: adapter.loss_sharded(p, b, info, shape, ctx),
            mesh=mesh, in_specs=(pspec_tree, bspec_tree), out_specs=P())

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: sm_loss(p, batch))(params)
            params, opt_state = opt.update(grads, opt_state, params)
            return params, opt_state, loss
    else:
        def step(params, opt_state, batch):
            def loss_fn(p):
                return adapter.loss(p, batch, info, shape, shard)
            loss, grads = jax.value_and_grad(loss_fn)(params)
            params, opt_state = opt.update(grads, opt_state, params)
            return params, opt_state, loss

    return CellSpec(
        step_fn=step, args=(params_a, opt_a, batch_a),
        in_shardings=(psh, osh, bsh) if mesh is not None else None,
        out_shardings=((psh, osh, tree_shardings(mesh, P()))
                       if mesh is not None else None),
        donate_argnums=(0, 1), kind="train",
        notes="halo-sharded" if use_halo else "")


REDUCED = {
    "full_graph_sm": dict(nodes=128, edges=512, d_feat=24, classes=7,
                          graphs=None),
    "minibatch_lg": dict(nodes=16 + 64 + 192, edges=16 * 4 + 64 * 3,
                         d_feat=16, classes=8, graphs=None, seeds=16),
    "ogb_products": dict(nodes=256, edges=1024, d_feat=12, classes=5,
                         graphs=None),
    "molecule": dict(nodes=8 * 6, edges=8 * 14, d_feat=8, classes=None,
                     graphs=8),
}


def gnn_smoke(adapter: GNNAdapter, reduced_init: Callable) -> dict:
    """Run one reduced train step per shape on CPU; assert finite loss."""
    out = {}
    opt = AdamW(lr=1e-3, weight_decay=0.0)
    for shape, info in REDUCED.items():
        n_out = info["classes"] if info["classes"] is not None else 1
        params = reduced_init(jax.random.key(1), info["d_feat"], n_out,
                              shape)
        batch = make_concrete_batch(info, seed=hash(shape) % 2 ** 16)
        opt_state = opt.init(params)

        def loss_fn(p):
            return adapter.loss(p, batch, info, shape, lambda x, *n: x)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        assert bool(jnp.isfinite(loss)), (adapter.name, shape)
        out[shape] = float(loss)
    return out


def make_gnn_arch(adapter: GNNAdapter,
                  reduced_init: Optional[Callable] = None) -> Arch:
    return Arch(
        name=adapter.name, family="gnn", shape_names=tuple(SHAPES),
        build_cell=lambda shape, mesh: build_gnn_cell(adapter, shape, mesh),
        smoke=lambda: gnn_smoke(adapter, reduced_init or adapter.init),
        description=adapter.description)

"""Minimal parameter/NN toolkit (plain-dict pytrees, no framework deps)."""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key: jax.Array, d_in: int, d_out: int, *, bias: bool = True,
               scale: float | None = None, dtype=jnp.float32) -> dict:
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def mlp_init(key: jax.Array, dims: Sequence[int], *, bias: bool = True,
             dtype=jnp.float32) -> list[dict]:
    keys = jax.random.split(key, len(dims) - 1)
    return [dense_init(k, a, b, bias=bias, dtype=dtype)
            for k, a, b in zip(keys, dims[:-1], dims[1:])]


def mlp(params: list[dict], x: jnp.ndarray, *, act=jax.nn.silu,
        final_act: bool = False) -> jnp.ndarray:
    for i, p in enumerate(params):
        x = dense(p, x)
        if i < len(params) - 1 or final_act:
            x = act(x)
    return x


def layer_norm_init(dim: int, dtype=jnp.float32) -> dict:
    return {"g": jnp.ones((dim,), dtype), "b": jnp.zeros((dim,), dtype)}


def layer_norm(p: dict, x: jnp.ndarray, *, eps: float = 1e-5) -> jnp.ndarray:
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return y * p["g"] + p["b"]


def rms_norm_init(dim: int, dtype=jnp.float32) -> dict:
    return {"g": jnp.ones((dim,), dtype)}


def rms_norm(p: dict, x: jnp.ndarray, *, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt((xf * xf).mean(-1, keepdims=True) + eps)
    return (y * p["g"]).astype(x.dtype)


def count_params(tree) -> int:
    return int(sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(tree)))


def param_bytes(tree) -> int:
    return int(sum(np.prod(l.shape) * l.dtype.itemsize
                   for l in jax.tree_util.tree_leaves(tree)))

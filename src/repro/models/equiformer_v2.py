"""EquiformerV2 (assigned arch: 12 layers, 128 channels, l_max=6, m_max=2,
8 heads, SO(2)-eSCN convolutions) — arXiv:2306.12059.

TPU-native eSCN graph attention (see so3.py for the rotation machinery):

  per edge:  x̃ = D_align(r̂) · x[src]          (per-l block rotations)
             ỹ = SO2Linear(x̃)                  (m-blockwise, m ≤ m_max)
             α = capped-exp attention           (segment-normalized per dst)
             m = D_align⁻¹ · (α ⊙ ỹ)
  per node:  h' = h + W_out · Σ_dst m ;  FFN = scalar MLP + sigmoid gates on
             l>0 irreps (S2-activation simplified to gate nonlinearity, a
             documented TPU adaptation), equivariant RMS layer norm per l.

System structure (what makes the big shapes lower at 512-way SPMD):
  * layers are stacked and scanned under jax.checkpoint — O(1) HLO in depth
    and remat'd activations;
  * Wigner rotation blocks and the radial basis are edge-quantities
    independent of depth — computed ONCE per step and reused by all layers
    (beyond-paper optimization; the reference implementation recomputes);
  * full-graph execution scans over edge chunks with associative
    numerator/denominator accumulation, so the (E, Σ(2l+1)², C) message
    working set is bounded;
  * an optional ``shard`` callable places node/edge tensors on the mesh.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.segment import segment_sum
from repro.models.common import dense, dense_init, mlp, mlp_init
from repro.models.so3 import edge_rotation_blocks, lm_index, num_coeffs


def _noshard(x, *names):
    return x


def _m0_rows(l_max: int) -> np.ndarray:
    return np.asarray([lm_index(l, 0) for l in range(l_max + 1)])


def _m_rows(l_max: int, m: int) -> tuple[np.ndarray, np.ndarray]:
    ls = np.arange(m, l_max + 1)
    return (np.asarray([lm_index(l, m) for l in ls]),
            np.asarray([lm_index(l, -m) for l in ls]))


def so2_init(key: jax.Array, l_max: int, m_max: int, c_in: int,
             c_out: int) -> dict:
    """SO(2) linear layer weights: full (l, channel) mixing per |m| block."""
    p = {}
    L1 = l_max + 1
    key, k0 = jax.random.split(key)
    p["w0"] = jax.random.normal(k0, (L1 * c_in, L1 * c_out)) / np.sqrt(
        L1 * c_in)
    for m in range(1, m_max + 1):
        Lm = l_max + 1 - m
        key, kr, ki = jax.random.split(key, 3)
        sc = 1.0 / np.sqrt(Lm * c_in)
        p[f"wr{m}"] = jax.random.normal(kr, (Lm * c_in, Lm * c_out)) * sc
        p[f"wi{m}"] = jax.random.normal(ki, (Lm * c_in, Lm * c_out)) * sc
    return p


def so2_apply(p: dict, x_rot: jnp.ndarray, l_max: int, m_max: int,
              c_out: int, rad_scale: jnp.ndarray) -> jnp.ndarray:
    """x_rot: (E, S, C) edge-frame features. rad_scale: (E, L1) per-l_out
    radial gate. Returns (E, S, c_out) with m > m_max components zero."""
    E = x_rot.shape[0]
    L1 = l_max + 1
    S = num_coeffs(l_max)
    out = jnp.zeros((E, S, c_out), x_rot.dtype)

    r0 = _m0_rows(l_max)
    x0 = x_rot[:, r0, :].reshape(E, -1)
    y0 = (x0 @ p["w0"]).reshape(E, L1, c_out) * rad_scale[:, :, None]
    out = out.at[:, r0, :].set(y0)

    for m in range(1, m_max + 1):
        rp, rn = _m_rows(l_max, m)
        Lm = rp.shape[0]
        xp = x_rot[:, rp, :].reshape(E, -1)
        xn = x_rot[:, rn, :].reshape(E, -1)
        yp = (xp @ p[f"wr{m}"] - xn @ p[f"wi{m}"]).reshape(E, Lm, c_out)
        yn = (xp @ p[f"wi{m}"] + xn @ p[f"wr{m}"]).reshape(E, Lm, c_out)
        sc = rad_scale[:, m:, None]
        out = out.at[:, rp, :].set(yp * sc)
        out = out.at[:, rn, :].set(yn * sc)
    return out


def _eq_layer_norm(g: jnp.ndarray, x: jnp.ndarray, l_max: int) -> jnp.ndarray:
    """Equivariant RMS norm: per (node, l) normalize over (m, channel);
    g: (L1, C) learned scale."""
    outs = []
    for l in range(l_max + 1):
        blk = x[:, l * l:(l + 1) ** 2, :]
        rms = jnp.sqrt((blk ** 2).mean(axis=(1, 2), keepdims=True) + 1e-6)
        outs.append(blk / rms * g[l][None, None, :])
    return jnp.concatenate(outs, axis=1)


def _layer_init(key: jax.Array, channels: int, l_max: int, m_max: int,
                n_heads: int, n_rbf: int) -> dict:
    L1 = l_max + 1
    k1, k2, k3, k4, k5, k6, k7 = jax.random.split(key, 7)
    return {
        "ln1_g": jnp.ones((L1, channels)),
        "so2": so2_init(k1, l_max, m_max, channels, channels),
        "rad": mlp_init(k2, [n_rbf, channels, L1]),
        "alpha": mlp_init(k3, [L1 * channels, channels, n_heads]),
        "out_proj": jax.random.normal(k4, (L1, channels, channels))
                    / np.sqrt(channels),
        "ln2_g": jnp.ones((L1, channels)),
        "ffn_scalar": mlp_init(k5, [channels, 2 * channels, channels]),
        "ffn_gate": mlp_init(k6, [channels, L1 * channels]),
        "ffn_mix": jax.random.normal(k7, (L1, channels, channels))
                   / np.sqrt(channels),
    }


def equiformer_init(key: jax.Array, *, n_layers: int = 12, channels: int = 128,
                    l_max: int = 6, m_max: int = 2, n_heads: int = 8,
                    n_rbf: int = 32, n_species: int = 32, d_feat_in: int = 0,
                    d_out: int = 1, cutoff: float = 5.0) -> dict:
    key, ke, kf, ko1, ko2, kl = jax.random.split(key, 6)
    params = {
        "embed": jax.random.normal(ke, (n_species, channels)) * 0.5,
        "out1": dense_init(ko1, channels, channels),
        "out2": dense_init(ko2, channels, d_out),
    }
    if d_feat_in:
        params["feat_proj"] = dense_init(kf, d_feat_in, channels)
    per_layer = [_layer_init(k, channels, l_max, m_max, n_heads, n_rbf)
                 for k in jax.random.split(kl, n_layers)]
    params["layers"] = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                              *per_layer)
    return params


def infer_cfg(params: dict, *, cutoff: float = 5.0) -> dict:
    """All architecture hyperparameters are recoverable from param shapes —
    params stay a pure array pytree (jit/grad/optimizer-safe)."""
    lay = params["layers"]
    n_layers, L1, channels = lay["ln1_g"].shape
    m_max = max([m for m in range(1, L1) if f"wr{m}" in lay["so2"]] or [0])
    return {"n_layers": int(n_layers), "channels": int(channels),
            "l_max": int(L1 - 1), "m_max": int(m_max),
            "n_heads": int(lay["alpha"][-1]["w"].shape[-1]),
            "n_rbf": int(lay["rad"][0]["w"].shape[-2]), "cutoff": cutoff}


def _rbf(dist: jnp.ndarray, n_rbf: int, cutoff: float) -> jnp.ndarray:
    mu = jnp.linspace(0.0, cutoff, n_rbf)
    return jnp.exp(-((dist[:, None] - mu[None, :]) ** 2)
                   * (n_rbf / max(cutoff, 1e-6)))


def _rotate(blocks: list[jnp.ndarray], x: jnp.ndarray,
            l_max: int) -> jnp.ndarray:
    outs = []
    for l in range(l_max + 1):
        blk = x[:, l * l:(l + 1) ** 2, :]
        outs.append(jnp.einsum("eij,ejc->eic", blocks[l], blk))
    return jnp.concatenate(outs, axis=1)


def _attention_edges(p: dict, cfg: dict, h_src: jnp.ndarray,
                     valid: jnp.ndarray, d: jnp.ndarray, D, Dinv,
                     rbf: jnp.ndarray, num_nodes: int
                     ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One pass over (a chunk of) edges. ``h_src``: gathered (and normed)
    source rows (E, S, C) — the caller chooses local gather (global path) or
    halo exchange (locality-sharded path); ``d``: destination row indices in
    [0, num_nodes). Attention uses tanh-capped exp weights so numerator/
    denominator accumulate associatively across chunks (exact softmax with
    bounded logits; no global-max pass needed)."""
    l_max, m_max = cfg["l_max"], cfg["m_max"]
    C, H = cfg["channels"], cfg["n_heads"]
    x_rot = _rotate(D, h_src, l_max)                       # (E, S, C)
    rad = mlp(p["rad"], rbf, act=jax.nn.silu)              # (E, L1)
    y = so2_apply(p["so2"], x_rot, l_max, m_max, C, jax.nn.silu(rad))

    inv = y[:, _m0_rows(l_max), :].reshape(y.shape[0], -1)  # invariant part
    logits = mlp(p["alpha"], inv, act=jax.nn.silu)          # (E, H)
    logits = 10.0 * jnp.tanh(logits / 10.0)                 # cap for exp
    w = jnp.where(valid[:, None], jnp.exp(logits), 0.0)     # (E, H)

    yh = y.reshape(y.shape[0], y.shape[1], H, C // H)
    yh = yh * w[:, None, :, None]
    y = yh.reshape(y.shape)
    msg = _rotate(Dinv, y, l_max)
    msg = jnp.where(valid[:, None, None], msg, 0.0)
    num = segment_sum(msg, d, num_nodes)                    # (N, S, C)
    den = segment_sum(w, d, num_nodes)                      # (N, H)
    return num, den


def _attention_finalize(p: dict, cfg: dict, num: jnp.ndarray,
                        den: jnp.ndarray) -> jnp.ndarray:
    l_max, C, H = cfg["l_max"], cfg["channels"], cfg["n_heads"]
    n = num.shape[0]
    agg = num.reshape(n, num.shape[1], H, C // H) / jnp.maximum(
        den, 1e-9)[:, None, :, None]
    agg = agg.reshape(num.shape)
    outs = []
    for l in range(l_max + 1):
        blk = agg[:, l * l:(l + 1) ** 2, :]
        outs.append(jnp.einsum("nic,co->nio", blk, p["out_proj"][l]))
    return jnp.concatenate(outs, axis=1)


def _ffn_block(p: dict, cfg: dict, x: jnp.ndarray) -> jnp.ndarray:
    l_max, C = cfg["l_max"], cfg["channels"]
    L1 = l_max + 1
    h = _eq_layer_norm(p["ln2_g"], x, l_max)
    scal = h[:, 0, :]
    gates = jax.nn.sigmoid(mlp(p["ffn_gate"], scal, act=jax.nn.silu)
                           ).reshape(-1, L1, C)
    outs = []
    for l in range(l_max + 1):
        blk = h[:, l * l:(l + 1) ** 2, :]
        mixed = jnp.einsum("nic,co->nio", blk, p["ffn_mix"][l])
        g = gates[:, l, :][:, None, :]
        outs.append(mixed * g)
    out = jnp.concatenate(outs, axis=1)
    scalar_update = mlp(p["ffn_scalar"], scal, act=jax.nn.silu)
    return out.at[:, 0, :].add(scalar_update)


def _chunk_edges(arr: jnp.ndarray, chunks: int, fill) -> jnp.ndarray:
    e = arr.shape[0]
    chunk = -(-e // chunks)
    pad = chunk * chunks - e
    widths = ((0, pad),) + ((0, 0),) * (arr.ndim - 1)
    return jnp.pad(arr, widths, constant_values=fill).reshape(
        (chunks, chunk) + arr.shape[1:])


def equiformer_forward(params: dict, species: jnp.ndarray,
                       positions: jnp.ndarray, src: jnp.ndarray,
                       dst: jnp.ndarray, *, num_nodes: int,
                       node_feat: Optional[jnp.ndarray] = None,
                       mol_id: Optional[jnp.ndarray] = None,
                       num_graphs: Optional[int] = None,
                       edge_chunks: int = 1, cutoff: float = 5.0,
                       shard: Callable = _noshard) -> jnp.ndarray:
    cfg = infer_cfg(params, cutoff=cutoff)
    l_max, C, H = cfg["l_max"], cfg["channels"], cfg["n_heads"]
    S = num_coeffs(l_max)
    N = num_nodes

    h0 = params["embed"][jnp.clip(species, 0, params["embed"].shape[0] - 1)]
    if node_feat is not None and "feat_proj" in params:
        h0 = h0 + dense(params["feat_proj"], node_feat)
    x = jnp.zeros((N, S, C), h0.dtype).at[:, 0, :].set(h0)
    x = shard(x, "nodes", None, None)

    sv, dv = jnp.maximum(src, 0), jnp.maximum(dst, 0)
    rij = positions[dv] - positions[sv]
    dist = jnp.sqrt((rij ** 2).sum(-1) + 1e-12)
    rhat = rij / jnp.maximum(dist, 1e-6)[:, None]
    # Edge geometry is depth-independent: rotations + radial basis are
    # computed once and reused by every layer (beyond-paper optimization).
    D, Dinv = edge_rotation_blocks(rhat, l_max)
    D = [shard(b, "edges", None, None) for b in D]
    Dinv = [shard(b, "edges", None, None) for b in Dinv]
    rbf = shard(_rbf(dist, cfg["n_rbf"], cfg["cutoff"]), "edges", None)

    if edge_chunks > 1:
        srcs = _chunk_edges(src, edge_chunks, -1)
        dsts = _chunk_edges(dst, edge_chunks, -1)
        Ds = [_chunk_edges(b, edge_chunks, 0) for b in D]
        Dinvs = [_chunk_edges(b, edge_chunks, 0) for b in Dinv]
        rbfs = _chunk_edges(rbf, edge_chunks, 0)

    def edges_pass(p, x, sc, dc, Dc, Dic, rc):
        h = _eq_layer_norm(p["ln1_g"], x, cfg["l_max"])
        valid = (sc >= 0) & (dc >= 0)
        h_src = h[jnp.maximum(sc, 0)]
        return _attention_edges(p, cfg, h_src, valid, jnp.maximum(dc, 0),
                                Dc, Dic, rc, N)

    def layer_step(x, p):
        if edge_chunks > 1:
            # The chunk body is itself remat'd: without this, the inner scan
            # stacks its backward residuals across ALL chunks — reinflating
            # the full-E message tensors the chunking exists to avoid
            # (measured: 4.6 TiB/device on ogb_products before this remat).
            def chunk_body(acc, args):
                sc, dc, rc, Dc, Dic = args
                n_, d_ = edges_pass(p, x, sc, dc, Dc, Dic, rc)
                return (acc[0] + n_, acc[1] + d_), None

            (num, den), _ = jax.lax.scan(
                jax.checkpoint(
                    chunk_body,
                    policy=jax.checkpoint_policies.nothing_saveable),
                (jnp.zeros_like(x), jnp.zeros((N, H), x.dtype)),
                (srcs, dsts, rbfs, Ds, Dinvs))
        else:
            num, den = edges_pass(p, x, src, dst, D, Dinv, rbf)
        x = x + _attention_finalize(p, cfg, num, den)
        x = x + _ffn_block(p, cfg, x)
        x = shard(x, "nodes", None, None)
        return x, None

    x, _ = jax.lax.scan(
        jax.checkpoint(layer_step,
                       policy=jax.checkpoint_policies.nothing_saveable),
        x, params["layers"])

    out = jax.nn.silu(dense(params["out1"], x[:, 0, :]))
    out = dense(params["out2"], out)
    if mol_id is not None:
        assert num_graphs is not None
        return segment_sum(out, jnp.maximum(mol_id, 0), num_graphs)
    return out


def equiformer_forward_local(params: dict, species_l: jnp.ndarray,
                             positions_g: jnp.ndarray,
                             node_feat_l: Optional[jnp.ndarray],
                             src_l: jnp.ndarray, dst_l: jnp.ndarray, *,
                             rows: int, offset: jnp.ndarray, halo_fn,
                             edge_chunks: int = 1,
                             cutoff: float = 5.0) -> jnp.ndarray:
    """Locality-sharded forward — runs INSIDE shard_map.

    species_l/node_feat_l: this shard's node rows; positions_g: replicated
    global positions (N×3, tiny); src_l/dst_l: this shard's dst-aligned edge
    slice (dst ∈ [offset, offset+rows)); halo_fn(h_local, global_ids) →
    gathered source rows via the capacity-bounded all-to-all
    (repro.core.halo). All scatters are shard-local; the halo exchange is
    the only communication — O(remote rows), not O(N·F) (DESIGN.md §Perf).
    """
    cfg = infer_cfg(params, cutoff=cutoff)
    l_max, C, H = cfg["l_max"], cfg["channels"], cfg["n_heads"]
    S = num_coeffs(l_max)

    h0 = params["embed"][jnp.clip(species_l, 0,
                                  params["embed"].shape[0] - 1)]
    if node_feat_l is not None and "feat_proj" in params:
        h0 = h0 + dense(params["feat_proj"], node_feat_l)
    x = jnp.zeros((rows, S, C), h0.dtype).at[:, 0, :].set(h0)

    sv, dv = jnp.maximum(src_l, 0), jnp.maximum(dst_l, 0)
    rij = positions_g[dv] - positions_g[sv]
    dist = jnp.sqrt((rij ** 2).sum(-1) + 1e-12)
    rhat = rij / jnp.maximum(dist, 1e-6)[:, None]
    D, Dinv = edge_rotation_blocks(rhat, l_max)
    rbf = _rbf(dist, cfg["n_rbf"], cfg["cutoff"])
    d_loc = jnp.clip(dv - offset, 0, rows - 1)
    valid = (src_l >= 0) & (dst_l >= 0)

    if edge_chunks > 1:
        srcs = _chunk_edges(src_l, edge_chunks, -1)
        dlocs = _chunk_edges(jnp.where(valid, d_loc, -1), edge_chunks, -1)
        Ds = [_chunk_edges(b, edge_chunks, 0) for b in D]
        Dinvs = [_chunk_edges(b, edge_chunks, 0) for b in Dinv]
        rbfs = _chunk_edges(rbf, edge_chunks, 0)

    def edges_pass(p, x, sc, dlc):
        h = _eq_layer_norm(p["ln1_g"], x, l_max)
        h_src = halo_fn(h, sc)                 # the one communication step
        v = (sc >= 0) & (dlc >= 0)
        return h_src, v, jnp.maximum(dlc, 0)

    def layer_step(x, p):
        if edge_chunks > 1:
            def chunk_body(acc, args):
                sc, dlc, rc, Dc, Dic = args
                h_src, v, dd = edges_pass(p, x, sc, dlc)
                n_, d_ = _attention_edges(p, cfg, h_src, v, dd, Dc, Dic,
                                          rc, rows)
                return (acc[0] + n_, acc[1] + d_), None

            # den init derives from x so it carries the same varying-manual-
            # axes type under shard_map (scan carries must type-match)
            den0 = x[:, 0, :H] * 0.0
            (num, den), _ = jax.lax.scan(
                jax.checkpoint(
                    chunk_body,
                    policy=jax.checkpoint_policies.nothing_saveable),
                (x * 0.0, den0), (srcs, dlocs, rbfs, Ds, Dinvs))
        else:
            h_src, v, dd = edges_pass(p, x, src_l,
                                      jnp.where(valid, d_loc, -1))
            num, den = _attention_edges(p, cfg, h_src, v, dd, D, Dinv, rbf,
                                        rows)
        x = x + _attention_finalize(p, cfg, num, den)
        x = x + _ffn_block(p, cfg, x)
        return x, None

    x, _ = jax.lax.scan(
        jax.checkpoint(layer_step,
                       policy=jax.checkpoint_policies.nothing_saveable),
        x, params["layers"])
    out = jax.nn.silu(dense(params["out1"], x[:, 0, :]))
    return dense(params["out2"], out)

"""Deep Interest Network (assigned recsys arch — arXiv:1706.06978).

embed_dim=18, history length 100, attention MLP 80-40, main MLP 200-80,
target-attention interaction. The item-embedding table is the hot path: it is
a huge sparse table (10⁷ rows in the full config) served through the *same*
tiered feature store as GNN features — item-popularity is the FAP analogue
(DESIGN.md §4), so Quiver's placement applies directly.

EmbeddingBag is built from first principles (JAX has none): ``jnp.take`` +
``segment_sum`` over ragged bags; the Pallas kernel in
repro/kernels/embedding_bag is the TPU hot-path version.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense, dense_init, mlp, mlp_init


@dataclasses.dataclass(frozen=True)
class DINConfig:
    n_items: int = 200_000
    n_cates: int = 2_000
    embed_dim: int = 18
    hist_len: int = 100
    attn_mlp: tuple[int, ...] = (80, 40)
    mlp: tuple[int, ...] = (200, 80)
    n_dense_feat: int = 4


def din_init(key: jax.Array, cfg: DINConfig, dtype=jnp.float32) -> dict:
    k = jax.random.split(key, 6)
    d = cfg.embed_dim
    de = 2 * d  # item ⊕ category
    attn_in = 4 * de  # [hist, target, hist-target, hist*target]
    mlp_in = 3 * de + cfg.n_dense_feat  # user-interest ⊕ target ⊕ hist-sum
    return {
        "item_embed": jax.random.normal(k[0], (cfg.n_items, d), dtype) * 0.05,
        "cate_embed": jax.random.normal(k[1], (cfg.n_cates, d), dtype) * 0.05,
        "attn": mlp_init(k[2], [attn_in, *cfg.attn_mlp, 1]),
        "mlp": mlp_init(k[3], [mlp_in, *cfg.mlp, 1]),
    }


def embedding_bag(table: jnp.ndarray, ids: jnp.ndarray,
                  weights: Optional[jnp.ndarray] = None, *,
                  mode: str = "sum") -> jnp.ndarray:
    """ids: (..., bag) with -1 padding → (..., d) reduced embeddings."""
    valid = (ids >= 0)
    rows = jnp.take(table, jnp.maximum(ids, 0), axis=0)
    w = valid.astype(rows.dtype)
    if weights is not None:
        w = w * weights
    rows = rows * w[..., None]
    out = rows.sum(axis=-2)
    if mode == "mean":
        out = out / jnp.maximum(w.sum(-1), 1.0)[..., None]
    return out


def _embed_pair(params: dict, item_ids: jnp.ndarray, cate_ids: jnp.ndarray,
                lookup: Optional[Callable] = None) -> jnp.ndarray:
    """item ⊕ category embedding; `lookup` overrides the item-table gather
    (this is where the tiered feature store plugs in)."""
    if lookup is not None:
        it = lookup(item_ids)
    else:
        it = jnp.take(params["item_embed"], jnp.maximum(item_ids, 0), axis=0)
        it = jnp.where((item_ids >= 0)[..., None], it, 0.0)
    ct = jnp.take(params["cate_embed"], jnp.maximum(cate_ids, 0), axis=0)
    ct = jnp.where((cate_ids >= 0)[..., None], ct, 0.0)
    return jnp.concatenate([it, ct], axis=-1)


def din_forward(params: dict, cfg: DINConfig, target_item: jnp.ndarray,
                target_cate: jnp.ndarray, hist_items: jnp.ndarray,
                hist_cates: jnp.ndarray, dense_feat: jnp.ndarray, *,
                item_lookup: Optional[Callable] = None) -> jnp.ndarray:
    """target_*: (B,); hist_*: (B, T) with -1 padding; dense: (B, F) → (B,)
    CTR logits."""
    tgt = _embed_pair(params, target_item, target_cate, item_lookup)  # (B,de)
    hist = _embed_pair(params, hist_items, hist_cates, item_lookup)   # (B,T,de)
    mask = (hist_items >= 0)

    t = tgt[:, None, :].astype(hist.dtype)
    t = jnp.broadcast_to(t, hist.shape)
    a_in = jnp.concatenate([hist, t, hist - t, hist * t], axis=-1)
    scores = mlp(params["attn"], a_in, act=jax.nn.sigmoid)[..., 0]  # (B, T)
    # DIN uses un-normalized sigmoid-ish attention; mask invalid slots
    scores = jnp.where(mask, scores, 0.0)
    interest = (hist * scores[..., None]).sum(1)                    # (B, de)
    hist_mean = (hist * mask[..., None]).sum(1) / jnp.maximum(
        mask.sum(-1, keepdims=True), 1.0)

    x = jnp.concatenate([interest, tgt, hist_mean, dense_feat], axis=-1)
    return mlp(params["mlp"], x, act=jax.nn.silu)[..., 0]


def din_loss(params: dict, cfg: DINConfig, batch: dict,
             item_lookup: Optional[Callable] = None) -> jnp.ndarray:
    logits = din_forward(params, cfg, batch["target_item"],
                         batch["target_cate"], batch["hist_items"],
                         batch["hist_cates"], batch["dense_feat"],
                         item_lookup=item_lookup)
    y = batch["label"].astype(jnp.float32)
    z = logits.astype(jnp.float32)
    return jnp.mean(jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))


def din_score_candidates(params: dict, cfg: DINConfig, user_hist_items,
                         user_hist_cates, dense_feat, cand_items, cand_cates,
                         *, chunk: int = 65536) -> jnp.ndarray:
    """Retrieval scoring: one user's history vs N candidates — batched dot
    through the full DIN tower, scanned in candidate chunks (no python loop).

    user_hist_*: (T,); cand_*: (N,). Returns (N,) scores.
    """
    n = cand_items.shape[0]
    chunks = -(-n // chunk)
    pad = chunks * chunk - n
    ci = jnp.pad(cand_items, (0, pad), constant_values=0).reshape(chunks,
                                                                  chunk)
    cc = jnp.pad(cand_cates, (0, pad), constant_values=0).reshape(chunks,
                                                                  chunk)
    hist_i = jnp.broadcast_to(user_hist_items[None], (chunk,) +
                              user_hist_items.shape)
    hist_c = jnp.broadcast_to(user_hist_cates[None], (chunk,) +
                              user_hist_cates.shape)
    dense = jnp.broadcast_to(dense_feat[None], (chunk,) + dense_feat.shape)

    def body(_, args):
        items, cates = args
        s = din_forward(params, cfg, items, cates, hist_i, hist_c, dense)
        return None, s

    _, scores = jax.lax.scan(body, None, (ci, cc))
    return scores.reshape(-1)[:n]

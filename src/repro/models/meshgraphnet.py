"""MeshGraphNet (assigned arch: 15 layers, 128 hidden, sum agg, 2-layer MLPs).

Encode–Process–Decode over a simulation mesh: per-edge MLP on
(edge_feat, h_src, h_dst) → scatter-sum → per-node MLP; residual updates on
both node and edge latents (arXiv:2010.03409).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.graph.segment import segment_sum
from repro.models.common import layer_norm, layer_norm_init, mlp, mlp_init


def _mlp_block(key, d_in, d_hidden, d_out, mlp_layers=2):
    dims = [d_in] + [d_hidden] * (mlp_layers - 1) + [d_out]
    return {"mlp": mlp_init(key, dims), "ln": layer_norm_init(d_out)}


def _apply_block(p, x):
    return layer_norm(p["ln"], mlp(p["mlp"], x, act=jax.nn.relu))


def mgn_init(key: jax.Array, *, d_node_in: int, d_edge_in: int,
             d_hidden: int = 128, n_layers: int = 15, d_out: int = 3,
             mlp_layers: int = 2) -> dict:
    key, k1, k2, k3, kb = jax.random.split(key, 5)
    blocks = []
    for k in jax.random.split(kb, n_layers):
        ke, kn = jax.random.split(k)
        blocks.append({
            "edge": _mlp_block(ke, 3 * d_hidden, d_hidden, d_hidden,
                               mlp_layers),
            "node": _mlp_block(kn, 2 * d_hidden, d_hidden, d_hidden,
                               mlp_layers),
        })
    return {
        "node_enc": _mlp_block(k1, d_node_in, d_hidden, d_hidden, mlp_layers),
        "edge_enc": _mlp_block(k2, d_edge_in, d_hidden, d_hidden, mlp_layers),
        # homogeneous processor blocks → stacked for lax.scan (+remat)
        "blocks": jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks),
        "decoder": mlp_init(k3, [d_hidden, d_hidden, d_out]),
    }


def mgn_forward(params: dict, node_feat: jnp.ndarray, edge_feat: jnp.ndarray,
                src: jnp.ndarray, dst: jnp.ndarray, *, num_nodes: int,
                shard=lambda x, *n: x) -> jnp.ndarray:
    valid = ((src >= 0) & (dst >= 0)).astype(node_feat.dtype)[:, None]
    s, d = jnp.maximum(src, 0), jnp.maximum(dst, 0)
    h = shard(_apply_block(params["node_enc"], node_feat), "nodes", None)
    e = shard(_apply_block(params["edge_enc"], edge_feat), "edges", None)

    def block_step(carry, blk):
        h, e = carry
        e_in = jnp.concatenate([e, h[s], h[d]], axis=-1)
        e = e + _apply_block(blk["edge"], e_in) * valid
        e = shard(e, "edges", None)
        agg = segment_sum(e * valid, d, num_nodes)
        h = h + _apply_block(blk["node"], jnp.concatenate([h, agg], axis=-1))
        h = shard(h, "nodes", None)
        return (h, e), None

    (h, e), _ = jax.lax.scan(
        jax.checkpoint(block_step,
                       policy=jax.checkpoint_policies.nothing_saveable),
        (h, e), params["blocks"])
    return mlp(params["decoder"], h, act=jax.nn.relu)

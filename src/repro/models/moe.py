"""Mixture-of-Experts FFN with sort-based, fixed-capacity dispatch.

Covers both assigned MoE archs:
  * deepseek-moe-16b — 2 shared + 64 fine-grained routed experts, top-6
  * phi3.5-moe-42b   — 16 experts, top-2

Dispatch is the TPU-friendly scheme: token→expert assignments are sorted,
positions-within-expert computed by a cumsum over a one-hot (T, E) matrix,
tokens scattered into a fixed (E, C, d) buffer (overflow beyond capacity is
dropped, standard GShard semantics), per-expert GEMMs run as one batched
einsum, and results are combined back with the routing weights. The (E, C, d)
buffer is sharded E→tensor axis under pjit — the all-to-all this induces is a
first-class roofline term (EXPERIMENTS.md §Perf).

Beyond-paper tie-in: router statistics are exactly Quiver's FAP analogue for
experts; `repro.core.placement.expert_placement` consumes them to replicate
hot experts (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int                    # per-expert FFN width
    n_shared: int = 0            # always-on shared experts (DeepSeek-MoE)
    d_ff_shared: int = 0         # total width of the shared FFN
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


def moe_init(key: jax.Array, d_model: int, cfg: MoEConfig,
             dtype=jnp.float32) -> dict:
    e, ff = cfg.num_experts, cfg.d_ff
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    sc_in = 1.0 / np.sqrt(d_model)
    sc_out = 1.0 / np.sqrt(ff)
    p = {
        "router": (jax.random.normal(k1, (d_model, e), jnp.float32)
                   * sc_in),
        "w1": jax.random.normal(k2, (e, d_model, ff), dtype) * sc_in,
        "w3": jax.random.normal(k3, (e, d_model, ff), dtype) * sc_in,
        "w2": jax.random.normal(k4, (e, ff, d_model), dtype) * sc_out,
    }
    if cfg.n_shared:
        ks1, ks2, ks3 = jax.random.split(k5, 3)
        ffs = cfg.d_ff_shared
        p["shared"] = {
            "w1": jax.random.normal(ks1, (d_model, ffs), dtype) * sc_in,
            "w3": jax.random.normal(ks2, (d_model, ffs), dtype) * sc_in,
            "w2": jax.random.normal(ks3, (ffs, d_model), dtype)
                  / np.sqrt(ffs),
        }
    return p


def moe_apply(p: dict, x: jnp.ndarray, cfg: MoEConfig, *,
              shard: Optional[Callable] = None
              ) -> tuple[jnp.ndarray, dict]:
    """x: (T, d) tokens. Returns (out (T, d), stats) where stats carries the
    router aux loss and per-expert load (the FAP-for-experts signal)."""
    t, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    cap = int(np.ceil(t * k * cfg.capacity_factor / e))
    cap = max(cap, 1)

    logits = x.astype(jnp.float32) @ p["router"]          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                # (T, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # --- fixed-capacity sort-based dispatch -----------------------------
    flat_e = top_e.reshape(-1)                            # (T*k,)
    flat_t = jnp.repeat(jnp.arange(t), k)
    flat_w = top_w.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)   # (T*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot        # exclusive cumsum
    slot = (pos_in_e * onehot).sum(-1)                    # (T*k,)
    keep = slot < cap
    buf_idx = jnp.where(keep, flat_e * cap + slot, e * cap)  # drop → sink

    dispatch = jnp.zeros((e * cap + 1, d), x.dtype)
    dispatch = dispatch.at[buf_idx].add(x[flat_t])
    dispatch = dispatch[:-1].reshape(e, cap, d)
    if shard is not None:
        dispatch = shard(dispatch, "expert", None, None)

    h1 = jnp.einsum("ecd,edf->ecf", dispatch, p["w1"].astype(x.dtype))
    h3 = jnp.einsum("ecd,edf->ecf", dispatch, p["w3"].astype(x.dtype))
    h = jax.nn.silu(h1) * h3
    y = jnp.einsum("ecf,efd->ecd", h, p["w2"].astype(x.dtype))
    if shard is not None:
        y = shard(y, "expert", None, None)

    flat_y = y.reshape(e * cap, d)
    gathered = flat_y[jnp.minimum(buf_idx, e * cap - 1)]
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    out = jnp.zeros((t, d), x.dtype).at[flat_t].add(
        gathered * flat_w[:, None].astype(x.dtype))

    if cfg.n_shared:
        s = p["shared"]
        hs = jax.nn.silu(x @ s["w1"].astype(x.dtype)) * (
            x @ s["w3"].astype(x.dtype))
        out = out + hs @ s["w2"].astype(x.dtype)

    # --- router statistics ----------------------------------------------
    load = jnp.bincount(flat_e, weights=keep.astype(jnp.float32), length=e)
    importance = probs.sum(0)
    # Switch-style load-balancing aux: E · Σ_e f_e · P_e
    f = load / jnp.maximum(load.sum(), 1.0)
    pr = importance / jnp.maximum(importance.sum(), 1e-9)
    aux = cfg.router_aux_weight * e * jnp.sum(f * pr)
    dropped = (~keep).sum()
    stats = {"aux_loss": aux, "expert_load": load, "dropped": dropped}
    return out, stats

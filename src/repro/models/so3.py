"""SO(3) machinery for eSCN-style equivariant convolutions (EquiformerV2).

The eSCN trick (arXiv:2302.03655 / 2306.12059): rotate each edge's irrep
features into a frame where the edge direction is the z-axis; there the
tensor-product convolution block-diagonalizes over the azimuthal order m, so
an SO(2) linear layer (O(L³)) replaces the full Clebsch–Gordan contraction
(O(L⁶)).

The rotation needs per-edge Wigner-D matrices for real spherical harmonics up
to l_max. We build them from the ZYZ decomposition

    D(α, β, γ) = Z(α) · d(β) · Z(γ)

where ``Z`` is the (block cos/sin) rotation about z in the real-SH basis and
``d(β)`` — the rotation about y — is evaluated from Wigner's explicit
small-d formula. Since every term of d^l has total degree 2l in
(cos β/2, sin β/2), d^l(β) = Σ_{b=0..2l} M_b · c^{2l-b} s^b with *constant*
matrices M_b. We precompute M_b in the complex basis with exact factorials
(NumPy, float64), conjugate once by the complex→real change of basis, and at
runtime evaluate a (2l+1)-term monomial contraction per edge — fully static
shapes, JIT-friendly, no table files (the e3nn ``_Jd.pt`` equivalent is
generated in-process).

Conventions are pinned by tests: the l=1 block of D equals the 3×3 rotation
matrix in the (y, z, x) real-SH ordering, and D(align(r)) maps the l=1
embedding of r̂ to that of ẑ.
"""
from __future__ import annotations

from functools import lru_cache, partial
from math import factorial

import jax
import jax.numpy as jnp
import numpy as np


def num_coeffs(l_max: int) -> int:
    return (l_max + 1) ** 2


def lm_index(l: int, m: int) -> int:
    return l * l + l + m


@lru_cache(maxsize=None)
def _complex_to_real_basis(l: int) -> np.ndarray:
    """Unitary C with real coefficients c_R = C c_C (Condon–Shortley).

    Real basis ordering m = -l..l; m<0 ↔ sin(|m|φ), m>0 ↔ cos(mφ).
    """
    C = np.zeros((2 * l + 1, 2 * l + 1), dtype=np.complex128)
    s2 = 1.0 / np.sqrt(2.0)
    for m in range(-l, l + 1):
        r = lm_index(l, m) - l * l - l + l  # row offset = m + l
        if m == 0:
            C[l, l] = 1.0
        elif m > 0:
            # Y_{l,m} = (1/√2)(Y^{-m} + (-1)^m Y^{m})
            C[m + l, -m + l] = s2
            C[m + l, m + l] = s2 * (-1.0) ** m
        else:  # m < 0
            a = -m
            # Y_{l,-a} = (i/√2)(Y^{-a} - (-1)^a Y^{a})
            C[m + l, -a + l] = 1j * s2
            C[m + l, a + l] = -1j * s2 * (-1.0) ** a
    return C


@lru_cache(maxsize=None)
def _wigner_d_monomials(l: int) -> np.ndarray:
    """M̃: (2l+1 monomials, 2l+1, 2l+1) real, real-SH basis, so that
    d_real(β) = Σ_b M̃[b] · cos(β/2)^{2l-b} · sin(β/2)^b."""
    dim = 2 * l + 1
    M = np.zeros((dim, dim, dim), dtype=np.float64)  # complex-basis (real)
    for mp in range(-l, l + 1):          # m' (row)
        for m in range(-l, l + 1):       # m (col)
            pref = np.sqrt(float(factorial(l + mp) * factorial(l - mp)
                                 * factorial(l + m) * factorial(l - m)))
            kmin = max(0, m - mp)
            kmax = min(l + m, l - mp)
            for k in range(kmin, kmax + 1):
                denom = (factorial(l + m - k) * factorial(k)
                         * factorial(l - mp - k) * factorial(mp - m + k))
                coeff = ((-1.0) ** (mp - m + k)) * pref / denom
                b = mp - m + 2 * k       # sin power; cos power = 2l - b
                M[b, mp + l, m + l] += coeff
    C = _complex_to_real_basis(l)
    Mr = np.einsum("ij,bjk,lk->bil", C, M, C.conj())
    assert np.abs(Mr.imag).max() < 1e-9, f"l={l} imag leak"
    # Sign-fix the m<0 (sine) basis functions so the l=1 block of D equals
    # the 3×3 rotation matrix in (y,z,x) ordering (e3nn convention) —
    # conjugation by S = diag(-1 for m<0, +1 otherwise), validated in tests.
    sgn = np.where(np.arange(-l, l + 1) < 0, -1.0, 1.0)
    return np.ascontiguousarray(Mr.real * sgn[None, :, None]
                                * sgn[None, None, :])


@lru_cache(maxsize=None)
def _z_rot_indices(l_max: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Index arrays for building the block z-rotation over the full
    (l_max+1)² coefficient vector: returns (idx_m, idx_negm, m_of_row)."""
    S = num_coeffs(l_max)
    idx = np.arange(S)
    ls = np.floor(np.sqrt(idx)).astype(np.int64)
    ms = idx - ls * ls - ls
    neg = ls * ls + ls - ms
    return idx, neg, ms


def z_rotation(theta: jnp.ndarray, l_max: int) -> jnp.ndarray:
    """(..., S, S) real-SH rotation about z by theta (batched).

    Acts block-diagonally: rows with order m mix with -m via cos/sin(mθ).
    """
    idx, neg, ms = _z_rot_indices(l_max)
    S = num_coeffs(l_max)
    msj = jnp.asarray(ms, jnp.float32)
    cos = jnp.cos(theta[..., None] * msj)
    sin = jnp.sin(theta[..., None] * msj)
    eye_pos = jnp.zeros((S, S), jnp.float32).at[idx, idx].set(1.0)
    swap = jnp.zeros((S, S), jnp.float32).at[idx, neg].set(1.0)
    swap = swap.at[idx[ms == 0], neg[ms == 0]].set(0.0)
    # Row of signed order m: D[m,m] = cos(mθ), D[m,-m] = -sin(mθ) — the same
    # S-conjugated convention as the monomial tensors. Validated by tests.
    return (cos[..., :, None] * eye_pos - sin[..., :, None] * swap)


def y_rotation(beta: jnp.ndarray, l_max: int) -> jnp.ndarray:
    """(..., S, S) real-SH rotation about y by beta (batched), block-diag
    over l, evaluated from the precomputed monomial tensors."""
    S = num_coeffs(l_max)
    shape = beta.shape
    c = jnp.cos(beta / 2.0)
    s = jnp.sin(beta / 2.0)
    out = jnp.zeros(shape + (S, S), jnp.float32)
    for l in range(l_max + 1):
        M = jnp.asarray(_wigner_d_monomials(l), jnp.float32)  # (2l+1,dim,dim)
        powers = jnp.stack([c ** (2 * l - b) * s ** b
                            for b in range(2 * l + 1)], axis=-1)
        blk = jnp.einsum("...b,bij->...ij", powers, M)
        out = out.at[..., l * l:(l + 1) ** 2, l * l:(l + 1) ** 2].set(blk)
    return out


def edge_rotations(rhat: jnp.ndarray, l_max: int
                   ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-edge Wigner matrices (D_align, D_inv) with D_align·emb(r̂)=emb(ẑ).

    rhat: (..., 3) unit vectors. R_align = Ry(-β)·Rz(-α) with α = atan2(y,x),
    β = arccos(z); D composes the same way in the real-SH rep.
    """
    x, y, z = rhat[..., 0], rhat[..., 1], rhat[..., 2]
    alpha = jnp.arctan2(y, x)
    beta = jnp.arccos(jnp.clip(z, -1.0, 1.0))
    Dz = z_rotation(-alpha, l_max)
    Dy = y_rotation(-beta, l_max)
    D = jnp.einsum("...ij,...jk->...ik", Dy, Dz)
    Dinv = jnp.swapaxes(D, -1, -2)  # orthogonal
    return D, Dinv


def edge_rotation_blocks(rhat: jnp.ndarray, l_max: int
                         ) -> tuple[list[jnp.ndarray], list[jnp.ndarray]]:
    """Per-l rotation blocks [(E, 2l+1, 2l+1)] — O(Σ(2l+1)²)=O(455) floats
    per edge at l_max=6 instead of O(49²) for the dense matrix; this is what
    makes full-batch Equiformer shapes fit (DESIGN.md §5)."""
    x, y, z = rhat[..., 0], rhat[..., 1], rhat[..., 2]
    alpha = jnp.arctan2(y, x)
    beta = jnp.arccos(jnp.clip(z, -1.0, 1.0))
    c = jnp.cos(-beta / 2.0)
    s = jnp.sin(-beta / 2.0)
    Ds, Dinvs = [], []
    for l in range(l_max + 1):
        dim = 2 * l + 1
        ms = jnp.asarray(np.arange(-l, l + 1), jnp.float32)
        theta = -alpha
        cos = jnp.cos(theta[..., None] * ms)
        sin = jnp.sin(theta[..., None] * ms)
        idx = np.arange(dim)
        neg = dim - 1 - idx
        eye = jnp.zeros((dim, dim), jnp.float32).at[idx, idx].set(1.0)
        swap = jnp.zeros((dim, dim), jnp.float32).at[idx, neg].set(1.0)
        if l > 0:
            swap = swap.at[l, l].set(0.0)
        else:
            swap = jnp.zeros((1, 1), jnp.float32)
        Dz = cos[..., :, None] * eye - sin[..., :, None] * swap
        M = jnp.asarray(_wigner_d_monomials(l), jnp.float32)
        powers = jnp.stack([c ** (2 * l - b) * s ** b
                            for b in range(2 * l + 1)], axis=-1)
        Dy = jnp.einsum("...b,bij->...ij", powers, M)
        D = jnp.einsum("...ij,...jk->...ik", Dy, Dz)
        Ds.append(D)
        Dinvs.append(jnp.swapaxes(D, -1, -2))
    return Ds, Dinvs


def rotation_matrix_zyz(alpha: float, beta: float, gamma: float) -> np.ndarray:
    """3×3 R = Rz(α)Ry(β)Rz(γ) — test helper for convention checks."""
    ca, sa = np.cos(alpha), np.sin(alpha)
    cb, sb = np.cos(beta), np.sin(beta)
    cg, sg = np.cos(gamma), np.sin(gamma)
    Rz1 = np.array([[ca, -sa, 0], [sa, ca, 0], [0, 0, 1]])
    Ry = np.array([[cb, 0, sb], [0, 1, 0], [-sb, 0, cb]])
    Rz2 = np.array([[cg, -sg, 0], [sg, cg, 0], [0, 0, 1]])
    return Rz1 @ Ry @ Rz2


def wigner_zyz(alpha, beta, gamma, l_max: int) -> jnp.ndarray:
    """Full real-SH Wigner D(α,β,γ) = Z(α)·d(β)·Z(γ) (batched)."""
    a = jnp.asarray(alpha, jnp.float32)
    b = jnp.asarray(beta, jnp.float32)
    g = jnp.asarray(gamma, jnp.float32)
    return jnp.einsum("...ij,...jk,...kl->...il",
                      z_rotation(a, l_max), y_rotation(b, l_max),
                      z_rotation(g, l_max))


def l1_embedding(vec: jnp.ndarray) -> jnp.ndarray:
    """Real-SH l=1 embedding ordering (y, z, x) (e3nn convention)."""
    return jnp.stack([vec[..., 1], vec[..., 2], vec[..., 0]], axis=-1)

"""Attention: blockwise (flash-style) causal attention + GQA + RoPE + decode.

``blockwise_attention`` is the XLA path (scan over KV chunks with online
softmax — never materializes the (S, S) score matrix); the Pallas kernel in
repro/kernels/flash_attention implements the same contraction for TPU and is
validated against this reference.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def rope_angles(positions: jnp.ndarray, head_dim: int,
                theta: float = 1e6) -> tuple[jnp.ndarray, jnp.ndarray]:
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray
               ) -> jnp.ndarray:
    """x: (..., S, H, dh); cos/sin: (..., S, dh/2) broadcast over heads."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return out.astype(x.dtype)  # rotate in f32, keep activation dtype


def _expand_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    if n_rep == 1:
        return k
    b, s, kh, dh = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kh, n_rep, dh)
                            ).reshape(b, s, kh * n_rep, dh)


def blockwise_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, q_chunk: int = 512,
                        kv_chunk: int = 1024,
                        q_offset: int = 0) -> jnp.ndarray:
    """q: (B, Sq, H, dh); k,v: (B, Skv, K, dh) with H % K == 0.

    Online-softmax over KV chunks; causal mask uses absolute positions
    (query i attends key j iff j <= i + q_offset).
    """
    b, sq, h, dh = q.shape
    skv, kh = k.shape[1], k.shape[2]
    k = _expand_kv(k, h // kh)
    v = _expand_kv(v, h // kh)
    scale = 1.0 / np.sqrt(dh)

    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    nq = -(-sq // q_chunk)
    nkv = -(-skv // kv_chunk)
    pad_q = nq * q_chunk - sq
    pad_kv = nkv * kv_chunk - skv
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    # (nq, B, H, qc, dh) / (nkv, B, H, kc, dh)
    qs = qp.reshape(b, nq, q_chunk, h, dh).transpose(1, 0, 3, 2, 4)
    ks = kp.reshape(b, nkv, kv_chunk, h, dh).transpose(1, 0, 3, 2, 4)
    vs = vp.reshape(b, nkv, kv_chunk, h, dh).transpose(1, 0, 3, 2, 4)

    q_pos = jnp.arange(nq * q_chunk).reshape(nq, q_chunk) + q_offset
    kv_pos = jnp.arange(nkv * kv_chunk).reshape(nkv, kv_chunk)
    kv_valid = kv_pos < skv

    def per_q_chunk(qi, q_blk):
        # q_blk: (B, H, qc, dh)
        acc0 = (jnp.zeros((b, h, q_chunk, dh), jnp.float32),
                jnp.full((b, h, q_chunk), -jnp.inf, jnp.float32),
                jnp.zeros((b, h, q_chunk), jnp.float32))

        def kv_step(carry, inputs):
            o, m, l = carry
            kj, k_blk, v_blk = inputs
            # bf16 operands, f32 accumulation (MXU-native) — upcasting the
            # operands doubled every attention collective and forced f32
            # matmuls (§Perf iteration log)
            s = jnp.einsum("bhqd,bhkd->bhqk", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            mask = kv_valid[kj][None, None, None, :]
            if causal:
                mask = mask & (kv_pos[kj][None, None, None, :]
                               <= q_pos[qi][None, None, :, None])
            s = jnp.where(mask, s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(-1))
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            corr = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf))
            l = l * corr + p.sum(-1)
            o = o * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32)
            return (o, m_new, l), None

        (o, m, l), _ = jax.lax.scan(kv_step, acc0,
                                    (jnp.arange(nkv), ks, vs))
        return o / jnp.maximum(l, 1e-20)[..., None]

    out = jax.lax.map(lambda args: per_q_chunk(*args),
                      (jnp.arange(nq), qs))        # (nq, B, H, qc, dh)
    out = out.transpose(1, 0, 3, 2, 4).reshape(b, nq * q_chunk, h, dh)
    return out[:, :sq].astype(q.dtype)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, cache_len: jnp.ndarray
                     ) -> jnp.ndarray:
    """Single-position decode. q: (B, 1, H, dh); caches: (B, S, K, dh);
    cache_len: () — number of valid cache positions (new token included)."""
    b, _, h, dh = q.shape
    skv, kh = k_cache.shape[1], k_cache.shape[2]
    k = _expand_kv(k_cache, h // kh)
    v = _expand_kv(v_cache, h // kh)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(k.dtype), k,
                   preferred_element_type=jnp.float32) / np.sqrt(dh)
    mask = (jnp.arange(skv) < cache_len)[None, None, None, :]
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def reference_attention(q, k, v, *, causal=True, q_offset: int = 0):
    """Naive O(S²) oracle for tests."""
    h, kh = q.shape[2], k.shape[2]
    k = _expand_kv(k, h // kh)
    v = _expand_kv(v, h // kh)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(q.shape[-1])
    if causal:
        sq, skv = q.shape[1], k.shape[1]
        mask = (jnp.arange(skv)[None, :]
                <= (jnp.arange(sq) + q_offset)[:, None])
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)

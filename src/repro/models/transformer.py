"""Decoder-only LM substrate: GQA + RoPE (+ optional QKV bias / qk-norm),
SwiGLU or MoE FFN, RMSNorm, layers stacked under a remat'd ``lax.scan``
(compact HLO at 512-way SPMD), fused vocab-sharded cross entropy (full logits
are never materialized unsharded).

Covers all five assigned LM archs via config:
  qwen1.5-4b / codeqwen1.5-7b  — QKV bias, MHA-style GQA (kv == heads)
  qwen3-4b                     — qk_norm, GQA kv=8, head_dim 128 (H·dh ≠ d)
  deepseek-moe-16b             — MoE(64e top-6 + 2 shared fine-grained)
  phi3.5-moe-42b               — MoE(16e top-2), GQA kv=8
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import (apply_rope, blockwise_attention,
                                    decode_attention, rope_angles)
from repro.models.common import rms_norm
from repro.models.moe import MoEConfig, moe_apply, moe_init


@dataclasses.dataclass(frozen=True)
class LMConfig:
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv: int
    head_dim: int
    d_ff: int
    qkv_bias: bool = False
    qk_norm: bool = False
    moe: Optional[MoEConfig] = None
    rope_theta: float = 1e6
    dtype: str = "float32"           # activation/compute dtype
    q_chunk: int = 512
    kv_chunk: int = 1024

    @property
    def adtype(self):
        return jnp.dtype(self.dtype)


def _noshard(x, *names):
    return x


def lm_init(key: jax.Array, cfg: LMConfig, dtype=jnp.float32) -> dict:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    L = cfg.n_layers
    sc = 1.0 / np.sqrt(d)
    keys = jax.random.split(key, 12)

    def pstack(k, shape, scale):
        return jax.random.normal(k, (L,) + shape, dtype) * scale

    params = {
        "embed": jax.random.normal(keys[0], (cfg.vocab, d), dtype) * 0.02,
        "unembed": jax.random.normal(keys[1], (d, cfg.vocab), dtype) * sc,
        "final_ln": jnp.ones((d,), dtype),
        "layers": {
            "ln1": jnp.ones((L, d), dtype),
            "wq": pstack(keys[2], (d, h * dh), sc),
            "wk": pstack(keys[3], (d, kv * dh), sc),
            "wv": pstack(keys[4], (d, kv * dh), sc),
            "wo": pstack(keys[5], (h * dh, d), 1.0 / np.sqrt(h * dh)),
            "ln2": jnp.ones((L, d), dtype),
        },
    }
    lay = params["layers"]
    if cfg.qkv_bias:
        lay["bq"] = jnp.zeros((L, h * dh), dtype)
        lay["bk"] = jnp.zeros((L, kv * dh), dtype)
        lay["bv"] = jnp.zeros((L, kv * dh), dtype)
    if cfg.qk_norm:
        lay["q_norm"] = jnp.ones((L, dh), dtype)
        lay["k_norm"] = jnp.ones((L, dh), dtype)
    if cfg.moe is None:
        lay["w1"] = pstack(keys[6], (d, cfg.d_ff), sc)
        lay["w3"] = pstack(keys[7], (d, cfg.d_ff), sc)
        lay["w2"] = pstack(keys[8], (cfg.d_ff, d), 1.0 / np.sqrt(cfg.d_ff))
    else:
        moe_keys = jax.random.split(keys[9], L)
        per_layer = [moe_init(mk, d, cfg.moe, dtype) for mk in moe_keys]
        lay["moe"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *per_layer)
    return params


def _attn(lp: dict, cfg: LMConfig, h: jnp.ndarray, cos, sin, shard,
          *, decode_cache=None, cache_len=None):
    b, s, d = h.shape
    H, KV, dh = cfg.n_heads, cfg.n_kv, cfg.head_dim
    x = rms_norm({"g": lp["ln1"]}, h)
    q = x @ lp["wq"].astype(x.dtype)
    k = x @ lp["wk"].astype(x.dtype)
    v = x @ lp["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + lp["bq"].astype(q.dtype)
        k = k + lp["bk"].astype(k.dtype)
        v = v + lp["bv"].astype(v.dtype)
    q = q.reshape(b, s, H, dh)
    k = k.reshape(b, s, KV, dh)
    v = v.reshape(b, s, KV, dh)
    if cfg.qk_norm:
        q = rms_norm({"g": lp["q_norm"]}, q)
        k = rms_norm({"g": lp["k_norm"]}, k)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = shard(q, "batch", None, "tp", None)
    k = shard(k, "batch", None, "tp_kv", None)
    if decode_cache is None:
        o = blockwise_attention(q, k, v, causal=True, q_chunk=cfg.q_chunk,
                                kv_chunk=cfg.kv_chunk)
        new_cache = None
    else:
        kc, vc = decode_cache
        idx = cache_len - 1
        kc = jax.lax.dynamic_update_slice(kc, k, (0, idx, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, idx, 0, 0))
        o = decode_attention(q, kc, vc, cache_len)
        new_cache = (kc, vc)
    o = o.reshape(b, s, H * dh)
    return h + o @ lp["wo"].astype(o.dtype), new_cache


def _ffn(lp: dict, cfg: LMConfig, h: jnp.ndarray, shard):
    b, s, d = h.shape
    x = rms_norm({"g": lp["ln2"]}, h)
    if cfg.moe is None:
        g = jax.nn.silu(x @ lp["w1"].astype(x.dtype))
        u = x @ lp["w3"].astype(x.dtype)
        y = (g * u) @ lp["w2"].astype(x.dtype)
        return h + y, jnp.zeros((), jnp.float32)
    out, stats = moe_apply(lp["moe"], x.reshape(b * s, d), cfg.moe,
                           shard=shard)
    return h + out.reshape(b, s, d), stats["aux_loss"]


def lm_forward(params: dict, tokens: jnp.ndarray, cfg: LMConfig,
               shard: Callable = _noshard) -> tuple[jnp.ndarray, jnp.ndarray]:
    """tokens: (B, S) → (hidden (B, S, d) in cfg dtype, aux loss scalar)."""
    b, s = tokens.shape
    h = params["embed"].astype(cfg.adtype)[tokens]
    h = shard(h, "batch", None, None)
    cos, sin = rope_angles(jnp.arange(s), cfg.head_dim, cfg.rope_theta)
    cos, sin = cos[None], sin[None]

    def block(carry, lp):
        h, aux = carry
        h, _ = _attn(lp, cfg, h, cos, sin, shard)
        h = shard(h, "batch", None, None)
        h, a = _ffn(lp, cfg, h, shard)
        h = shard(h, "batch", None, None)
        return (h, aux + a), None

    (h, aux), _ = jax.lax.scan(
        jax.checkpoint(block, policy=jax.checkpoint_policies.nothing_saveable),
        (h, jnp.zeros((), jnp.float32)), params["layers"])
    h = rms_norm({"g": params["final_ln"]}, h)
    return h, aux


def lm_loss(params: dict, tokens: jnp.ndarray, targets: jnp.ndarray,
            cfg: LMConfig, shard: Callable = _noshard) -> jnp.ndarray:
    """Fused vocab-sharded cross entropy: logits stay (batch, seq, vocab_tp)-
    sharded; the log-sum-exp reduces across the tp axis inside the same
    program (XLA inserts the small collectives)."""
    h, aux = lm_forward(params, tokens, cfg, shard)
    logits = h @ params["unembed"].astype(h.dtype)
    logits = shard(logits, "batch", None, "tp").astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (lse - tgt).mean()
    return nll + aux


def lm_prefill(params: dict, tokens: jnp.ndarray, cfg: LMConfig,
               shard: Callable = _noshard
               ) -> tuple[jnp.ndarray, dict]:
    """Prefill: run the full prompt, return last-position logits and the
    stacked KV cache (L, B, S, KV, dh) for subsequent decode steps."""
    b, s = tokens.shape
    h = params["embed"].astype(cfg.adtype)[tokens]
    h = shard(h, "batch", None, None)
    cos, sin = rope_angles(jnp.arange(s), cfg.head_dim, cfg.rope_theta)
    cos, sin = cos[None], sin[None]
    H, KV, dh = cfg.n_heads, cfg.n_kv, cfg.head_dim

    def block(h, lp):
        x = rms_norm({"g": lp["ln1"]}, h)
        q = x @ lp["wq"].astype(x.dtype)
        k = x @ lp["wk"].astype(x.dtype)
        v = x @ lp["wv"].astype(x.dtype)
        if cfg.qkv_bias:
            q = q + lp["bq"].astype(q.dtype)
            k = k + lp["bk"].astype(k.dtype)
            v = v + lp["bv"].astype(v.dtype)
        q = q.reshape(b, s, H, dh)
        k = k.reshape(b, s, KV, dh)
        v = v.reshape(b, s, KV, dh)
        if cfg.qk_norm:
            q = rms_norm({"g": lp["q_norm"]}, q)
            k = rms_norm({"g": lp["k_norm"]}, k)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        q = shard(q, "batch", None, "tp", None)
        k = shard(k, "batch", None, "tp_kv", None)
        o = blockwise_attention(q, k, v, causal=True, q_chunk=cfg.q_chunk,
                                kv_chunk=cfg.kv_chunk)
        h = h + o.reshape(b, s, H * dh) @ lp["wo"].astype(o.dtype)
        h, _ = _ffn(lp, cfg, h, shard)
        h = shard(h, "batch", None, None)
        kv_out = (shard(k.astype(jnp.bfloat16), "batch", None, "tp_kv", None),
                  shard(v.astype(jnp.bfloat16), "batch", None, "tp_kv", None))
        return h, kv_out

    (h, (ks, vs)) = jax.lax.scan(
        jax.checkpoint(block, policy=jax.checkpoint_policies.nothing_saveable),
        h, params["layers"])
    h = rms_norm({"g": params["final_ln"]}, h)
    logits = (h[:, -1, :] @ params["unembed"].astype(h.dtype)).astype(
        jnp.float32)
    logits = shard(logits, "batch", "tp")
    return logits, {"k": ks, "v": vs}


def lm_decode_step(params: dict, token: jnp.ndarray, cache: dict,
                   cache_len: jnp.ndarray, cfg: LMConfig,
                   shard: Callable = _noshard
                   ) -> tuple[jnp.ndarray, dict]:
    """One serving step: token (B, 1) + KV cache → (logits (B, V), cache').

    cache: {"k": (L, B, S, KV, dh), "v": ...} pre-allocated to max length;
    cache_len is the absolute position of the *new* token + 1.
    """
    b = token.shape[0]
    h = params["embed"].astype(cfg.adtype)[token]
    pos = (cache_len - 1)[None]
    cos, sin = rope_angles(pos, cfg.head_dim, cfg.rope_theta)
    cos, sin = cos[None], sin[None]

    def block(carry, xs):
        h = carry
        lp, kc, vc = xs
        h, new_kv = _attn(lp, cfg, h, cos, sin, shard,
                          decode_cache=(kc, vc), cache_len=cache_len)
        h, _ = _ffn(lp, cfg, h, shard)
        return h, new_kv

    h, (k_new, v_new) = jax.lax.scan(
        block, h, (params["layers"], cache["k"], cache["v"]))
    h = rms_norm({"g": params["final_ln"]}, h)
    logits = (h[:, 0, :] @ params["unembed"].astype(h.dtype)).astype(
        jnp.float32)
    logits = shard(logits, "batch", "tp")
    return logits, {"k": k_new, "v": v_new}


def init_decode_cache(cfg: LMConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16) -> dict:
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def lm_param_count(cfg: LMConfig) -> int:
    d, h, kv, dh, L = (cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim,
                       cfg.n_layers)
    n = 2 * cfg.vocab * d + d
    attn = d * h * dh + 2 * d * kv * dh + h * dh * d
    if cfg.moe is None:
        ffn = 3 * d * cfg.d_ff
    else:
        m = cfg.moe
        ffn = m.num_experts * 3 * d * m.d_ff + d * m.num_experts
        if m.n_shared:
            ffn += 3 * d * m.d_ff_shared
    return n + L * (attn + ffn + 2 * d)


def lm_active_param_count(cfg: LMConfig) -> int:
    if cfg.moe is None:
        return lm_param_count(cfg)
    d, h, kv, dh, L = (cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim,
                       cfg.n_layers)
    m = cfg.moe
    attn = d * h * dh + 2 * d * kv * dh + h * dh * d
    ffn = m.top_k * 3 * d * m.d_ff + d * m.num_experts
    if m.n_shared:
        ffn += 3 * d * m.d_ff_shared
    return 2 * cfg.vocab * d + d + L * (attn + ffn + 2 * d)

"""GraphSAGE / GAT (the paper's served models) and GIN (assigned arch).

Each model exposes two execution forms:

* ``full_graph_forward(params, x, src, dst, num_nodes)`` — message passing
  over an explicit (possibly padded) edge list via ``scatter_spmm`` — used by
  full-batch training shapes (full_graph_sm / ogb_products) and by the
  Pallas ``segment_spmm`` hot path.
* ``layered_forward(params, hop_feats, fanouts)`` — dense fan-out aggregation
  over sampled hop arrays (serving / minibatch path): hop k features have
  shape (B·∏f, d); layer k reduces (n, f, d) → (n, d). This is the
  fixed-shape TPU serving form fed by the device sampler.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.graph.segment import scatter_spmm, segment_softmax, segment_sum
from repro.models.common import dense, dense_init, layer_norm, layer_norm_init


# ---------------------------------------------------------------------------
# GraphSAGE (mean aggregator)
# ---------------------------------------------------------------------------
def sage_init(key: jax.Array, dims: Sequence[int]) -> dict:
    """dims = [d_in, h1, ..., h_L]; layer i maps dims[i] -> dims[i+1]."""
    layers = []
    for i in range(len(dims) - 1):
        key, k1, k2 = jax.random.split(key, 3)
        layers.append({"self": dense_init(k1, dims[i], dims[i + 1]),
                       "neigh": dense_init(k2, dims[i], dims[i + 1]),
                       "ln": layer_norm_init(dims[i + 1])})
    return {"layers": layers}


def _sage_layer(p: dict, h_self: jnp.ndarray, h_agg: jnp.ndarray,
                *, final: bool) -> jnp.ndarray:
    out = dense(p["self"], h_self) + dense(p["neigh"], h_agg)
    out = layer_norm(p["ln"], out)
    return out if final else jax.nn.relu(out)


def sage_full_graph(params: dict, x: jnp.ndarray, src: jnp.ndarray,
                    dst: jnp.ndarray, *, num_nodes: int) -> jnp.ndarray:
    deg = segment_sum(jnp.ones_like(src, dtype=x.dtype),
                      jnp.maximum(src, 0), num_nodes)
    h = x
    L = len(params["layers"])
    for i, p in enumerate(params["layers"]):
        agg = scatter_spmm(h, dst, src, num_nodes)  # mean over out-neighbors
        agg = agg / jnp.maximum(deg, 1.0)[:, None]
        h = _sage_layer(p, h, agg, final=i == L - 1)
    return h


def sage_layered(params: dict, hop_feats: list[jnp.ndarray],
                 fanouts: Sequence[int],
                 hop_masks: list[jnp.ndarray] | None = None,
                 deep_agg: jnp.ndarray | None = None) -> jnp.ndarray:
    """Minibatch/serving GraphSAGE: layer ℓ is applied at every remaining hop
    level, shrinking the deepest level each round (standard layered
    evaluation). hop_feats[k]: (B·∏_{h≤k} f_h, d), -1-padded slots masked.

    ``deep_agg`` is the fused gather→aggregate fast path: the store already
    reduced the deepest hop's child rows into per-parent sums
    (``TieredFeatureStore.lookup_aggregate``), so ``hop_feats`` carries one
    entry FEWER (the dense deepest-hop tensor is never materialized) while
    ``hop_masks``, when given, still covers every hop including the deepest —
    its counts finish the mean here with the same ``m.sum(1)`` expression
    the unfused branch uses, keeping the two forms bit-identical."""
    L = len(params["layers"])
    assert L == len(fanouts), (L, fanouts)
    h = list(hop_feats)
    masks = (list(hop_masks) if hop_masks is not None
             else [None] * (len(h) + (deep_agg is not None)))
    for layer in range(L):
        p = params["layers"][layer]
        new_h = []
        for lvl in range(L - layer):
            fan = fanouts[lvl]
            fused_lvl = False
            if deep_agg is not None:
                fused_lvl = layer == 0 and lvl == L - 1
            if fused_lvl:
                if masks[lvl + 1] is not None:
                    m = masks[lvl + 1].reshape(h[lvl].shape[0], fan, 1)
                    m = m.astype(deep_agg.dtype)
                    agg = deep_agg / jnp.maximum(m.sum(1), 1.0)
                else:
                    agg = deep_agg / fan
            else:
                child = h[lvl + 1].reshape(h[lvl].shape[0], fan, -1)
                if masks[lvl + 1] is not None:
                    m = masks[lvl + 1].reshape(h[lvl].shape[0], fan, 1)
                    m = m.astype(child.dtype)
                    agg = (child * m).sum(1) / jnp.maximum(m.sum(1), 1.0)
                else:
                    agg = child.mean(1)
            new_h.append(_sage_layer(p, h[lvl], agg,
                                     final=layer == L - 1))
        h = new_h
    return h[0]


# ---------------------------------------------------------------------------
# GAT (4 heads, the paper's second model)
# ---------------------------------------------------------------------------
def gat_init(key: jax.Array, dims: Sequence[int], *, heads: int = 4) -> dict:
    layers = []
    for i in range(len(dims) - 1):
        key, k1, k2, k3 = jax.random.split(key, 4)
        d_out = dims[i + 1]
        d_in = dims[i] if i == 0 else dims[i] * heads  # heads concatenate
        layers.append({
            "proj": dense_init(k1, d_in, heads * d_out, bias=False),
            "attn_src": jax.random.normal(k2, (heads, d_out)) * 0.1,
            "attn_dst": jax.random.normal(k3, (heads, d_out)) * 0.1,
            "ln": layer_norm_init(heads * d_out),
        })
    return {"layers": layers, "heads": heads}


def gat_full_graph(params: dict, x: jnp.ndarray, src: jnp.ndarray,
                   dst: jnp.ndarray, *, num_nodes: int) -> jnp.ndarray:
    heads = params["heads"]
    h = x
    L = len(params["layers"])
    for i, p in enumerate(params["layers"]):
        d_out = p["attn_src"].shape[1]
        z = dense(p["proj"], h).reshape(num_nodes, heads, d_out)
        s = jnp.maximum(src, 0)
        d = jnp.maximum(dst, 0)
        e = ((z[s] * p["attn_src"]).sum(-1)
             + (z[d] * p["attn_dst"]).sum(-1))            # (E, heads)
        e = jax.nn.leaky_relu(e, 0.2)
        e = jnp.where((src >= 0)[:, None], e, -jnp.inf)
        alpha = segment_softmax(e, d, num_nodes)           # (E, heads)
        msg = z[s] * alpha[..., None]                      # (E, heads, d_out)
        msg = jnp.where((src >= 0)[:, None, None], msg, 0.0)
        agg = segment_sum(msg.reshape(msg.shape[0], -1), d, num_nodes)
        h = layer_norm(p["ln"], agg)
        if i < L - 1:
            h = jax.nn.elu(h)
    return h


# ---------------------------------------------------------------------------
# GIN (assigned: gin-tu — 5 layers, 64 hidden, sum agg, learnable eps)
# ---------------------------------------------------------------------------
def gin_init(key: jax.Array, d_in: int, d_hidden: int, n_layers: int,
             d_out: int) -> dict:
    layers = []
    dims_in = d_in
    for i in range(n_layers):
        key, k1, k2 = jax.random.split(key, 3)
        layers.append({
            "mlp1": dense_init(k1, dims_in, d_hidden),
            "mlp2": dense_init(k2, d_hidden, d_hidden),
            "eps": jnp.zeros(()),  # learnable ε (GIN-ε)
            "ln": layer_norm_init(d_hidden),
        })
        dims_in = d_hidden
    key, k = jax.random.split(key)
    return {"layers": layers, "readout": dense_init(k, d_hidden, d_out)}


def gin_full_graph(params: dict, x: jnp.ndarray, src: jnp.ndarray,
                   dst: jnp.ndarray, *, num_nodes: int,
                   shard=lambda x, *n: x) -> jnp.ndarray:
    h = shard(x, "nodes", None)
    for p in params["layers"]:
        agg = scatter_spmm(h, src, dst, num_nodes)  # sum over in-neighbors
        z = (1.0 + p["eps"]) * h + agg
        z = jax.nn.relu(dense(p["mlp1"], z))
        z = dense(p["mlp2"], z)
        h = shard(jax.nn.relu(layer_norm(p["ln"], z)), "nodes", None)
    return dense(params["readout"], h)


def gin_graph_readout(params: dict, x: jnp.ndarray, src: jnp.ndarray,
                      dst: jnp.ndarray, graph_id: jnp.ndarray,
                      *, num_nodes: int, num_graphs: int,
                      shard=lambda x, *n: x) -> jnp.ndarray:
    """Graph classification: node embeddings → per-graph sum readout."""
    h = shard(x, "nodes", None)
    outs = []
    for p in params["layers"]:
        agg = scatter_spmm(h, src, dst, num_nodes)
        z = (1.0 + p["eps"]) * h + agg
        z = jax.nn.relu(dense(p["mlp1"], z))
        z = dense(p["mlp2"], z)
        h = jax.nn.relu(layer_norm(p["ln"], z))
        outs.append(segment_sum(h, graph_id, num_graphs))
    pooled = sum(outs)
    return dense(params["readout"], pooled)

from repro.models.attention import (blockwise_attention, decode_attention,
                                    reference_attention)
from repro.models.din import (DINConfig, din_forward, din_init, din_loss,
                              din_score_candidates, embedding_bag)
from repro.models.equiformer_v2 import equiformer_forward, equiformer_init
from repro.models.gnn_basic import (gat_full_graph, gat_init, gin_full_graph,
                                    gin_graph_readout, gin_init,
                                    sage_full_graph, sage_init, sage_layered)
from repro.models.meshgraphnet import mgn_forward, mgn_init
from repro.models.moe import MoEConfig, moe_apply, moe_init
from repro.models.schnet import schnet_forward, schnet_init
from repro.models.transformer import (LMConfig, init_decode_cache,
                                      lm_active_param_count, lm_decode_step,
                                      lm_forward, lm_init, lm_loss,
                                      lm_param_count)

__all__ = [
    "blockwise_attention", "decode_attention", "reference_attention",
    "DINConfig", "din_init", "din_forward", "din_loss",
    "din_score_candidates", "embedding_bag", "equiformer_init",
    "equiformer_forward", "sage_init", "sage_full_graph", "sage_layered",
    "gat_init", "gat_full_graph", "gin_init", "gin_full_graph",
    "gin_graph_readout", "mgn_init", "mgn_forward", "MoEConfig", "moe_init",
    "moe_apply", "schnet_init", "schnet_forward", "LMConfig", "lm_init",
    "lm_forward", "lm_loss", "lm_decode_step", "init_decode_cache",
    "lm_param_count", "lm_active_param_count",
]

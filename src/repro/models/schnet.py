"""SchNet (assigned arch: 3 interactions, 64 hidden, 300 RBF, cutoff 10Å).

Continuous-filter convolution: per edge, a filter W(r_ij) generated from a
radial-basis expansion of the distance modulates the source features; messages
are scatter-summed (the triplet-free molecular regime of the kernel taxonomy).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.graph.segment import segment_sum
from repro.models.common import dense, dense_init


def shifted_softplus(x):
    return jax.nn.softplus(x) - jnp.log(2.0)


def schnet_init(key: jax.Array, *, d_hidden: int = 64, n_interactions: int = 3,
                n_rbf: int = 300, cutoff: float = 10.0, d_out: int = 1,
                n_species: int = 32, d_feat_in: int = 0) -> dict:
    keys = jax.random.split(key, n_interactions * 4 + 4)
    params = {
        "embed": jax.random.normal(keys[0], (n_species, d_hidden)) * 0.1,
        "out1": dense_init(keys[1], d_hidden, d_hidden // 2),
        "out2": dense_init(keys[2], d_hidden // 2, d_out),
    }
    if d_feat_in:
        params["feat_proj"] = dense_init(keys[-1], d_feat_in, d_hidden)
    inter = []
    for i in range(n_interactions):
        k = keys[3 + 4 * i: 3 + 4 * (i + 1)]
        inter.append({
            "in_proj": dense_init(k[0], d_hidden, d_hidden, bias=False),
            "filter1": dense_init(k[1], n_rbf, d_hidden),
            "filter2": dense_init(k[2], d_hidden, d_hidden),
            "out_proj": dense_init(k[3], d_hidden, d_hidden),
        })
    # homogeneous interaction blocks → stacked for lax.scan (+remat)
    params["interactions"] = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *inter)
    return params


def _rbf(dist: jnp.ndarray, n_rbf: int, cutoff: float) -> jnp.ndarray:
    mu = jnp.linspace(0.0, cutoff, n_rbf)
    return jnp.exp(-(10.0 / cutoff)
                   * (dist[:, None] - mu[None, :]) ** 2)


def schnet_forward(params: dict, species: jnp.ndarray, positions: jnp.ndarray,
                   src: jnp.ndarray, dst: jnp.ndarray, *, num_nodes: int,
                   mol_id: jnp.ndarray | None = None,
                   num_graphs: int | None = None,
                   node_feat: jnp.ndarray | None = None,
                   cutoff: float = 10.0,
                   shard=lambda x, *n: x) -> jnp.ndarray:
    """species: (N,) int; positions: (N,3); edges src→dst (E,), -1 padded.

    Returns per-graph energies (num_graphs, d_out) if mol_id given, else
    per-node outputs.
    """
    valid = (src >= 0) & (dst >= 0)
    s = jnp.maximum(src, 0)
    d = jnp.maximum(dst, 0)
    rij = positions[d] - positions[s]
    dist = jnp.sqrt((rij ** 2).sum(-1) + 1e-12)
    n_rbf = params["interactions"]["filter1"]["w"].shape[1]
    rbf = shard(_rbf(dist, n_rbf, cutoff), "edges", None)
    # cosine cutoff envelope
    env = 0.5 * (jnp.cos(jnp.pi * jnp.minimum(dist / cutoff, 1.0))
                 + 1.0)
    env = jnp.where(valid, env, 0.0)

    h = params["embed"][jnp.clip(species, 0, params["embed"].shape[0] - 1)]
    if node_feat is not None and "feat_proj" in params:
        h = h + dense(params["feat_proj"], node_feat)
    h = shard(h, "nodes", None)

    def interaction(h, p):
        w = shifted_softplus(dense(p["filter1"], rbf))
        w = dense(p["filter2"], w) * env[:, None]          # (E, d)
        msg = dense(p["in_proj"], h)[s] * w
        agg = segment_sum(msg, d, num_nodes)
        v = shifted_softplus(dense(p["out_proj"], agg))
        return shard(h + v, "nodes", None), None

    h, _ = jax.lax.scan(
        jax.checkpoint(interaction,
                       policy=jax.checkpoint_policies.nothing_saveable),
        h, params["interactions"])
    out = shifted_softplus(dense(params["out1"], h))
    out = dense(params["out2"], out)
    if mol_id is not None:
        assert num_graphs is not None
        return segment_sum(out, jnp.maximum(mol_id, 0), num_graphs)
    return out

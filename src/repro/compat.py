"""Version-drift compatibility aliases for the pinned JAX toolchain.

The repo targets the current JAX API surface; where the installed version
predates a rename, fall back to the old location:

  * ``CompilerParams`` — Pallas-TPU compiler params were
    ``pltpu.TPUCompilerParams`` before the rename.
  * ``shard_map`` — promoted to ``jax.shard_map``; previously lived in
    ``jax.experimental.shard_map``.
  * ``make_mesh`` — newer versions take ``axis_types``; older ones don't.

Everything here must import cleanly on a CPU-only host.
"""
from __future__ import annotations

from typing import Sequence

import jax
from jax.experimental.pallas import tpu as _pltpu

CompilerParams = getattr(_pltpu, "CompilerParams", None) \
    or _pltpu.TPUCompilerParams

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map  # noqa: F401


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """``jax.make_mesh`` with auto axis types where the kwarg exists."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                             axis_types=(AxisType.Auto,) * len(axis_names))
    except (ImportError, TypeError):
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))

"""SLO-aware serving gateway: priority classes, deadline slack, telemetry.

The engine's admission window treats every batch the same — FIFO order,
one wait/shed policy. At production scale that is not enough: interactive
requests must not starve behind batch traffic, and a request that cannot
meet its deadline should be shed *before* it burns an executor lane
(OMEGA makes the same case for latency-class isolation; the dataflow-
aware online-scheduling line shows the win comes from ordering the queue
by a cost model rather than arrival order). This module puts a gateway in
front of :class:`~repro.serving.engine.ServingEngine`:

* requests carry a priority class (``interactive`` / ``batch``) and an
  optional **relative** deadline (``Request.deadline_s``);
* the admission queue is ordered by *deadline slack* — ``deadline − now −
  est`` with ``est`` from the router's calibrated ``LatencyCurve``s
  (``CostModelRouter.estimate_seconds``) — plus an aging term so batch
  traffic cannot starve; an interactive request that has waited past
  ``aging_bound_s`` preempts every batch request outright;
* hopeless requests are shed with a distinct ``shed_deadline`` outcome at
  **two** points: immediately at admission when slack is already
  negative, and again at dequeue so a request that went stale while
  queued never occupies an executor;
* live telemetry — queue depth, saturation (``inflight ÷ window``),
  per-class p50/p95/p99 — is buffered as time-series samples and exposed
  through :meth:`ServingGateway.telemetry_stream`, pollable while the
  engine serves.

Every request submitted through the gateway terminates in exactly one of
``{"completed", "shed_window", "shed_deadline"}`` (``Request.outcome``) —
the property the hypothesis suite in ``tests/test_gateway.py`` drives.

Concurrency notes. The gateway owns no threads: dispatch happens on the
submitting thread and on executor-pool threads via future done-callbacks.
The pump is re-entrancy-safe (``Future.add_done_callback`` runs inline
when the future is already done), and the gateway gates dispatch on its
*own* inflight gauge rather than the engine's: the engine notifies hooks
before decrementing its accounting, so gating on ``engine.inflight`` from
a completion callback would dead-stall a full window.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Callable, Iterator, Optional, Sequence

from repro.serving.engine import ServeMetrics, ServingEngine
from repro.serving.registry import DEFAULT_MODEL

# Keys of `ServingGateway.stats` — quiverlint's schema pass cross-checks
# this constant against the class's stats declaration and the marked
# gateway-schema table in docs/invariants.md.
GATEWAY_SCHEMA = ("admitted", "dispatched", "completed", "shed_window",
                  "shed_deadline", "aged_dispatches", "max_queue_depth",
                  "telemetry_samples")

# Keys of every telemetry sample yielded by `telemetry_stream` /
# `telemetry_samples`; the per-class blocks under "classes" carry exactly
# `repro.serving.engine.CLASS_SAMPLE_SCHEMA`.
TELEMETRY_SAMPLE_SCHEMA = ("t", "queue_depth", "inflight", "saturation",
                           "classes")


@dataclasses.dataclass
class GatewayConfig:
    """Tuning knobs of the SLO gateway.

    Attributes:
        queue_limit: admission-queue bound; a submit past it sheds with
            outcome ``shed_window``. The adaptive controller nudges this
            live from observed saturation (``tune_admission``).
        aging_bound_s: wait after which a queued *interactive* request
            preempts every batch request outright (tier promotion) — the
            starvation bound the property tests pin.
        aging_gain: seconds of slack credit per second waited — ages
            *both* classes toward the front so batch traffic drains even
            under a steady interactive flow.
        batch_bias_s: slack handicap added to batch-class requests; ties
            between a fresh interactive and a fresh batch request break
            interactive-first by this margin.
        slack_cap_s: slack assigned to requests without a deadline (and
            cap for very loose deadlines) — keeps no-deadline batch
            traffic reachable by aging instead of infinitely deprioritized.
        default_deadline_s: deadline applied to requests that carry none
            (``None`` = no implied deadline).
        telemetry_capacity: ring-buffer size of the telemetry series.
        telemetry_min_interval_s: minimum spacing between automatic
            samples (0 = sample on every submit/completion).
    """

    queue_limit: int = 256
    aging_bound_s: float = 0.25
    aging_gain: float = 1.0
    batch_bias_s: float = 0.05
    slack_cap_s: float = 30.0
    default_deadline_s: Optional[float] = None
    telemetry_capacity: int = 1024
    telemetry_min_interval_s: float = 0.0


@dataclasses.dataclass(eq=False)
class _Queued:
    """One admitted request waiting for dispatch (identity-compared)."""
    seq: int
    request: object
    model: str
    priority: str
    enqueued: float            # gateway-clock admission time
    deadline: Optional[float]  # ABSOLUTE gateway-clock deadline (or None)
    est: float                 # curve-estimated service seconds


class ServingGateway:
    """Priority/deadline-aware admission in front of a serving engine.

    Ingest one request at a time via :meth:`submit` (or a whole stream via
    :meth:`serve`). The gateway queues admissible requests, orders the
    queue by deadline slack with aging, dispatches one-request batches to
    the engine whenever it holds a free window slot, and sheds hopeless
    requests — at admission and again at dequeue — without ever occupying
    an executor with them. Telemetry is sampled on every submit and
    completion and exposed as a pollable stream.

    Dequeue order is defined by a two-level key, smallest first::

        tier  = 0 if (interactive and waited >= aging_bound_s) else 1
        value = class_bias + min(slack, cap) − aging_gain · waited

    which yields the three properties the test suite pins: interactive
    requests past the aging bound are never passed over for batch work,
    batch work cannot starve (its key decreases linearly with wait), and
    with one class and no deadlines the order degenerates to FIFO.
    """

    def __init__(self, engine: ServingEngine, *,
                 config: Optional[GatewayConfig] = None,
                 clock: Optional[Callable[[], float]] = None):
        """Args:
            engine: the serving engine to front (its ``max_inflight`` is
                the dispatch window the gateway fills).
            config: gateway tuning knobs (default :class:`GatewayConfig`).
            clock: zero-arg seconds source; defaults to the engine's clock
                so deadlines and engine timestamps share one domain.
        """
        self.engine = engine
        self.config = config or GatewayConfig()
        self.clock = clock if clock is not None else engine.clock
        self._cv = threading.Condition()
        self._queue: list[_Queued] = []
        self._seq = 0
        self._gw_inflight = 0
        self._pump_active = False
        self._pump_again = False
        self._last_sample_t = float("-inf")
        self._telemetry: collections.deque = collections.deque(
            maxlen=int(self.config.telemetry_capacity))
        self.stats = {"admitted": 0, "dispatched": 0, "completed": 0,
                      "shed_window": 0, "shed_deadline": 0,
                      "aged_dispatches": 0, "max_queue_depth": 0,
                      "telemetry_samples": 0}

    # -- admission -----------------------------------------------------------
    def submit(self, request) -> str:
        """Admit one request: slack-check, enqueue (or shed), pump.

        Stamps ``request.arrival`` with the gateway clock and converts its
        relative ``deadline_s`` to an absolute deadline. Returns the
        admission verdict: ``"queued"``, ``"shed_window"`` (queue at
        ``queue_limit``) or ``"shed_deadline"`` (slack already negative —
        the deadline cannot be met even if dispatched right now).
        """
        cfg = self.config
        now = self.clock()
        request.arrival = now
        model = getattr(request, "model", DEFAULT_MODEL)
        est = self._estimate(request, model)
        dl_rel = getattr(request, "deadline_s", None)
        if dl_rel is None:
            dl_rel = cfg.default_deadline_s
        deadline = (now + float(dl_rel)) if dl_rel is not None else None
        if deadline is not None and deadline - now - est < 0.0:
            self.engine.record_shed([request], model, reason="deadline")
            with self._cv:
                self.stats["shed_deadline"] += 1
            self._maybe_sample()
            return "shed_deadline"
        shed_window = False
        with self._cv:
            if len(self._queue) >= cfg.queue_limit:
                shed_window = True
                self.stats["shed_window"] += 1
            else:
                self._seq += 1
                self._queue.append(_Queued(
                    seq=self._seq, request=request, model=model,
                    priority=getattr(request, "priority", "batch"),
                    enqueued=now, deadline=deadline, est=est))
                self.stats["admitted"] += 1
                depth = len(self._queue)
                if depth > self.stats["max_queue_depth"]:
                    self.stats["max_queue_depth"] = depth
        if shed_window:
            self.engine.record_shed([request], model, reason="window")
            self._maybe_sample()
            return "shed_window"
        self._maybe_sample()
        self.pump()
        return "queued"

    def serve(self, requests: Sequence, *, gap_s: float = 0.0) -> ServeMetrics:
        """Run a whole request stream through the gateway and return the
        engine's run metrics (per-class breakdown included). ``gap_s``
        spaces arrivals for client emulation."""
        metrics = self.engine.begin_run()
        try:
            for r in requests:
                if gap_s:
                    time.sleep(gap_s)
                self.submit(r)
            self.drain()
        finally:
            self.engine.end_run(metrics)
        return metrics

    def _estimate(self, request, model: str) -> float:
        """Curve-based service-time estimate of a request (0.0 when the
        model's router offers none — optimistic, never sheds blind)."""
        router = self.engine.registry.router_for(model)
        fn = getattr(router, "estimate_seconds", None)
        if fn is None:
            return 0.0
        return max(float(fn(request.seeds)), 0.0)

    # -- dispatch ------------------------------------------------------------
    def pump(self) -> int:
        """Dispatch as many queued requests as the window allows; returns
        the number dispatched. Re-entrancy-safe: a call arriving while a
        pump is active (e.g. a future completing inline) flags a re-sweep
        and returns immediately instead of recursing."""
        with self._cv:
            if self._pump_active:
                self._pump_again = True
                return 0
            self._pump_active = True
            self._pump_again = False
        total = 0
        while True:
            try:
                total += self._sweep()
            except BaseException:
                with self._cv:
                    self._pump_active = False
                raise
            with self._cv:
                if self._pump_again:
                    self._pump_again = False
                    continue
                self._pump_active = False
                return total

    def _sweep(self) -> int:
        """One dispatch sweep: shed stale requests, then pop-and-submit the
        best admissible request while window slots are free."""
        n = 0
        while True:
            item: Optional[_Queued] = None
            aged = False
            with self._cv:
                now = self.clock()
                stale = self._pop_stale_locked(now)
                if stale:
                    self.stats["shed_deadline"] += len(stale)
                if (self._queue
                        and self._gw_inflight < self.engine.max_inflight):
                    idx, aged = self._select_locked(now)
                    item = self._queue.pop(idx)
                    self._gw_inflight += 1  # reserve the slot pre-submit
                if not self._queue:
                    self._cv.notify_all()
            for s in stale:
                # dequeue-time re-check: went stale while queued — shed
                # without ever occupying an executor
                self.engine.record_shed([s.request], s.model,
                                        reason="deadline")
            if item is None:
                return n
            item.request.dispatched = self.clock()
            fut = self.engine.submit_batch([item.request])
            if fut is None:
                # engine window raced shut under foreign traffic; the
                # engine already counted the shed — release our slot
                with self._cv:
                    self._gw_inflight -= 1
                    self.stats["shed_window"] += 1
                continue
            with self._cv:
                self.stats["dispatched"] += 1
                if aged:
                    self.stats["aged_dispatches"] += 1
            n += 1
            fut.add_done_callback(self._on_dispatched_done)

    def _on_dispatched_done(self, fut: Future) -> None:
        """Completion callback of a gateway-dispatched batch: release the
        window slot, count, sample telemetry, re-pump. Runs *after* the
        engine's own accounting (callbacks fire in registration order)."""
        ok = fut.exception() is None
        with self._cv:
            self._gw_inflight -= 1
            if ok:
                self.stats["completed"] += 1
            self._cv.notify_all()
        self._maybe_sample()
        self.pump()

    def _select_locked(self, now: float) -> tuple[int, bool]:
        """Index of the next request to dispatch under the slack+aging
        order, and whether it won by aging-tier promotion. Lock-held-only
        helper (registered in quiverlint's exempt list); the queue must be
        non-empty."""
        best_key, best_i, best_aged = None, 0, False
        for i, item in enumerate(self._queue):
            key, aged = self._order_key(item, now)
            if best_key is None or key < best_key:
                best_key, best_i, best_aged = key, i, aged
        return best_i, best_aged

    def _pop_stale_locked(self, now: float) -> list[_Queued]:
        """Remove and return queued requests whose slack went negative
        while waiting. Lock-held-only helper (registered exempt)."""
        stale = [it for it in self._queue
                 if it.deadline is not None
                 and it.deadline - now - it.est < 0.0]
        if stale:
            dead = {id(it) for it in stale}
            self._queue = [it for it in self._queue if id(it) not in dead]
        return stale

    def _order_key(self, item: _Queued, now: float) -> tuple[tuple, bool]:
        """Dequeue sort key of one queued request (see class docstring)."""
        cfg = self.config
        wait = now - item.enqueued
        interactive = item.priority == "interactive"
        aged = interactive and wait >= cfg.aging_bound_s
        slack = (item.deadline - now - item.est
                 if item.deadline is not None else cfg.slack_cap_s)
        slack = min(slack, cfg.slack_cap_s)
        bias = 0.0 if interactive else cfg.batch_bias_s
        tier = 0 if aged else 1
        return (tier, bias + slack - cfg.aging_gain * wait, item.seq), aged

    def drain(self) -> None:
        """Block until the queue is empty (everything dispatched or shed),
        then drain the engine — on return every submitted request carries
        a terminal ``outcome``."""
        self.pump()
        with self._cv:
            self._cv.wait_for(lambda: not self._queue)
        self.engine.drain()

    # -- telemetry -----------------------------------------------------------
    @property
    def queue_depth(self) -> int:
        """Requests currently queued (admitted, not yet dispatched)."""
        with self._cv:
            return len(self._queue)

    def sample_telemetry(self) -> dict:
        """Record and return one telemetry sample (keys
        ``TELEMETRY_SAMPLE_SCHEMA``): queue depth, engine inflight and
        saturation, per-class latency percentiles. Timestamps across the
        buffered series are monotone non-decreasing."""
        classes = self.engine.class_summaries()
        inflight = self.engine.inflight
        saturation = self.engine.saturation
        with self._cv:
            sample = {"t": self.clock(), "queue_depth": len(self._queue),
                      "inflight": inflight, "saturation": saturation,
                      "classes": classes}
            self._telemetry.append(sample)
            self.stats["telemetry_samples"] += 1
            self._last_sample_t = sample["t"]
            self._cv.notify_all()
        return sample

    def _maybe_sample(self) -> None:
        """Auto-sample unless within ``telemetry_min_interval_s`` of the
        previous sample."""
        with self._cv:
            due = (self.clock() - self._last_sample_t
                   >= self.config.telemetry_min_interval_s)
        if due:
            self.sample_telemetry()

    def telemetry_samples(self) -> list[dict]:
        """Snapshot of the buffered telemetry series (oldest first)."""
        with self._cv:
            return list(self._telemetry)

    def telemetry_stream(self, *, stop: Optional[Callable[[], bool]] = None,
                         poll_s: float = 0.05) -> Iterator[dict]:
        """Stream telemetry samples as they are recorded — the pollable
        endpoint. Yields every new sample; between samples it waits up to
        ``poll_s`` on the gateway condition. Ends when ``stop()`` returns
        true with no samples pending; without ``stop`` the iterator is
        infinite (consume it from its own thread)."""
        seen = 0
        while True:
            with self._cv:
                total = self.stats["telemetry_samples"]
                if total > seen:
                    take = min(total - seen, len(self._telemetry))
                    fresh = list(self._telemetry)[-take:]
                    seen = total
                elif stop is not None and stop():
                    return
                else:
                    self._cv.wait(poll_s)
                    continue
            for sample in fresh:
                yield sample

    def report(self) -> dict:
        """Gateway counters plus the live queue depth and saturation."""
        with self._cv:
            out = dict(self.stats)
            out["queue_depth"] = len(self._queue)
        out["saturation"] = self.engine.saturation
        return out

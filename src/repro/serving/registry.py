"""Multi-model serving registry: one engine, N models over shared state.

Quiver's workload metrics (PSGS, FAP) govern GPU use per request, but the
calibration that turns them into routing decisions is *model-specific*: two
GNNs over the same graph have different latency-vs-PSGS curves and therefore
different PSGS cut-points. Real deployments co-serve many models over one
graph and one feature store (OMEGA, arXiv:2501.08547, makes shared state the
centerpiece of low-latency GNN serving; arXiv:2411.16342 shows routing must
be conditioned on the model, not just the request). This module provides the
registry the :class:`~repro.serving.engine.ServingEngine` serves from:

  ModelEntry      one served model: its ``infer_fn``-bearing executor set
                  (built against the *shared* stores/samplers) and its
                  calibrated router.
  ModelRegistry   name → ModelEntry mapping; the single-model engine API is
                  the 1-entry special case (``ModelRegistry.single``),
                  mirroring how the binary PSGS threshold is the 2-executor
                  special case of ``CostModelRouter``.

What is shared vs per-model:

  shared     graph topology, ``TieredFeatureStore``/``ShardedFeatureStore``
             (one copy of every feature row), samplers, the admission window
             (one capacity bound over the shared hardware), the
             ``FrequencySketch`` (FAP placement is store-wide).
  per-model  ``infer_fn``, executors, calibrated ``LatencyCurve``s, the
             ``CostModelRouter``, metrics breakdowns, micro-batching state
             (micro-batches never coalesce across models).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Iterator, Mapping, Optional, Sequence

import numpy as np

from repro.serving.executors import Executor
from repro.serving.router import CostModelRouter, calibrate_executors

#: Model tag used when the caller never mentions models (single-model API).
DEFAULT_MODEL = "default"


@dataclasses.dataclass
class ModelEntry:
    """One served model inside a :class:`ModelRegistry`.

    The entry owns only what is model-specific — executors wrapping the
    model's ``infer_fn`` and the router holding its calibrated curves; the
    feature stores and graph those executors read are shared across entries.

    Attributes:
        name: model tag carried by requests (``Request.model``).
        executors: executor-name → :class:`Executor` registry for this model.
        router: anything with ``route(seeds) -> executor name`` over this
            model's executor names (usually a ``CostModelRouter`` fit from
            this model's calibration).
        infer_fn: the model's inference callable, kept for rebuilds and
            introspection (executors already close over it).
    """

    name: str
    executors: dict[str, Executor]
    router: Any
    infer_fn: Optional[Callable] = None


class ModelRegistry:
    """Name → :class:`ModelEntry` registry the serving engine serves from.

    Insertion order is preserved (it decides warmup/close order and the
    order of per-model report sections). The single-model engine API is the
    1-entry special case built by :meth:`single`.
    """

    def __init__(self, entries: Iterable[ModelEntry] = ()):
        """Args:
            entries: optional initial :class:`ModelEntry` objects; later
                entries with a repeated name replace earlier ones.
        """
        self._entries: dict[str, ModelEntry] = {}
        for e in entries:
            self.add(e)

    # -- registration --------------------------------------------------------
    def add(self, entry: ModelEntry) -> "ModelRegistry":
        """Add (or replace) a model entry under ``entry.name``; returns the
        registry for chaining."""
        if not entry.executors:
            raise ValueError(
                f"model {entry.name!r} needs at least one executor")
        self._entries[entry.name] = entry
        return self

    def register(self, name: str,
                 executors: Mapping[str, Executor] | Iterable[Executor],
                 router, *, infer_fn: Optional[Callable] = None
                 ) -> "ModelRegistry":
        """Register a model from its parts (see :class:`ModelEntry`).

        Args:
            name: model tag requests will carry.
            executors: executor-name → executor mapping, or an iterable of
                executors keyed by their ``name`` attribute.
            router: ``route(seeds) -> executor name`` over those executors.
            infer_fn: optional inference callable, kept for introspection.

        Returns:
            The registry, for chaining.
        """
        if not isinstance(executors, Mapping):
            executors = {e.name: e for e in executors}
        return self.add(ModelEntry(name=name, executors=dict(executors),
                                   router=router, infer_fn=infer_fn))

    @staticmethod
    def single(executors: Mapping[str, Executor] | Iterable[Executor],
               router) -> "ModelRegistry":
        """The single-model special case: one entry under
        :data:`DEFAULT_MODEL` — what ``ServingEngine(executors, router)``
        builds under the hood."""
        return ModelRegistry().register(DEFAULT_MODEL, executors, router)

    # -- lookup --------------------------------------------------------------
    def get(self, name: str) -> ModelEntry:
        """Entry for model ``name``.

        Raises:
            KeyError: naming the registered models, so a typo'd request tag
                is diagnosable from the exception alone.
        """
        try:
            return self._entries[name]
        except KeyError:
            raise KeyError(f"unknown model {name!r}; registered: "
                           f"{list(self._entries)}") from None

    def router_for(self, name: str = DEFAULT_MODEL):
        """The router serving ``name`` — the gateway's slack-estimation
        handle (``router.estimate_seconds`` when the router offers it)."""
        return self.get(name).router

    @property
    def names(self) -> list[str]:
        """Registered model names, in registration order."""
        return list(self._entries)

    def entries(self) -> list[ModelEntry]:
        """Registered entries, in registration order."""
        return list(self._entries.values())

    def routers(self) -> dict[str, Any]:
        """Model name → router mapping (what the adaptive controller refits
        per model)."""
        return {n: e.router for n, e in self._entries.items()}

    def all_executors(self) -> Iterator[tuple[str, str, Executor]]:
        """Yield ``(model, executor_name, executor)`` over every entry."""
        for model, entry in self._entries.items():
            for name, ex in entry.executors.items():
                yield model, name, ex

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, name: object) -> bool:
        return name in self._entries

    def __iter__(self) -> Iterator[str]:
        return iter(self._entries)

    def __repr__(self) -> str:
        return f"ModelRegistry({self.names})"


def build_model_entry(name: str, *, graph, store, fanouts: Sequence[int],
                      infer_fn: Callable, psgs_table: np.ndarray,
                      policy: str = "latency_preferred", capacity: int = 2,
                      max_batch: int = 128, fused: bool = True,
                      rng_seed: int = 0,
                      calibration_batches: Optional[Sequence[np.ndarray]] = None,
                      calibration_repeats: int = 2,
                      load_aware: bool = False) -> ModelEntry:
    """Build one model's host+device executor pair against a *shared* store,
    calibrate it, and wrap the result in a :class:`ModelEntry`.

    This is the standard recipe used by ``launch/serve.py --models`` and
    ``benchmarks/multi_model.py``; callers with extra executors (sharded) or
    pre-fit curves assemble the entry by hand instead.

    Args:
        name: model tag (``ModelEntry.name``).
        graph: CSR topology shared by every model.
        store: shared ``TieredFeatureStore`` the executors read.
        fanouts: per-layer sampling fanouts for this model.
        infer_fn: this model's inference callable
            (``infer_fn(hop_feats, hop_ids) -> (B, d_out)``).
        psgs_table: ``(N,)`` per-seed PSGS table (routing x-coordinate).
        policy: routing policy for the model's ``CostModelRouter``.
        capacity: worker lanes per executor.
        max_batch: device executor static shape (chunking bound).
        fused: fused feature-collection path flag for both executors.
        rng_seed: sampling RNG seed for the executors.
        calibration_batches: probe batches for ``calibrate_executors``;
            defaults to 6 PSGS-spread slices of the node set.
        calibration_repeats: steady-state repeats per probe batch.
        load_aware: forwarded to the model's router.

    Returns:
        A fully calibrated :class:`ModelEntry` ready for
        ``ModelRegistry.add``.
    """
    from repro.serving.executors import DeviceExecutor, HostExecutor

    executors: dict[str, Executor] = {
        "host": HostExecutor(graph, store, fanouts, infer_fn,
                             capacity=capacity, psgs_table=psgs_table,
                             rng_seed=rng_seed, fused=fused),
        "device": DeviceExecutor(graph.device_arrays(), store, fanouts,
                                 infer_fn, max_batch=max_batch,
                                 capacity=capacity, psgs_table=psgs_table,
                                 rng_seed=rng_seed, fused=fused),
    }
    if calibration_batches is None:
        order = np.argsort(psgs_table)
        n = order.size
        calibration_batches = [
            order[int(q * n):][:max(min(max_batch, 32), 4)].astype(np.int64)
            for q in np.linspace(0.05, 0.95, 6)]
    curves = calibrate_executors(executors, calibration_batches, psgs_table,
                                 repeats=calibration_repeats)
    router = CostModelRouter.from_curves(psgs_table, curves, policy,
                                         executors=executors,
                                         load_aware=load_aware)
    return ModelEntry(name=name, executors=executors, router=router,
                      infer_fn=infer_fn)

"""Executor-graph serving stack: pluggable executors, N-way cost-model
routing, and the futures-based serving engine.

Layering (each importable without ``repro.core``; the legacy
``repro.core.{pipeline,scheduler}`` modules are thin shims onto this
package):

    executors.py  Executor protocol + Host/Device/Sharded executors
    router.py     LatencyCurve calibration + CostModelRouter (N-way) and the
                  binary HybridScheduler / StaticScheduler special cases
    engine.py     ServingEngine: admission control, per-batch futures,
                  telemetry hooks
    adaptive.py   online workload adaptation: decayed seed-frequency sketch,
                  live FAP re-placement (bounded tier migration) and router
                  drift refit (AdaptiveController plugs into engine hooks)

To add a new executor: subclass ``BaseExecutor``, implement
``process(seeds) -> one output row per seed``, calibrate it with
``calibrate_executors`` and register the curve on a ``CostModelRouter``
plus the executor on the ``ServingEngine``.
"""
from repro.serving.executors import (BaseExecutor, DeviceExecutor, Executor,
                                     HostExecutor, ShardedExecutor,
                                     pad_to_bucket)
from repro.serving.router import (POLICIES, CalibrationResult,
                                  CostModelRouter, HybridScheduler,
                                  LatencyCurve, StaticScheduler, calibrate,
                                  calibrate_executors)
from repro.serving.engine import MicroBatcher, ServeMetrics, ServingEngine
from repro.serving.adaptive import (AdaptiveConfig, AdaptiveController,
                                    FrequencySketch, curve_drift)

__all__ = [
    "Executor", "BaseExecutor", "HostExecutor", "DeviceExecutor",
    "ShardedExecutor", "pad_to_bucket", "POLICIES", "LatencyCurve",
    "CalibrationResult", "calibrate", "calibrate_executors",
    "CostModelRouter", "HybridScheduler", "StaticScheduler",
    "ServingEngine", "ServeMetrics", "MicroBatcher", "AdaptiveConfig",
    "AdaptiveController", "FrequencySketch", "curve_drift",
]

"""Executor-graph serving stack: pluggable executors, N-way cost-model
routing, multi-model registries, and the futures-based serving engine.

Layering (each importable without ``repro.core``; the legacy
``repro.core.{pipeline,scheduler}`` modules are thin shims onto this
package):

    executors.py  Executor protocol + Host/Device/Sharded executors
    router.py     LatencyCurve calibration + CostModelRouter (N-way) and the
                  binary HybridScheduler / StaticScheduler special cases
    registry.py   ModelRegistry/ModelEntry: N models sharing the stores and
                  samplers, each with its own infer_fn, executors and
                  calibrated router (the single-model API is the 1-entry
                  special case)
    engine.py     ServingEngine: admission control (global across models),
                  per-batch futures, per-model + per-class metrics,
                  telemetry hooks
    gateway.py    ServingGateway: SLO-aware admission in front of the
                  engine — priority classes, deadline-slack queue ordering
                  with anti-starvation aging, shed-before-dispatch, and a
                  pollable streaming-telemetry endpoint
    adaptive.py   online workload adaptation: decayed seed-frequency sketch
                  (shared across models), live FAP re-placement (bounded
                  tier migration), per-model router drift refit,
                  micro-batch auto-tuning, and gateway admission-window
                  tuning (AdaptiveController plugs into engine hooks)

To add a new executor: subclass ``BaseExecutor``, implement
``process(seeds) -> one output row per seed``, calibrate it with
``calibrate_executors`` and register the curve on a ``CostModelRouter``
plus the executor on the ``ServingEngine``. To co-serve another model:
build its executors against the *shared* store (``build_model_entry``) and
``ModelRegistry.register`` it — requests select it via ``Request.model``.
"""
from repro.serving.executors import (BaseExecutor, DeviceExecutor, Executor,
                                     HostExecutor, ShardedExecutor,
                                     pad_to_bucket)
from repro.serving.router import (POLICIES, CalibrationResult,
                                  CostModelRouter, HybridScheduler,
                                  LatencyCurve, StaticScheduler, calibrate,
                                  calibrate_executors)
from repro.serving.registry import (DEFAULT_MODEL, ModelEntry, ModelRegistry,
                                    build_model_entry)
from repro.serving.engine import (CLASS_SAMPLE_SCHEMA, ClassStats,
                                  MicroBatcher, ModelStats, ServeMetrics,
                                  ServingEngine)
from repro.serving.gateway import (GATEWAY_SCHEMA, TELEMETRY_SAMPLE_SCHEMA,
                                   GatewayConfig, ServingGateway)
from repro.serving.adaptive import (AdaptiveConfig, AdaptiveController,
                                    FrequencySketch, curve_drift)

__all__ = [
    "Executor", "BaseExecutor", "HostExecutor", "DeviceExecutor",
    "ShardedExecutor", "pad_to_bucket", "POLICIES", "LatencyCurve",
    "CalibrationResult", "calibrate", "calibrate_executors",
    "CostModelRouter", "HybridScheduler", "StaticScheduler",
    "DEFAULT_MODEL", "ModelEntry", "ModelRegistry", "build_model_entry",
    "ServingEngine", "ServeMetrics", "ModelStats", "ClassStats",
    "CLASS_SAMPLE_SCHEMA", "MicroBatcher",
    "ServingGateway", "GatewayConfig", "GATEWAY_SCHEMA",
    "TELEMETRY_SAMPLE_SCHEMA",
    "AdaptiveConfig", "AdaptiveController", "FrequencySketch", "curve_drift",
]

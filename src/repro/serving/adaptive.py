"""Online workload adaptation: live FAP re-placement + router drift refit.

The paper computes its workload metrics offline: FAP ranks features once,
the placement plan and the per-executor latency curves are frozen at
startup. A drifting request mix (a hot subgraph emerging at serve time)
leaves the feature tiers and routing thresholds stale. This module closes
the loop, in the spirit of OMEGA's serve-time recomputation
(arXiv:2501.08547) and data-driven online GNN scheduling (arXiv:2411.16342):

  FrequencySketch       decayed seed-access counts, fed by the engine on
                        every admitted batch (``on_admit`` hook).
  AdaptiveController    periodically (every ``interval_batches`` completions)
                        (a) recomputes FAP with the *empirical* seed
                        distribution, (b) derives the target placement,
                        (c) migrates a bounded number of rows between the
                        HOT/WARM/HOST tiers of the live TieredFeatureStore
                        (swap-based — serving never pauses, lookups stay
                        bit-identical), and (d) refits per-executor
                        LatencyCurves from live ``(psgs, latency)`` samples,
                        swapping them into the CostModelRouter when the
                        measured drift exceeds a threshold.

Wire-up::

    controller = AdaptiveController(graph, fanouts, store, router,
                                    psgs_table=psgs)
    engine = ServingEngine(executors, router, hooks=[controller])

The controller runs its control step inline on the completion-callback
thread that crossed the period boundary: that one lane stalls for the
recompute (O(edges) FAP pass + a migration bounded by ``rows_per_step``),
while every other lane's callbacks — and every concurrent lookup — keep
serving from the previous placement snapshot (steps hold a dedicated lock;
telemetry takes a separate short-lived one).
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Optional, Sequence

import numpy as np

from repro.core.fap import compute_fap
from repro.core.placement import migration_pairs, quiver_placement
from repro.serving.router import CostModelRouter, LatencyCurve


class FrequencySketch:
    """Exponentially-decayed seed-access frequency over the node set.

    ``observe`` is called from executor callback threads; ``decay`` once per
    control period, so the sketch tracks the *recent* request mix: with decay
    ``d`` per period, a seed last hot ``k`` periods ago retains weight d^k.
    """

    def __init__(self, num_nodes: int, *, decay: float = 0.9):
        if not 0.0 < decay <= 1.0:
            raise ValueError(f"decay must be in (0, 1], got {decay}")
        self.num_nodes = int(num_nodes)
        self.decay = float(decay)
        self.counts = np.zeros(self.num_nodes, dtype=np.float64)
        self.total_observed = 0
        self._lock = threading.Lock()

    def observe(self, seeds: np.ndarray) -> None:
        """Count one batch's seed accesses (``-1`` padding ignored).
        Thread-safe; called from executor callback threads."""
        seeds = np.asarray(seeds)
        seeds = seeds[seeds >= 0]
        with self._lock:
            np.add.at(self.counts, seeds, 1.0)
            self.total_observed += int(seeds.size)

    def decay_step(self) -> None:
        """Multiply every count by ``decay`` (called once per control
        period, so old traffic fades geometrically)."""
        with self._lock:
            self.counts *= self.decay

    def empirical_prob(self, *, prior_weight: float = 0.2) -> np.ndarray:
        """Normalized access distribution, blended with a uniform prior so
        never-seen nodes keep non-zero FAP mass (cold-start safety)."""
        with self._lock:
            c = self.counts.copy()
        total = c.sum()
        uniform = np.full(self.num_nodes, 1.0 / self.num_nodes)
        if total <= 0.0:
            return uniform
        return (1.0 - prior_weight) * (c / total) + prior_weight * uniform


@dataclasses.dataclass
class AdaptiveConfig:
    interval_batches: int = 32     # control period, in completed batches
    rows_per_step: int = 64        # migration budget per control step
    decay: float = 0.9             # sketch decay per control period
    prior_weight: float = 0.2      # uniform blend in the empirical seed dist
    min_refit_samples: int = 24    # live samples before a curve refit
    curve_bins: int = 8
    curve_tail: float = 1.0        # tail statistic for the refit curves
    drift_threshold: float = 0.25  # mean relative avg-curve error to swap
    sample_window: int = 512       # live (psgs, latency) samples kept/executor
    fap_truncated: bool = False    # forwarded to compute_fap


def curve_drift(old: LatencyCurve, new: LatencyCurve) -> float:
    """Mean relative disagreement of the two average-latency curves,
    evaluated on the new curve's calibrated support."""
    grid = np.asarray(new.psgs, dtype=np.float64)
    a = np.asarray(old.eval_avg(grid), dtype=np.float64)
    b = np.asarray(new.eval_avg(grid), dtype=np.float64)
    return float(np.mean(np.abs(b - a) / np.maximum(np.abs(a), 1e-12)))


class AdaptiveController:
    """Telemetry-driven control loop over a live serving stack.

    Implements the engine hook protocol (``on_admit`` / ``on_batch_complete``)
    and owns the whole adaptation state: the frequency sketch, the live
    latency samples, and the migration/refit counters in :attr:`stats`.
    ``router`` may be ``None`` (placement-only adaptation).
    """

    def __init__(self, graph, fanouts: Sequence[int], store,
                 router: Optional[CostModelRouter] = None, *,
                 psgs_table: Optional[np.ndarray] = None,
                 config: Optional[AdaptiveConfig] = None):
        self.graph = graph
        self.fanouts = tuple(int(f) for f in fanouts)
        self.store = store
        self.router = router
        self.psgs_table = psgs_table
        self.config = config or AdaptiveConfig()
        self.sketch = FrequencySketch(graph.num_nodes,
                                      decay=self.config.decay)
        self.samples: dict[str, collections.deque] = {}
        self.stats = {"steps": 0, "migrated_rows": 0, "refits": 0,
                      "batches_seen": 0, "last_drift": {}}
        self._since_step = 0
        # _lock guards telemetry (samples/stats/counters) and is only ever
        # held briefly; _step_lock serializes control steps. The heavy work
        # (FAP recompute, placement, migration) runs under _step_lock alone,
        # so completion callbacks on other lanes never block behind it.
        self._lock = threading.Lock()
        self._step_lock = threading.Lock()
        self.enabled = True

    # -- engine hook protocol ------------------------------------------------
    def on_admit(self, name: str, seeds: np.ndarray) -> None:
        """Engine hook: feed the admitted batch's seeds into the frequency
        sketch (``-1`` padding is ignored by the sketch).

        Args:
            name: executor the batch was routed to (unused here).
            seeds: ``(B,)`` seed ids of the admitted batch.
        """
        self.sketch.observe(seeds)

    def on_batch_complete(self, name: str, seeds: np.ndarray,
                          latency_s: float) -> None:
        """Engine hook: record a live ``(psgs, latency)`` sample for the
        executor and run a control step when the period boundary is crossed
        (inline, on this callback thread).

        Args:
            name: executor that served the batch.
            seeds: ``(B,)`` seed ids of the batch.
            latency_s: per-batch service time (queueing + processing).
        """
        due = False
        with self._lock:
            if self.psgs_table is not None:
                seeds = np.asarray(seeds)
                q = float(self.psgs_table[seeds[seeds >= 0]].sum())
                dq = self.samples.setdefault(
                    name,
                    collections.deque(maxlen=self.config.sample_window))
                dq.append((q, float(latency_s)))
            self.stats["batches_seen"] += 1
            self._since_step += 1
            if (self.enabled
                    and self._since_step >= self.config.interval_batches):
                self._since_step = 0
                due = True
        if due:
            self.step()

    # -- control step --------------------------------------------------------
    def target_plan(self):
        """Placement the *current* empirical workload asks for.

        Returns:
            ``(plan, fap)`` — the target :class:`PlacementPlan` from FAP
            recomputed with the sketch's empirical seed distribution, and
            that FAP vector itself.
        """
        p0 = self.sketch.empirical_prob(prior_weight=self.config.prior_weight)
        fap = compute_fap(self.graph, self.fanouts, seed_prob=p0,
                          truncated=self.config.fap_truncated)
        return quiver_placement(fap, self.store.plan.topology), fap

    def step(self) -> dict:
        """One control step: re-place (bounded) + refit curves. Thread-safe;
        concurrent steps serialize on their own lock — telemetry callbacks
        from other lanes are never blocked by the recompute.

        Returns:
            ``{"migrated_rows", "refits", "pending"}`` — rows moved this
            step, curves swapped, and nodes still off their target tier
            (0 means the placement has converged for this workload).
        """
        with self._step_lock:
            target, fap = self.target_plan()
            pairs = migration_pairs(self.store.plan.tier, target.tier, fap,
                                    budget=max(self.config.rows_per_step // 2,
                                               1))
            moved = self.store.swap_assignments(pairs)
            refits = self.refit_curves()
            self.sketch.decay_step()
            with self._lock:
                self.stats["steps"] += 1
                self.stats["migrated_rows"] += moved
            return {"migrated_rows": moved, "refits": refits,
                    "pending": int((target.tier != self.store.plan.tier)
                                   .sum())}

    def refit_curves(self) -> int:
        """Refit per-executor curves from live samples; swap any whose drift
        against the router's current curve exceeds the threshold.

        Returns:
            Number of curves swapped into the router (0 when routerless,
            under-sampled, or drift stayed below the threshold).
        """
        if self.router is None:
            return 0
        swapped = 0
        with self._lock:
            items = [(name, list(dq)) for name, dq in self.samples.items()]
        for name, dq in items:
            if len(dq) < self.config.min_refit_samples:
                continue
            ps, ls = zip(*dq)
            new = LatencyCurve.fit(ps, ls, bins=self.config.curve_bins,
                                   tail=self.config.curve_tail)
            try:
                old = self.router.curve(name)
            except KeyError:
                continue
            drift = curve_drift(old, new)
            self.stats["last_drift"][name] = drift
            if drift > self.config.drift_threshold:
                self.router.update_curve(name, new)
                swapped += 1
        with self._lock:
            self.stats["refits"] += swapped
        return swapped

    def report(self) -> dict:
        """Adaptation counters for logging: steps, migrated rows, refits,
        batches seen, per-executor last drift, and seeds observed."""
        return {**{k: v for k, v in self.stats.items() if k != "last_drift"},
                "last_drift": {k: round(v, 4)
                               for k, v in self.stats["last_drift"].items()},
                "seeds_observed": self.sketch.total_observed}

"""Online workload adaptation: live FAP re-placement + router drift refit.

The paper computes its workload metrics offline: FAP ranks features once,
the placement plan and the per-executor latency curves are frozen at
startup. A drifting request mix (a hot subgraph emerging at serve time)
leaves the feature tiers and routing thresholds stale. This module closes
the loop, in the spirit of OMEGA's serve-time recomputation
(arXiv:2501.08547) and data-driven online GNN scheduling (arXiv:2411.16342):

  FrequencySketch       decayed seed-access counts, fed by the engine on
                        every admitted batch (``on_admit`` hook).
  AdaptiveController    periodically (every ``interval_batches`` completions)
                        (a) recomputes FAP with the *empirical* seed
                        distribution, (b) derives the target placement,
                        (c) migrates a bounded number of rows between the
                        HOT/WARM/HOST tiers of the live TieredFeatureStore
                        (swap-based — serving never pauses, lookups stay
                        bit-identical), (d) refits LatencyCurves from live
                        ``(psgs, latency)`` samples — *per model*, swapping
                        them into that model's CostModelRouter when the
                        measured drift exceeds a threshold — (e)
                        optionally nudges an attached MicroBatcher's
                        ``deadline_s``/``max_seeds`` toward the measured
                        knee of the live latency curve (micro-batch
                        auto-tuning, clamped to configured bounds), and (f)
                        promotes miss-hammered DISK rows and re-stages an
                        attached Prefetcher's device-side buffer with the
                        fresh FAP as the prediction score (cold-tier reads
                        leave the request critical path), and (g) sizes the
                        store's device cache capacity, the prefetch staging
                        budget and the refresh cadence from the measured
                        cold working set (``tune_cold_path`` — clamped to
                        bounds, so sizing stays bounded under any sketch).

Multi-model serving shares ONE sketch (FAP placement is store-wide — every
model reads the same feature rows) but keeps latency samples and curve
refits per ``(model, executor)``: two models over the same store have
different curves, so their refits must never blend.

Wire-up::

    controller = AdaptiveController(graph, fanouts, store, router,
                                    psgs_table=psgs)
    engine = ServingEngine(executors, router, hooks=[controller])

or, multi-model (``registry`` is a ModelRegistry)::

    controller = AdaptiveController(graph, fanouts, store,
                                    registry.routers(), psgs_table=psgs)
    engine = ServingEngine(registry, hooks=[controller])

The controller runs its control step inline on the completion-callback
thread that crossed the period boundary: that one lane stalls for the
recompute (O(edges) FAP pass + a migration bounded by ``rows_per_step``),
while every other lane's callbacks — and every concurrent lookup — keep
serving from the previous placement snapshot (steps hold a dedicated lock;
telemetry takes a separate short-lived one).
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Mapping, Optional, Sequence

import numpy as np

from repro.core.fap import compute_fap
from repro.core.placement import (TIER_HOST, migration_pairs,
                                  quiver_placement)
from repro.serving.registry import DEFAULT_MODEL, ModelRegistry
from repro.serving.router import CostModelRouter, LatencyCurve


class FrequencySketch:
    """Exponentially-decayed seed-access frequency over the node set.

    ``observe`` is called from executor callback threads; ``decay`` once per
    control period, so the sketch tracks the *recent* request mix: with decay
    ``d`` per period, a seed last hot ``k`` periods ago retains weight d^k.
    One sketch serves every model of a registry — feature placement is
    store-wide, so accesses blend across models by design.
    """

    def __init__(self, num_nodes: int, *, decay: float = 0.9):
        if not 0.0 < decay <= 1.0:
            raise ValueError(f"decay must be in (0, 1], got {decay}")
        self.num_nodes = int(num_nodes)
        self.decay = float(decay)
        self.counts = np.zeros(self.num_nodes, dtype=np.float64)
        self.total_observed = 0
        self._lock = threading.Lock()

    def observe(self, seeds: np.ndarray) -> None:
        """Count one batch's seed accesses (``-1`` padding ignored).
        Thread-safe; called from executor callback threads."""
        seeds = np.asarray(seeds)
        seeds = seeds[seeds >= 0]
        with self._lock:
            np.add.at(self.counts, seeds, 1.0)
            self.total_observed += int(seeds.size)

    def decay_step(self) -> None:
        """Multiply every count by ``decay`` (called once per control
        period, so old traffic fades geometrically)."""
        with self._lock:
            self.counts *= self.decay

    def empirical_prob(self, *, prior_weight: float = 0.2) -> np.ndarray:
        """Normalized access distribution, blended with a uniform prior so
        never-seen nodes keep non-zero FAP mass (cold-start safety)."""
        with self._lock:
            c = self.counts.copy()
        total = c.sum()
        uniform = np.full(self.num_nodes, 1.0 / self.num_nodes)
        if total <= 0.0:
            return uniform
        return (1.0 - prior_weight) * (c / total) + prior_weight * uniform


@dataclasses.dataclass
class AdaptiveConfig:
    interval_batches: int = 32     # control period, in completed batches
    rows_per_step: int = 64        # migration budget per control step
    decay: float = 0.9             # sketch decay per control period
    prior_weight: float = 0.2      # uniform blend in the empirical seed dist
    min_refit_samples: int = 24    # live samples before a curve refit
    curve_bins: int = 8
    curve_tail: float = 1.0        # tail statistic for the refit curves
    drift_threshold: float = 0.25  # mean relative avg-curve error to swap
    sample_window: int = 512       # live (psgs, latency) samples kept/executor
    fap_truncated: bool = False    # forwarded to compute_fap
    promote_budget: int = 16       # miss-driven DISK promotions per step
    # micro-batch auto-tuning (active only when a MicroBatcher is attached):
    # per control step, nudge deadline_s/max_seeds a `micro_step` fraction of
    # the way toward the knee of the live latency curve, clamped to bounds
    micro_step: float = 0.5
    micro_seeds_bounds: tuple[int, int] = (16, 4096)
    micro_deadline_bounds: tuple[float, float] = (5e-4, 5e-2)
    micro_deadline_frac: float = 0.5   # deadline target: frac of knee latency
    # cold-path auto-sizing (active when a GPUFeatureCache is attached to
    # the store and/or a Prefetcher to the controller): per control step,
    # nudge the cache capacity / staging budget a `cold_step` fraction
    # toward targets sized from the measured cold working set, and the
    # prefetch refresh cadence from the interval's prefetch miss ratio —
    # every target is clamped to its bounds, so a pathological sketch
    # (every node scoring hot) can never grow the sizes without bound
    cold_step: float = 0.5
    cache_rows_bounds: tuple[int, int] = (64, 8192)
    stage_budget_bounds: tuple[int, int] = (64, 8192)
    prefetch_cadence_bounds: tuple[int, int] = (1, 8)
    cache_headroom: float = 1.25   # cache target: headroom × cold working set
    cadence_miss_ratio: float = 0.25  # miss ratio above which cadence snaps
    #                                   back to refreshing every step
    # gateway admission tuning (active when a ServingGateway is attached):
    # per control step, nudge the gateway's queue_limit an `admission_step`
    # fraction toward a target set by the interval's deadline sheds (halve —
    # requests are going stale while queued, refuse them at admission
    # instead) or by slack saturation (relax toward the cap), clamped to
    # `queue_limit_bounds`
    admission_step: float = 0.5
    queue_limit_bounds: tuple[int, int] = (16, 4096)
    admission_sat_low: float = 0.5  # saturation below which the window relaxes


def curve_drift(old: LatencyCurve, new: LatencyCurve) -> float:
    """Mean relative disagreement of the two average-latency curves,
    evaluated on the new curve's calibrated support."""
    grid = np.asarray(new.psgs, dtype=np.float64)
    a = np.asarray(old.eval_avg(grid), dtype=np.float64)
    b = np.asarray(new.eval_avg(grid), dtype=np.float64)
    return float(np.mean(np.abs(b - a) / np.maximum(np.abs(a), 1e-12)))


def _normalize_routers(router) -> dict[str, CostModelRouter]:
    """Model → router mapping from any accepted ``router`` argument: a
    single router (default model), a mapping, a ModelRegistry, or None."""
    if router is None:
        return {}
    if isinstance(router, ModelRegistry):
        return router.routers()
    if isinstance(router, Mapping):
        return dict(router)
    return {DEFAULT_MODEL: router}


class AdaptiveController:
    """Telemetry-driven control loop over a live serving stack.

    Implements the engine hook protocol (``on_admit`` / ``on_batch_complete``
    — model-aware: the engine passes the batch's model tag) and owns the
    whole adaptation state: the shared frequency sketch, per-``(model,
    executor)`` latency samples, and the migration/refit counters in
    :attr:`stats`. ``router`` may be a single ``CostModelRouter`` (the
    single-model case), a model → router mapping, a ``ModelRegistry``
    (its routers are extracted), or ``None`` (placement-only adaptation).
    Attach a ``MicroBatcher`` (constructor ``micro=`` or
    :meth:`attach_micro`) to enable micro-batch auto-tuning.
    """

    def __init__(self, graph, fanouts: Sequence[int], store,
                 router=None, *, psgs_table: Optional[np.ndarray] = None,
                 config: Optional[AdaptiveConfig] = None, micro=None,
                 prefetcher=None):
        self.graph = graph
        self.fanouts = tuple(int(f) for f in fanouts)
        self.store = store
        self.routers = _normalize_routers(router)
        # single-model view kept for pre-multi-model callers/logs
        self.router = self.routers.get(DEFAULT_MODEL) or (
            next(iter(self.routers.values()), None))
        self.psgs_table = psgs_table
        self.config = config or AdaptiveConfig()
        self.micro = micro
        self.sketch = FrequencySketch(graph.num_nodes,
                                      decay=self.config.decay)
        # live (psgs, latency) samples keyed (model, executor name): refits
        # must never blend two models' curves even on shared executor names
        self.samples: dict[tuple[str, str], collections.deque] = {}
        self.stats = {"steps": 0, "migrated_rows": 0, "refits": 0,
                      "batches_seen": 0, "micro_tunings": 0,
                      "promoted_rows": 0, "prefetch_refreshes": 0,
                      "cold_tunings": 0, "admission_tunings": 0,
                      "last_drift": {}}
        # every attached prefetcher is refreshed/tuned per step; the first
        # one stays aliased as `.prefetcher` for pre-multi-store callers
        self.prefetchers: list = []
        self.prefetcher = None
        if prefetcher is not None:
            self.attach_prefetcher(prefetcher)
        self.gateway = None
        self._last_gateway_shed = 0
        self._since_step = 0
        # cold-path feedback state: last store-stats snapshot (interval
        # deltas), current prefetch refresh cadence (in control steps) and
        # steps elapsed since the last refresh
        self._last_store_stats: dict[str, int] = {}
        self._cadence = max(1, int(self.config.prefetch_cadence_bounds[0]))
        self._steps_since_refresh = 0
        self._psgs_seen = 0.0   # running Σ accumulated PSGS of sampled batches
        self._seeds_seen = 0    # running seed count — per-seed PSGS estimate
        # _lock guards telemetry (samples/stats/counters) and is only ever
        # held briefly; _step_lock serializes control steps. The heavy work
        # (FAP recompute, placement, migration) runs under _step_lock alone,
        # so completion callbacks on other lanes never block behind it.
        self._lock = threading.Lock()
        self._step_lock = threading.Lock()
        self.enabled = True

    def attach_micro(self, micro) -> "AdaptiveController":
        """Attach the live ``MicroBatcher`` whose ``deadline_s``/
        ``max_seeds`` the control step may nudge; returns the controller
        for chaining."""
        self.micro = micro
        return self

    def attach_prefetcher(self, prefetcher) -> "AdaptiveController":
        """Attach a :class:`~repro.core.prefetch.Prefetcher` the control
        step re-stages each period (with the freshly recomputed FAP as the
        prediction score — it covers multi-hop frontier accesses, which the
        seed sketch alone cannot). May be called more than once: each
        prefetcher keeps its own store (e.g. one over the single-host
        tiered store, one driving the sharded store's per-shard stages)
        and all of them are refreshed and budget-tuned per control step
        from the one shared sketch. ``None`` detaches them all. Returns
        the controller for chaining."""
        if prefetcher is None:
            self.prefetchers = []
            self.prefetcher = None
            return self
        prefetcher.sketch = self.sketch
        self.prefetchers.append(prefetcher)
        self.prefetcher = self.prefetchers[0]
        return self

    def attach_gateway(self, gateway) -> "AdaptiveController":
        """Attach the :class:`~repro.serving.gateway.ServingGateway` whose
        admission window (``config.queue_limit``) the control step may
        tighten from observed saturation and deadline sheds; returns the
        controller for chaining."""
        self.gateway = gateway
        return self

    # -- engine hook protocol ------------------------------------------------
    def on_admit(self, name: str, seeds: np.ndarray,
                 model: str = DEFAULT_MODEL) -> None:
        """Engine hook: feed the admitted batch's seeds into the frequency
        sketch (``-1`` padding is ignored by the sketch).

        Args:
            name: executor the batch was routed to (unused here).
            seeds: ``(B,)`` seed ids of the admitted batch.
            model: model tag of the batch (unused — the sketch is shared:
                placement is store-wide across models).
        """
        self.sketch.observe(seeds)

    def on_batch_complete(self, name: str, seeds: np.ndarray,
                          latency_s: float,
                          model: str = DEFAULT_MODEL) -> None:
        """Engine hook: record a live ``(psgs, latency)`` sample for the
        ``(model, executor)`` pair and run a control step when the period
        boundary is crossed (inline, on this callback thread).

        Args:
            name: executor that served the batch.
            seeds: ``(B,)`` seed ids of the batch.
            latency_s: per-batch service time (queueing + processing).
            model: model tag of the batch (defaults to the single model).
        """
        due = False
        with self._lock:
            if self.psgs_table is not None:
                seeds = np.asarray(seeds)
                valid = seeds[seeds >= 0]
                q = float(self.psgs_table[valid].sum())
                dq = self.samples.setdefault(
                    (model, name),
                    collections.deque(maxlen=self.config.sample_window))
                dq.append((q, float(latency_s)))
                self._psgs_seen += q
                self._seeds_seen += int(valid.size)
            self.stats["batches_seen"] += 1
            self._since_step += 1
            if (self.enabled
                    and self._since_step >= self.config.interval_batches):
                self._since_step = 0
                due = True
        if due:
            self.step()

    # -- control step --------------------------------------------------------
    def target_plan(self):
        """Placement the *current* empirical workload asks for.

        Returns:
            ``(plan, fap)`` — the target :class:`PlacementPlan` from FAP
            recomputed with the sketch's empirical seed distribution, and
            that FAP vector itself.
        """
        p0 = self.sketch.empirical_prob(prior_weight=self.config.prior_weight)
        fap = compute_fap(self.graph, self.fanouts, seed_prob=p0,
                          truncated=self.config.fap_truncated)
        return quiver_placement(fap, self.store.plan.topology), fap

    def step(self) -> dict:
        """One control step: re-place (bounded) + refit curves + tune the
        micro-batcher. Thread-safe; concurrent steps serialize on their own
        lock — telemetry callbacks from other lanes are never blocked by
        the recompute.

        Returns:
            ``{"migrated_rows", "refits", "pending", "micro",
            "promoted_rows", "prefetched", "cold", "admission"}`` — rows
            moved this step, curves swapped, nodes still off their target
            tier (0 means the placement has converged for this workload),
            the micro-batcher bounds after tuning (``None`` when no
            micro-batcher is attached), miss-driven DISK rows promoted,
            whether a prefetch refresh was kicked off (subject to the
            tuned cadence), the :meth:`tune_cold_path` sizing result, and
            the :meth:`tune_admission` gateway-window result (``None``
            when no gateway is attached).
        """
        with self._step_lock:
            target, fap = self.target_plan()
            pairs = migration_pairs(self.store.plan.tier, target.tier, fap,
                                    budget=max(self.config.rows_per_step // 2,
                                               1))
            moved = self.store.swap_assignments(pairs)
            # miss-driven DISK promotion: rows the workload actually missed
            # jump the FAP queue (bounded, swap-based — serving never sees
            # a torn row)
            promote = getattr(self.store, "promote_misses", None)
            promoted = (promote(budget=self.config.promote_budget)
                        if promote is not None else 0)
            refits = self.refit_curves()
            micro = self.tune_micro()
            # close the prefetch feedback loop BEFORE the refresh so the
            # freshly sized staging budget shapes this step's stage
            cold = self.tune_cold_path()
            admission = self.tune_admission()
            prefetched = False
            if self.prefetchers:
                self._steps_since_refresh += 1
                if self._steps_since_refresh >= self._cadence:
                    self._steps_since_refresh = 0
                    # re-stage the cold tiers off the critical path, scored
                    # by the fresh FAP (covers multi-hop frontiers, not
                    # just seeds) — every attached stage, single-host and
                    # per-shard alike, restages from the same score vector
                    for pf in self.prefetchers:
                        pf.refresh_async(scores=fap)
                    prefetched = True
            self.sketch.decay_step()
            with self._lock:
                self.stats["steps"] += 1
                self.stats["migrated_rows"] += moved + promoted
                self.stats["promoted_rows"] += promoted
                self.stats["prefetch_refreshes"] += int(prefetched)
                self.stats["admission_tunings"] += int(admission is not None)
            return {"migrated_rows": moved, "refits": refits,
                    "micro": micro, "promoted_rows": promoted,
                    "prefetched": prefetched, "cold": cold,
                    "admission": admission,
                    "pending": int((target.tier != self.store.plan.tier)
                                   .sum())}

    # -- cold-path feedback loop ---------------------------------------------
    def tune_cold_path(self) -> Optional[dict]:
        """Size the device cache, the prefetch staging budget and the
        refresh cadence from the measured cold working set.

        Per control step: the cold working set is the number of cold-tier
        (HOST/DISK) nodes with non-zero decayed sketch weight — the nodes
        the *recent* request mix actually touched below HBM. The attached
        :class:`~repro.core.gpu_cache.GPUFeatureCache` is resized a
        ``cold_step`` fraction toward ``cache_headroom ×`` that set
        (clamped to ``cache_rows_bounds``); the prefetcher's staging
        budget toward the set itself (``stage_budget_bounds``); and the
        refresh cadence from the interval's prefetch miss ratio
        (``prefetch_hits/misses`` deltas of the store's dispatch stats):
        misses above ``cadence_miss_ratio`` snap the cadence back to
        refreshing every step, a clean interval stretches it toward the
        upper bound. Every target is clamped, so sizes stay bounded under
        any sketch (see ``tests/test_gpu_cache.py``).

        Returns:
            ``{"cold_ws", "cache_rows"?, "stage_budget"?,
            "refresh_cadence"?}`` — or ``None`` when there is neither a
            cache nor a prefetcher to tune.
        """
        cache = getattr(self.store, "cache", None)
        pfs = self.prefetchers
        if cache is None and not pfs:
            return None
        cfg = self.config
        step = float(np.clip(cfg.cold_step, 0.0, 1.0))
        tier = np.asarray(self.store.tier_t)
        cold_ws = int(((tier >= TIER_HOST)
                       & (self.sketch.counts > 0.0)).sum())
        snapshot = getattr(self.store, "snapshot_stats", None)
        snap = snapshot() if snapshot is not None else {}
        delta = {k: max(0, int(v) - self._last_store_stats.get(k, 0))
                 for k, v in snap.items()}
        self._last_store_stats = {k: int(v) for k, v in snap.items()}
        out: dict = {"cold_ws": cold_ws}
        if cache is not None:
            lo, hi = cfg.cache_rows_bounds
            target = int(np.clip(round(cfg.cache_headroom * cold_ws),
                                 lo, hi))
            cur = int(cache.capacity)
            new = int(np.clip(round(cur + step * (target - cur)), lo, hi))
            if new != cur:
                cache.resize(new)
            out["cache_rows"] = new
        if pfs:
            lo, hi = cfg.stage_budget_bounds
            target = int(np.clip(cold_ws, lo, hi))
            for pf in pfs:
                cur = int(pf.budget)
                new = int(np.clip(round(cur + step * (target - cur)),
                                  lo, hi))
                pf.budget = new
            # the reported budget is the primary (first-attached) stage's
            out["stage_budget"] = int(pfs[0].budget)
            c_lo, c_hi = cfg.prefetch_cadence_bounds
            hits = delta.get("prefetch_hits", 0)
            misses = delta.get("prefetch_misses", 0)
            if hits + misses > 0:
                ratio = misses / (hits + misses)
                target_c = (c_lo if ratio > cfg.cadence_miss_ratio
                            else min(c_hi, self._cadence + 1))
                self._cadence = int(np.clip(
                    round(self._cadence + step * (target_c - self._cadence)),
                    c_lo, c_hi))
            out["refresh_cadence"] = self._cadence
        with self._lock:
            self.stats["cold_tunings"] += 1
        return out

    def tune_admission(self) -> Optional[dict]:
        """Tighten or relax the attached gateway's admission window.

        Per control step: when the interval saw deadline sheds (requests
        going stale while queued, or hopeless at admission), the gateway's
        ``queue_limit`` is nudged an ``admission_step`` fraction toward
        half its current value — a shorter queue turns late dequeue-time
        sheds into cheap admission-time refusals. When the interval was
        shed-free and engine saturation is below ``admission_sat_low``,
        the window relaxes toward the upper bound. Clamped to
        ``queue_limit_bounds`` either way; the gateway reads
        ``config.queue_limit`` per submit, so the nudge takes effect
        immediately (plain attribute write, no torn state).

        Returns:
            ``{"queue_limit", "saturation", "deadline_sheds"}`` after the
            nudge, or ``None`` when no gateway is attached.
        """
        gw = self.gateway
        if gw is None:
            return None
        cfg = self.config
        step = float(np.clip(cfg.admission_step, 0.0, 1.0))
        lo, hi = cfg.queue_limit_bounds
        rep = gw.report()
        shed_dl = int(rep.get("shed_deadline", 0))
        dl_delta = max(0, shed_dl - self._last_gateway_shed)
        self._last_gateway_shed = shed_dl
        saturation = float(rep.get("saturation", 0.0))
        cur = int(gw.config.queue_limit)
        if dl_delta > 0:
            target = max(lo, cur // 2)
        elif saturation < cfg.admission_sat_low:
            target = hi
        else:
            target = cur
        new = int(np.clip(round(cur + step * (target - cur)), lo, hi))
        gw.config.queue_limit = new
        return {"queue_limit": new, "saturation": saturation,
                "deadline_sheds": dl_delta}

    def refit_curves(self) -> int:
        """Refit curves from live samples, per ``(model, executor)``; swap
        any whose drift against that model's router curve exceeds the
        threshold. Models without a registered router are skipped.

        Returns:
            Number of curves swapped into the routers (0 when routerless,
            under-sampled, or drift stayed below the threshold).
        """
        if not self.routers:
            return 0
        swapped = 0
        with self._lock:
            items = [(key, list(dq)) for key, dq in self.samples.items()]
        for (model, name), dq in items:
            router = self.routers.get(model)
            if router is None or len(dq) < self.config.min_refit_samples:
                continue
            ps, ls = zip(*dq)
            new = LatencyCurve.fit(ps, ls, bins=self.config.curve_bins,
                                   tail=self.config.curve_tail)
            try:
                old = router.curve(name)
            except KeyError:
                continue
            drift = curve_drift(old, new)
            key = name if model == DEFAULT_MODEL else f"{model}/{name}"
            with self._lock:
                # unlocked writes here race report()'s iteration over
                # last_drift (dict-changed-size-during-iteration)
                self.stats["last_drift"][key] = drift
            if drift > self.config.drift_threshold:
                router.update_curve(name, new)
                swapped += 1
        with self._lock:
            self.stats["refits"] += swapped
        return swapped

    # -- micro-batch auto-tuning ---------------------------------------------
    def micro_targets(self) -> Optional[dict]:
        """Measured-knee targets for the attached micro-batcher.

        Fits one latency curve over *all* live samples (every model and
        executor — the micro stage feeds them all), finds the PSGS with the
        best latency-per-unit-work (the knee: below it, fixed dispatch
        overhead dominates; past it, marginal cost is flat), and converts it
        to a seed count via the observed mean per-seed PSGS. The deadline
        target is ``micro_deadline_frac`` of the knee's own service latency
        — waiting longer than a fraction of the work itself cannot pay off.

        Returns:
            ``{"max_seeds", "deadline_s", "knee_psgs"}`` clamped to the
            configured bounds, or ``None`` when there are not yet
            ``min_refit_samples`` samples (or no per-seed PSGS estimate).
        """
        with self._lock:
            flat = [s for dq in self.samples.values() for s in dq]
            psgs_seen, seeds_seen = self._psgs_seen, self._seeds_seen
        if len(flat) < self.config.min_refit_samples or seeds_seen == 0:
            return None
        per_seed = psgs_seen / seeds_seen
        if per_seed <= 0.0:
            return None
        ps, ls = zip(*flat)
        curve = LatencyCurve.fit(ps, ls, bins=self.config.curve_bins,
                                 tail=self.config.curve_tail)
        lo, hi = float(curve.psgs[0]), float(curve.psgs[-1])
        grid = np.linspace(max(lo, 1e-9), max(hi, lo + 1e-9), 256)
        eff = np.asarray(curve.eval_avg(grid)) / grid   # s per unit PSGS
        knee_q = float(grid[int(np.argmin(eff))])
        s_lo, s_hi = self.config.micro_seeds_bounds
        d_lo, d_hi = self.config.micro_deadline_bounds
        return {
            "max_seeds": int(np.clip(round(knee_q / per_seed), s_lo, s_hi)),
            "deadline_s": float(np.clip(
                float(curve.eval_avg(knee_q))
                * self.config.micro_deadline_frac, d_lo, d_hi)),
            "knee_psgs": knee_q,
        }

    def tune_micro(self) -> Optional[dict]:
        """Nudge the attached micro-batcher's ``max_seeds``/``deadline_s`` a
        ``micro_step`` fraction of the way toward :meth:`micro_targets`
        (clamped to the configured bounds; plain attribute writes — the
        batcher reads them per ``add``, so no torn state is possible).

        Returns:
            The batcher's bounds after the nudge plus the knee estimate, or
            ``None`` when no micro-batcher is attached / targets are not
            yet measurable.
        """
        if self.micro is None:
            return None
        targets = self.micro_targets()
        if targets is None:
            return None
        step = float(np.clip(self.config.micro_step, 0.0, 1.0))
        s_lo, s_hi = self.config.micro_seeds_bounds
        d_lo, d_hi = self.config.micro_deadline_bounds
        cur_seeds, cur_dl = self.micro.max_seeds, self.micro.deadline_s
        new_seeds = int(np.clip(
            round(cur_seeds + step * (targets["max_seeds"] - cur_seeds)),
            s_lo, s_hi))
        new_dl = float(np.clip(
            cur_dl + step * (targets["deadline_s"] - cur_dl), d_lo, d_hi))
        self.micro.max_seeds = new_seeds
        self.micro.deadline_s = new_dl
        with self._lock:
            self.stats["micro_tunings"] += 1
        return {"max_seeds": new_seeds, "deadline_s": new_dl,
                "knee_psgs": targets["knee_psgs"]}

    def report(self) -> dict:
        """Adaptation counters for logging: steps, migrated rows, refits,
        micro tunings, batches seen, per-``(model/)executor`` last drift,
        and seeds observed."""
        with self._lock:
            stats = dict(self.stats)
            last_drift = dict(stats["last_drift"])
        return {**{k: v for k, v in stats.items() if k != "last_drift"},
                "last_drift": {k: round(v, 4)
                               for k, v in last_drift.items()},
                "seeds_observed": self.sketch.total_observed}

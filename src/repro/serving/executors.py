"""Pluggable serving executors (paper §4.2–§4.3, generalized).

Quiver's serving contribution is *workload-aware routing between executors*:
the paper ships exactly two (host sampler vs device sampler). This module
turns "executor" into a first-class, pluggable unit so the router can choose
among N of them:

  ``HostExecutor``     exact dynamic-shape sampling on the host (CPU path).
  ``DeviceExecutor``   padded static-shape sampling on one accelerator
                       (GPU path); oversized batches are *chunked*, never
                       silently truncated.
  ``ShardedExecutor``  the distributed path: mesh-local sampling under
                       ``shard_map`` plus one-sided sharded feature reads
                       through ``ShardedFeatureStore.lookup``.

Every executor owns ``capacity`` worker lanes (the paper's "multiplexed
pipelines in a processor", §4.3(1)) and exposes

  ``cost(seeds)``   accumulated PSGS of the batch — O(1) per seed,
  ``submit(seeds)`` → ``concurrent.futures.Future`` of the model output,
  ``capacity``      number of batches it can process concurrently.

This module must stay importable without ``repro.core`` (the core package
shims onto it), so it depends only on ``repro.graph`` + numpy/jax.
"""
from __future__ import annotations

import threading
import warnings
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Optional, Protocol, Sequence, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import shard_map
from repro.graph.sampler import (_sample_one_hop, device_sample,
                                 host_sample_dense)


def pad_to_bucket(arr: np.ndarray, *, min_size: int = 16,
                  fill: int = -1) -> np.ndarray:
    """Pad a dynamic-size host array up to the next power-of-two bucket so
    jit re-compilation is bounded to O(log max_size) shapes."""
    n = max(int(arr.shape[0]), 1)
    size = max(min_size, 1 << (n - 1).bit_length())
    out = np.full((size,) + arr.shape[1:], fill, dtype=arr.dtype)
    out[:arr.shape[0]] = arr  # arr may be empty: pad-only bucket
    return out


def _accumulated_psgs(psgs_table: np.ndarray, seeds: np.ndarray) -> float:
    """Accumulated PSGS of a batch (paper §4.2.2). Local copy of
    ``repro.core.psgs.batch_psgs`` so this package stays core-free."""
    seeds = np.asarray(seeds)
    valid = seeds >= 0
    return float(psgs_table[seeds[valid]].sum())


@runtime_checkable
class Executor(Protocol):
    """What the router and engine require of an executor.

    Attributes:
        name: registry key used by the router and the engine.
        kind: ``"host"`` | ``"device"`` — selects which latency statistic
            a routing policy judges this executor by (Fig. 6(b) roles).
        capacity: number of concurrent worker lanes (batches in flight).
    """

    name: str
    kind: str           # "host" | "device" | ... (policy stat selection)
    capacity: int

    def cost(self, seeds: np.ndarray) -> float:
        """Routing signal for a batch.

        Args:
            seeds: ``(B,)`` seed node ids, ``-1`` entries ignored.

        Returns:
            Accumulated PSGS of the batch (batch size when the executor has
            no PSGS table).
        """
        ...

    def submit(self, seeds: np.ndarray) -> Future:
        """Enqueue a batch on one of the executor's worker lanes.

        Args:
            seeds: ``(B,)`` seed node ids.

        Returns:
            A future resolving to the ``(B, d_out)`` model output (one row
            per seed — padding is an internal concern).
        """
        ...


class BaseExecutor:
    """Shared machinery: worker lanes, PSGS costing, inflight accounting.

    Subclasses implement ``process(seeds) -> jnp.ndarray`` returning one
    output row per seed (padding is an internal concern — callers never see
    truncated or zero-filled extra rows).
    """

    kind = "device"

    def __init__(self, name: str, *, capacity: int = 1,
                 psgs_table: Optional[np.ndarray] = None,
                 rng_seed: int = 0, fused: bool = True,
                 fuse_aggregate: bool = False):
        self.name = name
        self.capacity = int(capacity)
        self.psgs_table = psgs_table
        # fused feature collection: one cross-hop dedup + one gather per
        # tier class (store.lookup_hops) instead of per-hop lookups. Output
        # is bit-identical; the flag exists for equivalence testing and for
        # stores that only implement lookup().
        self.fused = bool(fused)
        # fused gather→aggregate: the store also reduces the innermost hop
        # into per-parent sums (store.lookup_aggregate), so the dense
        # deepest-hop tensor never materializes. Requires an ``infer_fn``
        # accepting ``deep_agg=``; the flag is opt-in for that reason.
        self.fuse_aggregate = bool(fuse_aggregate)
        self._pool = ThreadPoolExecutor(max_workers=self.capacity,
                                        thread_name_prefix=f"exec-{name}")
        self._lock = threading.Lock()
        self._inflight = 0
        self._key = jax.random.key(rng_seed)
        self._seed_rng = np.random.default_rng(rng_seed)

    # -- cost model signal ---------------------------------------------------
    def cost(self, seeds: np.ndarray) -> float:
        """Routing signal: accumulated PSGS (or batch size if no table)."""
        seeds = np.asarray(seeds)
        if self.psgs_table is None:
            return float((seeds >= 0).sum())
        return _accumulated_psgs(self.psgs_table, seeds)

    # -- rng (thread-safe draws for concurrent lanes) ------------------------
    def _next_key(self) -> jax.Array:
        with self._lock:
            self._key, sub = jax.random.split(self._key)
        return sub

    def _child_rng(self) -> np.random.Generator:
        with self._lock:
            seed = int(self._seed_rng.integers(0, 2**63))
        return np.random.default_rng(seed)

    # -- execution -----------------------------------------------------------
    @property
    def inflight(self) -> int:
        """Batches currently submitted and not yet completed (the router's
        load-aware signal)."""
        with self._lock:
            return self._inflight

    def process(self, seeds: np.ndarray) -> jnp.ndarray:
        """Subclass hook: sample + collect features + infer for one batch.

        Args:
            seeds: ``(B,)`` seed node ids (``-1`` padding allowed).

        Returns:
            ``(B, d_out)`` model output, one row per input seed.

        Raises:
            NotImplementedError: on the base class.
        """
        raise NotImplementedError

    def _collect(self, store, hops):
        """Feature collection for a layered sample. Returns
        ``(hop_feats, deep_agg)``: the fused gather→aggregate fast path
        (``store.lookup_aggregate``) when ``fuse_aggregate`` is enabled and
        the store supports it — ``hop_feats`` then omits the innermost hop
        and ``deep_agg`` carries its pre-reduced per-parent sums — else the
        fused single-dispatch path (``store.lookup_hops``) or the legacy
        per-hop loop, both with ``deep_agg=None``."""
        if (self.fuse_aggregate and len(hops) > 1
                and hasattr(store, "lookup_aggregate")):
            return store.lookup_aggregate(hops)
        if self.fused and hasattr(store, "lookup_hops"):
            return store.lookup_hops(hops), None
        return [store.lookup(h) for h in hops], None

    def collect_mode(self, store) -> str:
        """The feature-collection path :meth:`_collect` takes for ``store``
        under the current flags on a multi-hop sample:
        ``"fuse_aggregate"`` (gather→aggregate fusion), ``"fused"``
        (single cross-hop ``lookup_hops`` dispatch) or ``"per_hop"`` (the
        legacy loop). The engine surfaces this per store in
        ``ServeMetrics.summary()["store"]`` so a silently-downgraded flag
        — e.g. ``fuse_aggregate=True`` against a store without
        ``lookup_aggregate`` — is visible in telemetry, not just in a
        construction-time warning. See the support matrix in
        ``docs/architecture.md``."""
        if self.fuse_aggregate and hasattr(store, "lookup_aggregate"):
            return "fuse_aggregate"
        if self.fused and hasattr(store, "lookup_hops"):
            return "fused"
        return "per_hop"

    def supports(self, seeds: np.ndarray) -> bool:
        """Eligibility for a batch — routers skip executors returning False
        (e.g. the sharded executor cannot serve cold-tier seeds exactly)."""
        return True

    def stores(self) -> list:
        """The feature store(s) this executor reads (shared across the
        models of a registry) — the engine snapshots their dispatch stats
        into ``ServeMetrics.store_stats`` at the end of a run."""
        return [s for s in (getattr(self, "store", None),
                            getattr(self, "sstore", None)) if s is not None]

    def run(self, seeds: np.ndarray) -> jnp.ndarray:
        """Synchronous convenience path (calibration, warmup, debugging)."""
        out = self.process(np.asarray(seeds))
        jax.block_until_ready(out)
        return out

    def submit(self, seeds: np.ndarray) -> Future:
        """Enqueue a batch on a worker lane (see :class:`Executor.submit`);
        resolves to the ``(B, d_out)`` output of :meth:`process`."""
        with self._lock:
            self._inflight += 1
        fut = self._pool.submit(self.run, seeds)
        fut.add_done_callback(self._one_done)
        return fut

    def _one_done(self, _fut: Future) -> None:
        with self._lock:
            self._inflight -= 1

    def warmup(self, seeds: np.ndarray, *, rounds: int = 2) -> None:
        """Run ``rounds`` synchronous passes so jit compilation happens
        outside any measured window."""
        for _ in range(rounds):
            self.run(seeds)

    def close(self) -> None:
        """Shut down the worker-lane pool (blocks until lanes drain)."""
        self._pool.shutdown(wait=True)


class HostExecutor(BaseExecutor):
    """Exact host sampling (the 'CPU path') in the dense fan-out layout;
    seeds bucket-padded so jit shapes stay O(log max_batch)."""

    kind = "host"

    def __init__(self, graph, store, fanouts: Sequence[int],
                 infer_fn: Callable, *, capacity: int = 1,
                 psgs_table: Optional[np.ndarray] = None, rng_seed: int = 0,
                 fused: bool = True, fuse_aggregate: bool = False,
                 name: str = "host"):
        super().__init__(name, capacity=capacity, psgs_table=psgs_table,
                         rng_seed=rng_seed, fused=fused,
                         fuse_aggregate=fuse_aggregate)
        self.graph = graph
        self.store = store
        self.fanouts = tuple(fanouts)
        self.infer_fn = infer_fn

    def process(self, seeds: np.ndarray) -> jnp.ndarray:
        """Exact host sampling → (fused) feature collection → inference;
        returns one output row per seed."""
        n = int(seeds.shape[0])
        seeds_p = pad_to_bucket(np.asarray(seeds).astype(np.int32))
        hops_np = host_sample_dense(self._child_rng(), self.graph, seeds_p,
                                    self.fanouts)
        hops = [jnp.asarray(h) for h in hops_np]
        hop_feats, deep_agg = self._collect(self.store, hops)
        if deep_agg is not None:
            return self.infer_fn(hop_feats, hops, deep_agg=deep_agg)[:n]
        return self.infer_fn(hop_feats, hops)[:n]


class DeviceExecutor(BaseExecutor):
    """Fully padded on-device pipeline (the 'GPU path'): one static shape
    (``max_batch``), jitted end to end. Batches larger than ``max_batch``
    are processed in ``max_batch``-sized chunks and re-concatenated — no
    seed is ever dropped (the old ``_device_path`` silently truncated)."""

    kind = "device"

    def __init__(self, graph_dev: tuple[jnp.ndarray, jnp.ndarray], store,
                 fanouts: Sequence[int], infer_fn: Callable, *,
                 max_batch: int = 128, capacity: int = 1,
                 psgs_table: Optional[np.ndarray] = None, rng_seed: int = 0,
                 fused: bool = True, fuse_aggregate: bool = False,
                 name: str = "device"):
        super().__init__(name, capacity=capacity, psgs_table=psgs_table,
                         rng_seed=rng_seed, fused=fused,
                         fuse_aggregate=fuse_aggregate)
        self.graph_dev = graph_dev
        self.store = store
        self.fanouts = tuple(fanouts)
        self.infer_fn = infer_fn
        self.max_batch = int(max_batch)

    def process(self, seeds: np.ndarray) -> jnp.ndarray:
        """Padded device sampling → (fused) feature collection → inference,
        chunked at ``max_batch``; returns one output row per seed."""
        seeds = np.asarray(seeds)
        n = int(seeds.shape[0])
        outs = []
        for lo in range(0, max(n, 1), self.max_batch):
            chunk = seeds[lo:lo + self.max_batch]
            seeds_p = np.full((self.max_batch,), -1, np.int32)
            seeds_p[:chunk.shape[0]] = chunk
            hops = device_sample(self._next_key(), *self.graph_dev,
                                 jnp.asarray(seeds_p), self.fanouts)
            hop_feats, deep_agg = self._collect(self.store, hops)
            out = (self.infer_fn(hop_feats, hops, deep_agg=deep_agg)
                   if deep_agg is not None
                   else self.infer_fn(hop_feats, hops))
            outs.append(out[:chunk.shape[0]])
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)


class ShardedExecutor(BaseExecutor):
    """Distributed serving path over a device mesh axis.

    Sampling runs mesh-local under ``shard_map`` (each device samples its
    contiguous slice of the seed vector against the replicated CSR
    topology); features come from the sharded store's fused
    ``lookup_hops`` — by default the owner-sorted dedup ``all_to_all``
    exchange of paper §5.3. A store built via
    ``ShardedFeatureStore.from_tiered`` resolves HOST/DISK rows exactly
    (per-shard staged rows inside the exchange, host fetch on a miss);
    only a directly-constructed store keeps the legacy zeros behavior
    for cold ids — pass ``tier_table`` (the placement's per-node tier
    array) there so :meth:`supports` declares cold-seed batches
    ineligible and the router keeps them on the host executor.

    Feature-collection support matrix: the sharded store serves whole
    rows only, so ``fuse_aggregate=True`` (the gather→aggregate fusion of
    ``TieredFeatureStore.lookup_aggregate``) cannot apply here — it is
    accepted for construction-site symmetry with the other executors but
    warns once and falls back to the fused ``lookup_hops`` path; the
    active mode is surfaced per store as ``collect_mode`` in
    ``ServeMetrics.summary()["store"]`` (full matrix:
    ``docs/architecture.md``).

    ``max_batch`` is rounded up to a multiple of the mesh world size so the
    per-device shard is static.
    """

    kind = "device"
    _warned_fuse_aggregate = False

    def __init__(self, mesh, axis_name: str,
                 graph_dev: tuple[jnp.ndarray, jnp.ndarray],
                 sharded_store, fanouts: Sequence[int], infer_fn: Callable, *,
                 max_batch: int = 128, capacity: int = 1,
                 psgs_table: Optional[np.ndarray] = None,
                 tier_table: Optional[np.ndarray] = None, rng_seed: int = 0,
                 fused: bool = True, fuse_aggregate: bool = False,
                 name: str = "sharded"):
        super().__init__(name, capacity=capacity, psgs_table=psgs_table,
                         rng_seed=rng_seed, fused=fused,
                         fuse_aggregate=fuse_aggregate)
        if fuse_aggregate and not hasattr(sharded_store, "lookup_aggregate"):
            self._warn_fuse_aggregate_downgrade()
        self.tier_table = tier_table
        from jax.sharding import NamedSharding, PartitionSpec as P
        self.mesh = mesh
        self.axis = axis_name
        self.sstore = sharded_store
        self.world = int(sharded_store.world)
        self.max_batch = -(-int(max_batch) // self.world) * self.world
        self.fanouts = tuple(fanouts)
        self.infer_fn = infer_fn
        rep = NamedSharding(mesh, P())
        self.graph_dev = tuple(jax.device_put(a, rep) for a in graph_dev)

        fanouts_t = self.fanouts
        axis = axis_name

        def sample_body(indptr, indices, seeds_l, key):
            # per-device stream: fold the lane key with the device index
            key = jax.random.fold_in(key, jax.lax.axis_index(axis))
            hops = [seeds_l]
            frontier = seeds_l
            for fan in fanouts_t:
                key, sub = jax.random.split(key)
                frontier = _sample_one_hop(sub, indptr, indices, frontier,
                                           fan)
                hops.append(frontier)
            return tuple(hops)

        self._sample = jax.jit(shard_map(
            sample_body, mesh=mesh,
            in_specs=(P(), P(), P(axis), P()), out_specs=P(axis)))

    @classmethod
    def _warn_fuse_aggregate_downgrade(cls) -> None:
        if cls._warned_fuse_aggregate:
            return
        cls._warned_fuse_aggregate = True
        warnings.warn(
            "ShardedExecutor: fuse_aggregate=True has no effect — the "
            "sharded store serves whole rows only (no lookup_aggregate); "
            "falling back to the fused lookup_hops path. The active mode "
            "is reported as collect_mode in "
            "ServeMetrics.summary()['store']; see the support matrix in "
            "docs/architecture.md.", RuntimeWarning, stacklevel=3)

    def supports(self, seeds: np.ndarray) -> bool:
        """Eligible only when every valid seed lives on an HBM tier.
        Stores built via ``from_tiered`` resolve cold rows exactly, so
        they leave ``tier_table`` unset and accept every batch; a
        directly-constructed store (cold ids read as zeros) passes the
        placement's tier array here so the router keeps cold-seed batches
        on the host executor. Always ``True`` without a ``tier_table``."""
        if self.tier_table is None:
            return True
        seeds = np.asarray(seeds)
        seeds = seeds[seeds >= 0]
        # tiers 0/1 are the HBM (hot/warm) tiers the sharded store serves
        return bool((self.tier_table[seeds] <= 1).all())

    def process(self, seeds: np.ndarray) -> jnp.ndarray:
        """Mesh-local shard_map sampling → (fused) sharded feature reads →
        inference, chunked at the mesh-padded ``max_batch``; returns one
        output row per seed."""
        seeds = np.asarray(seeds)
        n = int(seeds.shape[0])
        outs = []
        for lo in range(0, max(n, 1), self.max_batch):
            chunk = seeds[lo:lo + self.max_batch]
            seeds_p = np.full((self.max_batch,), -1, np.int32)
            seeds_p[:chunk.shape[0]] = chunk
            hops = list(self._sample(*self.graph_dev, jnp.asarray(seeds_p),
                                     self._next_key()))
            # ShardedFeatureStore has no lookup_aggregate — _collect falls
            # back to the fused whole-row path there, deep_agg stays None
            hop_feats, deep_agg = self._collect(self.sstore, hops)
            out = (self.infer_fn(hop_feats, hops, deep_agg=deep_agg)
                   if deep_agg is not None
                   else self.infer_fn(hop_feats, hops))
            outs.append(out[:chunk.shape[0]])
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)

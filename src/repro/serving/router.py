"""Cost-model routing over a registry of executors (paper §4.2, generalized).

Offline, a serving-workload generator measures end-to-end processing latency
of batches with varying accumulated PSGS on every executor. Per executor we
fit an *average* and a *maximum* latency curve over PSGS
(:class:`LatencyCurve`). The four operating points of Fig. 6(b) select which
statistic each executor is judged by:

    1 cpu_preferred        : host.max  vs device.avg
    2 gpu_preferred        : host.avg  vs device.max
    3 latency_preferred    : host.max  vs device.max   (bound tail latency)
    4 throughput_preferred : host.avg  vs device.avg   (maximize throughput)

The paper's scheduler reduces this to a single PSGS threshold because it has
exactly two executors and single-crossing curves; :class:`HybridScheduler`
(kept below, re-exported from ``repro.core.scheduler``) is that special case.
:class:`CostModelRouter` is the N-way generalization: a batch goes to the
executor whose policy-selected curve value at the batch's accumulated PSGS is
minimal — with two executors this is exactly the threshold rule.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Mapping, Optional, Sequence

import numpy as np

from repro.serving.executors import Executor, _accumulated_psgs

POLICIES = ("cpu_preferred", "gpu_preferred", "latency_preferred",
            "throughput_preferred")


def _policy_stat(policy: str, kind: str) -> str:
    """Which curve ("avg" | "max") policy ``policy`` judges a ``kind``-kind
    executor by. Host-kind executors are the CPU sampler of Fig. 6(b); every
    other kind (device, sharded, ...) takes the device role."""
    if policy in ("latency_preferred", "strict"):
        return "max"
    if policy in ("throughput_preferred", "loose"):
        return "avg"
    if policy == "cpu_preferred":
        return "max" if kind == "host" else "avg"
    if policy == "gpu_preferred":
        return "avg" if kind == "host" else "max"
    raise ValueError(f"unknown policy {policy!r}")


@dataclasses.dataclass
class LatencyCurve:
    """Piecewise-linear latency-vs-PSGS curve (avg + tail) fit from samples.

    Queries above the calibrated PSGS range extrapolate linearly along the
    last (non-negative-slope) segment instead of ``np.interp``'s flat
    continuation — a flat tail silently underestimated the cost of batches
    far larger than anything calibrated, starving the cheap executor.
    :meth:`covers` flags out-of-range queries for callers that want to
    trigger recalibration instead.
    """

    psgs: np.ndarray      # (B,) bin centers, ascending
    avg: np.ndarray       # (B,) mean latency per bin (seconds)
    mx: np.ndarray        # (B,) tail (max or p99) latency per bin

    @staticmethod
    def fit(samples_psgs: Sequence[float], samples_lat: Sequence[float],
            *, bins: int = 12, tail: float = 1.0) -> "LatencyCurve":
        p = np.asarray(samples_psgs, dtype=np.float64)
        l = np.asarray(samples_lat, dtype=np.float64)
        if p.size == 0:
            raise ValueError("LatencyCurve.fit needs at least one sample")
        order = np.argsort(p)
        p, l = p[order], l[order]
        # Degenerate sample sets (fewer samples than bins, or repeated /
        # constant PSGS) produce duplicate quantile edges; without dedup all
        # but one duplicate bin came back empty and the curve collapsed to a
        # near-empty point set. Dedupe, and fall back to one all-inclusive
        # bin when every sample shares one PSGS value.
        bins = max(1, min(int(bins), p.size))
        edges = np.unique(np.quantile(p, np.linspace(0, 1, bins + 1)))
        if edges.size < 2:
            edges = np.array([edges[0], edges[0] + 1e-9])
        edges[-1] += 1e-9
        centers, avgs, maxs = [], [], []
        for i in range(edges.size - 1):
            m = (p >= edges[i]) & (p < edges[i + 1])
            if not m.any():
                continue
            centers.append(p[m].mean())
            avgs.append(l[m].mean())
            maxs.append(np.quantile(l[m], tail) if tail < 1.0 else l[m].max())
        return LatencyCurve(np.asarray(centers), np.asarray(avgs),
                            np.asarray(maxs))

    def covers(self, q: float | np.ndarray) -> bool | np.ndarray:
        """Whether ``q`` falls inside the calibrated PSGS range."""
        inside = (np.asarray(q) >= self.psgs[0]) & (np.asarray(q)
                                                    <= self.psgs[-1])
        return bool(inside) if np.ndim(q) == 0 else inside

    def _eval(self, q: float | np.ndarray, ys: np.ndarray) -> np.ndarray:
        out = np.interp(q, self.psgs, ys)
        if self.psgs.size >= 2:
            # latency is non-decreasing in work: clamp the extrapolation
            # slope at >= 0 so a noisy last bin can't make huge batches
            # look *cheaper* than the calibrated maximum
            dq = float(self.psgs[-1] - self.psgs[-2])
            slope = max(float(ys[-1] - ys[-2]) / max(dq, 1e-12), 0.0)
            out = np.where(np.asarray(q) > self.psgs[-1],
                           ys[-1] + slope * (np.asarray(q) - self.psgs[-1]),
                           out)
        return out

    def eval_avg(self, q: float | np.ndarray) -> np.ndarray:
        return self._eval(q, self.avg)

    def eval_max(self, q: float | np.ndarray) -> np.ndarray:
        return self._eval(q, self.mx)

    def eval(self, q: float | np.ndarray, stat: str) -> np.ndarray:
        return self.eval_max(q) if stat == "max" else self.eval_avg(q)


@dataclasses.dataclass
class CalibrationResult:
    """Binary host/device calibration (the paper's Fig. 6 setting)."""

    host: LatencyCurve
    device: LatencyCurve

    def _cross(self, f_host: Callable, f_dev: Callable) -> float:
        lo = min(self.host.psgs.min(), self.device.psgs.min())
        hi = max(self.host.psgs.max(), self.device.psgs.max())
        grid = np.linspace(lo, hi, 512)
        diff = f_host(grid) - f_dev(grid)
        sign = np.signbit(diff)
        flips = np.flatnonzero(sign[1:] != sign[:-1])
        if flips.size == 0:
            # no intersection: host always faster → +inf threshold (never use
            # device); device always faster → 0 (always device)
            return float("inf") if diff[-1] < 0 else 0.0
        i = flips[0]
        # linear interpolation of the crossing, clamped to the measured range
        x0, x1, d0, d1 = grid[i], grid[i + 1], diff[i], diff[i + 1]
        denom = d1 - d0
        if abs(denom) < 1e-15:
            return float(x0)
        return float(np.clip(x0 + (x1 - x0) * (0 - d0) / denom, lo, hi))

    def threshold(self, policy: str) -> float:
        h, d = self.host, self.device
        if policy == "cpu_preferred":
            return self._cross(h.eval_max, d.eval_avg)
        if policy == "gpu_preferred":
            return self._cross(h.eval_avg, d.eval_max)
        if policy in ("latency_preferred", "strict"):
            return self._cross(h.eval_max, d.eval_max)
        if policy in ("throughput_preferred", "loose"):
            return self._cross(h.eval_avg, d.eval_avg)
        raise ValueError(f"unknown policy {policy!r}")


def calibrate_executors(executors: Mapping[str, Callable] | Sequence[Executor],
                        batches: Sequence[np.ndarray],
                        psgs_table: np.ndarray, *, repeats: int = 3,
                        warmup: int = 1, tail: float = 1.0
                        ) -> dict[str, LatencyCurve]:
    """Measure every executor on the same batches and fit one
    :class:`LatencyCurve` each (N-way generalization of :func:`calibrate`).

    ``executors`` maps name → a synchronous runner — either a plain callable
    taking a seed array or an :class:`Executor` (its blocking ``run`` is
    used). Measurements follow the paper's protocol: steady-state repeats
    after warmup, no queueing.
    """
    if not isinstance(executors, Mapping):
        executors = {ex.name: ex for ex in executors}
    curves: dict[str, LatencyCurve] = {}
    for name, ex in executors.items():
        run = ex.run if hasattr(ex, "run") else ex
        ps, ls = [], []
        for b in batches:
            q = _accumulated_psgs(psgs_table, b)
            for _ in range(warmup):
                run(b)
            for _ in range(repeats):
                t0 = time.perf_counter()
                run(b)
                ls.append(time.perf_counter() - t0)
                ps.append(q)
        curves[name] = LatencyCurve.fit(ps, ls, tail=tail)
    return curves


def calibrate(host_run: Callable[[np.ndarray], None],
              device_run: Callable[[np.ndarray], None],
              batches: Sequence[np.ndarray], psgs_table: np.ndarray,
              *, repeats: int = 3, warmup: int = 1,
              tail: float = 1.0) -> CalibrationResult:
    """Binary special case kept for the paper's Fig. 6 experiments."""
    curves = calibrate_executors({"host": host_run, "device": device_run},
                                 batches, psgs_table, repeats=repeats,
                                 warmup=warmup, tail=tail)
    return CalibrationResult(host=curves["host"], device=curves["device"])


class CostModelRouter:
    """N-way routing over a registry of calibrated executors.

    ``route(seeds)`` evaluates every registered executor's policy-selected
    latency curve at the batch's accumulated PSGS and picks the minimum
    (ties break toward earlier registration). With ``load_aware=True`` the
    estimate is additionally scaled by ``1 + inflight/capacity`` for
    registered executor objects, shifting load off busy executors — off by
    default so the two-executor case stays bit-identical to the paper's
    threshold policies.
    """

    def __init__(self, psgs_table: np.ndarray,
                 policy: str = "latency_preferred", *,
                 load_aware: bool = False):
        self.psgs_table = psgs_table
        self.policy = policy
        self.load_aware = load_aware
        self._curves: dict[str, LatencyCurve] = {}
        self._kinds: dict[str, str] = {}
        self._executors: dict[str, Executor] = {}
        self.routed: dict[str, int] = {}

    # -- registry ------------------------------------------------------------
    def register(self, name: str, curve: LatencyCurve, *,
                 kind: Optional[str] = None,
                 executor: Optional[Executor] = None) -> "CostModelRouter":
        """Register an executor's calibrated latency curve.

        Args:
            name: executor name (must match the engine registry).
            curve: calibrated avg+tail :class:`LatencyCurve` over PSGS.
            kind: ``"host"`` | ``"device"`` policy role; defaults to the
                executor's ``kind`` attribute (``"device"`` if absent).
            executor: optional live executor — enables ``supports``-based
                eligibility and load-aware estimates.

        Returns:
            The router, for chaining.
        """
        if kind is None:
            kind = getattr(executor, "kind", "device")
        self._curves[name] = curve
        self._kinds[name] = kind
        if executor is not None:
            self._executors[name] = executor
        self.routed.setdefault(name, 0)
        return self

    @property
    def names(self) -> list[str]:
        """Registered executor names, in registration order."""
        return list(self._curves)

    def curve(self, name: str) -> LatencyCurve:
        """Current latency curve for ``name``.

        Raises:
            KeyError: if ``name`` was never registered.
        """
        return self._curves[name]

    def update_curve(self, name: str, curve: LatencyCurve) -> None:
        """Swap in a freshly fitted curve (online recalibration). The swap is
        a single reference assignment, so concurrent ``route()`` calls see
        either the old or the new curve — never a torn mix.

        Args:
            name: a registered executor name.
            curve: the replacement :class:`LatencyCurve`.

        Raises:
            KeyError: if ``name`` was never registered (guards against
                typo'd refits silently creating unroutable entries).
        """
        if name not in self._curves:
            raise KeyError(f"unknown executor {name!r}")
        self._curves[name] = curve

    @staticmethod
    def from_curves(psgs_table: np.ndarray,
                    curves: Mapping[str, LatencyCurve],
                    policy: str = "latency_preferred", *,
                    kinds: Optional[Mapping[str, str]] = None,
                    executors: Optional[Mapping[str, Executor]] = None,
                    load_aware: bool = False) -> "CostModelRouter":
        """Build a router from a name → curve mapping (the usual output of
        :func:`calibrate_executors`). ``kinds`` overrides the policy role
        per name; otherwise the executor's ``kind`` decides, falling back to
        ``"host"`` for the name ``"host"`` and ``"device"`` elsewhere."""
        r = CostModelRouter(psgs_table, policy, load_aware=load_aware)
        for name, curve in curves.items():
            executor = (executors or {}).get(name)
            if kinds and name in kinds:
                kind = kinds[name]
            elif executor is not None:
                kind = getattr(executor, "kind", "device")
            else:
                kind = "host" if name == "host" else "device"
            r.register(name, curve, kind=kind, executor=executor)
        return r

    @staticmethod
    def from_calibration(psgs_table: np.ndarray, calib: CalibrationResult,
                         policy: str = "latency_preferred"
                         ) -> "CostModelRouter":
        """The 2-executor special case: host+device curves from a binary
        calibration — routing equals the PSGS-threshold rule."""
        return CostModelRouter.from_curves(
            psgs_table, {"host": calib.host, "device": calib.device}, policy)

    # -- routing -------------------------------------------------------------
    def batch_cost(self, seeds: np.ndarray) -> float:
        """Accumulated PSGS of a batch (``-1`` padding ignored) — the
        x-coordinate every latency curve is evaluated at."""
        return _accumulated_psgs(self.psgs_table, seeds)

    def estimate(self, name: str, q: float) -> float:
        """Policy-selected latency estimate for one executor.

        Args:
            name: registered executor name.
            q: accumulated PSGS of the batch (see :meth:`batch_cost`).

        Returns:
            Estimated seconds from the avg or tail curve (whichever the
            policy judges this executor's kind by), scaled by
            ``1 + inflight/capacity`` when ``load_aware``.

        Raises:
            KeyError: if ``name`` was never registered.
        """
        stat = _policy_stat(self.policy, self._kinds[name])
        est = float(self._curves[name].eval(q, stat))
        if self.load_aware and name in self._executors:
            ex = self._executors[name]
            est *= 1.0 + ex.inflight / max(ex.capacity, 1)
        return est

    def estimate_seconds(self, seeds: np.ndarray) -> float:
        """Best-case service-time estimate of a batch: the minimum
        policy-selected estimate over its eligible executors — the number
        the SLO gateway subtracts from a request's deadline to order the
        admission queue by slack.

        Args:
            seeds: ``(B,)`` seed ids of the batch (``-1`` padding ignored).

        Returns:
            Estimated seconds on the cheapest eligible executor (including
            load-aware inflation when enabled), or ``0.0`` when no curve
            has been fit yet — an optimistic gateway never sheds on a
            missing estimate.
        """
        if not self._curves:
            return 0.0
        q = self.batch_cost(seeds)
        return min(self.estimate(name, q) for name in self._eligible(seeds))

    def crossover(self, a: str, b: str, *, lo: Optional[float] = None,
                  hi: Optional[float] = None, grid_points: int = 512
                  ) -> float:
        """PSGS cut-point between two registered executors under the current
        policy: below it ``a``'s policy-selected estimate is cheaper, above
        it ``b``'s is (the N-way analogue of the paper's binary threshold).
        Per-model routers fit different curves, so this is where multi-model
        routing divergence is visible as a number.

        Args:
            a: executor judged cheaper below the cut-point.
            b: executor judged cheaper above it.
            lo: grid lower bound (defaults to the curves' joint minimum).
            hi: grid upper bound (defaults to the curves' joint maximum).
            grid_points: resolution of the crossing search.

        Returns:
            The crossing PSGS, ``0.0`` when ``b`` is cheaper everywhere and
            ``inf`` when ``a`` is (mirroring
            ``CalibrationResult.threshold``). Load-aware scaling is ignored
            — the cut-point describes the calibrated curves, not the
            instantaneous queue state.

        Raises:
            KeyError: if either name was never registered.
        """
        ca, cb = self._curves[a], self._curves[b]
        stat_a = _policy_stat(self.policy, self._kinds[a])
        stat_b = _policy_stat(self.policy, self._kinds[b])
        lo = float(min(ca.psgs.min(), cb.psgs.min()) if lo is None else lo)
        hi = float(max(ca.psgs.max(), cb.psgs.max()) if hi is None else hi)
        grid = np.linspace(lo, hi, int(grid_points))
        diff = ca.eval(grid, stat_a) - cb.eval(grid, stat_b)
        sign = np.signbit(diff)
        flips = np.flatnonzero(sign[1:] != sign[:-1])
        if flips.size == 0:
            return float("inf") if diff[-1] < 0 else 0.0
        i = flips[0]
        x0, x1, d0, d1 = grid[i], grid[i + 1], diff[i], diff[i + 1]
        denom = d1 - d0
        if abs(denom) < 1e-15:
            return float(x0)
        return float(np.clip(x0 + (x1 - x0) * (0 - d0) / denom, lo, hi))

    def _eligible(self, seeds: np.ndarray) -> list[str]:
        names = [n for n in self._curves
                 if n not in self._executors
                 or getattr(self._executors[n], "supports",
                            lambda _s: True)(seeds)]
        # degrade rather than refuse: if nothing claims support, consider all
        return names or list(self._curves)

    def route(self, seeds: np.ndarray) -> str:
        """Pick the executor with the minimal policy-selected estimate.

        Args:
            seeds: ``(B,)`` seed ids of the batch (``-1`` padding ignored).

        Returns:
            The chosen executor's name; the choice is tallied in
            :attr:`routed`. Ineligible executors (``supports`` returned
            ``False``) are skipped unless that would leave none.

        Raises:
            RuntimeError: if no executor was ever registered.
        """
        if not self._curves:
            raise RuntimeError("no executors registered")
        q = self.batch_cost(seeds)
        best, best_e = None, float("inf")
        for name in self._eligible(seeds):
            e = self.estimate(name, q)
            if e < best_e:
                best, best_e = name, e
        self.routed[best] += 1
        return best


class HybridScheduler:
    """Binary PSGS-threshold routing — the paper's scheduler, kept as the
    2-executor special case of :class:`CostModelRouter`."""

    def __init__(self, psgs_table: np.ndarray, threshold: float,
                 policy: str = "latency_preferred"):
        self.psgs_table = psgs_table
        self.threshold = float(threshold)
        self.policy = policy
        self.routed = {"host": 0, "device": 0}

    @staticmethod
    def from_calibration(psgs_table: np.ndarray, calib: CalibrationResult,
                         policy: str = "latency_preferred") -> "HybridScheduler":
        return HybridScheduler(psgs_table, calib.threshold(policy), policy)

    def batch_cost(self, seeds: np.ndarray) -> float:
        return _accumulated_psgs(self.psgs_table, seeds)

    def route(self, seeds: np.ndarray) -> str:
        dest = "host" if self.batch_cost(seeds) < self.threshold else "device"
        self.routed[dest] += 1
        return dest


class StaticScheduler:
    """Baselines: always route to one named executor ("CPU sampling" /
    "GPU"; any registered executor name works)."""

    def __init__(self, dest: str):
        self.dest = dest
        self.routed: dict[str, int] = {dest: 0}

    def route(self, seeds: np.ndarray) -> str:
        self.routed[self.dest] += 1
        return self.dest

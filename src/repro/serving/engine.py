"""Executor-graph serving engine (paper §4.3, re-architected).

The engine owns a registry of named :class:`~repro.serving.executors.Executor`
objects and a router (anything with ``route(seeds) -> name``). Each closed
batch becomes a *future* on the chosen executor's worker lanes; the paper's
design points survive as:

(1) *Multiplexing pipelines in a processor* — every executor runs
    ``capacity`` concurrent lanes; XLA overlaps sampling, feature collection
    and model compute across lanes.
(2) *Shared queue* — admission is a bounded window over all executors: a
    straggler occupies one lane while small batches keep flowing.
(3) *Shared graph* — topology and feature stores are read-only singletons
    captured by the executors.

New over the seed implementation: N-way routing (not a hardcoded
host/device pair), per-batch futures, and admission control — when
``max_inflight`` batches are outstanding the engine either blocks the
producer (``admission="wait"``, backpressure) or drops the batch
(``admission="shed"``, counted in ``ServeMetrics.shed``).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Iterable, Mapping, Optional, Sequence

import numpy as np

from repro.serving.executors import Executor


def _batch_seeds(batch: Sequence) -> np.ndarray:
    return np.concatenate([r.seeds for r in batch])


class MicroBatcher:
    """PSGS-aware micro-batching stage between the request batcher and the
    executor graph.

    The fused feature-collection path (``TieredFeatureStore.lookup_hops``)
    amortizes its one-dispatch-per-tier cost over the *unique* ids of a
    sample, so it pays off most when batches are large enough for hop
    frontiers to overlap. Under light load the ``DynamicBatcher`` closes
    small batches (its deadline is per-request); this stage coalesces those
    closed batches into gather-friendly super-batches under a second
    latency deadline.

    A super-batch closes when (a) its accumulated seed count reaches
    ``max_seeds``, (b) its accumulated PSGS reaches ``psgs_budget`` (the
    workload-aware bound — processing cost, not request count), or (c) the
    coalescing deadline since the first queued request has expired.
    Like ``DynamicBatcher``, the deadline is evaluated at ``add`` time —
    an expired super-batch is emitted when the NEXT batch arrives (or at
    the stream-end ``flush``), so on sparse streams the realized wait can
    reach the inter-arrival gap, not ``deadline_s``. Size ``deadline_s``
    against the expected arrival rate, or skip the stage for latency-
    critical sparse traffic.
    """

    def __init__(self, *, deadline_s: float = 0.004, max_seeds: int = 256,
                 psgs_budget: Optional[float] = None,
                 psgs_table: Optional[np.ndarray] = None):
        """Args:
            deadline_s: max time a closed batch may wait for company.
            max_seeds: seed-count bound of a super-batch.
            psgs_budget: accumulated-PSGS bound (needs ``psgs_table``);
                ``None`` disables the workload-aware close condition.
            psgs_table: ``(N,)`` per-seed PSGS table for the budget.
        """
        self.deadline_s = float(deadline_s)
        self.max_seeds = int(max_seeds)
        self.psgs_budget = psgs_budget
        self.psgs_table = psgs_table
        self._pending: list = []
        self._opened: Optional[float] = None
        self._sources = 0
        self._n_seeds = 0
        self._acc_psgs = 0.0
        self.emitted = 0      # super-batches emitted
        self.coalesced = 0    # emitted super-batches built from >1 batch

    def add(self, batch: list) -> Optional[list]:
        """Queue one closed batch; return a super-batch if a bound was hit.

        Args:
            batch: a closed request batch (non-empty list of requests).

        Returns:
            The coalesced super-batch when seed-count / PSGS / deadline
            closed it, else ``None`` (the batch is held for coalescing).
        """
        now = time.perf_counter()
        if self._opened is None:
            self._opened = now
        self._pending.extend(batch)
        self._sources += 1
        self._n_seeds += sum(int(r.seeds.size) for r in batch)
        if self.psgs_table is not None:
            for r in batch:
                self._acc_psgs += float(
                    self.psgs_table[r.seeds[r.seeds >= 0]].sum())
        full = self._n_seeds >= self.max_seeds
        over_budget = (self.psgs_budget is not None
                       and self._acc_psgs >= self.psgs_budget)
        expired = now - self._opened >= self.deadline_s
        if full or over_budget or expired:
            return self.flush()
        return None

    def flush(self) -> Optional[list]:
        """Emit whatever is queued (``None`` when empty)."""
        if not self._pending:
            return None
        out, self._pending = self._pending, []
        self.emitted += 1
        if self._sources > 1:
            self.coalesced += 1
        self._opened, self._sources = None, 0
        self._n_seeds, self._acc_psgs = 0, 0.0
        return out


@dataclasses.dataclass
class ServeMetrics:
    latencies: list[float] = dataclasses.field(default_factory=list)
    started: float = 0.0
    finished: float = 0.0
    requests: int = 0
    shed: int = 0
    routed: dict[str, int] = dataclasses.field(default_factory=dict)

    # backwards-compatible views of the two-executor counters
    @property
    def routed_host(self) -> int:
        return self.routed.get("host", 0)

    @property
    def routed_device(self) -> int:
        return self.routed.get("device", 0)

    @property
    def throughput(self) -> float:
        dur = max(self.finished - self.started, 1e-9)
        return self.requests / dur

    def percentile(self, q: float) -> float:
        # all-shed runs have no completed latencies; report 0.0 like
        # summary() does instead of crashing on an empty quantile
        if not self.latencies:
            return 0.0
        return float(np.quantile(np.asarray(self.latencies), q))

    def summary(self) -> dict:
        # no completed requests (e.g. everything shed): report a zeroed
        # profile, NOT a perfect one — pct_in_400ms must not claim SLO wins
        served = bool(self.latencies)
        lat = np.asarray(self.latencies if served else [0.0])
        return {"requests": self.requests,
                "throughput_rps": self.throughput,
                "p50_ms": float(np.quantile(lat, 0.5) * 1e3),
                "p99_ms": float(np.quantile(lat, 0.99) * 1e3),
                "max_ms": float(lat.max() * 1e3),
                "pct_in_400ms": float((lat < 0.4).mean()) if served else 0.0,
                "shed": self.shed,
                "routed": dict(self.routed),
                "routed_host": self.routed_host,
                "routed_device": self.routed_device}


class ServingEngine:
    """End-to-end GNN serving over a pluggable executor registry.

    ``executors`` is a mapping name → executor (or an iterable of executors,
    keyed by their ``name``). ``router.route(seeds)`` must return one of the
    registered names. Register additional executors with :meth:`register`.
    """

    def __init__(self, executors: Mapping[str, Executor] | Iterable[Executor],
                 router, *, max_inflight: int = 64,
                 admission: str = "wait", hooks: Sequence = ()):
        if isinstance(executors, Mapping):
            self.executors: dict[str, Executor] = dict(executors)
        else:
            self.executors = {e.name: e for e in executors}
        if not self.executors:
            raise ValueError("at least one executor is required")
        if admission not in ("wait", "shed"):
            raise ValueError(f"admission must be 'wait' or 'shed', "
                             f"got {admission!r}")
        self.router = router
        self.admission = admission
        # telemetry hooks (e.g. serving.adaptive.AdaptiveController): called
        # with every admitted batch and every completion — the feed for
        # online FAP re-placement and latency-curve refitting
        self.hooks = list(hooks)
        self.max_inflight = int(max_inflight)
        self._window = threading.BoundedSemaphore(self.max_inflight)
        self._lock = threading.Lock()
        # drain() synchronizes on this counter, not on the futures:
        # done-callbacks run *after* future waiters wake, so waiting on the
        # futures could observe metrics/errors before _complete recorded them
        self._acct = threading.Condition()
        self._inflight_batches = 0
        self._error: Optional[BaseException] = None
        self._metrics = ServeMetrics()

    # -- registry ------------------------------------------------------------
    def register(self, executor: Executor) -> "ServingEngine":
        """Add (or replace) an executor under its ``name``; returns the
        engine for chaining. The router must know the name before a batch
        can be routed there."""
        self.executors[executor.name] = executor
        return self

    def add_hook(self, hook) -> "ServingEngine":
        """Attach a telemetry hook. Optional methods, all best-effort:
        ``on_admit(name, seeds)`` after a batch is admitted and routed,
        ``on_batch_complete(name, seeds, latency_s)`` after it finishes."""
        self.hooks.append(hook)
        return self

    def _notify(self, method: str, *args) -> None:
        for h in self.hooks:
            fn = getattr(h, method, None)
            if fn is None:
                continue
            try:
                fn(*args)
            except BaseException as exc:  # surface hook bugs via drain()
                with self._lock:
                    if self._error is None:
                        self._error = exc

    # -- per-batch futures ---------------------------------------------------
    def submit_batch(self, batch: list) -> Optional[Future]:
        """Route one closed batch and submit it to its executor.

        Returns the future of the model output, or ``None`` when the
        admission window is full and the policy is ``"shed"`` (the batch is
        dropped and counted in ``ServeMetrics.shed``).
        """
        if not self._window.acquire(blocking=self.admission == "wait"):
            with self._lock:
                self._metrics.shed += len(batch)
            return None
        metrics = self._metrics  # bind this run: stragglers from a failed
        with self._acct:         # run must not pollute the next run's stats
            self._inflight_batches += 1
        name = None
        try:
            # route only admitted batches, so router.routed matches executed
            # work and load-aware estimates see post-admission inflight
            seeds = _batch_seeds(batch)
            name = self.router.route(seeds)
            submitted_at = time.perf_counter()
            fut = self.executors[name].submit(seeds)
        except BaseException:
            if name is not None:
                # the router already counted this batch but the executor
                # never accepted it — roll the count back so router.routed
                # keeps matching work that actually executed
                routed = getattr(self.router, "routed", None)
                if isinstance(routed, dict) and routed.get(name, 0) > 0:
                    routed[name] -= 1
            self._window.release()
            self._finish_one()
            raise
        self._notify("on_admit", name, seeds)
        fut.add_done_callback(
            lambda f: self._complete(f, batch, name, metrics, seeds,
                                     submitted_at))
        return fut

    def _complete(self, fut: Future, batch: list, name: str,
                  metrics: ServeMetrics, seeds: np.ndarray,
                  submitted_at: float) -> None:
        self._window.release()
        now = time.perf_counter()
        with self._lock:
            if fut.exception() is not None:
                if self._error is None:
                    self._error = fut.exception()
            else:
                for r in batch:
                    r.done = now
                    metrics.latencies.append(r.latency)
                metrics.requests += len(batch)
                metrics.routed[name] = metrics.routed.get(name, 0) + 1
        if fut.exception() is None:
            # per-batch service time (lane queueing + processing): the live
            # counterpart of the offline calibration samples
            self._notify("on_batch_complete", name, seeds, now - submitted_at)
        self._finish_one()

    def _finish_one(self) -> None:
        with self._acct:
            self._inflight_batches -= 1
            self._acct.notify_all()

    def drain(self) -> None:
        """Wait until every outstanding batch — including its metrics
        accounting — has finished; then re-raise the first executor failure
        (the old thread-pool loop swallowed them)."""
        with self._acct:
            self._acct.wait_for(lambda: self._inflight_batches == 0)
        with self._lock:
            err, self._error = self._error, None
        if err is not None:
            raise err

    # -- serving loops (drop-in for the old pipeline API) --------------------
    def _reset(self) -> ServeMetrics:
        self._metrics = ServeMetrics()
        self._metrics.started = time.perf_counter()
        return self._metrics

    def serve_stream(self, requests: Sequence, batcher, *, gap_s: float = 0.0,
                     micro: Optional[MicroBatcher] = None) -> ServeMetrics:
        """Client-stream serving: requests arrive one by one (``gap_s``
        apart), the DynamicBatcher closes batches by deadline / PSGS budget /
        max size, and closed batches are admitted to the executor graph
        (paper §4.2.2).

        Args:
            requests: request stream (anything yielding ``Request``-like
                objects with ``seeds``/``arrival``).
            batcher: batch closer (``DynamicBatcher`` protocol:
                ``add(request)`` / ``flush()``).
            gap_s: inter-arrival gap, client emulation.
            micro: optional :class:`MicroBatcher` coalescing stage — closed
                batches are held (deadline evaluated on the next arrival;
                see the class docstring for sparse-stream caveats) and
                merged into gather-friendly super-batches before admission,
                so the fused feature path sees large unique-id sets.

        Returns:
            The run's :class:`ServeMetrics` (latencies include any
            micro-batching wait, since arrival is stamped at ingest).
        """
        metrics = self._reset()
        try:
            for r in requests:
                if gap_s:
                    time.sleep(gap_s)
                r.arrival = time.perf_counter()
                out = batcher.add(r)
                if out and micro is not None:
                    out = micro.add(out)
                if out:
                    self.submit_batch(out)
            for closer in ((batcher, micro) if micro is not None
                           else (batcher,)):
                tail = closer.flush()
                if tail and closer is batcher and micro is not None:
                    tail = micro.add(tail)
                if tail:
                    self.submit_batch(tail)
            self.drain()
        finally:
            # stamp even when drain() re-raises an executor failure, so a
            # partially-failed run reports throughput over real wall time
            # instead of dividing by finished=0
            metrics.finished = time.perf_counter()
        return metrics

    def run(self, batches: Sequence[list], *,
            pace_s: Optional[float] = None) -> ServeMetrics:
        """Process pre-formed batches. ``pace_s`` spaces arrivals
        (client-stream emulation) and re-stamps request arrival at submit
        time so latency = queueing + processing."""
        metrics = self._reset()
        try:
            for b in batches:
                if pace_s:
                    time.sleep(pace_s)
                now = time.perf_counter()
                for r in b:
                    r.arrival = now
                self.submit_batch(b)
            self.drain()
        finally:
            metrics.finished = time.perf_counter()
        return metrics

    def warmup(self, batch, *, rounds: int = 2) -> None:
        """Compile/warm every registered executor outside the measured
        window. Accepts a request batch or a raw seed array."""
        seeds = (np.asarray(batch) if isinstance(batch, np.ndarray)
                 else _batch_seeds(batch))
        for ex in self.executors.values():
            for _ in range(rounds):
                ex.run(seeds)

    def close(self) -> None:
        """Shut down every executor's worker pool (blocking)."""
        for ex in self.executors.values():
            close = getattr(ex, "close", None)
            if close:
                close()

"""Executor-graph serving engine (paper §4.3, re-architected; multi-model).

The engine serves a :class:`~repro.serving.registry.ModelRegistry` — one or
more models, each with its own executor set and router, all sharing the
graph, the feature stores, and one admission window. Each closed batch
becomes a *future* on the chosen executor's worker lanes; the paper's
design points survive as:

(1) *Multiplexing pipelines in a processor* — every executor runs
    ``capacity`` concurrent lanes; XLA overlaps sampling, feature collection
    and model compute across lanes.
(2) *Shared queue* — admission is a bounded window over all executors of
    all models: a straggler occupies one lane while small batches keep
    flowing, and no model can starve the others beyond the shared bound.
(3) *Shared graph* — topology and feature stores are read-only singletons
    captured by the executors of every model.

New over the seed implementation: N-way routing (not a hardcoded
host/device pair), per-batch futures, admission control — when
``max_inflight`` batches are outstanding the engine either blocks the
producer (``admission="wait"``, backpressure) or drops the batch
(``admission="shed"``, counted in ``ServeMetrics.shed``) — and multi-model
serving: requests carry a ``model`` tag, routing/metrics are per model
(``ServingEngine(executors, router)`` remains the 1-entry-registry special
case).
"""
from __future__ import annotations

import dataclasses
import functools
import inspect
import threading
import time
from concurrent.futures import Future
from typing import Any, Callable, Iterable, Mapping, Optional, Sequence

import numpy as np

from repro.serving.executors import Executor
from repro.serving.registry import DEFAULT_MODEL, ModelEntry, ModelRegistry


def _batch_seeds(batch: Sequence) -> np.ndarray:
    return np.concatenate([r.seeds for r in batch])


def _batch_model(batch: Sequence) -> str:
    """Model tag of a closed batch; every request must agree (micro-batches
    and batches never mix models — mixing would make the per-model routing
    decision meaningless)."""
    model = getattr(batch[0], "model", DEFAULT_MODEL)
    for r in batch[1:]:
        other = getattr(r, "model", DEFAULT_MODEL)
        if other != model:
            raise ValueError(f"batch mixes models {model!r} and {other!r}; "
                             f"batchers must never coalesce across models")
    return model


def _clone_stage(stage):
    """Fresh same-config instance of a batching stage (``clone()``); multi-
    model streams need one stage per model so batches never mix models."""
    clone = getattr(stage, "clone", None)
    if clone is None:
        raise TypeError(
            f"{type(stage).__name__} has no clone(); multi-model streams "
            f"need one batching stage per model")
    return clone()


class MicroBatcher:
    """PSGS-aware micro-batching stage between the request batcher and the
    executor graph.

    The fused feature-collection path (``TieredFeatureStore.lookup_hops``)
    amortizes its one-dispatch-per-tier cost over the *unique* ids of a
    sample, so it pays off most when batches are large enough for hop
    frontiers to overlap. Under light load the ``DynamicBatcher`` closes
    small batches (its deadline is per-request); this stage coalesces those
    closed batches into gather-friendly super-batches under a second
    latency deadline.

    A super-batch closes when (a) its accumulated seed count reaches
    ``max_seeds``, (b) its accumulated PSGS reaches ``psgs_budget`` (the
    workload-aware bound — processing cost, not request count), or (c) the
    coalescing deadline since the first queued request has expired.
    Like ``DynamicBatcher``, the deadline is evaluated at ``add`` time —
    an expired super-batch is emitted when the NEXT batch arrives (or at
    the stream-end ``flush``), so on sparse streams the realized wait can
    reach the inter-arrival gap, not ``deadline_s``. Size ``deadline_s``
    against the expected arrival rate, or skip the stage for latency-
    critical sparse traffic.

    Super-batches never mix models: ``serve_stream`` keeps one clone per
    model, and ``add`` additionally emits the pending super-batch whenever
    an incoming batch carries a different model tag (defense in depth for
    callers driving one instance by hand). ``deadline_s``/``max_seeds`` may
    be re-assigned live (single reference writes) — the adaptive
    controller's micro-batch auto-tuning does exactly that.
    """

    def __init__(self, *, deadline_s: float = 0.004, max_seeds: int = 256,
                 psgs_budget: Optional[float] = None,
                 psgs_table: Optional[np.ndarray] = None,
                 clock: Callable[[], float] = time.monotonic):
        """Args:
            deadline_s: max time a closed batch may wait for company.
            max_seeds: seed-count bound of a super-batch.
            psgs_budget: accumulated-PSGS bound (needs ``psgs_table``);
                ``None`` disables the workload-aware close condition.
            psgs_table: ``(N,)`` per-seed PSGS table for the budget.
            clock: zero-arg seconds source for the coalescing deadline
                (injectable — tests pass ``repro.testing.FakeClock``).
        """
        self.deadline_s = float(deadline_s)
        self.max_seeds = int(max_seeds)
        self.psgs_budget = psgs_budget
        self.psgs_table = psgs_table
        self.clock = clock
        self._pending: list = []
        self._opened: Optional[float] = None
        self._model: Optional[str] = None
        self._sources = 0
        self._n_seeds = 0
        self._acc_psgs = 0.0
        self.emitted = 0      # super-batches emitted
        self.coalesced = 0    # emitted super-batches built from >1 batch

    def clone(self) -> "MicroBatcher":
        """Fresh empty stage with the same bounds — ``serve_stream`` clones
        one per model so super-batches never coalesce across models.
        Built via ``type(self)`` so subclasses stay subclasses (override
        when a subclass adds constructor arguments)."""
        return type(self)(deadline_s=self.deadline_s,
                          max_seeds=self.max_seeds,
                          psgs_budget=self.psgs_budget,
                          psgs_table=self.psgs_table,
                          clock=self.clock)

    def add(self, batch: list) -> Optional[list]:
        """Queue one closed batch; return a super-batch if a bound was hit.

        Args:
            batch: a closed request batch (non-empty list of requests,
                all carrying the same ``model`` tag).

        Returns:
            The coalesced super-batch when seed-count / PSGS / deadline
            closed it — or the *previous* pending super-batch when
            ``batch`` carries a different model tag (the incoming batch is
            then queued fresh; super-batches never mix models). ``None``
            when the batch is held for coalescing.
        """
        model = _batch_model(batch)
        flushed = None
        if self._pending and model != self._model:
            flushed = self.flush()
        now = self.clock()
        if self._opened is None:
            self._opened = now
        self._model = model
        self._pending.extend(batch)
        self._sources += 1
        self._n_seeds += sum(int(r.seeds.size) for r in batch)
        if self.psgs_table is not None:
            for r in batch:
                self._acc_psgs += float(
                    self.psgs_table[r.seeds[r.seeds >= 0]].sum())
        if flushed is not None:
            # the model boundary already emitted a super-batch this call;
            # the fresh batch's own bounds are evaluated on the next add
            # (or the stream-end flush)
            return flushed
        full = self._n_seeds >= self.max_seeds
        over_budget = (self.psgs_budget is not None
                       and self._acc_psgs >= self.psgs_budget)
        expired = now - self._opened >= self.deadline_s
        if full or over_budget or expired:
            return self.flush()
        return None

    def flush(self) -> Optional[list]:
        """Emit whatever is queued (``None`` when empty)."""
        if not self._pending:
            return None
        out, self._pending = self._pending, []
        self.emitted += 1
        if self._sources > 1:
            self.coalesced += 1
        self._opened, self._sources, self._model = None, 0, None
        self._n_seeds, self._acc_psgs = 0, 0.0
        return out


@dataclasses.dataclass
class ModelStats:
    """Per-model slice of :class:`ServeMetrics`: requests, shed, latencies,
    routing tallies, and per-executor service times (lane queueing +
    processing, keyed by executor name)."""

    requests: int = 0
    shed: int = 0
    shed_deadline: int = 0
    latencies: list[float] = dataclasses.field(default_factory=list)
    routed: dict[str, int] = dataclasses.field(default_factory=dict)
    exec_latencies: dict[str, list[float]] = dataclasses.field(
        default_factory=dict)

    def percentile(self, q: float) -> float:
        """Latency quantile over this model's completed requests (0.0 when
        none completed)."""
        if not self.latencies:
            return 0.0
        return float(np.quantile(np.asarray(self.latencies), q))

    def summary(self) -> dict:
        """Per-model report block (requests/shed, p50/p99, routing)."""
        return {"requests": self.requests, "shed": self.shed,
                "shed_deadline": self.shed_deadline,
                "p50_ms": self.percentile(0.5) * 1e3,
                "p99_ms": self.percentile(0.99) * 1e3,
                "routed": dict(self.routed)}


# Pinned key set of every per-priority-class block — `ClassStats.summary()`
# and the `classes` entries of gateway telemetry samples both carry exactly
# these keys (cross-checked by quiverlint's schema pass against the marked
# table in docs/invariants.md and by tests/test_gateway.py).
CLASS_SAMPLE_SCHEMA = ("requests", "shed_window", "shed_deadline",
                       "p50_ms", "p95_ms", "p99_ms")


@dataclasses.dataclass
class ClassStats:
    """Per-priority-class slice of :class:`ServeMetrics` (SLO view): how
    many requests of this class completed, how many were shed at the
    admission window vs. for a hopeless deadline, and the class's latency
    distribution. Keys of :meth:`summary` are pinned by
    ``CLASS_SAMPLE_SCHEMA``."""

    requests: int = 0
    shed_window: int = 0
    shed_deadline: int = 0
    latencies: list[float] = dataclasses.field(default_factory=list)

    def percentile(self, q: float) -> float:
        """Latency quantile over this class's completed requests (0.0 when
        none completed)."""
        if not self.latencies:
            return 0.0
        return float(np.quantile(np.asarray(self.latencies), q))

    def summary(self) -> dict:
        """Per-class report block — keys exactly ``CLASS_SAMPLE_SCHEMA``."""
        return {"requests": self.requests,
                "shed_window": self.shed_window,
                "shed_deadline": self.shed_deadline,
                "p50_ms": self.percentile(0.5) * 1e3,
                "p95_ms": self.percentile(0.95) * 1e3,
                "p99_ms": self.percentile(0.99) * 1e3}


def _exec_key(model: str, name: str) -> str:
    """Executor key in the flat per-executor breakdown: bare name for the
    single-model default, ``model/name`` otherwise."""
    return name if model == DEFAULT_MODEL else f"{model}/{name}"


@dataclasses.dataclass
class ServeMetrics:
    latencies: list[float] = dataclasses.field(default_factory=list)
    started: float = 0.0
    finished: float = 0.0
    requests: int = 0
    shed: int = 0
    shed_deadline: int = 0
    routed: dict[str, int] = dataclasses.field(default_factory=dict)
    # per-model breakdowns (aggregate fields above are preserved: they sum
    # over models, and executor names repeated across models merge in
    # ``routed``); ``store_stats`` carries the shared stores' fused-gather
    # dispatch counters snapshotted at the end of the run; ``classes`` the
    # per-priority-class SLO breakdown (gateway traffic — plain runs land
    # everything in the default "batch" class)
    models: dict[str, ModelStats] = dataclasses.field(default_factory=dict)
    classes: dict[str, ClassStats] = dataclasses.field(default_factory=dict)
    store_stats: dict[str, dict] = dataclasses.field(default_factory=dict)

    def model(self, name: str) -> ModelStats:
        """This model's stats slice (created on first touch)."""
        return self.models.setdefault(name, ModelStats())

    def for_class(self, name: str) -> ClassStats:
        """This priority class's stats slice (created on first touch)."""
        return self.classes.setdefault(name, ClassStats())

    # backwards-compatible views of the two-executor counters
    @property
    def routed_host(self) -> int:
        return self.routed.get("host", 0)

    @property
    def routed_device(self) -> int:
        return self.routed.get("device", 0)

    @property
    def throughput(self) -> float:
        dur = max(self.finished - self.started, 1e-9)
        return self.requests / dur

    def percentile(self, q: float) -> float:
        # all-shed runs have no completed latencies; report 0.0 like
        # summary() does instead of crashing on an empty quantile
        if not self.latencies:
            return 0.0
        return float(np.quantile(np.asarray(self.latencies), q))

    def executor_percentiles(self) -> dict[str, dict]:
        """Per-executor service-time percentiles (lane queueing +
        processing, seconds → ms), keyed ``name`` for the default model and
        ``model/name`` otherwise."""
        out: dict[str, dict] = {}
        for model, ms in self.models.items():
            for name, lats in ms.exec_latencies.items():
                if not lats:
                    continue
                arr = np.asarray(lats)
                out[_exec_key(model, name)] = {
                    "batches": int(arr.size),
                    "p50_ms": float(np.quantile(arr, 0.5) * 1e3),
                    "p99_ms": float(np.quantile(arr, 0.99) * 1e3)}
        return out

    def summary(self) -> dict:
        # no completed requests (e.g. everything shed): report a zeroed
        # profile, NOT a perfect one — pct_in_400ms must not claim SLO wins
        served = bool(self.latencies)
        lat = np.asarray(self.latencies if served else [0.0])
        return {"requests": self.requests,
                "throughput_rps": self.throughput,
                "p50_ms": float(np.quantile(lat, 0.5) * 1e3),
                "p99_ms": float(np.quantile(lat, 0.99) * 1e3),
                "max_ms": float(lat.max() * 1e3),
                "pct_in_400ms": float((lat < 0.4).mean()) if served else 0.0,
                "shed": self.shed,
                "shed_deadline": self.shed_deadline,
                "routed": dict(self.routed),
                "routed_host": self.routed_host,
                "routed_device": self.routed_device,
                "models": {m: s.summary() for m, s in self.models.items()},
                "classes": {c: s.summary() for c, s in self.classes.items()},
                "executors": self.executor_percentiles(),
                "store": {k: dict(v) for k, v in self.store_stats.items()}}


class ServingEngine:
    """End-to-end GNN serving over a registry of models sharing the stores.

    Construction accepts either the single-model parts —
    ``ServingEngine(executors, router)`` where ``executors`` maps name →
    executor (or is an iterable of executors keyed by their ``name``) and
    ``router.route(seeds)`` returns a registered name — or a
    :class:`~repro.serving.registry.ModelRegistry`
    (``ServingEngine(registry)``). The single-model form is exactly the
    1-entry-registry special case: requests default to
    ``model="default"``. Admission (``max_inflight``) is global across
    models — one capacity bound over the shared hardware — while routing
    and metrics are per model.
    """

    def __init__(self,
                 executors: (Mapping[str, Executor] | Iterable[Executor]
                             | ModelRegistry | None) = None,
                 router=None, *, registry: Optional[ModelRegistry] = None,
                 max_inflight: int = 64, admission: str = "wait",
                 hooks: Sequence = (),
                 clock: Callable[[], float] = time.monotonic):
        if isinstance(executors, ModelRegistry):
            if router is not None or registry is not None:
                raise ValueError("pass either a ModelRegistry or "
                                 "(executors, router), not both")
            registry = executors
        elif registry is None:
            if executors is None or router is None:
                raise ValueError("ServingEngine needs (executors, router) "
                                 "or a ModelRegistry")
            registry = ModelRegistry.single(executors, router)
        elif executors is not None or router is not None:
            raise ValueError("pass either registry= or (executors, router), "
                             "not both")
        if not len(registry):
            raise ValueError("at least one model is required")
        if admission not in ("wait", "shed"):
            raise ValueError(f"admission must be 'wait' or 'shed', "
                             f"got {admission!r}")
        self.registry = registry
        self.admission = admission
        # telemetry hooks (e.g. serving.adaptive.AdaptiveController): called
        # with every admitted batch and every completion — the feed for
        # online FAP re-placement and latency-curve refitting. Hooks may
        # accept (name, seeds[, model]) — the model tag is passed when the
        # hook's signature takes it.
        self.hooks = list(hooks)
        # injectable seconds source: every timestamp the engine takes
        # (arrival re-stamps, submit/complete times, run bounds) comes from
        # here, so deadline tests drive a FakeClock instead of sleeping
        self.clock = clock
        self.max_inflight = int(max_inflight)
        self._window = threading.BoundedSemaphore(self.max_inflight)
        self._lock = threading.Lock()
        # drain() synchronizes on this counter, not on the futures:
        # done-callbacks run *after* future waiters wake, so waiting on the
        # futures could observe metrics/errors before _complete recorded them
        self._acct = threading.Condition()
        self._inflight_batches = 0
        self._error: Optional[BaseException] = None
        self._metrics = ServeMetrics()

    # -- registry ------------------------------------------------------------
    @property
    def executors(self) -> dict[str, Executor]:
        """The default model's executor registry (single-model view). Multi-
        model callers address executors through ``registry`` instead."""
        return self.registry.get(DEFAULT_MODEL).executors

    @property
    def router(self):
        """The default model's router (single-model view)."""
        return self.registry.get(DEFAULT_MODEL).router

    def register(self, executor: Executor,
                 model: str = DEFAULT_MODEL) -> "ServingEngine":
        """Add (or replace) an executor under its ``name`` in ``model``'s
        entry; returns the engine for chaining. The model's router must know
        the name before a batch can be routed there."""
        self.registry.get(model).executors[executor.name] = executor
        return self

    def add_hook(self, hook) -> "ServingEngine":
        """Attach a telemetry hook. Optional methods, all best-effort:
        ``on_admit(name, seeds[, model])`` after a batch is admitted and
        routed, ``on_batch_complete(name, seeds, latency_s[, model])``
        after it finishes — the trailing model tag is passed only when the
        hook's signature accepts it."""
        self.hooks.append(hook)
        return self

    def _notify(self, method: str, *args) -> None:
        for h in self.hooks:
            fn = getattr(h, method, None)
            if fn is None:
                continue
            try:
                _call_adaptive(fn, args)
            except BaseException as exc:  # surface hook bugs via drain()
                with self._lock:
                    if self._error is None:
                        self._error = exc

    # -- per-batch futures ---------------------------------------------------
    def submit_batch(self, batch: list) -> Optional[Future]:
        """Route one closed batch and submit it to its model's executor.

        The batch's ``model`` tag (uniform across its requests — mixing
        raises) selects the registry entry whose router and executors serve
        it; requests without a tag take the default model.

        Returns the future of the model output, or ``None`` when the
        admission window is full and the policy is ``"shed"`` (the batch is
        dropped and counted in ``ServeMetrics.shed``, aggregate and
        per-model).
        """
        if not batch:
            raise ValueError("submit_batch needs a non-empty batch")
        model = _batch_model(batch)
        entry = self.registry.get(model)
        if not self._window.acquire(blocking=self.admission == "wait"):
            self.record_shed(batch, model)
            return None
        with self._lock:         # bind this run: stragglers from a failed
            metrics = self._metrics  # run must not pollute the next run
        with self._acct:
            self._inflight_batches += 1
        name = None
        try:
            # route only admitted batches, so router.routed matches executed
            # work and load-aware estimates see post-admission inflight
            seeds = _batch_seeds(batch)
            name = entry.router.route(seeds)
            submitted_at = self.clock()
            fut = entry.executors[name].submit(seeds)
        except BaseException:
            if name is not None:
                # the router already counted this batch but the executor
                # never accepted it — roll the count back so router.routed
                # keeps matching work that actually executed
                routed = getattr(entry.router, "routed", None)
                if isinstance(routed, dict) and routed.get(name, 0) > 0:
                    routed[name] -= 1
            self._window.release()
            self._finish_one()
            raise
        self._notify("on_admit", name, seeds, model)
        fut.add_done_callback(
            lambda f: self._complete(f, batch, name, model, metrics, seeds,
                                     submitted_at))
        return fut

    def record_shed(self, batch: Sequence, model: Optional[str] = None, *,
                    reason: str = "window") -> None:
        """Count a rejected batch in the current run's metrics and stamp
        every request's ``outcome``.

        ``reason="window"`` is the admission-window drop (counted in
        ``shed``, outcome ``shed_window``); ``reason="deadline"`` is the
        SLO-aware gateway's hopeless-slack drop (counted in
        ``shed_deadline``, outcome ``shed_deadline`` — the request never
        occupied an executor). Both also land in the per-model and
        per-priority-class breakdowns.
        """
        if reason not in ("window", "deadline"):
            raise ValueError(f"reason must be 'window' or 'deadline', "
                             f"got {reason!r}")
        if model is None:
            model = _batch_model(batch)
        with self._lock:
            metrics = self._metrics
            ms = metrics.model(model)
            for r in batch:
                cs = metrics.for_class(getattr(r, "priority", "batch"))
                if reason == "deadline":
                    metrics.shed_deadline += 1
                    ms.shed_deadline += 1
                    cs.shed_deadline += 1
                    r.outcome = "shed_deadline"
                else:
                    metrics.shed += 1
                    ms.shed += 1
                    cs.shed_window += 1
                    r.outcome = "shed_window"

    def _complete(self, fut: Future, batch: list, name: str, model: str,
                  metrics: ServeMetrics, seeds: np.ndarray,
                  submitted_at: float) -> None:
        self._window.release()
        now = self.clock()
        with self._lock:
            if fut.exception() is not None:
                if self._error is None:
                    self._error = fut.exception()
            else:
                ms = metrics.model(model)
                for r in batch:
                    r.done = now
                    r.outcome = "completed"
                    metrics.latencies.append(r.latency)
                    ms.latencies.append(r.latency)
                    cs = metrics.for_class(getattr(r, "priority", "batch"))
                    cs.requests += 1
                    cs.latencies.append(r.latency)
                metrics.requests += len(batch)
                metrics.routed[name] = metrics.routed.get(name, 0) + 1
                ms.requests += len(batch)
                ms.routed[name] = ms.routed.get(name, 0) + 1
                ms.exec_latencies.setdefault(name, []).append(
                    now - submitted_at)
        if fut.exception() is None:
            # per-batch service time (lane queueing + processing): the live
            # counterpart of the offline calibration samples
            self._notify("on_batch_complete", name, seeds,
                         now - submitted_at, model)
        self._finish_one()

    def _finish_one(self) -> None:
        with self._acct:
            self._inflight_batches -= 1
            self._acct.notify_all()

    def drain(self) -> None:
        """Wait until every outstanding batch — including its metrics
        accounting — has finished; then re-raise the first executor failure
        (the old thread-pool loop swallowed them)."""
        with self._acct:
            self._acct.wait_for(lambda: self._inflight_batches == 0)
        with self._lock:
            err, self._error = self._error, None
        if err is not None:
            raise err

    # -- live load view (the gateway's dispatch gate + telemetry feed) -------
    @property
    def inflight(self) -> int:
        """Batches admitted but not yet fully accounted (monotonic view of
        the admission window's occupancy)."""
        with self._acct:
            return self._inflight_batches

    @property
    def saturation(self) -> float:
        """``inflight ÷ max_inflight`` — 1.0 means the window is full and
        the next submit blocks or sheds."""
        return self.inflight / max(self.max_inflight, 1)

    def class_summaries(self) -> dict[str, dict]:
        """Live per-priority-class blocks of the current run (keys of each
        block are ``CLASS_SAMPLE_SCHEMA``) — safe to poll mid-run."""
        with self._lock:
            return {c: cs.summary() for c, cs in self._metrics.classes.items()}

    # -- serving loops (drop-in for the old pipeline API) --------------------
    def _reset(self) -> ServeMetrics:
        metrics = ServeMetrics()
        metrics.started = self.clock()
        with self._lock:
            self._metrics = metrics
        return metrics

    def begin_run(self) -> ServeMetrics:
        """Open a fresh measured run and return its metrics object — for
        callers (the gateway, by-hand tests) that drive ``submit_batch``
        directly instead of through :meth:`run`/:meth:`serve_stream`."""
        return self._reset()

    def end_run(self, metrics: ServeMetrics) -> ServeMetrics:
        """Close a run opened with :meth:`begin_run`: stamp the wall-clock
        end and snapshot the shared stores' dispatch counters."""
        metrics.finished = self.clock()
        metrics.store_stats = self._store_stats()
        return metrics

    def _store_stats(self) -> dict[str, dict]:
        """Snapshot of the shared stores' dispatch counters (deduplicated by
        identity — every model's executors read the same stores). Keys are
        ``<StoreClass>`` (``#i``-suffixed only if several distinct stores of
        one class are in play). Each snapshot additionally carries
        ``collect_mode``: the feature-collection path(s) the executors
        actually take for that store (``fuse_aggregate`` / ``fused`` /
        ``per_hop``, ``+``-joined when executors disagree) — so a
        silently-downgraded flag is visible in telemetry."""
        out: dict[str, dict] = {}
        keys: dict[int, str] = {}
        modes: dict[str, set] = {}
        for _model, _name, ex in self.registry.all_executors():
            get_stores = getattr(ex, "stores", None)
            stores = (get_stores() if get_stores else
                      [s for s in (getattr(ex, "store", None),
                                   getattr(ex, "sstore", None)) if s])
            for store in stores:
                stats = getattr(store, "stats", None)
                if stats is None:
                    continue
                key = keys.get(id(store))
                if key is None:
                    key = type(store).__name__
                    if key in out:
                        key = f"{key}#{sum(k.startswith(key) for k in out)}"
                    keys[id(store)] = key
                    out[key] = dict(stats)
                    modes[key] = set()
                mode = getattr(ex, "collect_mode", None)
                if mode is not None:
                    modes[key].add(mode(store))
        for key, ms in modes.items():
            out[key]["collect_mode"] = "+".join(sorted(ms)) if ms else "n/a"
        return out

    def serve_stream(self, requests: Sequence, batcher, *, gap_s: float = 0.0,
                     micro: Optional[MicroBatcher] = None) -> ServeMetrics:
        """Client-stream serving: requests arrive one by one (``gap_s``
        apart), the DynamicBatcher closes batches by deadline / PSGS budget /
        max size, and closed batches are admitted to the executor graph
        (paper §4.2.2).

        Batching state is per model: the passed ``batcher`` (and ``micro``)
        serve the first model seen on the stream, and every further model
        tag gets its own ``clone()`` — batches and super-batches never
        coalesce across models, and the stream-end drain flushes *every*
        model's batcher and micro-batcher (a tail batch below the PSGS
        budget is never dropped).

        Args:
            requests: request stream (anything yielding ``Request``-like
                objects with ``seeds``/``arrival``; an optional ``model``
                tag selects the registry entry, defaulting to the single
                model).
            batcher: batch closer (``DynamicBatcher`` protocol:
                ``add(request)`` / ``flush()``; must also offer ``clone()``
                when the stream carries several models).
            gap_s: inter-arrival gap, client emulation.
            micro: optional :class:`MicroBatcher` coalescing stage — closed
                batches are held (deadline evaluated on the next arrival;
                see the class docstring for sparse-stream caveats) and
                merged into gather-friendly super-batches before admission,
                so the fused feature path sees large unique-id sets.

        Returns:
            The run's :class:`ServeMetrics` (latencies include any
            micro-batching wait, since arrival is stamped at ingest).
        """
        metrics = self._reset()
        batchers: dict[str, Any] = {}
        micros: dict[str, MicroBatcher] = {}

        def stages(model: str):
            if model not in batchers:
                batchers[model] = (batcher if not batchers
                                   else _clone_stage(batcher))
                if micro is not None:
                    micros[model] = (micro if not micros
                                     else _clone_stage(micro))
            return batchers[model], micros.get(model)

        try:
            for r in requests:
                if gap_s:
                    time.sleep(gap_s)
                r.arrival = self.clock()
                b, m = stages(getattr(r, "model", DEFAULT_MODEL))
                out = b.add(r)
                if out and m is not None:
                    out = m.add(out)
                if out:
                    self.submit_batch(out)
            # stream-end drain: flush per model — the batcher tail passes
            # through that model's micro stage, then the micro stage itself
            # is flushed, so no tail super-batch below the PSGS budget is
            # ever dropped
            for model, b in batchers.items():
                m = micros.get(model)
                tail = b.flush()
                if tail and m is not None:
                    tail = m.add(tail)
                if tail:
                    self.submit_batch(tail)
                if m is not None:
                    tail = m.flush()
                    if tail:
                        self.submit_batch(tail)
            self.drain()
        finally:
            # stamp even when drain() re-raises an executor failure, so a
            # partially-failed run reports throughput over real wall time
            # instead of dividing by finished=0
            self.end_run(metrics)
        return metrics

    def run(self, batches: Sequence[list], *,
            pace_s: Optional[float] = None) -> ServeMetrics:
        """Process pre-formed batches (each single-model; the ``model`` tag
        of its requests selects the registry entry). ``pace_s`` spaces
        arrivals (client-stream emulation) and re-stamps request arrival at
        submit time so latency = queueing + processing."""
        metrics = self._reset()
        try:
            for b in batches:
                if pace_s:
                    time.sleep(pace_s)
                now = self.clock()
                for r in b:
                    r.arrival = now
                self.submit_batch(b)
            self.drain()
        finally:
            self.end_run(metrics)
        return metrics

    def warmup(self, batch, *, rounds: int = 2) -> None:
        """Compile/warm every registered executor of every model outside the
        measured window. Accepts a request batch or a raw seed array."""
        seeds = (np.asarray(batch) if isinstance(batch, np.ndarray)
                 else _batch_seeds(batch))
        for _model, _name, ex in self.registry.all_executors():
            for _ in range(rounds):
                ex.run(seeds)

    def close(self) -> None:
        """Shut down every executor's worker pool across all models
        (blocking; executors shared between entries close once)."""
        seen: set[int] = set()
        for _model, _name, ex in self.registry.all_executors():
            if id(ex) in seen:
                continue
            seen.add(id(ex))
            close = getattr(ex, "close", None)
            if close:
                close()


@functools.lru_cache(maxsize=256)
def _max_positional(fn) -> Optional[int]:
    """Positional arity of a hook callable (``None`` = unbounded/unknown).
    Cached — signature inspection is pure in the callable, and this runs on
    the per-batch hot path (twice per batch per hook); bound methods of one
    object hash/compare equal across ``getattr`` calls, so the cache hits."""
    try:
        params = inspect.signature(fn).parameters.values()
    except (TypeError, ValueError):
        return None
    if any(p.kind is inspect.Parameter.VAR_POSITIONAL for p in params):
        return None
    return sum(p.kind in (inspect.Parameter.POSITIONAL_ONLY,
                          inspect.Parameter.POSITIONAL_OR_KEYWORD)
               for p in params)


def _call_adaptive(fn, args: tuple):
    """Call a hook with as many of ``args`` as its signature accepts —
    pre-multi-model hooks keep their ``(name, seeds[, latency])`` arity,
    model-aware hooks get the trailing model tag too."""
    n = _max_positional(fn)
    return fn(*(args if n is None else args[:n]))

"""Sketch-driven async prefetcher for the cold feature tiers.

The paper's feature-aggregation argument (§ feature aggregation) is that
CPU–GPU data movement must stay off the request critical path; OMEGA
(PAPERS.md) shows cold-feature fetch latency dominating large-graph GNN
serving tails. The tiered store's HOST/DISK rows used to cost one
synchronous ``io_callback`` per sample regardless of how predictable the
workload was. This module closes that gap:

  Prefetcher    predicts the next window's cold-tier hits from a decayed
                seed-frequency sketch (or any caller-supplied score vector,
                e.g. the AdaptiveController's freshly recomputed FAP),
                reads those rows on a background thread (host RAM + the
                mmap spill file — never the request path), and publishes
                them to the store's device-side staging buffer.

Double buffering: the previously published stage keeps serving lookups
while the next one is built; :meth:`TieredFeatureStore.publish_stage` swaps
the new buffer in atomically, so readers always see one coherent
(placement, stage) snapshot. Staged rows are *copies* of the same feature
values, so prefetching can never change a lookup result — only remove the
host round-trip (hits and fallback misses are counted in the store's
dispatch stats).

The store is duck-typed: anything exposing ``tier_t``, ``read_cold_rows``
and ``publish_stage`` works. In particular one unmodified prefetcher
drives the distributed store's per-shard staging —
:meth:`ShardedFeatureStore.publish_stage` accepts the same global
``(N,)`` id → row layout and re-bins it per shard, so the mesh-wide
staging buffers are fed from the one shared sketch/FAP signal.

Wire-up, standalone (the prefetcher feeds its own sketch via engine hooks
and refreshes every ``refresh_every`` completed batches)::

    pf = Prefetcher(store, sketch, budget=1024, refresh_every=32)
    engine = ServingEngine(executors, router, hooks=[pf])

or driven by the adaptive control loop (shared sketch, refresh + miss-driven
DISK promotion every control step)::

    controller = AdaptiveController(..., prefetcher=pf)
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.placement import TIER_HOST


class Prefetcher:
    """Double-buffered cold-row prefetcher over a :class:`TieredFeatureStore`
    (or any store with the same ``tier_t`` / ``read_cold_rows`` /
    ``publish_stage`` surface, e.g. :class:`ShardedFeatureStore`).

    Attributes:
        store: the store whose stage this prefetcher owns.
        sketch: optional seed-frequency sketch (duck-typed: ``observe`` +
            ``counts``) used for prediction when no score vector is given;
            fed by :meth:`on_admit` when the prefetcher is an engine hook.
        budget: max rows staged per refresh (device staging-buffer size).
            A plain attribute read at :meth:`predict` time, so the
            AdaptiveController may re-assign it live each control step
            (sized from the measured cold working set, clamped to its
            configured bounds) — the next refresh picks it up.
        refresh_every: when set, :meth:`on_batch_complete` triggers an async
            refresh every that many completed batches (standalone mode —
            the AdaptiveController path refreshes per control step instead,
            at a cadence tuned from the prefetch miss ratio).
        refresh_every_s: wall-clock twin of ``refresh_every``: when set, a
            completion also triggers a refresh once that many seconds (by
            ``clock``) passed since the last one — the two cadences
            compose, whichever fires first.
        clock: zero-arg seconds source for the time-based cadence
            (injectable — tests pass ``repro.testing.FakeClock``).
    """

    def __init__(self, store, sketch=None, *, budget: int = 1024,
                 refresh_every: Optional[int] = None,
                 refresh_every_s: Optional[float] = None,
                 clock: Callable[[], float] = time.monotonic):
        if budget <= 0:
            raise ValueError(f"budget must be positive, got {budget}")
        self.store = store
        self.sketch = sketch
        self.budget = int(budget)
        self.refresh_every = refresh_every
        self.refresh_every_s = refresh_every_s
        self.clock = clock
        self.stats = {"refreshes": 0, "staged_rows": 0, "skipped": 0,
                      "batches_seen": 0}
        self._last_refresh_t = clock()
        self._lock = threading.Lock()
        self._refresh_lock = threading.Lock()
        self._inflight: Optional[Future] = None
        self._error: Optional[BaseException] = None
        self._pool = ThreadPoolExecutor(max_workers=1,
                                        thread_name_prefix="prefetch")

    # -- engine hook protocol ------------------------------------------------
    def on_admit(self, name: str, seeds: np.ndarray, model: str = "") -> None:
        """Engine hook: feed the admitted batch's seeds into the sketch
        (no-op without one — e.g. when sharing the controller's sketch,
        which the controller's own ``on_admit`` already feeds)."""
        if self.sketch is not None:
            self.sketch.observe(seeds)

    def on_batch_complete(self, name: str, seeds: np.ndarray,
                          latency_s: float, model: str = "") -> None:
        """Engine hook: count completions and, in standalone mode
        (``refresh_every``), kick an async refresh at each period — then
        decay the (owned) sketch so predictions track the *recent* mix
        rather than freezing on the all-time hot set. (Controller-driven
        prefetchers share the controller's sketch, which decays per control
        step instead; they leave ``refresh_every`` unset.)"""
        with self._lock:
            self.stats["batches_seen"] += 1
            due = (self.refresh_every is not None
                   and self.stats["batches_seen"] % self.refresh_every == 0)
            if not due and self.refresh_every_s is not None:
                now = self.clock()
                due = now - self._last_refresh_t >= self.refresh_every_s
            if due:
                self._last_refresh_t = self.clock()
        if due:
            self.refresh_async()
            decay = getattr(self.sketch, "decay_step", None)
            if decay is not None:
                decay()

    # -- prediction + staging ------------------------------------------------
    def predict(self, scores: Optional[np.ndarray] = None) -> np.ndarray:
        """Node ids to stage: the top-``budget`` cold-tier (HOST/DISK) nodes
        by score. ``scores`` defaults to the sketch's decayed seed counts;
        the adaptive loop passes its freshly recomputed FAP instead, which
        also predicts multi-hop frontier accesses. Zero-score nodes are
        never staged (cold start stages nothing).

        Raises:
            ValueError: with neither ``scores`` nor a sketch.
        """
        if scores is None:
            if self.sketch is None:
                raise ValueError("predict() needs scores or a sketch")
            scores = self.sketch.counts
        scores = np.asarray(scores, dtype=np.float64)
        # prefer a store-provided host-side tier mirror (the sharded
        # store's tables are static) over a device→host transfer of the
        # full tier table on every refresh
        tier = getattr(self.store, "tier_table_host", None)
        if tier is None:
            tier = np.asarray(self.store.tier_t)
        cold = np.flatnonzero((tier >= TIER_HOST) & (scores > 0.0))
        if not cold.size:
            return cold
        order = np.argsort(-scores[cold], kind="stable")
        return cold[order[:self.budget]]

    def refresh(self, scores: Optional[np.ndarray] = None) -> int:
        """Synchronously rebuild and publish the staging buffer.

        Predicts the stage set, reads the rows host-side (RAM + spill file
        — never the request path), uploads them to device, and atomically
        publishes the new stage; the previous stage keeps serving until the
        swap (double buffering). With nothing to stage the stage is
        cleared.

        Args:
            scores: optional per-node hotness (defaults to sketch counts).

        Returns:
            Number of rows staged.
        """
        with self._refresh_lock:
            ids = self.predict(scores)
            if ids.size == 0:
                self.store.publish_stage(None, None)
                staged = 0
            else:
                rows = self.store.read_cold_rows(ids)
                # shape is array metadata — no device→host transfer here
                n = int(self.store.tier_t.shape[0])
                stage_slot = np.full(n, -1, np.int32)
                stage_slot[ids] = np.arange(ids.size, dtype=np.int32)
                self.store.publish_stage(stage_slot, jnp.asarray(rows))
                staged = int(ids.size)
            with self._lock:
                self.stats["refreshes"] += 1
                self.stats["staged_rows"] = staged
            return staged

    def refresh_async(self, scores: Optional[np.ndarray] = None
                      ) -> Optional[Future]:
        """Submit a refresh to the background worker; returns its future,
        or ``None`` when one is already in flight (the new request is
        dropped, not queued — the next period retries with fresher
        scores). Worker errors are kept and re-raised by the next
        :meth:`refresh_async` / :meth:`close` call."""
        with self._lock:
            if self._error is not None:
                err, self._error = self._error, None
                raise err
            if self._inflight is not None and not self._inflight.done():
                self.stats["skipped"] += 1
                return None
            fut = self._pool.submit(self.refresh, scores)
            self._inflight = fut
        fut.add_done_callback(self._done)
        return fut

    def _done(self, fut: Future) -> None:
        exc = fut.exception()
        if exc is not None:
            with self._lock:
                if self._error is None:
                    self._error = exc

    def report(self) -> dict:
        """Prefetch counters for logging (refreshes, rows staged by the
        last refresh, skipped overlapping refreshes, batches seen)."""
        with self._lock:
            return dict(self.stats)

    def close(self) -> None:
        """Drain the background worker and clear the published stage;
        re-raises the last background refresh failure, if any."""
        self._pool.shutdown(wait=True)
        self.store.publish_stage(None, None)
        with self._lock:
            err, self._error = self._error, None
        if err is not None:
            raise err

"""Request-granularity device cache in front of the tiered store's cold path.

Quiver's feature-access-probability placement adapts only at control-step
granularity: a flash-crowd node stays in a cold tier — paying a host
callback per access — for an entire adaptive interval. This module closes
that timescale gap with the ``GPUCachedFeature`` pattern (DGL GraphBolt,
see SNIPPETS.md): a fixed-capacity device-side row cache queried *before*
the tier dispatch, so a cold row is fetched from host/disk at most once
per residency and every repeat access is a plain HBM gather.

  query(ids)    -> (values, miss_index, miss_ids): static-shape gather of
                the cached rows (full-width gather + ``jnp.where`` mask —
                no per-hit-count recompilation), plus the positions and
                ids that must flow through the normal tier path.
  replace(ids, rows)  admit the missed rows on return from the tier path;
                eviction is CLOCK (second-chance) weighted by the shared
                :class:`~repro.serving.adaptive.FrequencySketch`: a
                resident whose decayed access count exceeds the
                candidate's is never evicted for it, and when *every*
                resident is hotter the admission is rejected outright
                (scan resistance — one cold sweep cannot flush the crowd).

Consistency: cached rows are copies of the exact feature values, and
:meth:`TieredFeatureStore.swap_assignments` preserves lookup equivalence
(rows travel with their nodes), so a stale cache entry can never change a
lookup result. The store still calls :meth:`GPUFeatureCache.invalidate`
for migrated ids on every publication — hygiene, so a row promoted into
HBM stops occupying cache capacity.

The row buffer is a ``jnp`` array replaced copy-on-write (``.at[].set``):
an in-flight :meth:`query` that captured the previous buffer keeps reading
a coherent (slot-table, rows) pair; all host-side tables mutate under one
lock.
"""
from __future__ import annotations

import threading
from typing import Optional

import jax.numpy as jnp
import numpy as np


def _new_cache_stats() -> dict[str, int]:
    return {"hits": 0, "misses": 0, "evictions": 0, "admitted": 0,
            "rejected": 0, "invalidated": 0, "resizes": 0}


class GPUFeatureCache:
    """Fixed-capacity device-side feature-row cache with sketch-weighted
    CLOCK eviction.

    Sits in front of :meth:`TieredFeatureStore.lookup` /
    :meth:`~TieredFeatureStore.lookup_hops` (attach with
    :meth:`TieredFeatureStore.attach_cache`): the store queries it for
    cold-tier (HOST/DISK) ids only, serves hits from the device buffer
    without touching the tier dispatch path, and admits the missed rows on
    return from the fused gather. Thread-safe; the
    :class:`~repro.serving.adaptive.AdaptiveController` may
    :meth:`resize` it live from the measured cold working set.

    Attributes:
        capacity: current row capacity (mutated only by :meth:`resize`).
        sketch: optional shared frequency sketch (duck-typed: ``counts``)
            that weights eviction and resize retention.
        stats: internal counters (hits/misses/evictions/admitted/rejected/
            invalidated/resizes); the store mirrors the first three into
            its dispatch-stats schema.
    """

    def __init__(self, num_nodes: int, capacity: int, feat_dim: int, *,
                 dtype=jnp.float32, sketch=None):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.num_nodes = int(num_nodes)
        self.capacity = int(capacity)
        self.feat_dim = int(feat_dim)
        self.sketch = sketch
        self.stats = _new_cache_stats()
        self._lock = threading.Lock()
        self._rows = jnp.zeros((self.capacity, self.feat_dim), dtype)
        self._slot_of = np.full(self.num_nodes, -1, np.int32)
        self._node_of = np.full(self.capacity, -1, np.int64)
        self._ref = np.zeros(self.capacity, bool)   # second-chance bits
        self._hand = 0
        self._free = list(range(self.capacity - 1, -1, -1))

    @staticmethod
    def for_store(store, capacity: int, *, sketch=None) -> "GPUFeatureCache":
        """Build a cache shaped for ``store`` (node count / feature width /
        dtype read off the store) — the launcher's one-liner."""
        return GPUFeatureCache(int(store.plan.tier.shape[0]), capacity,
                               store.feat_dim, dtype=store.hot.dtype,
                               sketch=sketch)

    # -- read path -----------------------------------------------------------
    def query(self, ids) -> tuple[jnp.ndarray, np.ndarray, np.ndarray]:
        """Probe the cache for one id vector (static-shape gather).

        Args:
            ids: ``(M,)`` int node ids; ``-1`` entries are "not asked"
                (padding, or ids the caller resolved elsewhere) and are
                neither hits nor misses.

        Returns:
            ``(values, miss_index, miss_ids)`` — ``values`` is ``(M, d)``
            with hit rows filled and every other row zero (full-width
            gather + mask, so the shape never depends on the hit count);
            ``miss_index`` the positions into ``ids`` that were asked but
            not resident; ``miss_ids`` is ``ids[miss_index]``. Hit slots
            get their second-chance bit set.
        """
        ids_np = np.asarray(ids).reshape(-1)
        safe = np.maximum(ids_np, 0)
        with self._lock:
            slots = self._slot_of[safe].copy()
            rows = self._rows          # coherent with slots: replaced, never
            hit = (ids_np >= 0) & (slots >= 0)   # mutated, under this lock
            if hit.any():
                self._ref[slots[hit]] = True
            self.stats["hits"] += int(hit.sum())
            self.stats["misses"] += int(((ids_np >= 0) & ~hit).sum())
        gathered = rows[jnp.asarray(np.maximum(slots, 0))]
        values = jnp.where(jnp.asarray(hit)[:, None], gathered, 0.0)
        miss_index = np.flatnonzero((ids_np >= 0) & ~hit)
        return values, miss_index, ids_np[miss_index]

    # -- admission / eviction ------------------------------------------------
    def _evict_slot(self, cand: int, counts) -> tuple[int, int]:
        """CLOCK scan for a slot to hand to ``cand``. Pass 1 honors
        second-chance bits and frequency protection; pass 2 drops the
        second chances but keeps protection. Returns ``(slot, evicted)``
        with ``slot == -1`` when every resident is hotter than the
        candidate (admission rejected)."""
        cand_w = np.inf if counts is None else float(counts[cand])
        for honor_ref in (True, False):
            for _ in range(self.capacity):
                s = self._hand
                self._hand = (self._hand + 1) % self.capacity
                if honor_ref and self._ref[s]:
                    self._ref[s] = False
                    continue
                resident = int(self._node_of[s])
                if (counts is not None and resident >= 0
                        and float(counts[resident]) > cand_w):
                    continue
                if resident >= 0:
                    self._slot_of[resident] = -1
                    self._node_of[s] = -1
                    self._ref[s] = False
                    return s, 1
                return s, 0
        return -1, 0

    def replace(self, ids, rows) -> int:
        """Admit missed rows (the ``cache.replace(miss_ids, miss_values)``
        half of the query-and-replace pattern).

        Args:
            ids: ``(K,)`` node ids to admit (duplicates collapsed, ``-1``
                and already-resident ids skipped — a racing lane may have
                admitted first).
            rows: ``(K, d)`` feature rows aligned with ``ids`` (device or
                host array; copied into the cache buffer).

        Returns:
            Number of resident rows evicted to make room (admissions into
            free slots and rejected admissions evict nothing).
        """
        ids_np = np.asarray(ids).reshape(-1)
        if ids_np.size == 0:
            return 0
        uniq, first = np.unique(ids_np, return_index=True)
        valid = uniq >= 0
        evicted = 0
        slots_out: list[int] = []
        take_idx: list[int] = []
        with self._lock:
            counts = None if self.sketch is None else self.sketch.counts
            for u, src in zip(uniq[valid], first[valid]):
                u = int(u)
                if self._slot_of[u] >= 0:
                    continue
                if self._free:
                    s = self._free.pop()
                else:
                    s, ev = self._evict_slot(u, counts)
                    if s < 0:
                        self.stats["rejected"] += 1
                        continue
                    evicted += ev
                self._slot_of[u] = s
                self._node_of[s] = u
                self._ref[s] = False
                slots_out.append(s)
                take_idx.append(int(src))
            self.stats["evictions"] += evicted
            self.stats["admitted"] += len(slots_out)
            if slots_out:
                vals = jnp.asarray(rows)[np.asarray(take_idx)]
                self._rows = self._rows.at[np.asarray(slots_out)].set(
                    vals.astype(self._rows.dtype))
        return evicted

    # -- maintenance ---------------------------------------------------------
    def invalidate(self, ids) -> int:
        """Drop the given ids from the cache (no-op for non-resident ids).

        Called by :meth:`TieredFeatureStore.swap_assignments` for exactly
        the migrated nodes — values never change on migration, so this is
        capacity hygiene, not a correctness requirement.

        Returns:
            Number of rows dropped.
        """
        ids_np = np.unique(np.asarray(ids).reshape(-1))
        n = 0
        with self._lock:
            for u in ids_np:
                u = int(u)
                if u < 0 or self._slot_of[u] < 0:
                    continue
                s = int(self._slot_of[u])
                self._slot_of[u] = -1
                self._node_of[s] = -1
                self._ref[s] = False
                self._free.append(s)
                n += 1
            self.stats["invalidated"] += n
        return n

    def resize(self, capacity: int) -> int:
        """Rebuild the cache at a new capacity, keeping the hottest
        residents (by sketch weight; insertion order without a sketch).

        The controller calls this each control step with a target sized
        from the measured cold working set, clamped to its configured
        bounds — capacity therefore never grows without bound.

        Returns:
            Number of resident rows dropped by a shrink (counted as
            evictions).
        """
        capacity = max(1, int(capacity))
        with self._lock:
            if capacity == self.capacity:
                return 0
            resident = np.flatnonzero(self._node_of >= 0)
            nodes = self._node_of[resident]
            if nodes.size > capacity:
                if self.sketch is not None:
                    order = np.argsort(-np.asarray(self.sketch.counts)[nodes],
                                       kind="stable")
                else:
                    order = np.arange(nodes.size)
                keep = np.sort(order[:capacity])
            else:
                keep = np.arange(nodes.size)
            dropped = int(nodes.size - keep.size)
            kept_slots = resident[keep]
            kept_nodes = nodes[keep]
            new_rows = jnp.zeros((capacity, self.feat_dim), self._rows.dtype)
            if keep.size:
                new_rows = new_rows.at[:keep.size].set(self._rows[kept_slots])
            self._slot_of[nodes] = -1
            self._slot_of[kept_nodes] = np.arange(keep.size, dtype=np.int32)
            node_of = np.full(capacity, -1, np.int64)
            node_of[:keep.size] = kept_nodes
            ref = np.zeros(capacity, bool)
            ref[:keep.size] = self._ref[kept_slots]
            self._rows, self._node_of, self._ref = new_rows, node_of, ref
            self._free = list(range(capacity - 1, keep.size - 1, -1))
            self._hand = 0
            self.capacity = capacity
            self.stats["evictions"] += dropped
            self.stats["resizes"] += 1
        return dropped

    # -- introspection -------------------------------------------------------
    def resident_rows(self) -> int:
        """Rows currently cached."""
        with self._lock:
            return int((self._node_of >= 0).sum())

    def report(self) -> dict:
        """Counters + sizing for logs: stats, capacity, resident rows,
        and the hit rate over the cache's lifetime."""
        with self._lock:
            stats = dict(self.stats)
            resident = int((self._node_of >= 0).sum())
            capacity = self.capacity
        asked = stats["hits"] + stats["misses"]
        return {**stats, "capacity": capacity, "resident": resident,
                "hit_rate": stats["hits"] / asked if asked else 0.0}

"""Tiered feature store + one-sided read engine (paper §5.3, TPU-native).

The paper's engine issues zero-copy one-sided reads (UVA / RDMA) from GPU
kernels. On TPU the equivalent is to keep the whole hot/warm path inside one
XLA program so no host mediation happens at all:

  HOT   rows live replicated in every chip's HBM → local gather.
  WARM  rows are node-sharded across chips → fetched with an explicit
        ``shard_map`` exchange (our one-sided read): either
        (a) ``allgather_ids + local gather + reduce_scatter`` (robust for small
            request vectors), or
        (b) capacity-bounded ``all_to_all`` with owner-sorted ids (moves only
            requested rows — the RDMA-read analogue; skew overflow spills to
            the host path, like a cache miss).
  HOST  rows are fetched with ``jax.experimental.io_callback`` (PCIe analogue).
  DISK  rows live in an mmap-backed spill tier (:class:`DiskSpillTier` — an
        ``np.memmap`` file written once at :meth:`TieredFeatureStore.build`
        plus a copy-on-write overlay for migrated rows) and resolve to the
        real feature rows through the same host callback; spill reads and
        critical-path misses are tracked per row, and hot DISK rows can be
        promoted up via :meth:`TieredFeatureStore.promote_misses` (swap-based,
        the existing migration machinery).

Cold-tier accesses can additionally be taken off the critical path entirely
by a :class:`~repro.core.prefetch.Prefetcher`: it stages predicted HOST/DISK
rows into a device-side staging buffer published through
:meth:`TieredFeatureStore.publish_stage`; ``lookup``/``lookup_hops`` resolve
staged ids from device memory and fall back to the synchronous host callback
only on a prefetch miss (hits and misses are counted in the dispatch stats).

The paper's address-sort/TLB optimization survives as: ids are deduplicated
(``fixed_size_unique``) and sorted before every gather/exchange, which both
shrinks collective payloads and improves gather locality.

Fused feature collection (serving hot path): :meth:`TieredFeatureStore.
lookup_hops` collapses the per-hop ``[store.lookup(h) for h in hops]``
pattern into ONE pipeline — concatenate all hops, deduplicate ids once
across hops, do a single address-sorted gather over the device-resident
HOT/WARM tiers (dispatching the Pallas ``tiered_gather`` kernel) plus a
single host callback for the HOST/DISK tiers, then scatter rows back per
hop. For an L-layer sample this replaces 2·(L+1) device gathers and (L+1)
host round-trips with 1 + 1, and the cross-hop dedup shrinks the gathered
row count (hop frontiers overlap heavily on skewed graphs).
"""
from __future__ import annotations

import dataclasses
import os
import threading
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import io_callback
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map

from repro.core.placement import (PlacementPlan, TIER_DISK, TIER_HOST,
                                  TIER_HOT, TIER_WARM)
from repro.graph.sampler import fixed_size_unique
from repro.kernels.gather_aggregate.ops import gather_aggregate
from repro.kernels.tiered_gather.ops import tiered_gather


# Canonical stats schema for TieredFeatureStore dispatch accounting — THE
# single source of truth. Tests import it (tests/test_prefetch.py,
# tests/test_metrics.py), docs/invariants.md tables it, and quiverlint's
# schema-sync pass cross-checks every producer and doc against it.
STATS_SCHEMA: tuple = (
    "lookup_calls", "fused_calls", "fused_aggregates", "device_gathers",
    "host_fetches", "disk_misses", "spill_reads", "prefetch_hits",
    "prefetch_misses", "cache_hits", "cache_misses", "cache_evictions")


def _new_stats() -> dict[str, int]:
    """Dispatch accounting shared by both lookup paths (benchmark signals:
    ``benchmarks/fused_gather.py`` reports the per-request dispatch
    reduction, ``benchmarks/prefetch.py`` the critical-path host-callback
    reduction). The schema is ``STATS_SCHEMA`` above — new counters are
    added there, documented in ``docs/invariants.md``, and picked up by
    the tests automatically:

      lookup_calls / fused_calls   per-hop vs fused lookup entries
      fused_aggregates             ``lookup_aggregate`` entries: samples
                                   whose innermost-hop aggregation was
                                   folded into the gather dispatch
      device_gathers               tiered_gather / gather_aggregate
                                   dispatches (HOT/WARM)
      host_fetches                 synchronous ``io_callback`` round-trips
                                   actually issued (a lookup whose cold rows
                                   are all staged — or that has none —
                                   issues zero)
      disk_misses                  DISK-tier rows resolved synchronously on
                                   the lookup critical path
      spill_reads                  rows read from the DISK spill tier by any
                                   path (critical-path misses + prefetch)
      prefetch_hits                cold rows resolved from the device-side
                                   staging buffer (no host round-trip)
      prefetch_misses              cold rows that fell back to the host
                                   callback while a stage was published
      cache_hits                   cold rows served straight from the
                                   attached device cache (tier dispatch
                                   skipped entirely)
      cache_misses                 cold rows that missed the device cache
                                   and flowed through the tier path (then
                                   admitted on return)
      cache_evictions              resident cache rows displaced by those
                                   admissions
    """
    return dict.fromkeys(STATS_SCHEMA, 0)


class DiskSpillTier:
    """mmap-backed DISK tier: one spill file + a copy-on-write overlay.

    The backing array is written ONCE (at :meth:`TieredFeatureStore.build`)
    and then only ever read: when ``path`` is given it is an ``np.memmap``
    reopened read-only, so cold rows genuinely live on disk, not in RAM.
    Rows that migrate INTO the disk tier afterwards (demotions from
    :meth:`TieredFeatureStore.swap_assignments`) land in a small dict
    overlay instead of mutating the file — ``copy()`` duplicates only the
    overlay and shares the memmap, which keeps the store's copy-on-write
    snapshot publication cheap and torn-read-free (in-flight lookups hold
    the previous ``DiskSpillTier`` object; the file underneath never
    changes). Indexing (``tier[rows]``) reads the backing store and applies
    the overlay, so callers see one coherent array.
    """

    def __init__(self, base: np.ndarray,
                 overlay: Optional[dict[int, np.ndarray]] = None,
                 path: Optional[str] = None):
        self._base = base
        self._overlay: dict[int, np.ndarray] = dict(overlay or {})
        self.path = path
        self._root = path       # first-generation file; .gN names derive
        self._generation = 0    # from it across compactions

    @staticmethod
    def build(rows: np.ndarray, path: Optional[str] = None) -> "DiskSpillTier":
        """Write the DISK-tier rows. With ``path`` the rows go to an
        ``np.memmap`` spill file (flushed, then reopened read-only); without
        it the backing store is plain host memory (tests / tiny stores)."""
        if path is None:
            return DiskSpillTier(rows)
        mm = np.memmap(path, dtype=rows.dtype, mode="w+", shape=rows.shape)
        mm[:] = rows
        mm.flush()
        del mm  # close the writable map before reopening read-only
        base = np.memmap(path, dtype=rows.dtype, mode="r", shape=rows.shape)
        return DiskSpillTier(base, path=path)

    @property
    def shape(self) -> tuple:
        """Backing-store shape ``(rows, d)`` (overlay rows shadow, never
        extend)."""
        return self._base.shape

    @property
    def dtype(self) -> np.dtype:
        """Row dtype of the backing store."""
        return self._base.dtype

    @property
    def overlay_rows(self) -> int:
        """Rows currently shadowed by post-build migrations."""
        return len(self._overlay)

    def __len__(self) -> int:
        return self._base.shape[0]

    def __getitem__(self, idx):
        if isinstance(idx, (int, np.integer)):
            hit = self._overlay.get(int(idx))
            return hit if hit is not None else np.asarray(self._base[idx])
        idx = np.asarray(idx)
        rows = np.asarray(self._base[idx])  # fancy indexing always copies
        if self._overlay:
            # vectorized membership test: the common case (no overlay hit
            # among the requested slots) costs one np.isin, not a Python
            # loop over every requested row
            keys = np.fromiter(self._overlay, dtype=np.int64,
                               count=len(self._overlay))
            flat = idx.ravel()
            for i in np.flatnonzero(np.isin(flat, keys)):
                rows[i] = self._overlay[int(flat[i])]
        return rows

    def __setitem__(self, idx, vals) -> None:
        """Writes go to the overlay, never to the spill file."""
        idx = np.atleast_1d(np.asarray(idx))
        vals = np.atleast_2d(np.asarray(vals))
        for slot, row in zip(idx.ravel(), vals):
            self._overlay[int(slot)] = np.array(row)

    def copy(self) -> "DiskSpillTier":
        """Copy-on-write duplicate: shares the backing store, copies only
        the overlay (the migration publish path calls this)."""
        dup = DiskSpillTier(self._base, self._overlay, self.path)
        dup._root, dup._generation = self._root, self._generation
        return dup

    @property
    def resident_nbytes(self) -> int:
        """Host-RAM bytes actually held by this tier: the overlay plus —
        only when there is no spill file — the backing array itself (the
        memmap pages live on disk and must not count as resident)."""
        row = int(self._base.itemsize * np.prod(self._base.shape[1:]))
        base = 0 if self.path is not None else int(self._base.nbytes)
        return base + row * len(self._overlay)

    def compact(self) -> "DiskSpillTier":
        """Fold the overlay into a fresh backing store and return it as a
        new tier object (the caller publishes it copy-on-write; in-flight
        snapshots keep reading the old base + overlay).

        With a spill file, the merged rows are written to a new generation
        file ``<path>.gN`` and the previous file is unlinked best-effort
        (POSIX keeps it alive for snapshots still mapping it). This bounds
        the RAM the overlay can accumulate under long-running adaptive
        demotion churn — the store auto-compacts on the migration publish
        path once the overlay outgrows ``len(self) // 8``.
        """
        merged = np.asarray(self)
        if self.path is None:
            return DiskSpillTier(merged)
        new_path = f"{self._root}.g{self._generation + 1}"
        fresh = DiskSpillTier.build(merged, new_path)
        fresh._root = self._root
        fresh._generation = self._generation + 1
        try:
            os.unlink(self.path)
        except OSError:
            pass
        return fresh

    def __array__(self, dtype=None, copy=None) -> np.ndarray:
        out = np.array(self._base)
        for slot, row in self._overlay.items():
            out[slot] = row
        return out.astype(dtype) if dtype is not None else out


@dataclasses.dataclass
class TieredFeatureStore:
    """Single-host runtime store (serving engine / tests / benchmarks).

    The distributed (mesh) variant is `ShardedFeatureStore` below; this class
    emulates the tier structure faithfully on one device + host memory, so
    policy benchmarks (Fig. 15/16) exercise the same code paths.
    """

    plan: PlacementPlan
    feat_dim: int
    hot: jnp.ndarray          # (n_hot, d) — "device HBM, replicated"
    warm: jnp.ndarray         # (warm_total, d) — "device HBM, partitioned"
    host: np.ndarray          # (host_total, d) — host RAM (numpy, off device)
    disk: "DiskSpillTier"     # (rest, d) — mmap-backed spill tier
    tier_t: jnp.ndarray       # (N,) int32 lookup tables (device-resident;
    slot_t: jnp.ndarray       # paper: "feature lookup table" via UVA)
    owner_t: jnp.ndarray      # (N,) global warm owner (pod*G + dev), -1 else
    warm_base: jnp.ndarray    # (world,) row offset of each owner's warm shard
    # Online migration support: every lookup reads one consistent snapshot of
    # (tables, tier arrays); swap_assignments publishes a new snapshot
    # atomically under this lock (copy-on-write — in-flight lookups keep
    # serving from the old snapshot, so serving never pauses or torn-reads).
    _mig_lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)
    migrated_rows: int = 0    # lifetime count of rows moved between tiers
    # Dispatch accounting: how many tier-store gathers / host round-trips
    # each lookup path issued (the fused path's whole point is to shrink
    # these). Guarded by its own lock so hot-path increments never contend
    # with migration publishes.
    stats: dict = dataclasses.field(default_factory=_new_stats, repr=False,
                                    compare=False)
    _stats_lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)
    # Prefetch staging state, published atomically like migrations:
    # (stage_slot, stage_rows) where stage_slot is a host-side (N,) int32
    # table (-1 = unstaged) and stage_rows a device-side (budget, d) buffer.
    _stage: Optional[tuple] = dataclasses.field(default=None, repr=False,
                                                compare=False)
    # Per-node DISK critical-path miss counts (guarded by _stats_lock) —
    # the signal for miss-driven promotion.
    _disk_miss_counts: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False, compare=False)
    promoted_rows: int = 0    # lifetime count of miss-driven DISK promotions
    # Optional request-granularity device cache in front of the cold tiers
    # (GPUFeatureCache): queried before tier dispatch, admitted on return.
    cache: Optional[object] = dataclasses.field(default=None, repr=False,
                                                compare=False)

    @staticmethod
    def build(features: np.ndarray, plan: PlacementPlan, *,
              spill_path: Optional[str] = None) -> "TieredFeatureStore":
        """Lay the feature matrix out across the four tiers of ``plan``.

        Args:
            features: ``(N, d)`` full feature matrix.
            plan: placement decision (tier/owner/slot per node).
            spill_path: when given, the DISK-tier rows are written to an
                ``np.memmap`` spill file at this path (the real cold store);
                ``None`` keeps them in host memory (small stores / tests).
        """
        n, d = features.shape
        topo = plan.topology
        world = topo.num_pods * topo.devices_per_pod
        hot_ids = np.flatnonzero(plan.tier == TIER_HOT)
        hot = np.zeros((max(plan.n_hot, 1), d), features.dtype)
        hot[plan.slot[hot_ids]] = features[hot_ids]

        # Warm rows concatenated owner-major: [owner0 rows | owner1 rows | ...]
        owner_global = np.where(
            plan.tier == TIER_WARM,
            np.maximum(plan.pod_owner, 0).astype(np.int64) * topo.devices_per_pod
            + plan.device_owner, -1)
        counts = np.array([(owner_global == w).sum() for w in range(world)],
                          dtype=np.int64)
        base = np.zeros(world, dtype=np.int64)
        np.cumsum(counts[:-1], out=base[1:])
        warm = np.zeros((max(int(counts.sum()), 1), d), features.dtype)
        warm_ids = np.flatnonzero(plan.tier == TIER_WARM)
        warm_rows = base[owner_global[warm_ids]] + plan.slot[warm_ids]
        warm[warm_rows] = features[warm_ids]

        host_ids = np.flatnonzero(plan.tier == TIER_HOST)
        # pod-major host layout
        hcounts = np.zeros(topo.num_pods, dtype=np.int64)
        hbase = np.zeros(topo.num_pods, dtype=np.int64)
        for p in range(topo.num_pods):
            hcounts[p] = ((plan.tier == TIER_HOST)
                          & ((plan.pod_owner == p) | (plan.pod_owner == -1))).sum()
        np.cumsum(hcounts[:-1], out=hbase[1:])
        host = np.zeros((max(int(hcounts.sum()), 1), d), features.dtype)
        hpod = np.maximum(plan.pod_owner[host_ids], 0)
        host[hbase[hpod] + plan.slot[host_ids]] = features[host_ids]

        disk_ids = np.flatnonzero(plan.tier == TIER_DISK)
        disk_rows = np.zeros((max(disk_ids.shape[0], 1), d), features.dtype)
        disk_rows[plan.slot[disk_ids]] = features[disk_ids]
        disk = DiskSpillTier.build(disk_rows, spill_path)

        # Unified slot table pointing into each tier's flat store.
        slot_flat = plan.slot.copy()
        slot_flat[warm_ids] = warm_rows
        slot_flat[host_ids] = hbase[hpod] + plan.slot[host_ids]

        return TieredFeatureStore(
            plan=plan, feat_dim=d,
            hot=jnp.asarray(hot), warm=jnp.asarray(warm), host=host, disk=disk,
            tier_t=jnp.asarray(plan.tier, jnp.int32),
            slot_t=jnp.asarray(slot_flat, jnp.int32),
            owner_t=jnp.asarray(owner_global, jnp.int32),
            warm_base=jnp.asarray(base, jnp.int32),
            _disk_miss_counts=np.zeros(n, dtype=np.int64))

    # -- lookup -------------------------------------------------------------
    def _snapshot(self) -> tuple:
        """Consistent view (hot, warm, host, disk, tier_t, slot_t, stage).
        Arrays are replaced — never mutated — by migration and by stage
        publication, so holding the references is enough to keep serving
        from one coherent placement + staging state."""
        with self._mig_lock:
            return (self.hot, self.warm, self.host, self.disk,
                    self.tier_t, self.slot_t, self._stage)

    def _count(self, **deltas: int) -> None:
        with self._stats_lock:
            for k, v in deltas.items():
                self.stats[k] += v

    def reset_stats(self) -> dict[str, int]:
        """Zero the dispatch counters, returning the previous values."""
        with self._stats_lock:
            prev, self.stats = self.stats, _new_stats()
        return prev

    def snapshot_stats(self) -> dict[str, int]:
        """Copy of the dispatch counters WITHOUT resetting them (the
        adaptive controller reads per-interval deltas from this, so it
        must not race benchmark-owned :meth:`reset_stats` windows)."""
        with self._stats_lock:
            return dict(self.stats)

    def attach_cache(self, cache) -> "TieredFeatureStore":
        """Attach (``None`` detaches) a request-granularity device cache
        (:class:`~repro.core.gpu_cache.GPUFeatureCache`) in front of the
        cold tiers: lookups probe it for HOST/DISK ids before tier
        dispatch, serve hits from device memory, and admit misses on
        return. Detaching never changes lookup results — cached rows are
        copies of the exact feature values. Returns the store for
        chaining."""
        with self._mig_lock:
            self.cache = cache
        return self

    def lookup(self, ids: jnp.ndarray, *, include_host: bool = True,
               dedup: bool = True) -> jnp.ndarray:
        """Gather feature rows for one id vector.

        Args:
            ids: ``(M,)`` int node ids; ``-1`` entries are padding and
                resolve to all-zero rows.
            include_host: also resolve HOST/DISK-tier ids through the host
                callback (the PCIe-analogue slow path). When ``False`` those
                rows come back as zeros (device-only probe).
            dedup: deduplicate + sort ids (``fixed_size_unique``) before
                gathering — the paper's TLB/address-sort optimization.

        Returns:
            ``(M, d)`` feature matrix in the input id order, read from one
            consistent placement snapshot (safe under concurrent
            :meth:`swap_assignments`).
        """
        snap = self._snapshot()
        self._count(lookup_calls=1)
        if dedup:
            uniq, inv = fixed_size_unique(jnp.asarray(ids, jnp.int32),
                                          int(ids.shape[0]))
            rows = self._cached_unique(uniq, include_host, snap, None,
                                       fused=False)
            out = rows[inv]
            return jnp.where((jnp.asarray(ids) >= 0)[:, None], out, 0.0)
        rows = self._cached_unique(jnp.asarray(ids, jnp.int32), include_host,
                                   snap, None, fused=False)
        return jnp.where((jnp.asarray(ids) >= 0)[:, None], rows, 0.0)

    def lookup_hops(self, hops, *, include_host: bool = True,
                    use_pallas: Optional[bool] = None) -> list[jnp.ndarray]:
        """Fused feature collection for a whole layered sample.

        Collapses the per-hop ``[store.lookup(h) for h in hops]`` pattern
        into one pipeline: concatenate all hop id vectors, deduplicate ids
        ONCE across hops, gather the device-resident HOT/WARM tiers with a
        single address-sorted dispatch of the Pallas ``tiered_gather``
        kernel, resolve HOST/DISK ids with a single host callback, and
        scatter rows back into per-hop order. Output is bit-identical to the
        per-hop path (gathers copy rows; no arithmetic is reordered) and
        reads one consistent placement snapshot for the *entire* sample,
        so it is safe under concurrent :meth:`swap_assignments`.

        Args:
            hops: sequence of id vectors (``hops[0]`` the seeds, ``hops[k]``
                the k-th frontier), each ``(M_k,)`` with ``-1`` padding.
                At least one hop must be non-empty.
            include_host: as in :meth:`lookup`.
            use_pallas: force (``True``) or suppress (``False``) the Pallas
                kernel for the device-tier gather; ``None`` picks it on TPU
                and the jnp reference elsewhere (interpret mode is used for
                the kernel off-TPU, so ``True`` is safe on CPU tests).

        Returns:
            List of ``(M_k, d)`` feature matrices, one per hop, matching
            ``[self.lookup(h) for h in hops]`` bit-for-bit.

        Raises:
            ValueError: if ``hops`` is empty or all hops have zero length.
        """
        hops_j = [jnp.asarray(h, jnp.int32).reshape(-1) for h in hops]
        sizes = [int(h.shape[0]) for h in hops_j]
        total = sum(sizes)
        if total == 0:
            raise ValueError("lookup_hops needs at least one non-empty hop")
        snap = self._snapshot()
        self._count(fused_calls=1)
        ids = hops_j[0] if len(hops_j) == 1 else jnp.concatenate(hops_j)
        uniq, inv = fixed_size_unique(ids, total)
        rows = self._cached_unique(uniq, include_host, snap, use_pallas,
                                   fused=True)
        out = jnp.where((ids >= 0)[:, None], rows[inv], 0.0)
        offs = np.concatenate([[0], np.cumsum(sizes)])
        return [out[int(offs[k]):int(offs[k + 1])]
                for k in range(len(sizes))]

    def lookup_aggregate(self, hops, *, include_host: bool = True,
                         use_pallas: Optional[bool] = None,
                         block_rows: int = 8, block_dim: int = 0):
        """Fused feature collection + innermost-hop segment aggregation.

        The innermost hop is the largest tensor of a layered sample and the
        model consumes it exactly once: layer 1 immediately reduces each
        fan-sized child segment into its parent. This entry point folds that
        reduction into the gather itself with the ``gather_aggregate``
        kernel — child rows stream from the HOT/WARM tier buffers (or the
        pre-resolved cold side-table) straight into per-parent accumulators,
        and the dense ``(n_sampled, d)`` neighbor tensor is never
        materialized. Outer hops ride in the same dispatch as singleton
        segments, so the whole sample still costs ONE device gather.

        Cold (HOST/DISK) ids are resolved *before* the kernel through the
        exact machinery :meth:`lookup_hops` uses — device cache probe,
        staging-buffer hit, then at most one ``_host_fetch`` callback (the
        single ``io_callback`` gateway) — into a compact side-table the
        kernel indexes as tier 2, preserving all dispatch counters and the
        one-gateway invariant.

        Tier-equivalence guarantee: the returned aggregate is bit-identical
        to gathering with :meth:`lookup_hops` and reducing in the model
        (``(child * mask).sum(1)``), regardless of how rows are spread
        across HOT/WARM/HOST/DISK tiers or moved by concurrent
        :meth:`swap_assignments` — gathers copy rows and the fused kernel
        accumulates in the same order over the same values.

        Args:
            hops: sequence of ≥ 2 id vectors (seeds first); the innermost
                hop must have ``len(hops[-2]) * fan`` entries, ``-1``
                padding for absent children.
            include_host: as in :meth:`lookup`; ``False`` makes cold
                children contribute zero rows (they still count toward the
                caller's mask-derived segment sizes, as in the unfused
                path).
            use_pallas: kernel dispatch override, as in :meth:`lookup_hops`.
            block_rows: segment-block height of the fused kernel.
            block_dim: feature-dim tile width (0 → untiled); see the
                ``gather_aggregate`` autotune harness.

        Returns:
            ``(feats, agg_sum)``: ``feats`` the ``(M_k, d)`` feature
            matrices for ``hops[:-1]`` (bit-identical to
            ``lookup_hops(hops)[:-1]``), ``agg_sum`` a
            ``(len(hops[-2]), d)`` matrix of per-parent child-row sums —
            divide by the mask count to finish mean aggregation
            (``models.gnn_basic.sage_layered(deep_agg=...)`` does).

        Raises:
            ValueError: fewer than two hops, or the innermost hop is not a
                whole multiple of the previous hop.
        """
        hops_j = [jnp.asarray(h, jnp.int32).reshape(-1) for h in hops]
        sizes = [int(h.shape[0]) for h in hops_j]
        if len(hops_j) < 2:
            raise ValueError(
                "lookup_aggregate needs seeds plus at least one frontier")
        p, n_inner = sizes[-2], sizes[-1]
        if p == 0 or n_inner == 0 or n_inner % p:
            raise ValueError(
                "innermost hop must be a (P*fan,) frontier of the previous "
                f"hop, got sizes {sizes[-2:]}")
        fan = n_inner // p
        total = sum(sizes)
        snap = self._snapshot()
        self._count(fused_calls=1, fused_aggregates=1)
        hot, warm = snap[0], snap[1]
        tier_t, slot_t = snap[4], snap[5]
        ids = jnp.concatenate(hops_j)
        uniq, inv = fixed_size_unique(ids, total)
        uniq_np = np.asarray(uniq)
        valid_u = uniq_np >= 0
        tier_np = np.asarray(tier_t)[np.maximum(uniq_np, 0)]
        slot_np = np.asarray(slot_t)[np.maximum(uniq_np, 0)]
        cold = valid_u & (tier_np >= TIER_HOST)
        cold_idx = np.flatnonzero(cold)
        # per-unique kernel addresses: 0=hot, 1=warm, 2=cold table, 99=skip
        ktier = np.full(total, 99, np.int32)
        ktier[valid_u & (tier_np == TIER_HOT)] = 0
        ktier[valid_u & (tier_np == TIER_WARM)] = 1
        kslot = slot_np.astype(np.int32)
        if include_host and cold_idx.size:
            cold_full = self._cached_unique(uniq, include_host, snap,
                                            use_pallas, fused=True,
                                            cold_only=True)
            # pad the side-table row count to a power of two so the jitted
            # kernel compiles once per bucket, not once per cold count
            kpad = max(1, 1 << (int(cold_idx.size) - 1).bit_length())
            pad_idx = np.zeros(kpad, np.int64)
            pad_idx[:cold_idx.size] = cold_idx
            cold_buf = cold_full[jnp.asarray(pad_idx)]
            ktier[cold] = 2
            kslot[cold] = np.arange(cold_idx.size, dtype=np.int32)
        else:
            # device-only probe (or nothing cold): cold children contribute
            # zero rows, exactly like the unfused include_host=False path
            cold_buf = jnp.zeros((1, self.feat_dim), hot.dtype)
        inner_np = np.asarray(hops_j[-1])
        inv_np = np.asarray(inv)
        inv_inner = inv_np[total - n_inner:]
        # segment matrix: one singleton segment per unique id (recovers the
        # outer-hop feature rows from the same dispatch), then one fan-wide
        # segment per innermost-hop parent. -1 children alias the last
        # unique slot via ``inv``, so they are re-masked to 99 here.
        seg_tier = np.full((total + p, fan), 99, np.int32)
        seg_slot = np.zeros((total + p, fan), np.int32)
        seg_tier[:total, 0] = ktier
        seg_slot[:total, 0] = kslot
        seg_tier[total:] = np.where(inner_np < 0, 99,
                                    ktier[inv_inner]).reshape(p, fan)
        seg_slot[total:] = np.where(inner_np < 0, 0,
                                    kslot[inv_inner]).reshape(p, fan)
        self._count(device_gathers=1)
        out = gather_aggregate(jnp.asarray(seg_tier), jnp.asarray(seg_slot),
                               hot, warm, cold_buf, block_rows=block_rows,
                               block_dim=block_dim, use_pallas=use_pallas)
        rows_u = out[:total]
        agg = out[total:]
        outer = ids[: total - n_inner]
        outer_rows = jnp.where((outer >= 0)[:, None],
                               rows_u[inv[: total - n_inner]], 0.0)
        offs = np.concatenate([[0], np.cumsum(sizes[:-1])])
        feats = [outer_rows[int(offs[k]):int(offs[k + 1])]
                 for k in range(len(sizes) - 1)]
        return feats, agg

    def _cached_unique(self, uniq: jnp.ndarray, include_host: bool,
                       snap: tuple, use_pallas: Optional[bool], *,
                       fused: bool, cold_only: bool = False) -> jnp.ndarray:
        """Route one (deduplicated) id vector through the optional device
        cache, then the tier dispatch for whatever remains.

        Cold-tier (HOST/DISK) ids probe the cache first; hits are blanked
        to ``-1`` in the tier path's id vector, so they never touch the
        tier gather or the host callback. Missed rows flow through the
        normal fused/per-hop pipeline and are admitted into the cache on
        return. When EVERY valid id is a cold cache hit the tier gather is
        skipped entirely — ``device_gathers`` is counted here, at the
        dispatch site, so that fast path is visible in the stats (the
        uncached counts are unchanged: 1 per fused call, 2 per plain
        lookup). ``include_host=False`` bypasses the cache: device-only
        probes must keep returning zeros for cold tiers.

        Bit-identity: cached rows are copies of the same feature values
        and migration moves rows with their nodes, so mixing cache hits
        with tier-path rows can never change a lookup result.
        """
        gathers = 0 if cold_only else (1 if fused else 2)
        if cold_only:
            # lookup_aggregate mode: the fused kernel reads HOT/WARM rows
            # itself, so the tier path only resolves the cold remainder
            tier_path = self._cold_unique
        else:
            tier_path = (partial(self._fused_unique, use_pallas=use_pallas)
                         if fused else self._lookup_unique)
        # lock-free single reference read: any published cache (or None) is
        # valid here — cached rows are copies, so bit-identity cannot break
        cache = self.cache  # quiverlint: disable=lock-discipline atomic reference read, any snapshot valid
        if cache is None or not include_host:
            self._count(device_gathers=gathers)
            return tier_path(uniq, include_host, snap)
        uniq_np = np.asarray(uniq)
        tier_np = np.asarray(snap[4][jnp.maximum(jnp.asarray(uniq), 0)])
        cold = (uniq_np >= 0) & (tier_np >= TIER_HOST)
        if not cold.any():
            self._count(device_gathers=gathers)
            return tier_path(uniq, include_host, snap)
        values, miss_index, miss_ids = cache.query(
            np.where(cold, uniq_np, -1))
        hit = cold.copy()
        hit[miss_index] = False
        self._count(cache_hits=int(hit.sum()),
                    cache_misses=int(miss_index.size))
        if not ((uniq_np >= 0) & ~hit).any():
            return values        # every valid id was a cold cache hit
        uniq_eff = jnp.where(jnp.asarray(hit), jnp.int32(-1),
                             jnp.asarray(uniq, jnp.int32))
        self._count(device_gathers=gathers)
        rows = tier_path(uniq_eff, include_host, snap)
        out = jnp.where(jnp.asarray(hit)[:, None], values, rows)
        if miss_index.size:
            evicted = cache.replace(miss_ids, out[jnp.asarray(miss_index)])
            self._count(cache_evictions=int(evicted))
        return out

    def _fused_unique(self, uniq: jnp.ndarray, include_host: bool,
                      snap: tuple, use_pallas: Optional[bool]) -> jnp.ndarray:
        """One gather per tier class for a deduplicated id vector: the
        HOT/WARM rows stream through ``tiered_gather`` in ascending
        (tier, slot) order — near-sequential DMAs, the paper's TLB
        optimization — and HOST/DISK rows come from the staging buffer
        (prefetch hit) or one ``_host_fetch`` (miss fallback)."""
        hot, warm, host, disk, tier_t, slot_t, stage = snap
        safe = jnp.maximum(uniq, 0)
        tier = tier_t[safe]
        slot = slot_t[safe]
        # address-sort key: tier-major, slot-minor. Slots are clamped into
        # the device-tier span only for key construction (host-tier slots
        # may exceed it; their gather result is zeros either way), which
        # keeps the key within int32 for any store below ~5e8 rows/tier.
        span = jnp.int32(max(int(hot.shape[0]), int(warm.shape[0]), 1))
        key = tier.astype(jnp.int32) * span + jnp.minimum(slot, span - 1)
        order = jnp.argsort(key)
        dev_sorted = tiered_gather(tier[order], slot[order], hot, warm,
                                   use_pallas=use_pallas)
        out = jnp.zeros_like(dev_sorted).at[order].set(dev_sorted)
        if include_host:
            out = self._resolve_cold(uniq, tier, slot, out, host, disk,
                                     stage)
        return jnp.where((uniq >= 0)[:, None], out, 0.0)

    def _cold_unique(self, uniq: jnp.ndarray, include_host: bool,
                     snap: tuple) -> jnp.ndarray:
        """Cold-rows-only tier path for :meth:`lookup_aggregate`: resolve
        HOST/DISK rows through the staging buffer / ``_host_fetch`` gateway
        exactly as the full paths do, but skip the device-tier gather (the
        fused kernel streams HOT/WARM rows straight from the tier buffers).
        Non-cold positions come back as zeros."""
        hot, warm, host, disk, tier_t, slot_t, stage = snap
        safe = jnp.maximum(uniq, 0)
        tier = tier_t[safe]
        slot = slot_t[safe]
        out = jnp.zeros((uniq.shape[0], self.feat_dim), hot.dtype)
        if include_host:
            out = self._resolve_cold(uniq, tier, slot, out, host, disk,
                                     stage)
        return jnp.where((uniq >= 0)[:, None], out, 0.0)

    def _lookup_unique(self, ids: jnp.ndarray, include_host: bool,
                       snap: Optional[tuple] = None) -> jnp.ndarray:
        hot, warm, host, disk, tier_t, slot_t, stage = (
            snap if snap is not None else self._snapshot())
        safe = jnp.maximum(ids, 0)
        tier = tier_t[safe]
        slot = slot_t[safe]
        out = jnp.zeros((ids.shape[0], self.feat_dim), hot.dtype)
        out = jnp.where((tier == TIER_HOT)[:, None],
                        hot[jnp.minimum(slot, hot.shape[0] - 1)], out)
        out = jnp.where((tier == TIER_WARM)[:, None],
                        warm[jnp.minimum(slot, warm.shape[0] - 1)],
                        out)
        if include_host:
            out = self._resolve_cold(ids, tier, slot, out, host, disk,
                                     stage)
        return jnp.where((ids >= 0)[:, None], out, 0.0)

    def _resolve_cold(self, ids: jnp.ndarray, tier: jnp.ndarray,
                      slot: jnp.ndarray, out: jnp.ndarray, host, disk,
                      stage: Optional[tuple]) -> jnp.ndarray:
        """Resolve HOST/DISK-tier rows of one id vector.

        Staged ids (prefetched into the device-side buffer) are gathered
        from device memory — no host round-trip; the rest fall back to the
        synchronous ``_host_fetch`` callback. When every cold id is staged
        (or there are none) the callback is skipped entirely, which is the
        whole point of the prefetcher: zero critical-path host callbacks.
        Hit/miss/disk counters land in the dispatch stats; staged rows are
        bit-identical to the host/disk rows (they are copies of the same
        float values), so this path never changes lookup results.
        """
        ids_np = np.asarray(ids)
        tier_np = np.asarray(tier)
        cold = (tier_np >= TIER_HOST) & (ids_np >= 0)
        if not cold.any():
            return out
        miss = cold
        if stage is not None:
            stage_slot, stage_rows = stage
            sslot = stage_slot[np.maximum(ids_np, 0)]
            hit = cold & (sslot >= 0)
            miss = cold & ~hit
            self._count(prefetch_hits=int(hit.sum()),
                        prefetch_misses=int(miss.sum()))
            if hit.any():
                # full-width gather + where keeps the shapes static (one
                # compile per id-bucket, like the host path) — a dynamic
                # hit-index scatter would recompile on every hit count
                gathered = stage_rows[jnp.asarray(np.maximum(sslot, 0))]
                out = jnp.where(jnp.asarray(hit)[:, None], gathered, out)
        if miss.any():
            disk_miss = miss & (tier_np == TIER_DISK)
            n_disk = int(disk_miss.sum())
            self._count(host_fetches=1, disk_misses=n_disk,
                        spill_reads=n_disk)
            if n_disk:
                with self._stats_lock:
                    if self._disk_miss_counts is not None:
                        np.add.at(self._disk_miss_counts, ids_np[disk_miss],
                                  1)
            # mask the staged positions out of the callback's tier vector so
            # it only gathers the rows that actually missed
            tier_eff = jnp.asarray(np.where(miss, tier_np, -1)
                                   .astype(np.int32))
            rows = self._host_fetch(ids, tier_eff, slot, host, disk)
            out = jnp.where(jnp.asarray(miss)[:, None], rows, out)
        return out

    def _host_fetch(self, ids, tier, slot, host=None, disk=None):
        """PCIe-analogue slow path: host callback, ids sorted by address
        (the paper's TLB optimization) before the gather."""
        if host is None:
            # one coherent snapshot — reading the two attributes directly
            # could tear across a concurrent migration publish
            _, _, host, disk, _, _, _ = self._snapshot()

        def cb(tier_np, slot_np):
            tier_np = np.asarray(tier_np)
            slot_np = np.asarray(slot_np)
            out = np.zeros((tier_np.shape[0], host.shape[1]), host.dtype)
            m_h = tier_np == TIER_HOST
            m_d = tier_np == TIER_DISK
            # address-sorted gathers
            for m, store in ((m_h, host), (m_d, disk)):
                idx = np.flatnonzero(m)
                if idx.size:
                    order = np.argsort(slot_np[idx])
                    rows = store[slot_np[idx][order]]
                    out[idx[order]] = rows
            return out

        return io_callback(
            cb, jax.ShapeDtypeStruct((ids.shape[0], self.feat_dim),
                                     host.dtype), tier, slot,
            ordered=False)

    # -- prefetch staging ----------------------------------------------------
    def publish_stage(self, stage_slot: Optional[np.ndarray],
                      stage_rows) -> None:
        """Atomically publish (or clear) the prefetch staging state.

        Args:
            stage_slot: ``(N,)`` int32 host-side table mapping node id →
                row in ``stage_rows`` (``-1`` = unstaged), or ``None`` to
                clear the stage.
            stage_rows: ``(budget, d)`` device-side staging buffer holding
                the prefetched cold rows (ignored when ``stage_slot`` is
                ``None``).

        Published under the migration lock like a placement snapshot:
        in-flight lookups keep resolving against the previous stage, new
        lookups see the new one — never a torn mix.
        """
        stage = None if stage_slot is None else (stage_slot, stage_rows)
        with self._mig_lock:
            self._stage = stage

    def staged_rows(self) -> int:
        """Number of cold rows currently staged on device (0 = no stage)."""
        with self._mig_lock:
            stage = self._stage
        return 0 if stage is None else int((stage[0] >= 0).sum())

    def read_cold_rows(self, ids: np.ndarray) -> np.ndarray:
        """Read the feature rows of ``ids`` for staging, OFF the critical
        path (plain host-side reads, no device round-trip for cold tiers).

        Each row is read from whichever tier currently holds it under one
        consistent snapshot, so a migration racing the prefetcher still
        yields exact values (rows travel with nodes; values never change).
        DISK reads are counted as ``spill_reads``.

        Args:
            ids: ``(K,)`` valid node ids (no ``-1`` padding).

        Returns:
            ``(K, d)`` feature rows in ``ids`` order.
        """
        hot, warm, host, disk, tier_t, slot_t, _ = self._snapshot()
        ids = np.asarray(ids)
        tier = np.asarray(tier_t)[ids]
        slot = np.asarray(slot_t)[ids]
        out = np.zeros((ids.shape[0], self.feat_dim),
                       np.asarray(host).dtype)
        m_host, m_disk = tier == TIER_HOST, tier == TIER_DISK
        if m_host.any():
            out[m_host] = host[slot[m_host]]
        if m_disk.any():
            out[m_disk] = disk[slot[m_disk]]
            self._count(spill_reads=int(m_disk.sum()))
        m_dev = ~(m_host | m_disk)  # raced a promotion: read device tiers
        if m_dev.any():
            hot_np, warm_np = np.asarray(hot), np.asarray(warm)
            for i in np.flatnonzero(m_dev):
                src = hot_np if tier[i] == TIER_HOT else warm_np
                out[i] = src[min(int(slot[i]), src.shape[0] - 1)]
        return out

    # -- miss-driven promotion -----------------------------------------------
    def promote_misses(self, *, budget: int = 32, min_misses: int = 1) -> int:
        """Swap the most-missed DISK rows up into the HOST tier.

        Candidates are DISK-tier nodes with at least ``min_misses``
        critical-path misses since the last promotion, hottest first;
        victims are HOST-tier rows with the fewest recorded misses, coldest
        build rank (highest slot) first. Swaps ride the existing
        :meth:`swap_assignments` machinery, so tier counts, capacity and
        the lookup-equivalence invariant are all preserved and concurrent
        lookups keep serving from the previous snapshot.

        Args:
            budget: max node pairs to exchange this call.
            min_misses: miss-count threshold for promotion.

        Returns:
            Number of feature rows moved (``2 *`` pairs swapped), also
            accumulated into :attr:`promoted_rows` / :attr:`migrated_rows`.
        """
        with self._stats_lock:
            if self._disk_miss_counts is None:
                return 0
            counts = self._disk_miss_counts.copy()
        # tier/slot must come from one coherent snapshot: reading them in
        # two separate attribute loads can tear across a migration publish
        # and pair a node's new tier with its old slot
        _, _, _, _, tier_t, slot_t, _ = self._snapshot()
        tier = np.asarray(tier_t)
        cand = np.flatnonzero((tier == TIER_DISK) & (counts >= min_misses))
        hosts = np.flatnonzero(tier == TIER_HOST)
        if not cand.size or not hosts.size:
            return 0
        cand = cand[np.argsort(-counts[cand], kind="stable")][:budget]
        slot = np.asarray(slot_t)
        victims = hosts[np.lexsort((-slot[hosts], counts[hosts]))]
        k = min(cand.size, victims.size)
        pairs = list(zip(cand[:k].tolist(), victims[:k].tolist()))
        moved = self.swap_assignments(pairs)
        with self._stats_lock:
            self._disk_miss_counts[cand[:k]] = 0
            self.promoted_rows += moved
        return moved

    def tier_histogram(self, ids: np.ndarray) -> dict[str, int]:
        ids = np.asarray(ids)
        ids = ids[ids >= 0]
        t = self.plan.tier[ids]
        return {"hot": int((t == TIER_HOT).sum()),
                "warm": int((t == TIER_WARM).sum()),
                "host": int((t == TIER_HOST).sum()),
                "disk": int((t == TIER_DISK).sum())}

    # -- online migration ----------------------------------------------------
    def swap_assignments(self, pairs: list[tuple[int, int]]) -> int:
        """Exchange the complete (tier, slot, owner) assignments — and the
        stored feature rows — of disjoint node pairs, atomically w.r.t.
        concurrent :meth:`lookup` / :meth:`lookup_hops`.

        Each node inherits its partner's placement wholesale, so per-tier
        counts, per-device capacity and the owner-major warm layout are all
        preserved; ``lookup(i)`` returns bit-identical features before,
        during and after the swap (the lookup-equivalence invariant — the
        rows travel with the nodes). New arrays are built copy-on-write and
        published under the migration lock; in-flight lookups keep reading
        the previous snapshot.

        Args:
            pairs: ``(a, b)`` node-id pairs to exchange. Node ids must be
                pairwise disjoint across all pairs.

        Returns:
            Number of feature rows moved (``2 * len(pairs)``), also
            accumulated into :attr:`migrated_rows`.

        Raises:
            ValueError: if any node id appears in more than one pair.
        """
        if not pairs:
            return 0
        flat = [n for ab in pairs for n in ab]
        if len(set(flat)) != len(flat):
            raise ValueError("migration pairs must be disjoint")

        tier = np.asarray(self.tier_t).copy()
        slot = np.asarray(self.slot_t).copy()
        owner = np.asarray(self.owner_t).copy()
        stores = {TIER_HOT: self.hot, TIER_WARM: self.warm,
                  TIER_HOST: self.host, TIER_DISK: self.disk}

        # 1) read every feature row out of its current tier store
        feat = {n: np.asarray(stores[int(tier[n])][int(slot[n])])
                for n in flat}

        # 2) exchange table entries — all on copies (plan arrays too, so a
        #    failure anywhere before publish leaves the store untouched and
        #    plan never disagrees with the live tier tables)
        plan = self.plan
        p_tier, p_slot = plan.tier.copy(), plan.slot.copy()
        p_pod, p_dev = plan.pod_owner.copy(), plan.device_owner.copy()
        for a, b in pairs:
            for table in (tier, slot, owner, p_tier, p_slot, p_pod, p_dev):
                table[a], table[b] = table[b], table[a]

        # 3) write each row into its new home, copy-on-write per tier store
        writes: dict[int, tuple[list[int], list[np.ndarray]]] = {}
        for n in flat:
            rows, vals = writes.setdefault(int(tier[n]), ([], []))
            rows.append(int(slot[n]))
            vals.append(feat[n])
        new_stores = dict(stores)
        for t, (rows, vals) in writes.items():
            arr = stores[t]
            vals_np = np.stack(vals)
            if isinstance(arr, jnp.ndarray):
                new_stores[t] = arr.at[np.asarray(rows)].set(
                    jnp.asarray(vals_np, arr.dtype))
            else:
                arr = arr.copy()
                arr[np.asarray(rows)] = vals_np
                # bound the spill tier's RAM overlay under demotion churn:
                # fold it back into a fresh spill-file generation once it
                # outgrows an eighth of the tier
                if (isinstance(arr, DiskSpillTier)
                        and arr.overlay_rows > max(64, len(arr) // 8)):
                    arr = arr.compact()
                new_stores[t] = arr

        # 4) publish the new snapshot (tier tables + plan) atomically
        with self._mig_lock:
            self.hot = new_stores[TIER_HOT]
            self.warm = new_stores[TIER_WARM]
            self.host = new_stores[TIER_HOST]
            self.disk = new_stores[TIER_DISK]
            self.tier_t = jnp.asarray(tier, jnp.int32)
            self.slot_t = jnp.asarray(slot, jnp.int32)
            self.owner_t = jnp.asarray(owner, jnp.int32)
            plan.tier, plan.slot = p_tier, p_slot
            plan.pod_owner, plan.device_owner = p_pod, p_dev
            self.migrated_rows += 2 * len(pairs)
            cache = self.cache
        # invalidate ONLY the migrated rows from the device cache: a node
        # promoted into HBM must stop holding cache capacity. Correctness
        # never depends on this — rows travel with their nodes, so even a
        # lookup racing between publish and invalidate reads exact values.
        if cache is not None:
            cache.invalidate(flat)
        return 2 * len(pairs)


# ---------------------------------------------------------------------------
# Distributed store: shard_map one-sided reads over the mesh
# ---------------------------------------------------------------------------

# Canonical stats schema for ShardedFeatureStore dispatch accounting —
# mirrored by the `sharded-schema` table in docs/invariants.md and
# cross-checked against the class's stats declaration by quiverlint's
# schema-sync pass.
SHARDED_STATS_SCHEMA: tuple = (
    "exchanges", "exchanged_ids", "stage_hits", "stage_misses",
    "host_fetches", "cold_rows", "spill_reads")


def _new_sharded_stats() -> dict[str, int]:
    """Dispatch accounting for the sharded exchange (schema:
    ``SHARDED_STATS_SCHEMA``; benchmark signal:
    ``benchmarks/sharded_hierarchy.py``):

      exchanges        dedup ``all_to_all`` exchanges dispatched
      exchanged_ids    distinct (device, id) pairs moved through the
                       exchange — an id duplicated across hops costs one
                       entry however many positions repeat it
      stage_hits       cold id occurrences resolved from a per-shard
                       staging buffer inside the exchange
      stage_misses     cold id occurrences that fell through to the
                       host-side miss path
      host_fetches     host-side cold fetch round-trips actually issued
                       (a lookup whose cold ids are all staged issues 0)
      cold_rows        id occurrences those fetches resolved
      spill_reads      rows read from the per-shard DISK spill files
    """
    return {"exchanges": 0, "exchanged_ids": 0, "stage_hits": 0,
            "stage_misses": 0, "host_fetches": 0, "cold_rows": 0,
            "spill_reads": 0}


class ShardedFeatureStore:
    """Feature store laid out over a device mesh axis.

    hot  : (n_hot, d) replicated
    warm : (world * rows_per_dev, d) sharded on axis 0 over ``axis_name``

    Lookup runs under ``shard_map``, with two exchange strategies:

    ``"alltoall"`` (default) — the owner-sorted, capacity-bounded dedup
    exchange. Ids are deduplicated host-side across *all* hops of a
    sample, sorted by owner, padded to a pow2 per-(device, owner)
    capacity, and moved through two untiled ``jax.lax.all_to_all``
    collectives (requests out, rows back — the RDMA-read analogue: only
    distinct rows travel). Cold (HOST/DISK) ids resolve from per-shard
    staging buffers *inside* the same exchange when staged
    (:meth:`publish_stage`); only actual misses fall back to one
    host-side fetch (:meth:`read_cold_rows`) merged after the exchange.

    ``"allgather"`` (legacy) — allgather every wanted warm slot, owners
    answer, ``psum_scatter`` returns each requester's rows; every
    occurrence is exchanged and cold ids are resolved by a host
    post-pass.

    Both strategies are bit-identical to each other, to per-hop calls
    and to the single-host :class:`TieredFeatureStore` — rows are moved
    and selected, never operated on. Built via :meth:`from_tiered` the
    store keeps a reference to the source store for host fetches, and
    optionally per-shard :class:`DiskSpillTier` files (``spill_dir=``)
    so each shard owns its cold rows. Directly-constructed stores (no
    tiered source) keep the documented zeros behavior for cold ids.
    Dispatch counters land in :attr:`stats` (schema
    ``SHARDED_STATS_SCHEMA``), which the serving engine snapshots into
    ``ServeMetrics.summary()["store"]``.
    """

    def __init__(self, mesh: Mesh, axis_name: str, hot: jnp.ndarray,
                 warm: jnp.ndarray, tier_t: jnp.ndarray, slot_t: jnp.ndarray,
                 owner_t: jnp.ndarray, strategy: str = "alltoall"):
        self.mesh, self.axis = mesh, axis_name
        self.world = int(np.prod([mesh.shape[a] for a in
                                  (axis_name if isinstance(axis_name, tuple)
                                   else (axis_name,))]))
        if self.world and warm.shape[0] % self.world:
            raise ValueError(
                f"warm.shape[0] ({warm.shape[0]}) must be divisible by the "
                f"mesh world size ({self.world}) — a ragged warm buffer "
                f"would silently truncate the last shard")
        if strategy not in ("alltoall", "allgather"):
            raise ValueError(f"unknown exchange strategy {strategy!r} "
                             f"(want 'alltoall' or 'allgather')")
        self.rows_per_dev = warm.shape[0] // max(self.world, 1)
        self.strategy = strategy
        rep = NamedSharding(mesh, P())
        shard0 = NamedSharding(mesh, P(axis_name))
        self.hot = jax.device_put(hot, rep)
        self.warm = jax.device_put(warm, shard0)
        self.tier_t = jax.device_put(tier_t, rep)
        self.slot_t = jax.device_put(slot_t, rep)
        self.owner_t = jax.device_put(owner_t, rep)
        self.feat_dim = hot.shape[1]
        # host-side table mirrors (static — the sharded store never
        # migrates) so per-lookup prep costs no device round-trips
        self._tier_np = np.asarray(tier_t)
        self._slot_np = np.asarray(slot_t).astype(np.int64)
        self._owner_np = np.asarray(owner_t).astype(np.int64)
        self._has_cold = bool((self._tier_np >= TIER_HOST).any())
        self._tiered: Optional[TieredFeatureStore] = None
        self._spill: Optional[list] = None
        self._spill_slot: Optional[np.ndarray] = None
        self._spill_dtype = np.dtype(np.float32)
        self._stage = None
        self._stage_lock = threading.Lock()
        self.stats = _new_sharded_stats()
        self._stats_lock = threading.Lock()

    @staticmethod
    def from_tiered(store: TieredFeatureStore, mesh: Mesh, axis_name: str,
                    strategy: str = "alltoall", *,
                    spill_dir: Optional[str] = None) -> "ShardedFeatureStore":
        topo = store.plan.topology
        world = topo.num_pods * topo.devices_per_pod
        mesh_world = int(np.prod([mesh.shape[a] for a in
                                  (axis_name if isinstance(axis_name, tuple)
                                   else (axis_name,))]))
        assert world == mesh_world, (world, mesh_world)
        # pad warm shards to equal size and rebuild the slot table against
        # the padded bases
        rows = store.warm.shape[0]
        per = -(-rows // world)
        counts = np.diff(np.append(np.asarray(store.warm_base), rows))
        owner = np.asarray(store.owner_t)
        slot = np.asarray(store.slot_t).astype(np.int64)
        tier = np.asarray(store.tier_t)
        base = np.asarray(store.warm_base).astype(np.int64)
        warm_np = np.zeros((per * world, store.feat_dim),
                           np.asarray(store.warm).dtype)
        src = np.asarray(store.warm)
        new_slot = slot.copy()
        for w in range(world):
            c = int(counts[w])
            warm_np[w * per: w * per + c] = src[base[w]: base[w] + c]
            m = (tier == TIER_WARM) & (owner == w)
            new_slot[m] = slot[m] - base[w] + w * per
        ss = ShardedFeatureStore(
            mesh, axis_name, store.hot, jnp.asarray(warm_np),
            store.tier_t, jnp.asarray(new_slot, dtype=jnp.int32),
            store.owner_t, strategy)
        ss._tiered = store    # cold-tier (HOST/DISK) host-fetch miss path
        if spill_dir is not None:
            ss._attach_spill(store, spill_dir)
        return ss

    def _attach_spill(self, store: TieredFeatureStore, spill_dir) -> None:
        """Build one per-shard :class:`DiskSpillTier` file per mesh device
        (shard ``w`` owns the DISK rows of ids with ``id % world == w``)
        plus the id → shard-local-row table the miss path reads through.
        Rows are copied at build time and stay exact under concurrent
        source-store migration: swaps move placements, never values."""
        world = max(self.world, 1)
        os.makedirs(spill_dir, exist_ok=True)
        n = self._tier_np.shape[0]
        spill_slot = np.full(n, -1, np.int32)
        tiers: list = []
        disk_ids = np.flatnonzero(self._tier_np == TIER_DISK)
        for w in range(world):
            ids_w = disk_ids[disk_ids % world == w]
            if ids_w.size == 0:
                tiers.append(None)
                continue
            rows = store.read_cold_rows(ids_w)
            path = os.path.join(spill_dir, f"shard{w:03d}.spill")
            tiers.append(DiskSpillTier.build(rows, path))
            spill_slot[ids_w] = np.arange(ids_w.size, dtype=np.int32)
            self._spill_dtype = rows.dtype
        self._spill = tiers
        self._spill_slot = spill_slot

    def read_cold_rows(self, ids: np.ndarray) -> np.ndarray:
        """Host-side exact reader for cold (HOST/DISK) rows — the dedup
        exchange's miss path and the staging source a
        :class:`~repro.core.prefetch.Prefetcher` reads through. DISK rows
        come from this store's per-shard spill files when built with
        ``from_tiered(..., spill_dir=...)`` (counted as ``spill_reads``);
        everything else — HOST rows, rows without a per-shard file, raced
        promotions — delegates to the source store's
        :meth:`TieredFeatureStore.read_cold_rows`. Plain numpy end to
        end, never an ``io_callback``: quiverlint's callback pass pins
        this as the only host-data route out of the sharded hot path.
        Without a tiered source (directly-constructed store) cold rows
        read as zeros."""
        ids = np.asarray(ids).reshape(-1)
        if self._spill is None or self._spill_slot is None:
            if self._tiered is None:
                return np.zeros((ids.shape[0], self.feat_dim),
                                self._spill_dtype)
            return self._tiered.read_cold_rows(ids)
        world = max(self.world, 1)
        safe = np.maximum(ids, 0)
        srow = self._spill_slot[safe]
        local = (ids >= 0) & (self._tier_np[safe] == TIER_DISK) & (srow >= 0)
        out = np.zeros((ids.shape[0], self.feat_dim), self._spill_dtype)
        if local.any():
            idx = np.flatnonzero(local)
            own = safe[idx] % world
            for w in np.unique(own):
                sel = idx[own == w]
                out[sel] = self._spill[int(w)][srow[sel]]
            with self._stats_lock:
                self.stats["spill_reads"] += int(local.sum())
        rest = (ids >= 0) & ~local
        if rest.any() and self._tiered is not None:
            out[rest] = self._tiered.read_cold_rows(ids[rest])
        return out

    def publish_stage(self, stage_slot, stage_rows) -> None:
        """Publish (``stage_slot, stage_rows``) or clear (``None, None``)
        the per-shard staging buffers. Accepts the global ``(N,)``
        id → staged-row layout the
        :class:`~repro.core.prefetch.Prefetcher` publishes (the
        :meth:`TieredFeatureStore.publish_stage` contract) and re-bins it
        per shard: cold id ``i`` goes to shard ``i % world``, every shard
        is padded to a shared pow2 row capacity, and the buffer is
        device_put sharded over the mesh axis — so the dedup exchange
        resolves staged cold ids with the exact same ``all_to_all`` that
        serves WARM rows, and one unmodified prefetcher feeds every
        shard."""
        if stage_slot is None or stage_rows is None:
            with self._stage_lock:
                self._stage = None
            return
        world = max(self.world, 1)
        stage_slot = np.asarray(stage_slot)
        rows_all = np.asarray(stage_rows)
        ids = np.flatnonzero(stage_slot >= 0)
        if ids.size == 0:
            with self._stage_lock:
                self._stage = None
            return
        rows = rows_all[stage_slot[ids]]
        owner = ids % world
        order = np.argsort(owner, kind="stable")
        ids_o, own_o = ids[order], owner[order]
        counts = np.bincount(own_o, minlength=world)
        cap = 1 << max(int(counts.max()) - 1, 0).bit_length()
        starts = np.zeros(world, np.int64)
        np.cumsum(counts[:-1], out=starts[1:])
        rank = np.arange(ids_o.size) - starts[own_o]
        local = np.full(stage_slot.shape[0], -1, np.int32)
        local[ids_o] = rank
        buf = np.zeros((world * cap, rows.shape[1]), rows.dtype)
        buf[own_o * cap + rank] = rows[order]
        buf_dev = jax.device_put(jnp.asarray(buf),
                                 NamedSharding(self.mesh, P(self.axis)))
        with self._stage_lock:
            self._stage = (local, buf_dev, int(cap))

    @property
    def tier_table_host(self) -> np.ndarray:
        """Host-side mirror of the per-node tier table. Static — the
        sharded store never migrates — so callers (the prefetcher's
        predict step, the cold post-pass gate) read it without a
        device→host transfer."""
        return self._tier_np

    def staged_rows(self) -> int:
        """Rows currently staged across all shards (0 with no stage)."""
        with self._stage_lock:
            stage = self._stage
        if stage is None:
            return 0
        return int((stage[0] >= 0).sum())

    def _snapshot_stage(self):
        with self._stage_lock:
            return self._stage

    def snapshot_stats(self) -> dict[str, int]:
        """Coherent copy of the dispatch counters."""
        with self._stats_lock:
            return dict(self.stats)

    def reset_stats(self) -> dict[str, int]:
        """Snapshot and zero the dispatch counters (benchmark windows)."""
        with self._stats_lock:
            out = dict(self.stats)
            for k in out:
                self.stats[k] = 0
        return out

    def _check_world_multiple(self, m: int, what: str) -> None:
        world = max(self.world, 1)
        if m == 0 or m % world:
            raise ValueError(
                f"{what} = {m} must be a non-zero multiple of the mesh "
                f"world size ({world}) so each device's shard is static — "
                f"pad with -1 (executor padding guarantees this)")

    def lookup(self, ids: jnp.ndarray) -> jnp.ndarray:
        """ids: (world * m,) global ids sharded over the axis (each device
        resolves m requests; ``-1`` pads to zeros). Returns
        (world * m, d) with the same sharding — bit-identical across
        strategies and to the single-host tiered store, HOST/DISK ids
        included.

        Raises:
            ValueError: when ``len(ids)`` is zero or not a multiple of
                the mesh world size (the per-device shard must be
                static)."""
        ids = jnp.asarray(ids).reshape(-1)
        self._check_world_multiple(int(ids.shape[0]), "len(ids)")
        if self.strategy == "allgather":
            return self._lookup_allgather(ids)
        return self._lookup_dedup(ids)

    def lookup_hops(self, hops) -> list[jnp.ndarray]:
        """Fused multi-hop variant of :meth:`lookup`: ONE exchange over
        the concatenated hop ids, rows scattered back per hop. Under the
        default ``"alltoall"`` strategy the ids are deduplicated across
        hops *before* the exchange, so a neighbor appearing in several
        hop frontiers crosses the interconnect once and its row fans back
        out through the inverse permutation — still bit-identical to
        per-hop calls.

        Args:
            hops: sequence of ``(M_k,)`` id vectors, each with ``-1``
                padding; every ``M_k`` must be a non-zero multiple of the
                mesh world size (executor padding guarantees this).

        Returns:
            List of ``(M_k, d)`` feature matrices, one per hop.

        Raises:
            ValueError: when any hop length is zero or not a multiple of
                the mesh world size — raised eagerly with the offending
                hop named, instead of failing opaquely inside
                ``shard_map``."""
        hops_j = [jnp.asarray(h).reshape(-1) for h in hops]
        if not hops_j:
            raise ValueError("lookup_hops needs at least one hop")
        sizes = [int(h.shape[0]) for h in hops_j]
        for k, s in enumerate(sizes):
            self._check_world_multiple(s, f"hop {k} length")
        ids = hops_j[0] if len(hops_j) == 1 else jnp.concatenate(hops_j)
        out = (self._lookup_allgather(ids) if self.strategy == "allgather"
               else self._lookup_dedup(ids))
        offs = np.concatenate([[0], np.cumsum(sizes)])
        return [out[int(offs[k]):int(offs[k + 1])]
                for k in range(len(sizes))]

    def _lookup_allgather(self, ids: jnp.ndarray) -> jnp.ndarray:
        """Legacy exchange: allgather every wanted warm slot, owners
        answer, ``psum_scatter`` returns each requester's rows; cold ids
        are resolved by a host-side post-pass. Kept as the baseline the
        ``sharded_hierarchy`` benchmark measures the dedup exchange
        against."""
        axis = self.axis
        per = self.rows_per_dev

        def allgather_body(hot, warm, tier_t, slot_t, owner_t, ids_l):
            my = jax.lax.axis_index(axis)
            safe = jnp.maximum(ids_l, 0)
            tier = tier_t[safe]
            slot = slot_t[safe]
            out = jnp.zeros((ids_l.shape[0], self.feat_dim), hot.dtype)
            out = jnp.where((tier == TIER_HOT)[:, None],
                            hot[jnp.minimum(slot, hot.shape[0] - 1)], out)
            is_warm = tier == TIER_WARM
            local = is_warm & (owner_t[safe] == my)
            lrow = jnp.clip(slot - my * per, 0, per - 1)
            out = jnp.where(local[:, None], warm[lrow], out)
            remote = is_warm & ~local
            # one-sided read: every device publishes its wanted global warm
            # rows; owners answer; reduce_scatter returns each requester's rows
            want_slot = jnp.where(remote, slot, -1)
            all_want = jax.lax.all_gather(want_slot, axis)      # (W, m)
            owned = (all_want >= my * per) & (all_want < (my + 1) * per)
            rows = warm[jnp.clip(all_want - my * per, 0, per - 1)]
            rows = jnp.where(owned[..., None], rows, 0.0)        # (W, m, d)
            answered = jax.lax.psum_scatter(rows, axis, scatter_dimension=0,
                                            tiled=False)         # (m, d)
            answered = answered.reshape(ids_l.shape[0], self.feat_dim)
            out = jnp.where(remote[:, None], answered, out)
            return jnp.where((ids_l >= 0)[:, None], out, 0.0)

        fn = shard_map(
            allgather_body, mesh=self.mesh,
            in_specs=(P(), P(axis), P(), P(), P(), P(axis)),
            out_specs=P(axis))
        out = fn(self.hot, self.warm, self.tier_t, self.slot_t, self.owner_t,
                 ids)
        # cold (HOST/DISK) post-pass. The static tier mirror gates the
        # device→host transfer of the id vector: a store with no cold
        # tiers at all never pays it.
        if self._tiered is None or not self._has_cold:
            return out
        ids_np = np.asarray(ids).reshape(-1)
        cold = (ids_np >= 0) & (self._tier_np[np.maximum(ids_np, 0)]
                                >= TIER_HOST)
        if not cold.any():
            return out
        rows = np.zeros((ids_np.shape[0], self.feat_dim),
                        dtype=np.dtype(out.dtype))
        rows[cold] = self._tiered.read_cold_rows(ids_np[cold])
        with self._stats_lock:
            self.stats["host_fetches"] += 1
            self.stats["cold_rows"] += int(cold.sum())
        shard0 = NamedSharding(self.mesh, P(self.axis))
        rows_j = jax.device_put(jnp.asarray(rows, out.dtype), shard0)
        mask = jax.device_put(jnp.asarray(cold), shard0)
        return jnp.where(mask[:, None], rows_j, out)

    def _lookup_dedup(self, ids: jnp.ndarray) -> jnp.ndarray:
        """Owner-sorted, capacity-bounded dedup exchange (strategy
        ``"alltoall"``).

        Host-side prep: each device's slice of the request vector is
        deduplicated (across every hop of a fused sample), classified per
        tier, and the distinct WARM/staged-cold ids are sorted by owner
        into a ``(world, world, cap)`` request tensor — ``cap`` is the
        pow2 ceiling of the max per-(device, owner) count, so recompiles
        stay bounded while shapes stay static. Inside ``shard_map`` the
        requests move to their owners with one untiled ``all_to_all``,
        owners answer with a single local gather from
        ``concat(warm_shard, stage_shard)``, a second ``all_to_all``
        carries the rows back, and an inverse permutation scatters each
        device's distinct rows to its request positions. HOT rows gather
        from the replicated buffer; cold ids without a staged row fall
        back to one host-side :meth:`read_cold_rows` fetch merged after
        the exchange — the miss path, counted only when actually issued.
        Rows are moved and selected, never summed, which is what keeps
        every path bit-identical."""
        world = max(self.world, 1)
        per = self.rows_per_dev
        d = self.feat_dim
        ids_np = np.asarray(ids).reshape(-1).astype(np.int64)
        m = ids_np.shape[0]
        m_dev = m // world
        stage = self._snapshot_stage()
        stage_local, stage_buf, _stage_cap = (
            stage if stage is not None else (None, None, 1))

        safe = np.maximum(ids_np, 0)
        tier = self._tier_np[safe]
        valid = ids_np >= 0
        is_hot = valid & (tier == TIER_HOT)
        is_warm = valid & (tier == TIER_WARM)
        is_cold = valid & (tier >= TIER_HOST)
        staged = (is_cold & (stage_local[safe] >= 0)
                  if stage_local is not None
                  else np.zeros(m, dtype=bool))
        exch = is_warm | staged
        miss = is_cold & ~staged

        # owner + owner-local row into concat(warm_shard, stage_shard);
        # values at non-exchange positions are never read
        owner = np.where(is_warm, self._owner_np[safe], safe % world)
        lrow = np.where(is_warm, self._slot_np[safe] - owner * per,
                        per + (stage_local[safe]
                               if stage_local is not None else 0))
        # per-device cross-hop dedup: device i requests each distinct id
        # in its slice once, whatever the hop multiplicity
        dev = np.repeat(np.arange(world), m_dev)
        eidx = np.flatnonzero(exch)
        n = self._tier_np.shape[0]
        pair = dev[eidx] * (n + 1) + ids_np[eidx]
        upair, urep, uinv = np.unique(pair, return_index=True,
                                      return_inverse=True)
        rep = eidx[urep]
        u_dev, u_own, u_row = dev[rep], owner[rep], lrow[rep]
        # owner-sort within each device (address-sorted requests);
        # cap = pow2 ceiling of the max per-(device, owner) count
        order = np.lexsort((u_row, u_own, u_dev))
        sd, so, sr = u_dev[order], u_own[order], u_row[order]
        grp = sd * world + so
        first = np.ones(grp.shape[0], dtype=bool)
        first[1:] = grp[1:] != grp[:-1]
        gstart = np.flatnonzero(first)
        glen = np.diff(np.append(gstart, grp.shape[0]))
        rank = np.arange(grp.shape[0]) - np.repeat(gstart, glen)
        cmax = int(glen.max()) if glen.size else 0
        cap = 1 << max(cmax - 1, 0).bit_length()
        req = np.full((world * world, cap), -1, np.int32)
        req[sd * world + so, rank] = sr
        # per-unique index into its requesting device's flat (world*cap)
        # answer buffer, then fanned out to every request position
        sel_u = np.zeros(upair.shape[0], np.int64)
        sel_u[order] = so * cap + rank
        sel = np.full(m, -1, np.int64)
        sel[eidx] = sel_u[uinv]
        hslot = np.where(is_hot, self._slot_np[safe], -1)

        with self._stats_lock:
            self.stats["exchanges"] += 1
            self.stats["exchanged_ids"] += int(upair.shape[0])
            self.stats["stage_hits"] += int(staged.sum())
            self.stats["stage_misses"] += int(miss.sum())

        axis = self.axis
        stage_g = (stage_buf if stage_buf is not None
                   else jnp.zeros((world, d), self.warm.dtype))

        def exchange_body(hot, warm_l, stage_l, req_l, sel_l, hslot_l):
            buf = jnp.concatenate([warm_l, stage_l], axis=0)
            incoming = jax.lax.all_to_all(req_l, axis, 0, 0)    # (W, cap)
            ans = buf[jnp.clip(incoming, 0, buf.shape[0] - 1)]  # (W, cap, d)
            back = jax.lax.all_to_all(ans, axis, 0, 0)
            flat = back.reshape(world * cap, d)
            out = jnp.zeros((sel_l.shape[0], d), hot.dtype)
            out = jnp.where((hslot_l >= 0)[:, None],
                            hot[jnp.clip(hslot_l, 0, hot.shape[0] - 1)], out)
            return jnp.where((sel_l >= 0)[:, None],
                             flat[jnp.clip(sel_l, 0, flat.shape[0] - 1)],
                             out)

        fn = shard_map(
            exchange_body, mesh=self.mesh,
            in_specs=(P(), P(axis), P(axis), P(axis), P(axis), P(axis)),
            out_specs=P(axis))
        out = fn(self.hot, self.warm, stage_g, jnp.asarray(req),
                 jnp.asarray(sel, dtype=jnp.int32),
                 jnp.asarray(hslot, dtype=jnp.int32))
        if not miss.any():
            return out
        if self._tiered is None and self._spill is None:
            return out    # no cold source: documented zeros behavior
        miss_ids, minv = np.unique(ids_np[miss], return_inverse=True)
        rows = np.zeros((m, d), dtype=np.dtype(out.dtype))
        rows[miss] = self.read_cold_rows(miss_ids)[minv]
        with self._stats_lock:
            self.stats["host_fetches"] += 1
            self.stats["cold_rows"] += int(miss.sum())
        shard0 = NamedSharding(self.mesh, P(self.axis))
        rows_j = jax.device_put(jnp.asarray(rows, out.dtype), shard0)
        mask = jax.device_put(jnp.asarray(miss), shard0)
        return jnp.where(mask[:, None], rows_j, out)

"""Tiered feature store + one-sided read engine (paper §5.3, TPU-native).

The paper's engine issues zero-copy one-sided reads (UVA / RDMA) from GPU
kernels. On TPU the equivalent is to keep the whole hot/warm path inside one
XLA program so no host mediation happens at all:

  HOT   rows live replicated in every chip's HBM → local gather.
  WARM  rows are node-sharded across chips → fetched with an explicit
        ``shard_map`` exchange (our one-sided read): either
        (a) ``allgather_ids + local gather + reduce_scatter`` (robust for small
            request vectors), or
        (b) capacity-bounded ``all_to_all`` with owner-sorted ids (moves only
            requested rows — the RDMA-read analogue; skew overflow spills to
            the host path, like a cache miss).
  HOST  rows are fetched with ``jax.experimental.io_callback`` (PCIe analogue).
  DISK  rows return zeros + a miss flag (callers prefetch asynchronously).

The paper's address-sort/TLB optimization survives as: ids are deduplicated
(``fixed_size_unique``) and sorted before every gather/exchange, which both
shrinks collective payloads and improves gather locality.

Fused feature collection (serving hot path): :meth:`TieredFeatureStore.
lookup_hops` collapses the per-hop ``[store.lookup(h) for h in hops]``
pattern into ONE pipeline — concatenate all hops, deduplicate ids once
across hops, do a single address-sorted gather over the device-resident
HOT/WARM tiers (dispatching the Pallas ``tiered_gather`` kernel) plus a
single host callback for the HOST/DISK tiers, then scatter rows back per
hop. For an L-layer sample this replaces 2·(L+1) device gathers and (L+1)
host round-trips with 1 + 1, and the cross-hop dedup shrinks the gathered
row count (hop frontiers overlap heavily on skewed graphs).
"""
from __future__ import annotations

import dataclasses
import threading
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import io_callback
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map

from repro.core.placement import (PlacementPlan, TIER_DISK, TIER_HOST,
                                  TIER_HOT, TIER_WARM)
from repro.graph.sampler import fixed_size_unique
from repro.kernels.tiered_gather.ops import tiered_gather


def _new_stats() -> dict[str, int]:
    """Dispatch accounting shared by both lookup paths (benchmark signal:
    ``benchmarks/fused_gather.py`` reports the per-request reduction)."""
    return {"lookup_calls": 0, "fused_calls": 0,
            "device_gathers": 0, "host_fetches": 0}


@dataclasses.dataclass
class TieredFeatureStore:
    """Single-host runtime store (serving engine / tests / benchmarks).

    The distributed (mesh) variant is `ShardedFeatureStore` below; this class
    emulates the tier structure faithfully on one device + host memory, so
    policy benchmarks (Fig. 15/16) exercise the same code paths.
    """

    plan: PlacementPlan
    feat_dim: int
    hot: jnp.ndarray          # (n_hot, d) — "device HBM, replicated"
    warm: jnp.ndarray         # (warm_total, d) — "device HBM, partitioned"
    host: np.ndarray          # (host_total, d) — host RAM (numpy, off device)
    disk: np.ndarray          # (rest, d) — cold store
    tier_t: jnp.ndarray       # (N,) int32 lookup tables (device-resident;
    slot_t: jnp.ndarray       # paper: "feature lookup table" via UVA)
    owner_t: jnp.ndarray      # (N,) global warm owner (pod*G + dev), -1 else
    warm_base: jnp.ndarray    # (world,) row offset of each owner's warm shard
    # Online migration support: every lookup reads one consistent snapshot of
    # (tables, tier arrays); swap_assignments publishes a new snapshot
    # atomically under this lock (copy-on-write — in-flight lookups keep
    # serving from the old snapshot, so serving never pauses or torn-reads).
    _mig_lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)
    migrated_rows: int = 0    # lifetime count of rows moved between tiers
    # Dispatch accounting: how many tier-store gathers / host round-trips
    # each lookup path issued (the fused path's whole point is to shrink
    # these). Guarded by its own lock so hot-path increments never contend
    # with migration publishes.
    stats: dict = dataclasses.field(default_factory=_new_stats, repr=False,
                                    compare=False)
    _stats_lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)

    @staticmethod
    def build(features: np.ndarray, plan: PlacementPlan) -> "TieredFeatureStore":
        n, d = features.shape
        topo = plan.topology
        world = topo.num_pods * topo.devices_per_pod
        hot_ids = np.flatnonzero(plan.tier == TIER_HOT)
        hot = np.zeros((max(plan.n_hot, 1), d), features.dtype)
        hot[plan.slot[hot_ids]] = features[hot_ids]

        # Warm rows concatenated owner-major: [owner0 rows | owner1 rows | ...]
        owner_global = np.where(
            plan.tier == TIER_WARM,
            np.maximum(plan.pod_owner, 0).astype(np.int64) * topo.devices_per_pod
            + plan.device_owner, -1)
        counts = np.array([(owner_global == w).sum() for w in range(world)],
                          dtype=np.int64)
        base = np.zeros(world, dtype=np.int64)
        np.cumsum(counts[:-1], out=base[1:])
        warm = np.zeros((max(int(counts.sum()), 1), d), features.dtype)
        warm_ids = np.flatnonzero(plan.tier == TIER_WARM)
        warm_rows = base[owner_global[warm_ids]] + plan.slot[warm_ids]
        warm[warm_rows] = features[warm_ids]

        host_ids = np.flatnonzero(plan.tier == TIER_HOST)
        # pod-major host layout
        hcounts = np.zeros(topo.num_pods, dtype=np.int64)
        hbase = np.zeros(topo.num_pods, dtype=np.int64)
        for p in range(topo.num_pods):
            hcounts[p] = ((plan.tier == TIER_HOST)
                          & ((plan.pod_owner == p) | (plan.pod_owner == -1))).sum()
        np.cumsum(hcounts[:-1], out=hbase[1:])
        host = np.zeros((max(int(hcounts.sum()), 1), d), features.dtype)
        hpod = np.maximum(plan.pod_owner[host_ids], 0)
        host[hbase[hpod] + plan.slot[host_ids]] = features[host_ids]

        disk_ids = np.flatnonzero(plan.tier == TIER_DISK)
        disk = np.zeros((max(disk_ids.shape[0], 1), d), features.dtype)
        disk[plan.slot[disk_ids]] = features[disk_ids]

        # Unified slot table pointing into each tier's flat store.
        slot_flat = plan.slot.copy()
        slot_flat[warm_ids] = warm_rows
        slot_flat[host_ids] = hbase[hpod] + plan.slot[host_ids]

        return TieredFeatureStore(
            plan=plan, feat_dim=d,
            hot=jnp.asarray(hot), warm=jnp.asarray(warm), host=host, disk=disk,
            tier_t=jnp.asarray(plan.tier, jnp.int32),
            slot_t=jnp.asarray(slot_flat, jnp.int32),
            owner_t=jnp.asarray(owner_global, jnp.int32),
            warm_base=jnp.asarray(base, jnp.int32))

    # -- lookup -------------------------------------------------------------
    def _snapshot(self) -> tuple:
        """Consistent view (hot, warm, host, disk, tier_t, slot_t). Arrays
        are replaced — never mutated — by migration, so holding the
        references is enough to keep serving from one coherent placement."""
        with self._mig_lock:
            return (self.hot, self.warm, self.host, self.disk,
                    self.tier_t, self.slot_t)

    def _count(self, **deltas: int) -> None:
        with self._stats_lock:
            for k, v in deltas.items():
                self.stats[k] += v

    def reset_stats(self) -> dict[str, int]:
        """Zero the dispatch counters, returning the previous values."""
        with self._stats_lock:
            prev, self.stats = self.stats, _new_stats()
        return prev

    def lookup(self, ids: jnp.ndarray, *, include_host: bool = True,
               dedup: bool = True) -> jnp.ndarray:
        """Gather feature rows for one id vector.

        Args:
            ids: ``(M,)`` int node ids; ``-1`` entries are padding and
                resolve to all-zero rows.
            include_host: also resolve HOST/DISK-tier ids through the host
                callback (the PCIe-analogue slow path). When ``False`` those
                rows come back as zeros (device-only probe).
            dedup: deduplicate + sort ids (``fixed_size_unique``) before
                gathering — the paper's TLB/address-sort optimization.

        Returns:
            ``(M, d)`` feature matrix in the input id order, read from one
            consistent placement snapshot (safe under concurrent
            :meth:`swap_assignments`).
        """
        snap = self._snapshot()
        self._count(lookup_calls=1, device_gathers=2,
                    host_fetches=1 if include_host else 0)
        if dedup:
            uniq, inv = fixed_size_unique(jnp.asarray(ids, jnp.int32),
                                          int(ids.shape[0]))
            rows = self._lookup_unique(uniq, include_host, snap)
            out = rows[inv]
            return jnp.where((jnp.asarray(ids) >= 0)[:, None], out, 0.0)
        rows = self._lookup_unique(jnp.asarray(ids, jnp.int32), include_host,
                                   snap)
        return jnp.where((jnp.asarray(ids) >= 0)[:, None], rows, 0.0)

    def lookup_hops(self, hops, *, include_host: bool = True,
                    use_pallas: Optional[bool] = None) -> list[jnp.ndarray]:
        """Fused feature collection for a whole layered sample.

        Collapses the per-hop ``[store.lookup(h) for h in hops]`` pattern
        into one pipeline: concatenate all hop id vectors, deduplicate ids
        ONCE across hops, gather the device-resident HOT/WARM tiers with a
        single address-sorted dispatch of the Pallas ``tiered_gather``
        kernel, resolve HOST/DISK ids with a single host callback, and
        scatter rows back into per-hop order. Output is bit-identical to the
        per-hop path (gathers copy rows; no arithmetic is reordered) and
        reads one consistent placement snapshot for the *entire* sample,
        so it is safe under concurrent :meth:`swap_assignments`.

        Args:
            hops: sequence of id vectors (``hops[0]`` the seeds, ``hops[k]``
                the k-th frontier), each ``(M_k,)`` with ``-1`` padding.
                At least one hop must be non-empty.
            include_host: as in :meth:`lookup`.
            use_pallas: force (``True``) or suppress (``False``) the Pallas
                kernel for the device-tier gather; ``None`` picks it on TPU
                and the jnp reference elsewhere (interpret mode is used for
                the kernel off-TPU, so ``True`` is safe on CPU tests).

        Returns:
            List of ``(M_k, d)`` feature matrices, one per hop, matching
            ``[self.lookup(h) for h in hops]`` bit-for-bit.

        Raises:
            ValueError: if ``hops`` is empty or all hops have zero length.
        """
        hops_j = [jnp.asarray(h, jnp.int32).reshape(-1) for h in hops]
        sizes = [int(h.shape[0]) for h in hops_j]
        total = sum(sizes)
        if total == 0:
            raise ValueError("lookup_hops needs at least one non-empty hop")
        snap = self._snapshot()
        self._count(fused_calls=1, device_gathers=1,
                    host_fetches=1 if include_host else 0)
        ids = hops_j[0] if len(hops_j) == 1 else jnp.concatenate(hops_j)
        uniq, inv = fixed_size_unique(ids, total)
        rows = self._fused_unique(uniq, include_host, snap, use_pallas)
        out = jnp.where((ids >= 0)[:, None], rows[inv], 0.0)
        offs = np.concatenate([[0], np.cumsum(sizes)])
        return [out[int(offs[k]):int(offs[k + 1])]
                for k in range(len(sizes))]

    def _fused_unique(self, uniq: jnp.ndarray, include_host: bool,
                      snap: tuple, use_pallas: Optional[bool]) -> jnp.ndarray:
        """One gather per tier class for a deduplicated id vector: the
        HOT/WARM rows stream through ``tiered_gather`` in ascending
        (tier, slot) order — near-sequential DMAs, the paper's TLB
        optimization — and HOST/DISK rows come from one ``_host_fetch``."""
        hot, warm, host, disk, tier_t, slot_t = snap
        safe = jnp.maximum(uniq, 0)
        tier = tier_t[safe]
        slot = slot_t[safe]
        # address-sort key: tier-major, slot-minor. Slots are clamped into
        # the device-tier span only for key construction (host-tier slots
        # may exceed it; their gather result is zeros either way), which
        # keeps the key within int32 for any store below ~5e8 rows/tier.
        span = jnp.int32(max(int(hot.shape[0]), int(warm.shape[0]), 1))
        key = tier.astype(jnp.int32) * span + jnp.minimum(slot, span - 1)
        order = jnp.argsort(key)
        dev_sorted = tiered_gather(tier[order], slot[order], hot, warm,
                                   use_pallas=use_pallas)
        out = jnp.zeros_like(dev_sorted).at[order].set(dev_sorted)
        if include_host:
            host_rows = self._host_fetch(uniq, tier, slot, host, disk)
            out = jnp.where((tier >= TIER_HOST)[:, None], host_rows, out)
        return jnp.where((uniq >= 0)[:, None], out, 0.0)

    def _lookup_unique(self, ids: jnp.ndarray, include_host: bool,
                       snap: Optional[tuple] = None) -> jnp.ndarray:
        hot, warm, host, disk, tier_t, slot_t = (snap if snap is not None
                                                 else self._snapshot())
        safe = jnp.maximum(ids, 0)
        tier = tier_t[safe]
        slot = slot_t[safe]
        out = jnp.zeros((ids.shape[0], self.feat_dim), hot.dtype)
        out = jnp.where((tier == TIER_HOT)[:, None],
                        hot[jnp.minimum(slot, hot.shape[0] - 1)], out)
        out = jnp.where((tier == TIER_WARM)[:, None],
                        warm[jnp.minimum(slot, warm.shape[0] - 1)],
                        out)
        if include_host:
            host_rows = self._host_fetch(ids, tier, slot, host, disk)
            out = jnp.where((tier >= TIER_HOST)[:, None], host_rows, out)
        return jnp.where((ids >= 0)[:, None], out, 0.0)

    def _host_fetch(self, ids, tier, slot, host=None, disk=None):
        """PCIe-analogue slow path: host callback, ids sorted by address
        (the paper's TLB optimization) before the gather."""
        if host is None:
            host, disk = self.host, self.disk

        def cb(tier_np, slot_np):
            tier_np = np.asarray(tier_np)
            slot_np = np.asarray(slot_np)
            out = np.zeros((tier_np.shape[0], host.shape[1]), host.dtype)
            m_h = tier_np == TIER_HOST
            m_d = tier_np == TIER_DISK
            # address-sorted gathers
            for m, store in ((m_h, host), (m_d, disk)):
                idx = np.flatnonzero(m)
                if idx.size:
                    order = np.argsort(slot_np[idx])
                    rows = store[slot_np[idx][order]]
                    out[idx[order]] = rows
            return out

        return io_callback(
            cb, jax.ShapeDtypeStruct((ids.shape[0], self.feat_dim),
                                     self.hot.dtype), tier, slot,
            ordered=False)

    def tier_histogram(self, ids: np.ndarray) -> dict[str, int]:
        ids = np.asarray(ids)
        ids = ids[ids >= 0]
        t = self.plan.tier[ids]
        return {"hot": int((t == TIER_HOT).sum()),
                "warm": int((t == TIER_WARM).sum()),
                "host": int((t == TIER_HOST).sum()),
                "disk": int((t == TIER_DISK).sum())}

    # -- online migration ----------------------------------------------------
    def swap_assignments(self, pairs: list[tuple[int, int]]) -> int:
        """Exchange the complete (tier, slot, owner) assignments — and the
        stored feature rows — of disjoint node pairs, atomically w.r.t.
        concurrent :meth:`lookup` / :meth:`lookup_hops`.

        Each node inherits its partner's placement wholesale, so per-tier
        counts, per-device capacity and the owner-major warm layout are all
        preserved; ``lookup(i)`` returns bit-identical features before,
        during and after the swap (the lookup-equivalence invariant — the
        rows travel with the nodes). New arrays are built copy-on-write and
        published under the migration lock; in-flight lookups keep reading
        the previous snapshot.

        Args:
            pairs: ``(a, b)`` node-id pairs to exchange. Node ids must be
                pairwise disjoint across all pairs.

        Returns:
            Number of feature rows moved (``2 * len(pairs)``), also
            accumulated into :attr:`migrated_rows`.

        Raises:
            ValueError: if any node id appears in more than one pair.
        """
        if not pairs:
            return 0
        flat = [n for ab in pairs for n in ab]
        if len(set(flat)) != len(flat):
            raise ValueError("migration pairs must be disjoint")

        tier = np.asarray(self.tier_t).copy()
        slot = np.asarray(self.slot_t).copy()
        owner = np.asarray(self.owner_t).copy()
        stores = {TIER_HOT: self.hot, TIER_WARM: self.warm,
                  TIER_HOST: self.host, TIER_DISK: self.disk}

        # 1) read every feature row out of its current tier store
        feat = {n: np.asarray(stores[int(tier[n])][int(slot[n])])
                for n in flat}

        # 2) exchange table entries — all on copies (plan arrays too, so a
        #    failure anywhere before publish leaves the store untouched and
        #    plan never disagrees with the live tier tables)
        plan = self.plan
        p_tier, p_slot = plan.tier.copy(), plan.slot.copy()
        p_pod, p_dev = plan.pod_owner.copy(), plan.device_owner.copy()
        for a, b in pairs:
            for table in (tier, slot, owner, p_tier, p_slot, p_pod, p_dev):
                table[a], table[b] = table[b], table[a]

        # 3) write each row into its new home, copy-on-write per tier store
        writes: dict[int, tuple[list[int], list[np.ndarray]]] = {}
        for n in flat:
            rows, vals = writes.setdefault(int(tier[n]), ([], []))
            rows.append(int(slot[n]))
            vals.append(feat[n])
        new_stores = dict(stores)
        for t, (rows, vals) in writes.items():
            arr = stores[t]
            vals_np = np.stack(vals)
            if isinstance(arr, jnp.ndarray):
                new_stores[t] = arr.at[np.asarray(rows)].set(
                    jnp.asarray(vals_np, arr.dtype))
            else:
                arr = arr.copy()
                arr[np.asarray(rows)] = vals_np
                new_stores[t] = arr

        # 4) publish the new snapshot (tier tables + plan) atomically
        with self._mig_lock:
            self.hot = new_stores[TIER_HOT]
            self.warm = new_stores[TIER_WARM]
            self.host = new_stores[TIER_HOST]
            self.disk = new_stores[TIER_DISK]
            self.tier_t = jnp.asarray(tier, jnp.int32)
            self.slot_t = jnp.asarray(slot, jnp.int32)
            self.owner_t = jnp.asarray(owner, jnp.int32)
            plan.tier, plan.slot = p_tier, p_slot
            plan.pod_owner, plan.device_owner = p_pod, p_dev
            self.migrated_rows += 2 * len(pairs)
        return 2 * len(pairs)


# ---------------------------------------------------------------------------
# Distributed store: shard_map one-sided reads over the mesh
# ---------------------------------------------------------------------------
class ShardedFeatureStore:
    """Feature store laid out over a device mesh axis.

    hot  : (n_hot, d) replicated
    warm : (world * rows_per_dev, d) sharded on axis 0 over ``axis_name``
    Lookup runs under ``shard_map``; each device resolves its own request
    vector; warm misses are exchanged with allgather+reduce_scatter (default)
    or capacity-bounded all_to_all.
    """

    def __init__(self, mesh: Mesh, axis_name: str, hot: jnp.ndarray,
                 warm: jnp.ndarray, tier_t: jnp.ndarray, slot_t: jnp.ndarray,
                 owner_t: jnp.ndarray, strategy: str = "allgather"):
        self.mesh, self.axis = mesh, axis_name
        self.world = int(np.prod([mesh.shape[a] for a in
                                  (axis_name if isinstance(axis_name, tuple)
                                   else (axis_name,))]))
        self.rows_per_dev = warm.shape[0] // max(self.world, 1)
        self.strategy = strategy
        rep = NamedSharding(mesh, P())
        shard0 = NamedSharding(mesh, P(axis_name))
        self.hot = jax.device_put(hot, rep)
        self.warm = jax.device_put(warm, shard0)
        self.tier_t = jax.device_put(tier_t, rep)
        self.slot_t = jax.device_put(slot_t, rep)
        self.owner_t = jax.device_put(owner_t, rep)
        self.feat_dim = hot.shape[1]

    @staticmethod
    def from_tiered(store: TieredFeatureStore, mesh: Mesh, axis_name: str,
                    strategy: str = "allgather") -> "ShardedFeatureStore":
        topo = store.plan.topology
        world = topo.num_pods * topo.devices_per_pod
        mesh_world = int(np.prod([mesh.shape[a] for a in
                                  (axis_name if isinstance(axis_name, tuple)
                                   else (axis_name,))]))
        assert world == mesh_world, (world, mesh_world)
        # pad warm shards to equal size
        rows = store.warm.shape[0]
        per = -(-rows // world)
        warm = jnp.zeros((per * world, store.feat_dim), store.warm.dtype)
        counts = np.diff(np.append(np.asarray(store.warm_base), rows))
        slot_shift = np.zeros(int(np.asarray(store.owner_t).shape[0]),
                              np.int64)
        # rebuild slot table with padded bases
        owner = np.asarray(store.owner_t)
        slot = np.asarray(store.slot_t).astype(np.int64)
        tier = np.asarray(store.tier_t)
        base = np.asarray(store.warm_base).astype(np.int64)
        warm_np = np.zeros((per * world, store.feat_dim),
                           np.asarray(store.warm).dtype)
        src = np.asarray(store.warm)
        new_slot = slot.copy()
        for w in range(world):
            c = int(counts[w])
            warm_np[w * per: w * per + c] = src[base[w]: base[w] + c]
            m = (tier == TIER_WARM) & (owner == w)
            new_slot[m] = slot[m] - base[w] + w * per
        return ShardedFeatureStore(
            mesh, axis_name, store.hot, jnp.asarray(warm_np),
            store.tier_t, jnp.asarray(new_slot, dtype=jnp.int32),
            store.owner_t, strategy)

    def lookup(self, ids: jnp.ndarray) -> jnp.ndarray:
        """ids: (world * m,) global ids sharded over the axis (each device
        resolves m requests). Returns (world * m, d) with the same sharding."""
        axis = self.axis
        per = self.rows_per_dev

        def body(hot, warm, tier_t, slot_t, owner_t, ids_l):
            my = jax.lax.axis_index(axis)
            safe = jnp.maximum(ids_l, 0)
            tier = tier_t[safe]
            slot = slot_t[safe]
            out = jnp.zeros((ids_l.shape[0], self.feat_dim), hot.dtype)
            out = jnp.where((tier == TIER_HOT)[:, None],
                            hot[jnp.minimum(slot, hot.shape[0] - 1)], out)
            is_warm = tier == TIER_WARM
            local = is_warm & (owner_t[safe] == my)
            lrow = jnp.clip(slot - my * per, 0, per - 1)
            out = jnp.where(local[:, None], warm[lrow], out)
            remote = is_warm & ~local
            # one-sided read: every device publishes its wanted global warm
            # rows; owners answer; reduce_scatter returns each requester's rows
            want_slot = jnp.where(remote, slot, -1)
            all_want = jax.lax.all_gather(want_slot, axis)      # (W, m)
            owned = (all_want >= my * per) & (all_want < (my + 1) * per)
            rows = warm[jnp.clip(all_want - my * per, 0, per - 1)]
            rows = jnp.where(owned[..., None], rows, 0.0)        # (W, m, d)
            answered = jax.lax.psum_scatter(rows, axis, scatter_dimension=0,
                                            tiled=False)         # (m, d)
            answered = answered.reshape(ids_l.shape[0], self.feat_dim)
            out = jnp.where(remote[:, None], answered, out)
            return jnp.where((ids_l >= 0)[:, None], out, 0.0)

        fn = shard_map(
            body, mesh=self.mesh,
            in_specs=(P(), P(axis), P(), P(), P(), P(axis)),
            out_specs=P(axis))
        return fn(self.hot, self.warm, self.tier_t, self.slot_t, self.owner_t,
                  ids)

    def lookup_hops(self, hops) -> list[jnp.ndarray]:
        """Fused multi-hop variant of :meth:`lookup`: concatenate the hop id
        vectors, run ONE ``shard_map`` exchange over the whole sample, and
        split the rows back per hop — (L+1) collective launches collapse to
        one. Every position is resolved independently inside the exchange
        (remote warm reads answer any id from any device), so the rows are
        bit-identical to per-hop calls regardless of how concatenation
        re-partitions the ids over the mesh.

        Args:
            hops: sequence of ``(M_k,)`` id vectors, each with ``-1``
                padding; every ``M_k`` (hence the total) must be a multiple
                of the mesh world size, which executor padding guarantees.

        Returns:
            List of ``(M_k, d)`` feature matrices, one per hop.
        """
        hops_j = [jnp.asarray(h).reshape(-1) for h in hops]
        sizes = [int(h.shape[0]) for h in hops_j]
        out = self.lookup(hops_j[0] if len(hops_j) == 1
                          else jnp.concatenate(hops_j))
        offs = np.concatenate([[0], np.cumsum(sizes)])
        return [out[int(offs[k]):int(offs[k + 1])]
                for k in range(len(sizes))]

"""GNN request types, workload generation and dynamic batching (paper §4.2.2).

The batcher closes a batch when (a) the batching deadline expires, (b) the
accumulated PSGS reaches the budget, or (c) the max batch size is hit —
(b) is what distinguishes Quiver from fixed-size batching (Batchsize-Bound in
Fig. 10): cost-aware batches have predictable processing latency even though
per-seed cost varies by orders of magnitude.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterator, Optional, Sequence

import numpy as np

from repro.serving.engine import MicroBatcher  # canonical home: serving pkg
from repro.serving.executors import pad_to_bucket  # canonical home moved
from repro.serving.registry import DEFAULT_MODEL

__all__ = ["Request", "WorkloadGenerator", "DynamicBatcher", "MicroBatcher",
           "batch_seeds", "pad_to_bucket", "DEFAULT_MODEL", "PRIORITIES"]

# SLO priority classes (gateway admission ordering): interactive traffic
# outranks batch, subject to the gateway's anti-starvation aging bound.
PRIORITIES = ("interactive", "batch")


@dataclasses.dataclass
class Request:
    req_id: int
    seeds: np.ndarray            # (s,) seed node ids
    arrival: float               # seconds (monotonic-clock domain)
    done: Optional[float] = None
    model: str = DEFAULT_MODEL   # registry entry that serves this request
    # SLO fields (gateway traffic): priority class, deadline RELATIVE to
    # arrival (None = no deadline), and the terminal outcome — exactly one
    # of {"completed", "shed_window", "shed_deadline"} once the request
    # leaves the system
    priority: str = "batch"
    deadline_s: Optional[float] = None
    outcome: Optional[str] = None

    @property
    def latency(self) -> float:
        assert self.done is not None
        return self.done - self.arrival


class WorkloadGenerator:
    """Client emulation. Seed nodes are drawn out-degree-weighted by default
    ("representative of real-world serving workloads", paper §6.1); uniform
    and zipf options cover the training-vs-serving distribution-shift
    experiments."""

    def __init__(self, num_nodes: int, out_degree: np.ndarray, *,
                 distribution: str = "degree", zipf_a: float = 1.4,
                 seed: int = 0):
        self.rng = np.random.default_rng(seed)
        self.num_nodes = num_nodes
        if distribution == "degree":
            w = out_degree.astype(np.float64) + 1e-6
            self.p = w / w.sum()
        elif distribution == "uniform":
            self.p = None
        elif distribution == "zipf":
            w = 1.0 / np.power(np.arange(1, num_nodes + 1), zipf_a)
            self.p = (w / w.sum())[self.rng.permutation(num_nodes)]
        else:
            raise ValueError(distribution)
        self._next_id = 0

    def set_seed_prob(self, p: Optional[np.ndarray]) -> None:
        """Shift the live seed distribution mid-stream (workload drift
        emulation). ``None`` reverts to uniform; otherwise ``p`` is
        normalized over the node set."""
        if p is None:
            self.p = None
            return
        p = np.asarray(p, dtype=np.float64)
        if p.shape != (self.num_nodes,):
            raise ValueError(f"seed_prob must have shape ({self.num_nodes},)")
        self.p = p / max(p.sum(), 1e-12)

    def make_request(self, seeds_per_request: int = 1, *,
                     model: str = DEFAULT_MODEL, priority: str = "batch",
                     deadline_s: Optional[float] = None) -> Request:
        seeds = self.rng.choice(self.num_nodes, size=seeds_per_request,
                                p=self.p)
        self._next_id += 1
        return Request(self._next_id, seeds.astype(np.int64),
                       time.monotonic(), model=model, priority=priority,
                       deadline_s=deadline_s)

    def stream(self, n: int, seeds_per_request: int = 1, *,
               models: Optional[list[str]] = None,
               priorities: Optional[Sequence[str]] = None,
               deadlines: Optional[Sequence[Optional[float]]] = None
               ) -> Iterator[Request]:
        """Yield ``n`` requests. ``models`` (optional) tags them round-robin
        across the given model names — the interleaved multi-model client
        mix; ``None`` keeps the untagged single-model stream. ``priorities``
        / ``deadlines`` (optional, cycled round-robin in lockstep with the
        request index) tag the SLO class and relative deadline of each
        request — the mixed interactive+batch client mix the gateway
        benchmarks drive."""
        for i in range(n):
            model = models[i % len(models)] if models else DEFAULT_MODEL
            pr = priorities[i % len(priorities)] if priorities else "batch"
            dl = deadlines[i % len(deadlines)] if deadlines else None
            yield self.make_request(seeds_per_request, model=model,
                                    priority=pr, deadline_s=dl)


class DynamicBatcher:
    """Accumulates requests into batches closed by deadline / PSGS budget /
    max size. ``psgs_budget=None`` degenerates to Batchsize-Bound.

    Batches never mix models: ``ServingEngine.serve_stream`` keeps one
    ``clone()`` per model, and ``add`` additionally closes the pending batch
    whenever the incoming request carries a different ``model`` tag
    (defense in depth for callers driving one instance by hand)."""

    def __init__(self, *, deadline_s: float = 0.002,
                 psgs_budget: Optional[float] = None, max_batch: int = 1024,
                 psgs_table: Optional[np.ndarray] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.deadline_s = deadline_s
        self.psgs_budget = psgs_budget
        self.max_batch = max_batch
        self.psgs_table = psgs_table
        # injectable seconds source for the batching deadline (tests pass
        # repro.testing.FakeClock instead of sleeping past deadline_s)
        self.clock = clock
        self._pending: list[Request] = []
        self._opened: Optional[float] = None
        self._model: Optional[str] = None
        self._acc_psgs = 0.0

    def clone(self) -> "DynamicBatcher":
        """Fresh empty batcher with the same bounds — multi-model streams
        need one batcher per model. Built via ``type(self)`` so subclasses
        stay subclasses (override when a subclass adds constructor
        arguments)."""
        return type(self)(deadline_s=self.deadline_s,
                          psgs_budget=self.psgs_budget,
                          max_batch=self.max_batch,
                          psgs_table=self.psgs_table,
                          clock=self.clock)

    def add(self, req: Request) -> Optional[list[Request]]:
        """Add a request; returns a closed batch if a boundary was hit (or
        the previous pending batch when ``req`` carries a different model
        tag — the new request is then queued fresh)."""
        model = getattr(req, "model", DEFAULT_MODEL)
        closed = None
        if self._pending and model != self._model:
            closed = self.flush()
        if self._opened is None:
            self._opened = self.clock()
        self._model = model
        self._pending.append(req)
        if self.psgs_table is not None:
            self._acc_psgs += float(
                self.psgs_table[req.seeds[req.seeds >= 0]].sum())
        if closed is not None:
            # the model boundary already closed a batch this call; the new
            # request's own bounds are evaluated on the next add (or flush)
            return closed
        full = len(self._pending) >= self.max_batch
        over_budget = (self.psgs_budget is not None
                       and self._acc_psgs >= self.psgs_budget)
        expired = self.clock() - self._opened >= self.deadline_s
        if full or over_budget or expired:
            return self.flush()
        return None

    def flush(self) -> Optional[list[Request]]:
        if not self._pending:
            return None
        batch, self._pending = self._pending, []
        self._opened, self._acc_psgs, self._model = None, 0.0, None
        return batch


def batch_seeds(batch: list[Request]) -> np.ndarray:
    return np.concatenate([r.seeds for r in batch])

"""Deprecated shim — the serving pipeline moved to ``repro.serving``.

The multiplexed two-path engine (paper §4.3) is now the executor-graph
engine of :mod:`repro.serving.engine`; this module keeps the historical
``ServingEngine(graph, store, fanouts, infer_fn, scheduler, ...)`` signature
working by building a host + device executor pair under the hood. The old
``_host_path`` / ``_device_path`` probes delegate to those executors (the
device path now chunks oversized batches instead of silently truncating
them). Import from ``repro.serving`` in new code.
"""
from __future__ import annotations

import warnings
from typing import Callable, Sequence

import jax.numpy as jnp
import numpy as np

from repro.serving.engine import ServeMetrics, ServingEngine as _EngineBase
from repro.serving.executors import DeviceExecutor, HostExecutor

__all__ = ["ServeMetrics", "ServingEngine"]

# one import-time warning per process (module execution happens once; later
# imports hit the sys.modules cache) — the legacy ServingEngine below warns
# again, per instantiation, with a construction-specific message
warnings.warn(
    "repro.core.pipeline is a deprecated shim; import ServingEngine / "
    "ServeMetrics from repro.serving (see docs/architecture.md)",
    DeprecationWarning, stacklevel=2)


class ServingEngine(_EngineBase):
    """Legacy two-executor construction: batch → (hybrid) sample →
    dedup/fetch → infer, with ``num_workers`` lanes per executor."""

    def __init__(self, graph, store, fanouts: Sequence[int],
                 infer_fn: Callable, scheduler, *, num_workers: int = 2,
                 rng_seed: int = 0, max_batch: int = 128):
        warnings.warn(
            "repro.core.pipeline.ServingEngine is a deprecated shim; build "
            "executors explicitly and use repro.serving.ServingEngine "
            "(see docs/architecture.md)", DeprecationWarning, stacklevel=2)
        self.graph = graph
        self.graph_dev = graph.device_arrays()  # shared, read-only (§4.3(3))
        self.store = store
        self.fanouts = tuple(fanouts)
        self.infer_fn = infer_fn
        self.scheduler = scheduler
        self.num_workers = num_workers
        self.max_batch = max_batch
        host = HostExecutor(graph, store, fanouts, infer_fn,
                            capacity=num_workers, rng_seed=rng_seed)
        device = DeviceExecutor(self.graph_dev, store, fanouts, infer_fn,
                                max_batch=max_batch, capacity=num_workers,
                                rng_seed=rng_seed)
        super().__init__([host, device], scheduler, max_inflight=256,
                         admission="wait")

    # legacy probes used by calibration drivers and tests
    def _host_path(self, seeds: np.ndarray) -> jnp.ndarray:
        return self.executors["host"].process(np.asarray(seeds))

    def _device_path(self, seeds: np.ndarray) -> jnp.ndarray:
        return self.executors["device"].process(np.asarray(seeds))

    def process_batch(self, batch: list) -> None:
        fut = self.submit_batch(batch)
        if fut is not None:
            fut.result()
            self.drain()  # metrics accounting runs after the result is set

"""Hybrid high-throughput serving pipeline (paper §4.3).

Design choices carried over from the paper, re-expressed for the JAX runtime:

(1) *Multiplexing pipelines in a processor* — CUDA streams become multiple
    host worker threads, each driving asynchronously-dispatched jitted stages;
    XLA overlaps the host sampler (pure Python/NumPy), feature collection and
    model compute across workers.
(2) *Shared queue* — all workers compete for batches on one queue, so an
    irregular (large-PSGS) batch never blocks small ones behind a fixed
    assignment: stragglers only occupy the worker they run on.
(3) *Shared graph* — the CSR topology and the feature store are read-only
    process-level singletons shared by every worker (UVA analogue: one copy,
    all pipelines).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.feature_store import TieredFeatureStore
from repro.core.scheduler import HybridScheduler, StaticScheduler
from repro.core.serving import Request, batch_seeds, pad_to_bucket
from repro.graph.csr import CSRGraph
from repro.graph.sampler import device_sample, host_sample_dense


@dataclasses.dataclass
class ServeMetrics:
    latencies: list[float] = dataclasses.field(default_factory=list)
    started: float = 0.0
    finished: float = 0.0
    requests: int = 0
    routed_host: int = 0
    routed_device: int = 0

    @property
    def throughput(self) -> float:
        dur = max(self.finished - self.started, 1e-9)
        return self.requests / dur

    def percentile(self, q: float) -> float:
        return float(np.quantile(np.asarray(self.latencies), q))

    def summary(self) -> dict:
        lat = np.asarray(self.latencies)
        return {"requests": self.requests,
                "throughput_rps": self.throughput,
                "p50_ms": float(np.quantile(lat, 0.5) * 1e3),
                "p99_ms": float(np.quantile(lat, 0.99) * 1e3),
                "max_ms": float(lat.max() * 1e3),
                "pct_in_400ms": float((lat < 0.4).mean()),
                "routed_host": self.routed_host,
                "routed_device": self.routed_device}


class ServingEngine:
    """End-to-end GNN serving: batch → (hybrid) sample → dedup/fetch → infer.

    ``infer_fn(hop_feats: list[jnp.ndarray], hop_shapes) -> jnp.ndarray`` is
    the model stage (layered aggregation over the hop arrays).
    """

    def __init__(self, graph: CSRGraph, store: TieredFeatureStore,
                 fanouts: Sequence[int],
                 infer_fn: Callable[[list[jnp.ndarray], list[jnp.ndarray]],
                                    jnp.ndarray],
                 scheduler: HybridScheduler | StaticScheduler, *,
                 num_workers: int = 2, rng_seed: int = 0,
                 max_batch: int = 128):
        self.graph = graph
        self.graph_dev = graph.device_arrays()  # shared, read-only (§4.3(3))
        self.store = store
        self.fanouts = tuple(fanouts)
        self.infer_fn = infer_fn  # (hop_feats, hop_ids) -> outputs
        self.scheduler = scheduler
        self.num_workers = num_workers
        self.max_batch = max_batch
        self.rng = np.random.default_rng(rng_seed)
        self._queue: "queue.Queue[Optional[list[Request]]]" = queue.Queue(
            maxsize=256)
        self._metrics = ServeMetrics()
        self._lock = threading.Lock()
        self._key = jax.random.key(rng_seed)

    # ---- stages ------------------------------------------------------------
    def _next_key(self) -> jax.Array:
        with self._lock:
            self._key, sub = jax.random.split(self._key)
        return sub

    def _device_path(self, seeds: np.ndarray) -> jnp.ndarray:
        """Fully padded on-device pipeline (the 'GPU path'): one static shape
        (max_batch), jitted end to end."""
        seeds_p = np.full((self.max_batch,), -1, np.int32)
        seeds_p[:min(seeds.shape[0], self.max_batch)] = \
            seeds[:self.max_batch]
        hops = device_sample(self._next_key(), *self.graph_dev,
                             jnp.asarray(seeds_p), self.fanouts)
        hop_feats = [self.store.lookup(h) for h in hops]
        return self.infer_fn(hop_feats, hops)

    def _host_path(self, seeds: np.ndarray) -> jnp.ndarray:
        """Exact host sampling (the 'CPU path') in the same dense layout;
        seeds bucket-padded so jit shapes stay O(log max_batch)."""
        seeds_p = pad_to_bucket(seeds.astype(np.int32))
        hops_np = host_sample_dense(self.rng, self.graph, seeds_p,
                                    self.fanouts)
        hops = [jnp.asarray(h) for h in hops_np]
        hop_feats = [self.store.lookup(h) for h in hops]
        return self.infer_fn(hop_feats, hops)

    def process_batch(self, batch: list[Request]) -> None:
        seeds = batch_seeds(batch)
        dest = self.scheduler.route(seeds)
        out = (self._host_path(seeds) if dest == "host"
               else self._device_path(seeds))
        jax.block_until_ready(out)
        now = time.perf_counter()
        with self._lock:
            for r in batch:
                r.done = now
                self._metrics.latencies.append(r.latency)
            self._metrics.requests += len(batch)
            if dest == "host":
                self._metrics.routed_host += 1
            else:
                self._metrics.routed_device += 1

    # ---- pipeline loop -------------------------------------------------
    def _worker(self) -> None:
        while True:
            batch = self._queue.get()  # shared queue: work stealing (§4.3(2))
            if batch is None:
                self._queue.task_done()
                return
            try:
                self.process_batch(batch)
            finally:
                self._queue.task_done()

    def serve_stream(self, requests: Sequence[Request], batcher, *,
                     gap_s: float = 0.0) -> ServeMetrics:
        """Client-stream serving: requests arrive one by one (``gap_s``
        apart), the DynamicBatcher closes batches by deadline / PSGS budget /
        max size, and closed batches enter the shared worker queue. This is
        the paper's end-to-end serving loop (§4.2.2)."""
        self._metrics = ServeMetrics()
        self._metrics.started = time.perf_counter()
        workers = [threading.Thread(target=self._worker, daemon=True)
                   for _ in range(self.num_workers)]
        for w in workers:
            w.start()
        for r in requests:
            if gap_s:
                time.sleep(gap_s)
            r.arrival = time.perf_counter()
            out = batcher.add(r)
            if out:
                self._queue.put(out)
        tail = batcher.flush()
        if tail:
            self._queue.put(tail)
        self._queue.join()
        for _ in workers:
            self._queue.put(None)
        for w in workers:
            w.join()
        self._metrics.finished = time.perf_counter()
        return self._metrics

    def warmup(self, batch: list[Request], *, rounds: int = 2) -> None:
        """Compile/warm both executor paths outside the measured window."""
        seeds = batch_seeds(batch)
        for _ in range(rounds):
            jax.block_until_ready(self._host_path(seeds))
            jax.block_until_ready(self._device_path(seeds))

    def run(self, batches: Sequence[list[Request]], *,
            pace_s: Optional[float] = None) -> ServeMetrics:
        """Process batches through the multiplexed pipeline. ``pace_s``
        spaces arrivals (client-stream emulation) and re-stamps request
        arrival at enqueue time so latency = queueing + processing."""
        self._metrics = ServeMetrics()
        self._metrics.started = time.perf_counter()
        workers = [threading.Thread(target=self._worker, daemon=True)
                   for _ in range(self.num_workers)]
        for w in workers:
            w.start()
        for b in batches:
            if pace_s:
                time.sleep(pace_s)
            now = time.perf_counter()
            for r in b:
                r.arrival = now  # client-observed latency starts at enqueue
            self._queue.put(b)
        self._queue.join()
        for _ in workers:
            self._queue.put(None)
        for w in workers:
            w.join()
        self._metrics.finished = time.perf_counter()
        return self._metrics

"""Probabilistic Sampled Sub-graph Size (PSGS) — paper §4.1.

For a K-hop sampling configuration with per-hop fanouts ``l_1..l_K`` the paper
defines

    Q_K[i] = Σ_{k=0..K} q_k[i]
    q_0[i] = 1
    q_k[i] = Σ_j δ_{k-1}(i, j) · min(|N⁺(j)|, l_k)

with δ_k = T^k the k-step transition probability of the row-stochastic
adjacency T. Since δ is a *probability* (its rows sum to 1), this counts the
expected fan-in of a single random-walk position per hop — it does not multiply
by the number of sampled slots at the previous hop (the paper's own worked
example, Fig. 5, makes the same simplification: q_2[3] = 1 · 1/2).

We implement two modes:

* ``mode="paper"`` — the formula exactly as published (faithful baseline).
* ``mode="branching"`` — beyond-paper correction that accounts for sampling
  multiplicity, i.e. the true expected number of sampled slots produced by the
  actual sampler:

      s_{K+1} ≡ 0
      s_k[j]  = min(deg_j, l_k) · (1 + (1/deg_j) Σ_{m∈N(j)} s_{k+1}[m])
      Q[i]    = 1 + s_1[i]

  This is what :func:`monte_carlo_psgs` converges to, and is the default
  scheduling signal (EXPERIMENTS.md records both).

Both evaluate with a Horner scheme in K sparse matrix–vector passes; each pass
is a ``segment_sum`` SpMV — the TPU analogue of the paper's CUDA sparse matmul
(O(K·|E|)). The output is the O(|V|) lookup table consulted in O(1) at serving
time (paper §4.2.2).
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph
from repro.graph.segment import segment_sum


@partial(jax.jit, static_argnames=("num_nodes", "fanouts", "mode"))
def _psgs_device(src: jnp.ndarray, dst: jnp.ndarray, deg: jnp.ndarray,
                 num_nodes: int, fanouts: tuple[int, ...],
                 mode: str) -> jnp.ndarray:
    degf = deg.astype(jnp.float32)
    inv_deg = jnp.where(deg > 0, 1.0 / jnp.maximum(degf, 1.0), 0.0)

    def spmv_T(v):
        # (T v)[i] = (1/deg_i) Σ_{j ∈ N⁺(i)} v[j]
        return segment_sum(v[dst], src, num_nodes) * inv_deg

    del mode  # only the faithful "paper" formula lives here
    u = jnp.minimum(degf, float(fanouts[-1]))
    for l_k in reversed(fanouts[:-1]):
        u = jnp.minimum(degf, float(l_k)) + spmv_T(u)
    return 1.0 + u


@partial(jax.jit, static_argnames=("num_nodes", "fanouts"))
def _psgs_branching(src: jnp.ndarray, dst: jnp.ndarray, deg: jnp.ndarray,
                    num_nodes: int, fanouts: tuple[int, ...]) -> jnp.ndarray:
    degf = deg.astype(jnp.float32)
    inv_deg = jnp.where(deg > 0, 1.0 / jnp.maximum(degf, 1.0), 0.0)

    def mean_over_neighbors(v):
        return segment_sum(v[dst], src, num_nodes) * inv_deg

    s = jnp.zeros((num_nodes,), jnp.float32)
    for l_k in reversed(fanouts):
        picks = jnp.minimum(degf, float(l_k))
        s = picks * (1.0 + mean_over_neighbors(s))
    return 1.0 + s


def compute_psgs(graph: CSRGraph, fanouts: Sequence[int], *,
                 mode: str = "branching") -> np.ndarray:
    """PSGS lookup table Q_K, shape (num_nodes,), float32."""
    if not fanouts:
        return np.ones((graph.num_nodes,), dtype=np.float32)
    src, dst = graph.to_coo()
    args = (jnp.asarray(src, jnp.int32), jnp.asarray(dst, jnp.int32),
            jnp.asarray(graph.out_degree, jnp.int32), graph.num_nodes,
            tuple(int(f) for f in fanouts))
    if mode == "branching":
        q = _psgs_branching(*args)
    elif mode == "paper":
        q = _psgs_device(*args, mode="paper")
    else:
        raise ValueError(f"unknown PSGS mode {mode!r}")
    return np.asarray(q)


def monte_carlo_psgs(graph: CSRGraph, node: int, fanouts: Sequence[int],
                     *, trials: int = 200, seed: int = 0) -> float:
    """Brute-force PSGS by running the actual sampler — the test oracle for
    ``mode="branching"`` (expected number of sampled *slots*, multiplicity
    included)."""
    rng = np.random.default_rng(seed)
    indptr, indices = graph.indptr, graph.indices
    total = 0
    for _ in range(trials):
        count = 1
        frontier = [node]
        for fan in fanouts:
            nxt = []
            for v in frontier:
                s, e = indptr[v], indptr[v + 1]
                deg = e - s
                if deg == 0:
                    continue
                if deg <= fan:
                    nxt.extend(indices[s:e].tolist())
                else:
                    nxt.extend(indices[s + rng.integers(0, deg, size=fan)]
                               .tolist())
            count += len(nxt)
            frontier = nxt
        total += count
    return total / trials


def batch_psgs(psgs_table: np.ndarray, seeds: np.ndarray) -> float:
    """Accumulated PSGS of a request batch (paper §4.2.2): O(1) per seed."""
    seeds = np.asarray(seeds)
    valid = seeds >= 0
    return float(psgs_table[seeds[valid]].sum())

"""Workload-aware feature placement (paper §5.2) + baselines.

The paper places features across a 4-level GPU topology (local GPU / NVLink
peer / host via PCIe / remote server via InfiniBand). On a TPU pod the levels
map to (DESIGN.md §2):

    HOT   — replicated in every chip's HBM            (local GPU)
    WARM  — partitioned across chips, fetched via ICI (NVLink peer)
    HOST  — host RAM, io_callback                     (PCIe host memory)
    DISK  — cold store                                (SSD/disk)

and the pod axis plays the server/InfiniBand role. The placement algorithm is
the paper's steps (i)–(v): sort by FAP, compute per-device and per-pod
capacity, partition-vs-replicate depending on interconnect, then balance the
aggregated FAP per device with a snake assignment.

``hot_replicate_fraction`` generalizes the paper's NVLink dichotomy: the
paper's no-NVLink case is ``1.0`` (replicate everything on-device), the
with-NVLink case is ``0.0`` (partition everything). Values in between are the
beyond-paper operating points evaluated in benchmarks/placement_compare.py.

Baselines implemented for Fig. 15: hash (DGL), degree (AliGraph),
training-frequency (GNNLab/PaGraph) and P3 feature-dimension partitioning.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

TIER_HOT, TIER_WARM, TIER_HOST, TIER_DISK = 0, 1, 2, 3
TIER_NAMES = {TIER_HOT: "hot", TIER_WARM: "warm", TIER_HOST: "host",
              TIER_DISK: "disk"}


@dataclasses.dataclass(frozen=True)
class TopologySpec:
    """Deployment topology. Defaults model one v5e pod-slice serving group."""

    num_pods: int = 1                 # servers (paper) ≙ pods (TPU)
    devices_per_pod: int = 8          # G
    numa_groups_per_pod: int = 1      # C (ICI makes a pod one group)
    rows_per_device: int = 1024       # N_g — feature rows per chip HBM budget
    rows_host: int = 4096             # N_m — rows in host RAM per pod
    rows_disk: Optional[int] = None   # N_d — None = unbounded cold store
    has_fast_intrapod: bool = True    # NVLink ≙ ICI present
    has_fast_interpod: bool = True    # InfiniBand ≙ fast DCN present
    hot_replicate_fraction: float = 0.25

    @property
    def group_devices(self) -> int:
        return max(1, self.devices_per_pod // self.numa_groups_per_pod)


@dataclasses.dataclass
class PlacementPlan:
    """Per-node placement decision consumed by the feature store and dry-run.

    tier[i]         ∈ {HOT, WARM, HOST, DISK}
    pod_owner[i]    owning pod, -1 ⇒ replicated across pods
    device_owner[i] owning device within pod, -1 ⇒ replicated across devices
    slot[i]         row index inside the owning store
    """

    tier: np.ndarray
    pod_owner: np.ndarray
    device_owner: np.ndarray
    slot: np.ndarray
    topology: TopologySpec
    n_hot: int
    warm_rows_per_device: int
    host_rows_per_pod: int
    dim_sharded: bool = False  # P3 baseline: feature *dimension* partitioned
    name: str = "quiver"

    def tier_counts(self) -> dict[str, int]:
        return {TIER_NAMES[t]: int((self.tier == t).sum())
                for t in (TIER_HOT, TIER_WARM, TIER_HOST, TIER_DISK)}

    def validate(self) -> None:
        n = self.tier.shape[0]
        assert self.pod_owner.shape == (n,) and self.slot.shape == (n,)
        hot = self.tier == TIER_HOT
        warm = self.tier == TIER_WARM
        assert (self.device_owner[hot] == -1).all()
        assert (self.device_owner[warm] >= 0).all()
        if not self.dim_sharded:
            # per-device capacity: hot rows + owned warm rows <= N_g
            for p in range(self.topology.num_pods):
                in_pod = (self.pod_owner == p) | (self.pod_owner == -1)
                for d in range(self.topology.devices_per_pod):
                    owned = int((warm & in_pod & (self.device_owner == d)).sum())
                    assert self.n_hot + owned <= self.topology.rows_per_device, \
                        (p, d, self.n_hot, owned)


def _snake(ranks: np.ndarray, num_buckets: int) -> np.ndarray:
    """Boustrophedon assignment: balances the aggregated sorted-FAP mass per
    bucket while keeping per-bucket counts equal (paper step v)."""
    period = 2 * num_buckets
    r = ranks % period
    return np.where(r < num_buckets, r, period - 1 - r).astype(np.int16)


def quiver_placement(fap: np.ndarray, topo: TopologySpec, *,
                     name: str = "quiver") -> PlacementPlan:
    n = fap.shape[0]
    order = np.argsort(-fap, kind="stable")  # (i) sort by FAP desc
    rank = np.empty(n, dtype=np.int64)
    rank[order] = np.arange(n)

    g = topo.group_devices                       # (ii) per-group capacity
    n_g = topo.rows_per_device
    hot_frac = 1.0 if not topo.has_fast_intrapod else topo.hot_replicate_fraction
    n_hot = min(int(round(hot_frac * n_g)), n_g, n)
    warm_per_dev = n_g - n_hot
    warm_per_pod = g * warm_per_dev              # distinct warm rows per pod
    if topo.has_fast_interpod:                   # (iv) partition across pods
        warm_total = topo.num_pods * warm_per_pod
        host_total = topo.num_pods * topo.rows_host
    else:                                        # replicate warm set per pod
        warm_total = warm_per_pod
        host_total = topo.rows_host
    warm_total = min(warm_total, max(n - n_hot, 0))
    host_total = min(host_total, max(n - n_hot - warm_total, 0))

    tier = np.full(n, TIER_DISK, dtype=np.int8)
    pod_owner = np.full(n, -1, dtype=np.int16)
    device_owner = np.full(n, -1, dtype=np.int16)
    slot = np.zeros(n, dtype=np.int64)

    hot_ids = order[:n_hot]
    tier[hot_ids] = TIER_HOT
    slot[hot_ids] = np.arange(n_hot)

    warm_ids = order[n_hot:n_hot + warm_total]
    wr = np.arange(warm_total)
    tier[warm_ids] = TIER_WARM
    if topo.has_fast_interpod and topo.num_pods > 1:
        # interleave pods first (snake), then devices within pod (snake):
        pod_of = _snake(wr, topo.num_pods)
        pod_owner[warm_ids] = pod_of
        # rank within pod
        within = np.zeros(warm_total, dtype=np.int64)
        for p in range(topo.num_pods):
            m = pod_of == p
            within[m] = np.arange(int(m.sum()))
    else:
        within = wr
    device_owner[warm_ids] = _snake(within, g)   # (v) balance FAP per device
    dslot = np.zeros(warm_total, dtype=np.int64)
    dev = device_owner[warm_ids]
    pw = pod_owner[warm_ids]
    for key in set(zip(pw.tolist(), dev.tolist())) if warm_total else set():
        m = (pw == key[0]) & (dev == key[1])
        dslot[m] = np.arange(int(m.sum()))
    slot[warm_ids] = dslot

    host_ids = order[n_hot + warm_total:n_hot + warm_total + host_total]
    tier[host_ids] = TIER_HOST
    hr = np.arange(host_total)
    if topo.has_fast_interpod and topo.num_pods > 1:
        hpod = _snake(hr, topo.num_pods)
        pod_owner[host_ids] = hpod
        hslot = np.zeros(host_total, dtype=np.int64)
        for p in range(topo.num_pods):
            m = hpod == p
            hslot[m] = np.arange(int(m.sum()))
        slot[host_ids] = hslot
    else:
        slot[host_ids] = hr

    disk_ids = order[n_hot + warm_total + host_total:]
    slot[disk_ids] = np.arange(disk_ids.shape[0])

    plan = PlacementPlan(tier=tier, pod_owner=pod_owner,
                         device_owner=device_owner, slot=slot, topology=topo,
                         n_hot=n_hot, warm_rows_per_device=warm_per_dev,
                         host_rows_per_pod=topo.rows_host, name=name)
    plan.validate()
    return plan


# ---------------------------------------------------------------------------
# Online re-placement (serve-time adaptation)
# ---------------------------------------------------------------------------
def migration_pairs(current_tier: np.ndarray, target_tier: np.ndarray,
                    score: np.ndarray, *, budget: int
                    ) -> list[tuple[int, int]]:
    """Plan one bounded migration step toward ``target_tier``.

    Returns up to ``budget`` disjoint ``(promote, demote)`` node pairs:
    ``promote`` currently sits in a colder tier than its target, ``demote``
    occupies the target tier but belongs colder. Swapping the two complete
    (tier, slot, owner) assignments preserves every per-tier count and
    capacity invariant, so a plan stays valid mid-migration. Each swap puts
    the promoted node in its final tier; the demoted node inherits the
    promoted node's old tier, which may still differ from its own target —
    later steps converge it (3-cycles resolve over multiple steps).

    ``score`` (typically the fresh FAP) orders candidates: hottest promotions
    and coldest demotions first, so a truncated budget moves the most
    valuable rows.
    """
    cur = np.asarray(current_tier)
    tgt = np.asarray(target_tier)
    assert cur.shape == tgt.shape
    pairs: list[tuple[int, int]] = []
    used: set[int] = set()
    for t in (TIER_HOT, TIER_WARM, TIER_HOST):
        if len(pairs) >= budget:
            break
        want_in = np.flatnonzero((tgt == t) & (cur > t))
        leaving = np.flatnonzero((cur == t) & (tgt > t))
        want_in = [int(i) for i in want_in[np.argsort(-score[want_in],
                                                      kind="stable")]
                   if int(i) not in used]
        leaving = [int(i) for i in leaving[np.argsort(score[leaving],
                                                      kind="stable")]
                   if int(i) not in used]
        for a, b in zip(want_in, leaving):
            pairs.append((a, b))
            used.add(a)
            used.add(b)
            if len(pairs) >= budget:
                break
    return pairs


# ---------------------------------------------------------------------------
# Baselines (Fig. 15)
# ---------------------------------------------------------------------------
def hash_placement(num_nodes: int, topo: TopologySpec) -> PlacementPlan:
    """DGL-style hash partitioning: workload-agnostic, node id modulo device.
    Each device keeps the first N_g of its hashed rows in HBM, rest on host."""
    n = num_nodes
    ids = np.arange(n, dtype=np.int64)
    h = (ids * 2654435761) % (2 ** 31)
    world = topo.num_pods * topo.devices_per_pod
    owner = (h % world).astype(np.int64)
    pod_owner = (owner // topo.devices_per_pod).astype(np.int16)
    device_owner = (owner % topo.devices_per_pod).astype(np.int16)
    tier = np.full(n, TIER_HOST, dtype=np.int8)
    slot = np.zeros(n, dtype=np.int64)
    for w in range(world):
        m = owner == w
        r = np.arange(int(m.sum()))
        tier[np.flatnonzero(m)[r < topo.rows_per_device]] = TIER_WARM
        slot_m = np.where(r < topo.rows_per_device, r,
                          r - topo.rows_per_device)
        slot[m] = slot_m
    plan = PlacementPlan(tier=tier, pod_owner=pod_owner,
                         device_owner=device_owner, slot=slot, topology=topo,
                         n_hot=0, warm_rows_per_device=topo.rows_per_device,
                         host_rows_per_pod=topo.rows_host, name="hash")
    return plan


def degree_placement(out_degree: np.ndarray, topo: TopologySpec) -> PlacementPlan:
    """AliGraph-style: importance = node degree (workload-agnostic ranking)."""
    return quiver_placement(out_degree.astype(np.float32), topo, name="degree")


def freq_placement(train_counts: np.ndarray, topo: TopologySpec) -> PlacementPlan:
    """GNNLab/PaGraph-style: rank by *training-time* access frequency. The
    paper's point (§2.3): training seeds are uniform, serving seeds are
    skewed, so this ranking deviates from serving-time access probability."""
    return quiver_placement(train_counts.astype(np.float32), topo, name="freq")


def p3_placement(num_nodes: int, topo: TopologySpec) -> PlacementPlan:
    """P3-style: partition the feature *dimension* — every node's feature is
    split across all devices; every lookup touches every device."""
    n = num_nodes
    plan = PlacementPlan(
        tier=np.full(n, TIER_WARM, dtype=np.int8),
        pod_owner=np.full(n, -1, dtype=np.int16),
        device_owner=np.zeros(n, dtype=np.int16),
        slot=np.arange(n, dtype=np.int64), topology=topo, n_hot=0,
        warm_rows_per_device=n, host_rows_per_pod=0, dim_sharded=True,
        name="p3")
    return plan


# ---------------------------------------------------------------------------
# Beyond-paper: FAP-style placement for MoE experts (DESIGN.md §4)
# ---------------------------------------------------------------------------
def expert_placement(expert_prob: np.ndarray, num_devices: int,
                     replication_budget: int) -> np.ndarray:
    """Distribute ``replication_budget`` extra expert replicas by access
    probability (router statistics ≙ FAP). Returns (num_experts,) replica
    counts ≥ 1; proportional (largest-remainder) allocation."""
    p = np.asarray(expert_prob, dtype=np.float64)
    p = p / max(p.sum(), 1e-12)
    extra = p * replication_budget
    base = np.floor(extra).astype(np.int64)
    rem = replication_budget - int(base.sum())
    if rem > 0:
        top = np.argsort(-(extra - base))[:rem]
        base[top] += 1
    return np.minimum(1 + base, num_devices)

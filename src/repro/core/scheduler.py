"""Deprecated shim — PSGS-guided scheduling moved to ``repro.serving.router``.

The binary threshold scheduler (paper §4.2, Fig. 6(b)) is now the 2-executor
special case of :class:`repro.serving.router.CostModelRouter`. Import
``HybridScheduler`` / ``CostModelRouter`` / ``LatencyCurve`` from
``repro.serving`` in new code (see docs/architecture.md for the module map);
this module only keeps historical ``repro.core.scheduler`` imports working.
"""
import warnings

from repro.serving.router import (CalibrationResult, CostModelRouter,
                                  HybridScheduler, LatencyCurve,
                                  StaticScheduler, calibrate,
                                  calibrate_executors)

# one import-time warning per process (later imports hit sys.modules)
warnings.warn(
    "repro.core.scheduler is a deprecated shim; import the routing API "
    "from repro.serving (see docs/architecture.md)",
    DeprecationWarning, stacklevel=2)

__all__ = [
    "LatencyCurve", "CalibrationResult", "calibrate", "calibrate_executors",
    "CostModelRouter", "HybridScheduler", "StaticScheduler",
]

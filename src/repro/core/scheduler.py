"""PSGS-guided hybrid scheduling (paper §4.2).

Offline, a serving-workload generator measures end-to-end processing latency
of batches with varying accumulated PSGS on both executors (host sampler vs
device sampler). Per executor we fit an *average* and a *maximum* latency
curve over PSGS. The four operating points of Fig. 6(b):

    1 cpu_preferred        : host.max  ∩ device.avg
    2 gpu_preferred        : host.avg  ∩ device.max
    3 latency_preferred    : host.max  ∩ device.max   (bound tail latency)
    4 throughput_preferred : host.avg  ∩ device.avg   (maximize throughput)

At serving time the scheduler accumulates per-seed PSGS lookups for each
batch (O(1) each) and routes the batch to the device only when the sum
exceeds the selected threshold (§4.2.2).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import numpy as np

from repro.core.psgs import batch_psgs


@dataclasses.dataclass
class LatencyCurve:
    """Piecewise-linear latency-vs-PSGS curve (avg + tail) fit from samples."""

    psgs: np.ndarray      # (B,) bin centers, ascending
    avg: np.ndarray       # (B,) mean latency per bin (seconds)
    mx: np.ndarray        # (B,) tail (max or p99) latency per bin

    @staticmethod
    def fit(samples_psgs: Sequence[float], samples_lat: Sequence[float],
            *, bins: int = 12, tail: float = 1.0) -> "LatencyCurve":
        p = np.asarray(samples_psgs, dtype=np.float64)
        l = np.asarray(samples_lat, dtype=np.float64)
        order = np.argsort(p)
        p, l = p[order], l[order]
        edges = np.quantile(p, np.linspace(0, 1, bins + 1))
        edges[-1] += 1e-9
        centers, avgs, maxs = [], [], []
        for i in range(bins):
            m = (p >= edges[i]) & (p < edges[i + 1])
            if not m.any():
                continue
            centers.append(p[m].mean())
            avgs.append(l[m].mean())
            maxs.append(np.quantile(l[m], tail) if tail < 1.0 else l[m].max())
        return LatencyCurve(np.asarray(centers), np.asarray(avgs),
                            np.asarray(maxs))

    def eval_avg(self, q: float | np.ndarray) -> np.ndarray:
        return np.interp(q, self.psgs, self.avg)

    def eval_max(self, q: float | np.ndarray) -> np.ndarray:
        return np.interp(q, self.psgs, self.mx)


@dataclasses.dataclass
class CalibrationResult:
    host: LatencyCurve
    device: LatencyCurve

    def _cross(self, f_host: Callable, f_dev: Callable) -> float:
        lo = min(self.host.psgs.min(), self.device.psgs.min())
        hi = max(self.host.psgs.max(), self.device.psgs.max())
        grid = np.linspace(lo, hi, 512)
        diff = f_host(grid) - f_dev(grid)
        sign = np.signbit(diff)
        flips = np.flatnonzero(sign[1:] != sign[:-1])
        if flips.size == 0:
            # no intersection: host always faster → +inf threshold (never use
            # device); device always faster → 0 (always device)
            return float("inf") if diff[-1] < 0 else 0.0
        i = flips[0]
        # linear interpolation of the crossing, clamped to the measured range
        x0, x1, d0, d1 = grid[i], grid[i + 1], diff[i], diff[i + 1]
        denom = d1 - d0
        if abs(denom) < 1e-15:
            return float(x0)
        return float(np.clip(x0 + (x1 - x0) * (0 - d0) / denom, lo, hi))

    def threshold(self, policy: str) -> float:
        h, d = self.host, self.device
        if policy == "cpu_preferred":
            return self._cross(h.eval_max, d.eval_avg)
        if policy == "gpu_preferred":
            return self._cross(h.eval_avg, d.eval_max)
        if policy in ("latency_preferred", "strict"):
            return self._cross(h.eval_max, d.eval_max)
        if policy in ("throughput_preferred", "loose"):
            return self._cross(h.eval_avg, d.eval_avg)
        raise ValueError(f"unknown policy {policy!r}")


def calibrate(host_run: Callable[[np.ndarray], None],
              device_run: Callable[[np.ndarray], None],
              batches: Sequence[np.ndarray], psgs_table: np.ndarray,
              *, repeats: int = 3, warmup: int = 1,
              tail: float = 1.0) -> CalibrationResult:
    """Measure both executors on the same batches (paper: measurements taken
    at near-full utilization with no queueing; here: steady-state repeats
    after warmup) and fit the curves."""
    def measure(run):
        ps, ls = [], []
        for b in batches:
            q = batch_psgs(psgs_table, b)
            for _ in range(warmup):
                run(b)
            for _ in range(repeats):
                t0 = time.perf_counter()
                run(b)
                ls.append(time.perf_counter() - t0)
                ps.append(q)
        return ps, ls

    hp, hl = measure(host_run)
    dp, dl = measure(device_run)
    return CalibrationResult(host=LatencyCurve.fit(hp, hl, tail=tail),
                             device=LatencyCurve.fit(dp, dl, tail=tail))


class HybridScheduler:
    """Routes request batches between executors by accumulated PSGS."""

    def __init__(self, psgs_table: np.ndarray, threshold: float,
                 policy: str = "latency_preferred"):
        self.psgs_table = psgs_table
        self.threshold = float(threshold)
        self.policy = policy
        self.routed = {"host": 0, "device": 0}

    @staticmethod
    def from_calibration(psgs_table: np.ndarray, calib: CalibrationResult,
                         policy: str = "latency_preferred") -> "HybridScheduler":
        return HybridScheduler(psgs_table, calib.threshold(policy), policy)

    def batch_cost(self, seeds: np.ndarray) -> float:
        return batch_psgs(self.psgs_table, seeds)

    def route(self, seeds: np.ndarray) -> str:
        dest = "host" if self.batch_cost(seeds) < self.threshold else "device"
        self.routed[dest] += 1
        return dest


class StaticScheduler:
    """Baselines: always-host ("CPU sampling") / always-device ("GPU")."""

    def __init__(self, dest: str):
        assert dest in ("host", "device")
        self.dest = dest
        self.routed = {"host": 0, "device": 0}

    def route(self, seeds: np.ndarray) -> str:
        self.routed[self.dest] += 1
        return self.dest

"""Deprecated shim — PSGS-guided scheduling moved to ``repro.serving.router``.

The binary threshold scheduler (paper §4.2, Fig. 6(b)) is now the 2-executor
special case of :class:`repro.serving.router.CostModelRouter`. Import
``HybridScheduler`` / ``CostModelRouter`` / ``LatencyCurve`` from
``repro.serving`` in new code (see docs/architecture.md for the module map);
this module only keeps historical ``repro.core.scheduler`` imports working.
"""
from repro.serving.router import (CalibrationResult, CostModelRouter,
                                  HybridScheduler, LatencyCurve,
                                  StaticScheduler, calibrate,
                                  calibrate_executors)

__all__ = [
    "LatencyCurve", "CalibrationResult", "calibrate", "calibrate_executors",
    "CostModelRouter", "HybridScheduler", "StaticScheduler",
]

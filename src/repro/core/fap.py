"""Feature Access Probability (FAP) — paper §5.1.

    P_K[i] = Σ_{k=0..K} p_k[i]
    p_0[i] = seed probability (uniform 1/|V| by default, or workload-supplied)
    p_k[i] = Σ_{j ∈ N⁻_k(i)} p_0(j) · δ_k(j, i)        (= p_0ᵀ Tᵏ)

computed with K transposed SpMV passes:  w_0 = p_0,  w_k = Tᵀ w_{k-1},
P = Σ w_k.  Beyond-paper option ``truncated=True`` damps each step by the
fanout acceptance ratio min(deg, l_k)/deg — the probability mass that actually
survives fanout truncation in the real sampler.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph
from repro.graph.segment import segment_sum


@partial(jax.jit, static_argnames=("num_nodes", "fanouts", "truncated"))
def _fap_device(src: jnp.ndarray, dst: jnp.ndarray, deg: jnp.ndarray,
                p0: jnp.ndarray, num_nodes: int, fanouts: tuple[int, ...],
                truncated: bool) -> jnp.ndarray:
    degf = deg.astype(jnp.float32)
    inv_deg = jnp.where(deg > 0, 1.0 / jnp.maximum(degf, 1.0), 0.0)

    w = p0
    total = p0
    for l_k in fanouts:
        # Untruncated (paper): per-edge transition mass 1/deg(j).
        # Truncated: P(specific neighbor among the l_k picks) = min(deg,l)/deg.
        rate = jnp.minimum(degf, float(l_k)) * inv_deg if truncated else inv_deg
        w = segment_sum((w * rate)[src], dst, num_nodes)
        total = total + w
    return total


def compute_fap(graph: CSRGraph, fanouts: Sequence[int], *,
                seed_prob: Optional[np.ndarray] = None,
                truncated: bool = False) -> np.ndarray:
    """FAP lookup table P_K, shape (num_nodes,), float32."""
    n = graph.num_nodes
    if seed_prob is None:
        p0 = np.full((n,), 1.0 / n, dtype=np.float32)
    else:
        p0 = np.asarray(seed_prob, dtype=np.float32)
        p0 = p0 / max(p0.sum(), 1e-12)
    src, dst = graph.to_coo()
    p = _fap_device(jnp.asarray(src, jnp.int32), jnp.asarray(dst, jnp.int32),
                    jnp.asarray(graph.out_degree, jnp.int32),
                    jnp.asarray(p0), n, tuple(int(f) for f in fanouts),
                    truncated)
    return np.asarray(p)


def monte_carlo_fap(graph: CSRGraph, fanouts: Sequence[int], *,
                    requests: int = 2000, seed: int = 0,
                    seed_prob: Optional[np.ndarray] = None) -> np.ndarray:
    """Empirical access frequency from running the actual sampler — the test
    oracle: relative ordering (rank correlation) should match compute_fap."""
    rng = np.random.default_rng(seed)
    n = graph.num_nodes
    counts = np.zeros((n,), dtype=np.float64)
    indptr, indices = graph.indptr, graph.indices
    p = seed_prob / seed_prob.sum() if seed_prob is not None else None
    seeds = rng.choice(n, size=requests, p=p)
    for s in seeds:
        frontier = [s]
        counts[s] += 1
        for fan in fanouts:
            nxt = []
            for v in frontier:
                a, b = indptr[v], indptr[v + 1]
                deg = b - a
                if deg == 0:
                    continue
                if deg <= fan:
                    nxt.extend(indices[a:b].tolist())
                else:
                    nxt.extend(indices[a + rng.integers(0, deg, size=fan)]
                               .tolist())
            for u in nxt:
                counts[u] += 1
            frontier = nxt
    return counts / requests

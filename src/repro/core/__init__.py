"""Quiver's primary contribution: workload metrics (PSGS/FAP), workload-aware
feature placement, the tiered one-sided-read feature store (with the fused
``lookup_hops`` serving hot path), and request batching/workload generation.

The serving engine, executors, routing and the multi-model registry live in
:mod:`repro.serving`; ``repro.core.pipeline`` and ``repro.core.scheduler``
remain as deprecation shims re-exporting from there. This package imports
the canonical serving-layer objects directly (same classes the shims
re-export), so merely importing ``repro.core`` stays warning-free — only
touching the shims themselves (including the legacy ``ServingEngine``
construction signature, resolved lazily below) emits the
``DeprecationWarning``."""
from repro.core.fap import compute_fap, monte_carlo_fap
from repro.core.feature_store import (STATS_SCHEMA, DiskSpillTier,
                                      ShardedFeatureStore,
                                      TieredFeatureStore)
from repro.core.gpu_cache import GPUFeatureCache
from repro.core.prefetch import Prefetcher
from repro.core.placement import (PlacementPlan, TopologySpec,
                                  degree_placement, expert_placement,
                                  freq_placement, hash_placement,
                                  migration_pairs, p3_placement,
                                  quiver_placement)
from repro.core.psgs import batch_psgs, compute_psgs, monte_carlo_psgs
from repro.core.serving import (DEFAULT_MODEL, PRIORITIES, DynamicBatcher,
                                MicroBatcher, Request, WorkloadGenerator,
                                batch_seeds, pad_to_bucket)
from repro.serving.engine import ServeMetrics
from repro.serving.router import (CalibrationResult, CostModelRouter,
                                  HybridScheduler, LatencyCurve,
                                  StaticScheduler, calibrate,
                                  calibrate_executors)

__all__ = [
    "compute_psgs", "monte_carlo_psgs", "batch_psgs", "compute_fap",
    "monte_carlo_fap", "TopologySpec", "PlacementPlan", "quiver_placement",
    "hash_placement", "degree_placement", "freq_placement", "p3_placement",
    "expert_placement", "migration_pairs", "TieredFeatureStore",
    "ShardedFeatureStore", "DiskSpillTier", "STATS_SCHEMA",
    "GPUFeatureCache", "Prefetcher",
    "LatencyCurve", "CalibrationResult", "calibrate", "calibrate_executors",
    "CostModelRouter", "HybridScheduler",
    "StaticScheduler", "Request", "WorkloadGenerator", "DynamicBatcher",
    "MicroBatcher", "batch_seeds", "pad_to_bucket", "ServingEngine",
    "ServeMetrics", "DEFAULT_MODEL", "PRIORITIES",
]


def __getattr__(name: str):
    # Lazy so `import repro.core` never triggers the shims' deprecation
    # warnings: only callers actually touching the legacy surface — the
    # two-executor ServingEngine signature, or attribute-style access to
    # the shim submodules (`repro.core.pipeline.X`) — pay them.
    if name == "ServingEngine":
        from repro.core.pipeline import ServingEngine
        globals()[name] = ServingEngine  # cache: warn once, resolve once
        return ServingEngine
    if name in ("pipeline", "scheduler"):
        import importlib
        return importlib.import_module(f"{__name__}.{name}")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

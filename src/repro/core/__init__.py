"""Quiver's primary contribution: workload metrics (PSGS/FAP), workload-aware
feature placement, the tiered one-sided-read feature store (with the fused
``lookup_hops`` serving hot path), and request batching/workload generation.

The serving engine, executors and routing live in :mod:`repro.serving`;
``repro.core.pipeline`` and ``repro.core.scheduler`` remain as deprecation
shims re-exporting from there."""
from repro.core.fap import compute_fap, monte_carlo_fap
from repro.core.feature_store import ShardedFeatureStore, TieredFeatureStore
from repro.core.pipeline import ServeMetrics, ServingEngine
from repro.core.placement import (PlacementPlan, TopologySpec,
                                  degree_placement, expert_placement,
                                  freq_placement, hash_placement,
                                  migration_pairs, p3_placement,
                                  quiver_placement)
from repro.core.psgs import batch_psgs, compute_psgs, monte_carlo_psgs
from repro.core.scheduler import (CalibrationResult, CostModelRouter,
                                  HybridScheduler, LatencyCurve,
                                  StaticScheduler, calibrate,
                                  calibrate_executors)
from repro.core.serving import (DynamicBatcher, MicroBatcher, Request,
                                WorkloadGenerator, batch_seeds, pad_to_bucket)

__all__ = [
    "compute_psgs", "monte_carlo_psgs", "batch_psgs", "compute_fap",
    "monte_carlo_fap", "TopologySpec", "PlacementPlan", "quiver_placement",
    "hash_placement", "degree_placement", "freq_placement", "p3_placement",
    "expert_placement", "migration_pairs", "TieredFeatureStore",
    "ShardedFeatureStore",
    "LatencyCurve", "CalibrationResult", "calibrate", "calibrate_executors",
    "CostModelRouter", "HybridScheduler",
    "StaticScheduler", "Request", "WorkloadGenerator", "DynamicBatcher",
    "MicroBatcher", "batch_seeds", "pad_to_bucket", "ServingEngine",
    "ServeMetrics",
]

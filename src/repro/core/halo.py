"""Halo exchange for locality-partitioned message passing (shard_map).

GSPMD lowers a GNN scatter (edge-sharded messages → node-sharded sums) to
dense partial-accumulator all-reduces — O(N·F) wire bytes per layer
regardless of how few rows actually cross shards. Quiver's thesis applied to
message passing says: partition edges by *destination owner* (the data
pipeline sorts edges once), then the scatter is purely local and the only
communication is gathering the *remote source rows* each shard needs — a
capacity-bounded all-to-all whose volume is the workload-aware remote
fraction, not O(N·F).

``halo_gather`` implements the exchange:

  1. dedup local wanted ids (``fixed_size_unique`` — hub sources repeat a
     lot; the paper's id-sort optimization),
  2. bucket unique ids by owner with a fixed per-peer capacity
     (over-capacity ids spill to zeros, like a cache miss — the capacity is
     a placement-time knob sized from partitioner statistics),
  3. ``all_to_all`` the request ids, answer with local row gathers,
     ``all_to_all`` the rows back,
  4. scatter rows to the original (duplicated) edge order.

Wire bytes per device ≈ 2 · P·cap_pp · row_bytes — independent of N.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.sampler import fixed_size_unique


def bucket_by_owner(ids: jnp.ndarray, num_owners: int, rows_per_owner: int,
                    cap_pp: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """ids: (U,) global ids (-1 pad). Returns (req (P, cap_pp) int32 with -1
    pad, slot (U,) int32 position of each id in the request matrix, or -1 if
    dropped/invalid)."""
    u = ids.shape[0]
    owner = jnp.where(ids >= 0, ids // rows_per_owner, num_owners)
    order = jnp.argsort(owner)
    sorted_owner = owner[order]
    # rank within owner block = position - first occurrence of the owner
    idx = jnp.arange(u)
    is_first = jnp.concatenate([jnp.array([True]),
                                sorted_owner[1:] != sorted_owner[:-1]])
    block_start = jnp.where(is_first, idx, 0)
    block_start = jax.lax.associative_scan(jnp.maximum, block_start)
    rank = idx - block_start
    keep = (sorted_owner < num_owners) & (rank < cap_pp)
    flat_pos = jnp.where(keep, sorted_owner * cap_pp + rank,
                         num_owners * cap_pp)
    req = jnp.full((num_owners * cap_pp + 1,), -1, jnp.int32)
    req = req.at[flat_pos].set(ids[order].astype(jnp.int32), mode="drop")
    slot = jnp.full((u,), -1, jnp.int32)
    slot = slot.at[order].set(
        jnp.where(keep, flat_pos, -1).astype(jnp.int32))
    return req[:-1].reshape(num_owners, cap_pp), slot


def halo_gather(x_local: jnp.ndarray, want_ids: jnp.ndarray, *, axis,
                num_shards: int, rows_per_shard: int,
                cap_pp: int) -> jnp.ndarray:
    """Inside shard_map: gather rows of the globally-sharded array ``x``
    (this shard holds ``x_local`` = rows [me·R, (me+1)·R)) for global
    ``want_ids`` (-1 padded). Over-capacity ids return zero rows.

    Returns (len(want_ids), *x_local.shape[1:])."""
    me = jax.lax.axis_index(axis)
    e = want_ids.shape[0]
    feat_shape = x_local.shape[1:]

    # 1. dedup (hubs repeat): unique wanted ids + inverse map
    uniq, inv = fixed_size_unique(jnp.asarray(want_ids, jnp.int32), e)

    # 2. bucket unique ids by owner, capacity per peer
    req, slot = bucket_by_owner(uniq, num_shards, rows_per_shard, cap_pp)

    # 3a. send requests to owners
    req_in = jax.lax.all_to_all(req[:, None, :], axis, split_axis=0,
                                concat_axis=0)[:, 0, :]     # (P, cap_pp)
    # 3b. answer with local rows (row 0-substituted for invalid, then zeroed)
    local_idx = jnp.clip(req_in - me * rows_per_shard, 0, rows_per_shard - 1)
    rows = x_local[local_idx.reshape(-1)]
    rows = rows.reshape((num_shards, cap_pp) + feat_shape)
    rows = jnp.where((req_in >= 0).reshape(num_shards, cap_pp,
                                           *([1] * len(feat_shape))),
                     rows, 0.0)
    # 3c. rows back to requesters
    rows_back = jax.lax.all_to_all(rows[:, None], axis, split_axis=0,
                                   concat_axis=0)[:, 0]
    flat_rows = rows_back.reshape((num_shards * cap_pp,) + feat_shape)

    # 4. unique rows → original duplicated order; dropped/padded ids → 0
    uniq_rows = jnp.where(
        (slot >= 0).reshape((-1,) + (1,) * len(feat_shape)),
        flat_rows[jnp.clip(slot, 0, num_shards * cap_pp - 1)], 0.0)
    out = uniq_rows[inv]
    return jnp.where((want_ids >= 0).reshape((-1,) + (1,) * len(feat_shape)),
                     out, 0.0)


class HaloCtx:
    """Sharding context handed to locality-sharded model code (inside
    shard_map): linear shard index over possibly-multiple mesh axes, halo
    gathers, replicated reductions."""

    def __init__(self, axes, mesh_shape: dict, rows: int, cap_pp: int):
        self.axes = tuple(axes) if not isinstance(axes, str) else (axes,)
        self.sizes = [mesh_shape[a] for a in self.axes]
        self.world = int(np.prod(self.sizes))
        self.rows = rows
        self.cap_pp = cap_pp

    def index(self) -> jnp.ndarray:
        idx = jnp.zeros((), jnp.int32)
        for a, s in zip(self.axes, self.sizes):
            idx = idx * s + jax.lax.axis_index(a)
        return idx

    def offset(self) -> jnp.ndarray:
        return self.index() * self.rows

    def gather(self, x_local: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
        return halo_gather(x_local, ids, axis=self.axes,
                           num_shards=self.world, rows_per_shard=self.rows,
                           cap_pp=self.cap_pp)

    def all_gather(self, x_local: jnp.ndarray) -> jnp.ndarray:
        return jax.lax.all_gather(x_local, self.axes, tiled=True)

    def mean(self, total: jnp.ndarray, count: jnp.ndarray) -> jnp.ndarray:
        return (jax.lax.psum(total, self.axes)
                / jnp.maximum(jax.lax.psum(count, self.axes), 1.0))


def partition_edges_by_dst(src: np.ndarray, dst: np.ndarray, num_nodes: int,
                           num_shards: int) -> tuple[np.ndarray, np.ndarray]:
    """Data-pipeline step: sort the edge list so shard d's slice only
    contains edges whose dst lives on shard d (dst-aligned partitioning).
    Pads each shard's slice to the common max with -1."""
    rows = -(-num_nodes // num_shards)
    owner = dst // rows
    order = np.argsort(owner, kind="stable")
    src_s, dst_s = src[order], dst[order]
    counts = np.bincount(owner, minlength=num_shards)
    cap = int(counts.max())
    out_src = np.full((num_shards, cap), -1, np.int32)
    out_dst = np.full((num_shards, cap), -1, np.int32)
    off = 0
    for d in range(num_shards):
        c = counts[d]
        out_src[d, :c] = src_s[off:off + c]
        out_dst[d, :c] = dst_s[off:off + c]
        off += c
    return out_src.reshape(-1), out_dst.reshape(-1)


def remote_fraction(src: np.ndarray, dst: np.ndarray, num_nodes: int,
                    num_shards: int) -> float:
    """Partitioner statistic that sizes ``cap_pp``: fraction of edges whose
    src lives on a different shard than dst."""
    rows = -(-num_nodes // num_shards)
    return float(np.mean((src // rows) != (dst // rows)))

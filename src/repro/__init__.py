"""Quiver-TPU: workload-aware GNN serving (Tan et al. 2023) re-architected
for TPU pods in JAX. See DESIGN.md for the system map."""
__version__ = "1.0.0"

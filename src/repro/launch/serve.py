"""Quiver serving launcher — the paper's end-to-end path.

    PYTHONPATH=src python -m repro.launch.serve --nodes 20000 --requests 400 \
        --policy latency_preferred

Builds the full stack: synthetic skewed graph → PSGS/FAP metrics → feature
placement → tiered store → latency calibration → PSGS-guided hybrid
scheduler → multiplexed serving pipeline; then reports throughput/latency.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DynamicBatcher, HybridScheduler, ServingEngine,
                        StaticScheduler, TieredFeatureStore, TopologySpec,
                        WorkloadGenerator, calibrate, compute_fap,
                        compute_psgs, quiver_placement)
from repro.graph import power_law_graph
from repro.models.gnn_basic import sage_init, sage_layered


def build_stack(*, nodes: int, avg_degree: float, d_feat: int,
                fanouts: tuple[int, ...], hot_frac: float, seed: int = 0,
                distribution: str = "degree"):
    graph = power_law_graph(nodes, avg_degree, seed=seed)
    rng = np.random.default_rng(seed + 1)
    feats = rng.normal(size=(nodes, d_feat)).astype(np.float32)

    psgs = compute_psgs(graph, fanouts)
    gen = WorkloadGenerator(nodes, graph.out_degree,
                            distribution=distribution, seed=seed + 2)
    fap = compute_fap(graph, fanouts, seed_prob=gen.p)
    topo = TopologySpec(num_pods=1, devices_per_pod=1,
                        rows_per_device=max(nodes // 4, 64),
                        rows_host=max(nodes // 2, 64),
                        hot_replicate_fraction=hot_frac)
    plan = quiver_placement(fap, topo)
    store = TieredFeatureStore.build(feats, plan)

    params = sage_init(jax.random.key(seed), [d_feat, 128, 128])

    @jax.jit
    def infer_fn(hop_feats, hop_ids):
        masks = [(h >= 0).astype(jnp.float32)[:, None] for h in hop_ids]
        return sage_layered(params, hop_feats, fanouts, hop_masks=masks)

    return graph, feats, psgs, fap, store, gen, infer_fn


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--nodes", type=int, default=20000)
    p.add_argument("--avg-degree", type=float, default=12.0)
    p.add_argument("--d-feat", type=int, default=128)
    p.add_argument("--fanouts", default="10,5")
    p.add_argument("--requests", type=int, default=300)
    p.add_argument("--batch", type=int, default=32)
    p.add_argument("--workers", type=int, default=2)
    p.add_argument("--policy", default="latency_preferred",
                   choices=["cpu_preferred", "gpu_preferred",
                            "latency_preferred", "throughput_preferred",
                            "host_only", "device_only"])
    p.add_argument("--hot-frac", type=float, default=0.25)
    args = p.parse_args()
    fanouts = tuple(int(x) for x in args.fanouts.split(","))

    graph, feats, psgs, fap, store, gen, infer_fn = build_stack(
        nodes=args.nodes, avg_degree=args.avg_degree, d_feat=args.d_feat,
        fanouts=fanouts, hot_frac=args.hot_frac)
    print(f"[serve] graph: {graph.num_nodes} nodes / {graph.num_edges} edges;"
          f" tiers: {store.plan.tier_counts()}")

    if args.policy in ("host_only", "device_only"):
        sched = StaticScheduler("host" if args.policy == "host_only"
                                else "device")
    else:
        # calibration (paper Fig. 6): measure both executors across PSGS range
        engine_probe = ServingEngine(graph, store, fanouts, infer_fn,
                                     StaticScheduler("host"), num_workers=1)
        batches = []
        order = np.argsort(psgs)
        for q in np.linspace(0.05, 0.95, 8):
            seeds = order[int(q * graph.num_nodes):][:args.batch]
            batches.append(seeds.astype(np.int64))
        calib = calibrate(
            lambda b: jax.block_until_ready(engine_probe._host_path(b)),
            lambda b: jax.block_until_ready(engine_probe._device_path(b)),
            batches, psgs, repeats=2)
        thr = calib.threshold(args.policy)
        print(f"[serve] calibrated threshold ({args.policy}): {thr:.1f}")
        sched = HybridScheduler(psgs, thr, args.policy)

    engine = ServingEngine(graph, store, fanouts, infer_fn, sched,
                           num_workers=args.workers)
    reqs = list(gen.stream(args.requests, seeds_per_request=args.batch))
    batches = [[r] for r in reqs]
    metrics = engine.run(batches)
    print(json.dumps(metrics.summary(), indent=2))


if __name__ == "__main__":
    main()
